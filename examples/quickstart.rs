//! Quickstart: quantize a tensor with every quantizer family and compare.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use bof4::quant::{quant_error, Method, Norm, OpqConfig, QuantConfig, Quantizer};
use bof4::util::rng::Pcg64;

fn main() {
    // 1M Gaussian "network weights"
    let mut rng = Pcg64::seed_from_u64(7);
    let mut w = vec![0.0f32; 1 << 20];
    rng.fill_gaussian_f32(&mut w, 1.0);

    println!("quantizing {} Gaussian weights, block size 64\n", w.len());
    println!(
        "{:<22} {:>12} {:>12} {:>8}",
        "quantizer", "MAE", "MSE", "bits/w"
    );

    let configs = [
        QuantConfig {
            method: Method::Nf4,
            norm: Norm::Absmax,
            ..Default::default()
        },
        QuantConfig {
            method: Method::Af4,
            norm: Norm::Absmax,
            ..Default::default()
        },
        QuantConfig {
            method: Method::Bof4 { mse: true },
            norm: Norm::Absmax,
            ..Default::default()
        },
        QuantConfig {
            method: Method::Bof4 { mse: true },
            norm: Norm::SignedAbsmax,
            ..Default::default()
        },
        QuantConfig {
            method: Method::Bof4 { mse: true },
            norm: Norm::SignedAbsmax,
            opq: Some(OpqConfig::default()),
            ..Default::default()
        },
        QuantConfig {
            method: Method::Bof4 { mse: true },
            norm: Norm::SignedAbsmax,
            double_quant: true,
            ..Default::default()
        },
    ];
    for cfg in configs {
        let q = Quantizer::new(cfg.clone());
        let (mae, mse) = quant_error(&q, &w);
        let qt = q.quantize(&w);
        println!(
            "{:<22} {:>12.5e} {:>12.5e} {:>8.3}",
            cfg.label(),
            mae,
            mse,
            qt.bits_per_weight()
        );
    }

    println!(
        "\nBOF4-S (MSE) is the paper's best block-wise quantizer; OPQ helps\n\
         most when weights carry outliers (try examples/llm_quantize_eval)."
    );
}
