//! Streaming serving demo: quantized weights (4-bit codes + 8-bit
//! double-quantized constants, end-to-end) behind the session engine —
//! KV-cached incremental decoding with multi-replica continuous batching
//! — plus the fused 4-bit dequant-matmul kernel on its own.
//!
//! ```bash
//! cargo run --release --example serve_batched
//! ```

use std::sync::Arc;

use bof4::coordinator::{Engine, EngineConfig, EngineParams};
use bof4::models::Corpus;
use bof4::quant::{Method, Norm, QuantConfig, Quantizer};
use bof4::runtime::{HostTensor, Runtime};
use bof4::util::timer::Stopwatch;

fn main() -> bof4::Result<()> {
    bof4::util::log::init_from_env();
    let rt = Arc::new(Runtime::new()?);
    let base = bof4::eval::ensure_trained(&rt)?;

    // --- 1. streaming sessions over the quantized serving path --------
    let cfg = QuantConfig {
        method: Method::Bof4 { mse: true },
        norm: Norm::SignedAbsmax,
        double_quant: true,
        ..Default::default()
    };
    let qsp = bof4::eval::quantize_for_serving(&rt.meta, &base, &cfg)?;
    println!(
        "serving {} with {}: weights stay 4-bit at rest ({} -> {} bytes, {:.2}x)",
        rt.platform(),
        cfg.label(),
        qsp.orig_bytes,
        qsp.quant_bytes,
        qsp.orig_bytes as f64 / qsp.quant_bytes as f64
    );
    let engine = Engine::start(
        rt.clone(),
        EngineParams::QuantizedQ4(qsp.prefix),
        EngineConfig {
            replicas: 2,
            ..EngineConfig::default()
        },
    )?;

    let corpus = Corpus::generate(100_000, 5);
    let n_sessions = 64;
    let tokens_per_session = 8;
    let sw = Stopwatch::start();
    let sessions: Vec<_> = (0..n_sessions)
        .map(|i| {
            let start = (i * 131) % (corpus.len() - 48);
            engine.session_with(&corpus.tokens[start..start + 48], tokens_per_session)
        })
        .collect::<bof4::Result<Vec<_>>>()?;
    let mut streamed = 0usize;
    for sess in sessions {
        streamed += sess.collect_tokens()?.len();
    }
    let secs = sw.elapsed().as_secs_f64();
    println!(
        "{n_sessions} concurrent sessions x {tokens_per_session} tokens in {secs:.2}s \
         -> {:.1} tok/s streamed",
        streamed as f64 / secs
    );
    println!("{}", engine.metrics.summary());

    // --- 2. the 4-bit compute path: fused dequant-matmul --------------
    let gm = rt.meta.graph("dequant_matmul")?.clone();
    let (m, k) = (gm.args[0].shape[0], gm.args[0].shape[1]);
    let n = gm.args[1].shape[1];
    let block = rt.meta.model.block;
    let mut rng = bof4::util::rng::Pcg64::seed_from_u64(3);
    let mut x = vec![0.0f32; m * k];
    let mut w = vec![0.0f32; k * n];
    rng.fill_gaussian_f32(&mut x, 1.0);
    rng.fill_gaussian_f32(&mut w, 0.05);

    let q = Quantizer::new(cfg);
    let qt = q.quantize(&w);
    let codes = bof4::quant::pack::unpack_u4(&qt.codes, k * n);
    let args = [
        HostTensor::f32(x, vec![m, k]),
        HostTensor::u8(codes, vec![k, n]),
        HostTensor::f32(qt.absmax.clone(), vec![k, n / block]),
        HostTensor::f32(q.codebook.levels.to_vec(), vec![16]),
    ];
    let sw = Stopwatch::start();
    let iters = 20;
    for _ in 0..iters {
        rt.run("dequant_matmul", &args)?;
    }
    let per = sw.elapsed().as_secs_f64() / iters as f64;
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    println!(
        "fused dequant-matmul {m}x{k}x{n}: {:.2} ms/iter ({:.2} GFLOP/s, interpret-mode)",
        per * 1e3,
        flops / per / 1e9
    );
    println!("(real-TPU perf is estimated analytically; see EXPERIMENTS.md §Perf)");
    Ok(())
}
