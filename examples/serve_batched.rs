//! Batched serving demo: quantized weights behind the dynamic batcher,
//! plus the 4-bit compute path — the fused Pallas dequant-matmul graph
//! executed with rust-packed codes.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_batched
//! ```

use std::sync::Arc;

use bof4::coordinator::{BatchedLm, ServiceConfig};
use bof4::models::Corpus;
use bof4::quant::{Method, Norm, QuantConfig, Quantizer};
use bof4::runtime::{HostTensor, Runtime};
use bof4::util::timer::Stopwatch;

fn main() -> bof4::Result<()> {
    bof4::util::log::init_from_env();
    let rt = Arc::new(Runtime::new()?);
    let base = bof4::eval::ensure_trained(&rt)?;

    // --- 1. serving through the dynamic batcher -----------------------
    let cfg = QuantConfig {
        method: Method::Bof4 { mse: true },
        norm: Norm::SignedAbsmax,
        ..Default::default()
    };
    let qm = bof4::eval::quantize_params(&base, &cfg)?;
    println!(
        "serving {} with {}: quant MSE {:.3e}",
        rt.platform(),
        cfg.label(),
        qm.mse
    );
    let svc = BatchedLm::start(rt.clone(), qm.params.to_tensors(), ServiceConfig::default())?;

    let corpus = Corpus::generate(100_000, 5);
    let n_requests = 128;
    let sw = Stopwatch::start();
    let pending: Vec<_> = (0..n_requests)
        .map(|i| {
            let start = (i * 131) % (corpus.len() - 40);
            svc.infer_async(&corpus.tokens[start..start + 40]).unwrap()
        })
        .collect();
    for rx in pending {
        rx.recv().unwrap()?;
    }
    let secs = sw.elapsed().as_secs_f64();
    println!(
        "{n_requests} concurrent requests in {secs:.2}s -> {:.1} req/s",
        n_requests as f64 / secs
    );
    println!("{}", svc.metrics.summary());

    // --- 2. the 4-bit compute path: fused dequant-matmul --------------
    let gm = rt.meta.graph("dequant_matmul")?.clone();
    let (m, k) = (gm.args[0].shape[0], gm.args[0].shape[1]);
    let n = gm.args[1].shape[1];
    let block = rt.meta.model.block;
    let mut rng = bof4::util::rng::Pcg64::seed_from_u64(3);
    let mut x = vec![0.0f32; m * k];
    let mut w = vec![0.0f32; k * n];
    rng.fill_gaussian_f32(&mut x, 1.0);
    rng.fill_gaussian_f32(&mut w, 0.05);

    let q = Quantizer::new(cfg);
    let qt = q.quantize(&w);
    let codes = bof4::quant::pack::unpack_u4(&qt.codes, k * n);
    let args = [
        HostTensor::f32(x, vec![m, k]),
        HostTensor::u8(codes, vec![k, n]),
        HostTensor::f32(qt.absmax.clone(), vec![k, n / block]),
        HostTensor::f32(q.codebook.levels.to_vec(), vec![16]),
    ];
    let sw = Stopwatch::start();
    let iters = 20;
    for _ in 0..iters {
        rt.run("dequant_matmul", &args)?;
    }
    let per = sw.elapsed().as_secs_f64() / iters as f64;
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    println!(
        "fused dequant-matmul {m}x{k}x{n}: {:.2} ms/iter ({:.2} GFLOP/s, interpret-mode)",
        per * 1e3,
        flops / per / 1e9
    );
    println!("(real-TPU perf is estimated analytically; see EXPERIMENTS.md §Perf)");
    Ok(())
}
