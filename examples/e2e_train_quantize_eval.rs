//! End-to-end driver (the repository's full-system proof): pre-train the
//! LM from scratch through the rust-driven XLA train step, log the loss
//! curve, quantize the trained weights with the paper's quantizers,
//! QLoRA-fine-tune on a downstream task over the quantized base, and
//! report perplexity + task accuracy. Every layer composes: L1 Pallas
//! kernels inside L2 JAX graphs, AOT-lowered, executed by the L3 rust
//! coordinator. Recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train_quantize_eval
//! ```

use std::sync::Arc;

use bof4::eval::report::Table;
use bof4::eval::tasks::FtTask;
use bof4::eval::{lora, ppl, quantize_params, trainer};
use bof4::quant::{Method, Norm, QuantConfig};
use bof4::runtime::Runtime;

fn main() -> bof4::Result<()> {
    bof4::util::log::init_from_env();
    let rt = Arc::new(Runtime::new()?);

    // --- 1. pre-train from scratch (fresh run, not the cache) ---------
    let train_cfg = trainer::TrainConfig {
        steps: 800, // enough for the LM to begin learning in-context recall
        log_every: 100,
        ..Default::default()
    };
    println!("[1/4] pre-training {} steps ...", train_cfg.steps);
    let outcome = trainer::train(&rt, &train_cfg)?;
    let losses = &outcome.losses;
    println!("loss curve (every 25 steps):");
    for (i, chunk) in losses.chunks(80).enumerate() {
        let avg = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!(
            "  steps {:>3}-{:>3}: {:.4} {}",
            i * 80 + 1,
            i * 80 + chunk.len(),
            avg,
            "#".repeat((avg * 12.0) as usize)
        );
    }
    assert!(
        losses.last().unwrap() + 0.5 < *losses.first().unwrap(),
        "training failed to learn"
    );

    // --- 2. quantize the trained model --------------------------------
    println!("\n[2/4] quantizing the trained model ...");
    let base = outcome.params;
    let nf4 = QuantConfig {
        method: Method::Nf4,
        norm: Norm::Absmax,
        ..Default::default()
    };
    let bof4s = QuantConfig {
        method: Method::Bof4 { mse: true },
        norm: Norm::SignedAbsmax,
        ..Default::default()
    };
    let qm_nf4 = quantize_params(&base, &nf4)?;
    let qm_bof4s = quantize_params(&base, &bof4s)?;
    println!(
        "  NF4          MSE {:.4e} ({} bytes)",
        qm_nf4.mse, qm_nf4.quant_bytes
    );
    println!(
        "  BOF4-S (MSE) MSE {:.4e} ({} bytes)",
        qm_bof4s.mse, qm_bof4s.quant_bytes
    );

    // --- 3. perplexity -------------------------------------------------
    println!("\n[3/4] held-out perplexity ...");
    let pcfg = ppl::PplConfig::default();
    let ppl_bf16 = ppl::perplexity(&rt, &base, &pcfg)?;
    let ppl_nf4 = ppl::perplexity(&rt, &qm_nf4.params, &pcfg)?;
    let ppl_bof4s = ppl::perplexity(&rt, &qm_bof4s.params, &pcfg)?;

    // --- 4. QLoRA fine-tune on the bracket-code task -------------------
    println!("\n[4/4] QLoRA fine-tuning (KeyRecall task) ...");
    let lcfg = lora::LoraConfig {
        steps: 200,
        ..Default::default()
    };
    let base_acc = lora::task_accuracy(&rt, &base, None, FtTask::KeyRecall, &lcfg)?;
    let ft = lora::finetune(&rt, &qm_bof4s.params, FtTask::KeyRecall, &lcfg)?;
    let ft_acc = lora::task_accuracy(
        &rt,
        &qm_bof4s.params,
        Some(&ft.lora),
        FtTask::KeyRecall,
        &lcfg,
    )?;
    println!(
        "  lora loss {:.3} -> {:.3}",
        ft.losses.first().unwrap(),
        ft.losses.last().unwrap()
    );

    let mut t = Table::new(
        "End-to-end: train -> quantize -> eval -> QLoRA",
        &["model", "PPL", "KeyRecall ACC"],
    );
    t.row(vec![
        "BF16 base".into(),
        format!("{ppl_bf16:.4}"),
        format!("{base_acc:.3}"),
    ]);
    t.row(vec![
        "NF4".into(),
        format!("{ppl_nf4:.4}"),
        "-".into(),
    ]);
    t.row(vec![
        "BOF4-S (MSE)".into(),
        format!("{ppl_bof4s:.4}"),
        "-".into(),
    ]);
    t.row(vec![
        "BOF4-S (MSE) + LoRA ft".into(),
        "-".into(),
        format!("{ft_acc:.3}"),
    ]);
    t.emit("example_e2e")?;

    assert!(
        ft_acc > base_acc,
        "fine-tuning should improve the task: {ft_acc} vs {base_acc}"
    );
    println!("e2e OK: all three layers compose.");
    Ok(())
}
