//! Quantize the trained in-repo LM with every quantizer and evaluate
//! held-out perplexity plus the multiple-choice suite — the shape of the
//! paper's Tables 1 and 2 on a real (small) model.
//!
//! ```bash
//! make artifacts && cargo run --release --example llm_quantize_eval
//! ```

use std::sync::Arc;

use bof4::eval::report::Table;
use bof4::eval::{ppl, quantize_params, tasks};
use bof4::quant::{Method, Norm, OpqConfig, QuantConfig};
use bof4::runtime::Runtime;

fn main() -> bof4::Result<()> {
    bof4::util::log::init_from_env();
    let rt = Arc::new(Runtime::new()?);
    let base = bof4::eval::ensure_trained(&rt)?;
    println!(
        "trained LM: {} params over {} tensors\n",
        base.n_params(),
        base.entries.len()
    );

    let suite = tasks::build_suite(32, 99);
    let mut table = Table::new(
        "Quantized-LM evaluation (Tables 1/2 shape)",
        &["quantizer", "MAE", "MSE", "PPL", "NAV ACC"],
    );

    let mut eval_one = |label: String, params: &bof4::models::ParamSet, mae: f64, mse: f64| -> bof4::Result<()> {
        let ppl = ppl::perplexity(&rt, params, &ppl::PplConfig::default())?;
        let mut accs = Vec::new();
        for t in &suite {
            accs.push((tasks::score_task(&rt, params, t)?, t.chance));
        }
        let nav = tasks::nav_acc(&accs);
        table.row(vec![
            label,
            format!("{mae:.4e}"),
            format!("{mse:.4e}"),
            format!("{ppl:.4}"),
            format!("{nav:.4}"),
        ]);
        Ok(())
    };

    eval_one("BF16 (reference)".into(), &base, 0.0, 0.0)?;

    let configs = [
        QuantConfig {
            method: Method::Nf4,
            norm: Norm::Absmax,
            ..Default::default()
        },
        QuantConfig {
            method: Method::Af4,
            norm: Norm::Absmax,
            ..Default::default()
        },
        QuantConfig {
            method: Method::Bof4 { mse: true },
            norm: Norm::SignedAbsmax,
            ..Default::default()
        },
        QuantConfig {
            method: Method::Bof4 { mse: true },
            norm: Norm::SignedAbsmax,
            opq: Some(OpqConfig::default()),
            ..Default::default()
        },
    ];
    for cfg in configs {
        let qm = quantize_params(&base, &cfg)?;
        eval_one(cfg.label(), &qm.params, qm.mae, qm.mse)?;
    }

    table.emit("example_llm_quantize_eval")?;
    Ok(())
}
