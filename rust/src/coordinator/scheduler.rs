//! Multithreaded quantization scheduler.
//!
//! Quantizing an LLM checkpoint is embarrassingly parallel across tensors;
//! this scheduler runs a worker pool over a bounded job queue (bounded =
//! backpressure when the producer reads tensors faster than workers
//! quantize) and returns results in deterministic submission order
//! regardless of completion order. Invariants (property-tested in
//! `rust/tests/coordinator_integration.rs`): every job is processed
//! exactly once; results are order-stable; worker panics surface as
//! errors, not hangs.
//!
//! Consumers: [`crate::eval::quantize_params`] (dequantize-for-eval) runs
//! whole checkpoints through this pool; the serving engine's ABI-shaped
//! quantization ([`crate::eval::quantize_for_serving`]) packs per-tensor
//! results directly since it must also emit the double-quantized constant
//! tensors next to the codes.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use crate::error::Result;

use super::metrics::Metrics;
use crate::quant::{QuantConfig, QuantizedTensor, Quantizer};

/// One tensor to quantize.
#[derive(Clone, Debug)]
pub struct QuantJob {
    pub name: String,
    pub data: Vec<f32>,
}

/// A finished tensor.
#[derive(Debug)]
pub struct QuantResult {
    pub name: String,
    pub tensor: QuantizedTensor,
    pub mae: f64,
    pub mse: f64,
}

/// Worker-pool scheduler for whole-model quantization.
pub struct QuantScheduler {
    pub config: QuantConfig,
    pub workers: usize,
    pub queue_cap: usize,
    pub metrics: Arc<Metrics>,
}

impl QuantScheduler {
    pub fn new(config: QuantConfig) -> Self {
        let workers = thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        QuantScheduler {
            config,
            workers,
            queue_cap: 2 * workers,
            metrics: Arc::new(Metrics::new()),
        }
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self.queue_cap = 2 * self.workers;
        self
    }

    /// Quantize all jobs; results return in submission order.
    pub fn run(&self, jobs: Vec<QuantJob>) -> Result<Vec<QuantResult>> {
        let n = jobs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        // The quantizer (incl. its possibly-EM-designed codebook) is built
        // once and shared read-only.
        let quantizer = Arc::new(Quantizer::new(self.config.clone()));

        // bounded job channel: backpressure against the producer
        let (job_tx, job_rx) = mpsc::sync_channel::<(usize, QuantJob)>(self.queue_cap);
        let job_rx = Arc::new(std::sync::Mutex::new(job_rx));
        let (res_tx, res_rx) = mpsc::channel::<(usize, Result<QuantResult>)>();

        let mut handles = Vec::new();
        for wid in 0..self.workers {
            let job_rx = job_rx.clone();
            let res_tx = res_tx.clone();
            let quantizer = quantizer.clone();
            let metrics = self.metrics.clone();
            handles.push(thread::Builder::new().name(format!("quant-{wid}")).spawn(
                move || {
                    loop {
                        let job = {
                            let guard = crate::util::sync::lock_recover(&job_rx);
                            guard.recv()
                        };
                        let (idx, job) = match job {
                            Ok(j) => j,
                            Err(_) => break, // channel closed: done
                        };
                        let sw = crate::util::timer::Stopwatch::start();
                        let _span = crate::obs::tracer::span(
                            crate::obs::TraceLevel::Engine,
                            "quantize_tensor",
                            &[("idx", idx as i64), ("elems", job.data.len() as i64)],
                        );
                        let result = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                let qt = quantizer.quantize(&job.data);
                                let deq = quantizer.dequantize(&qt);
                                let mae = crate::quant::error::mae(&job.data, &deq);
                                let mse = crate::quant::error::mse(&job.data, &deq);
                                QuantResult {
                                    name: job.name.clone(),
                                    tensor: qt,
                                    mae,
                                    mse,
                                }
                            }),
                        )
                        .map_err(|_| crate::err!("worker panic on tensor '{}'", job.name));
                        metrics.observe("quantize_tensor", sw.elapsed());
                        metrics.inc("tensors_done");
                        if res_tx.send((idx, result)).is_err() {
                            break;
                        }
                    }
                },
            )?);
        }
        drop(res_tx);

        // producer: feed jobs (blocks when the queue is full = backpressure)
        let producer = thread::spawn(move || {
            for (idx, job) in jobs.into_iter().enumerate() {
                if job_tx.send((idx, job)).is_err() {
                    break;
                }
            }
            // drop closes the channel -> workers drain and exit
        });

        // collect and re-order
        let mut slots: Vec<Option<Result<QuantResult>>> = (0..n).map(|_| None).collect();
        for (idx, res) in res_rx {
            slots[idx] = Some(res);
        }
        producer.join().map_err(|_| crate::err!("producer panicked"))?;
        for h in handles {
            h.join().map_err(|_| crate::err!("worker panicked"))?;
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.ok_or_else(|| crate::err!("job {i} lost"))?)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Method;
    use crate::util::rng::Pcg64;

    fn jobs(n: usize, len: usize, seed: u64) -> Vec<QuantJob> {
        let mut rng = Pcg64::seed_from_u64(seed);
        (0..n)
            .map(|i| {
                let mut data = vec![0.0f32; len];
                rng.fill_gaussian_f32(&mut data, 1.0);
                QuantJob {
                    name: format!("t{i}"),
                    data,
                }
            })
            .collect()
    }

    fn sched() -> QuantScheduler {
        QuantScheduler::new(QuantConfig {
            method: Method::Nf4,
            ..Default::default()
        })
        .with_workers(3)
    }

    #[test]
    fn processes_all_in_order() {
        let s = sched();
        let js = jobs(17, 640, 1);
        let res = s.run(js).unwrap();
        assert_eq!(res.len(), 17);
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.name, format!("t{i}"));
            assert!(r.mse > 0.0);
        }
        assert_eq!(s.metrics.get("tensors_done"), 17);
    }

    #[test]
    fn empty_job_list() {
        let s = sched();
        assert!(s.run(vec![]).unwrap().is_empty());
    }

    #[test]
    fn single_worker_matches_many_workers() {
        let js = jobs(8, 512, 2);
        let r1 = sched().with_workers(1).run(js.clone()).unwrap();
        let r4 = sched().with_workers(4).run(js).unwrap();
        for (a, b) in r1.iter().zip(&r4) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.tensor.codes, b.tensor.codes);
            assert_eq!(a.mse, b.mse);
        }
    }

    #[test]
    fn results_match_direct_quantizer() {
        let js = jobs(3, 256, 3);
        let s = sched();
        let res = s.run(js.clone()).unwrap();
        let q = Quantizer::new(s.config.clone());
        for (j, r) in js.iter().zip(&res) {
            let direct = q.quantize(&j.data);
            assert_eq!(r.tensor.codes, direct.codes);
            assert_eq!(r.tensor.absmax, direct.absmax);
        }
    }
}
