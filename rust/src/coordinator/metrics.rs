//! Lightweight metrics: atomic counters + lock-protected latency
//! reservoirs, shared across coordinator threads.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::sync::lock_recover;

/// Max samples retained per latency/value series (see
/// [`Metrics::observe_value`]).
pub const SERIES_CAP: usize = 16_384;

/// Nearest-rank percentile index over a sorted series of `len` samples:
/// `round((len-1) * p)`, with `round` half-away-from-zero. Truncation
/// (the old behavior) systematically underestimates upper percentiles on
/// small counts — p99 of 50 samples truncated to index 48 instead of 49,
/// and p50 of 2 samples read index 0 (the *minimum*).
pub fn percentile_index(len: usize, p: f64) -> usize {
    (((len - 1) as f64) * p).round() as usize
}

/// One bounded value series: the retained samples plus a count of the
/// samples evicted by the [`SERIES_CAP`] halving, so stats can say *how
/// much* history they no longer describe.
#[derive(Debug, Default)]
struct Series {
    samples: Vec<f64>,
    dropped: u64,
}

/// Process-local metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    latencies: Mutex<BTreeMap<String, Series>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, v: u64) {
        let mut g = lock_recover(&self.counters);
        g.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self, name: &str) -> u64 {
        lock_recover(&self.counters)
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn observe(&self, name: &str, d: Duration) {
        self.observe_value(name, d.as_secs_f64() * 1e3);
    }

    /// Record a raw sample (milliseconds for latencies, but any unit-free
    /// value works — e.g. the engine's slot-occupancy fraction). Series
    /// are bounded: at [`SERIES_CAP`] samples the oldest half is dropped,
    /// so per-token recording on a long-running engine cannot grow memory
    /// without bound (stats then describe a recent window). Evictions are
    /// counted per series and surfaced as [`LatencyStats::dropped`], so a
    /// long run's percentiles are never mistaken for lifetime stats.
    pub fn observe_value(&self, name: &str, v: f64) {
        let mut g = lock_recover(&self.latencies);
        let series = g.entry(name.to_string()).or_default();
        if series.samples.len() >= SERIES_CAP {
            series.samples.drain(..SERIES_CAP / 2);
            series.dropped += (SERIES_CAP / 2) as u64;
        }
        series.samples.push(v);
    }

    /// Order statistics for a latency series, computed over the *finite*
    /// samples; non-finite ones (NaN/inf from e.g. a zero-duration timer
    /// division upstream) are filtered out and counted in
    /// [`LatencyStats::non_finite`] instead of panicking the sort.
    /// Percentiles use nearest-rank indexing (see [`percentile_index`]).
    /// Returns `None` when the series is absent, empty, or has no finite
    /// samples at all.
    pub fn latency_stats(&self, name: &str) -> Option<LatencyStats> {
        let g = lock_recover(&self.latencies);
        let s = g.get(name)?;
        if s.samples.is_empty() {
            return None;
        }
        let dropped = s.dropped;
        let mut sorted: Vec<f64> = s.samples.iter().copied().filter(|v| v.is_finite()).collect();
        let non_finite = s.samples.len() - sorted.len();
        drop(g);
        if sorted.is_empty() {
            return None;
        }
        sorted.sort_by(f64::total_cmp);
        let pct = |p: f64| sorted[percentile_index(sorted.len(), p)];
        Some(LatencyStats {
            count: sorted.len(),
            non_finite,
            dropped,
            mean_ms: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50_ms: pct(0.5),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            max_ms: *sorted.last().unwrap(),
        })
    }

    /// Copy out all counters as `(name, value)` pairs, sorted by name —
    /// the exporter-facing view of the registry.
    pub fn counter_snapshot(&self) -> Vec<(String, u64)> {
        lock_recover(&self.counters)
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Names of all value series, sorted. Pair with
    /// [`Metrics::latency_stats`] to build a full snapshot without
    /// holding any lock across the two calls.
    pub fn series_names(&self) -> Vec<String> {
        lock_recover(&self.latencies).keys().cloned().collect()
    }

    /// Render all metrics for reports.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (k, v) in lock_recover(&self.counters).iter() {
            out.push_str(&format!("{k}: {}\n", v.load(Ordering::Relaxed)));
        }
        let names: Vec<String> = lock_recover(&self.latencies).keys().cloned().collect();
        for k in names {
            if let Some(s) = self.latency_stats(&k) {
                out.push_str(&format!(
                    "{k}: n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms\n",
                    s.count, s.mean_ms, s.p50_ms, s.p95_ms, s.p99_ms, s.max_ms
                ));
                if s.non_finite > 0 {
                    out.push_str(&format!("{k}: dropped {} non-finite samples\n", s.non_finite));
                }
                if s.dropped > 0 {
                    out.push_str(&format!(
                        "{k}: {} older samples evicted (stats describe the \
                         most recent window)\n",
                        s.dropped
                    ));
                }
            }
        }
        out
    }
}

#[derive(Clone, Copy, Debug)]
pub struct LatencyStats {
    /// Finite samples the stats describe.
    pub count: usize,
    /// Non-finite samples (NaN/inf) excluded from the stats.
    pub non_finite: usize,
    /// Older samples evicted by the [`SERIES_CAP`] halving over the
    /// series' lifetime — when non-zero, the stats describe only the most
    /// recent window, not the whole run.
    pub dropped: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

/// Per-token latency histogram bucket upper bounds, in milliseconds.
/// Public so the Prometheus exporter can emit the same `le` bounds it
/// documents ([`crate::obs::export`]).
pub const TOKEN_LATENCY_BOUNDS_MS: [f64; 10] =
    [0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 100.0, 1000.0];

/// Serving-engine metrics: the shared counter/latency registry plus a
/// fixed-bucket per-token latency histogram and the prefill/decode token
/// split. The `core` registry is what the legacy `BatchedLm` shim exposes
/// as its `metrics` field, so the old counter names (`batches`,
/// `batched_requests`) keep working.
///
/// Counter names: `batches` (prefill executions), `batched_requests`
/// (sessions admitted), `sessions`, `prefill_tokens`, `decode_tokens`,
/// `decode_steps`, `deadline_overruns` (sessions that closed past their
/// [`crate::coordinator::EngineConfig::session_deadline`]),
/// `deadline_cancelled` (sessions the engine *evicted* at a decode-step
/// boundary for exceeding that deadline — always a subset of
/// `deadline_overruns`), `sessions_shed` (admissions refused or queued
/// sessions evicted by admission control, split into
/// `sessions_shed_rejected` and `sessions_shed_evicted` by
/// [`crate::coordinator::ShedPolicy`]), `replica_exits` (replica worker
/// loop exits, fatal or clean) and `replica_restarts` (supervisor
/// rebuilds of a faulted replica). Latency
/// series: `prefill_exec`, `decode_step_exec`, `token_latency` (ms),
/// `ttft` (time-to-first-token: submit → first streamed token, ms),
/// `inter_token` (gap between consecutive streamed tokens of one
/// session, ms), `queue_wait` (submit → admission, ms),
/// `slot_occupancy` (fraction, 0..=1) and `pool_busy` (kernel-pool lane
/// occupancy, fraction 0..=1 — the replica-worker saturation counterpart
/// of `slot_occupancy`, sampled after every prefill/decode step on
/// backends with a thread pool; each sample covers the launches since
/// the previous one, so the series tracks current saturation, not a
/// lifetime mean). The instantaneous queue depth (submitted sessions not
/// yet admitted) is a dedicated gauge ([`EngineMetrics::queue_depth`]) —
/// the admission-control signal the ROADMAP's load-shedding item needs.
#[derive(Debug)]
pub struct EngineMetrics {
    /// Shared counter/latency registry (cloneable handle: the `BatchedLm`
    /// shim re-exposes this same registry as its `metrics` field).
    pub core: std::sync::Arc<Metrics>,
    buckets: [AtomicU64; TOKEN_LATENCY_BOUNDS_MS.len() + 1],
    /// Sessions submitted but not yet admitted into a batch slot.
    queue_depth: AtomicU64,
    /// Engine start time, for uptime / tokens-per-second rates.
    started: Instant,
}

impl Default for EngineMetrics {
    fn default() -> Self {
        EngineMetrics {
            core: std::sync::Arc::default(),
            buckets: Default::default(),
            queue_depth: AtomicU64::new(0),
            started: Instant::now(),
        }
    }
}

impl EngineMetrics {
    pub fn new() -> EngineMetrics {
        EngineMetrics::default()
    }

    /// Wall time since the engine (metrics) started.
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Decode tokens streamed per second of uptime — the throughput
    /// headline of the snapshot exporters.
    pub fn tokens_per_sec(&self) -> f64 {
        let up = self.uptime().as_secs_f64();
        if up > 0.0 {
            self.core.get("decode_tokens") as f64 / up
        } else {
            0.0
        }
    }

    /// A session entered an admission queue ([`crate::coordinator::Engine`]
    /// submit path).
    pub fn queue_enter(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// A queued session was admitted (or rejected): record its queue wait
    /// and drop the depth gauge.
    pub fn queue_exit(&self, waited: Duration) {
        // saturating: a racing snapshot between enter/exit pairs must
        // never underflow the gauge
        let _ = self
            .queue_depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
        self.core.observe("queue_wait", waited);
    }

    /// Sessions currently queued and not yet admitted.
    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Record a session's time-to-first-token (submit → first token).
    pub fn record_ttft(&self, d: Duration) {
        self.core.observe("ttft", d);
    }

    /// Record the gap between two consecutive tokens of one session.
    pub fn record_inter_token(&self, d: Duration) {
        self.core.observe("inter_token", d);
    }

    /// A session closed later than its configured deadline allowed.
    pub fn record_deadline_overrun(&self) {
        self.core.inc("deadline_overruns");
    }

    /// A session was cancelled mid-stream for exceeding its deadline
    /// (enforcement, not just observation).
    pub fn record_deadline_cancelled(&self) {
        self.core.inc("deadline_cancelled");
    }

    /// Admission control refused a new session (ShedPolicy::Reject, or
    /// Oldest with an empty queue).
    pub fn record_shed_rejected(&self) {
        self.core.inc("sessions_shed");
        self.core.inc("sessions_shed_rejected");
    }

    /// Admission control evicted the oldest queued session in a new
    /// one's favour (ShedPolicy::Oldest).
    pub fn record_shed_evicted(&self) {
        self.core.inc("sessions_shed");
        self.core.inc("sessions_shed_evicted");
    }

    /// Total sessions shed by admission control (either policy).
    pub fn shed_total(&self) -> u64 {
        self.core.get("sessions_shed")
    }

    /// Supervisor rebuilds of faulted replicas.
    pub fn restart_count(&self) -> u64 {
        self.core.get("replica_restarts")
    }

    /// Sessions evicted mid-stream by deadline enforcement.
    pub fn deadline_cancelled_count(&self) -> u64 {
        self.core.get("deadline_cancelled")
    }

    /// Record one emitted token's latency (the wall time of the prefill
    /// or decode step that produced it).
    pub fn record_token_latency(&self, d: Duration) {
        let ms = d.as_secs_f64() * 1e3;
        self.core.observe_value("token_latency", ms);
        let idx = TOKEN_LATENCY_BOUNDS_MS
            .iter()
            .position(|&b| ms < b)
            .unwrap_or(TOKEN_LATENCY_BOUNDS_MS.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Record the fraction of batch slots occupied at a decode step.
    pub fn record_occupancy(&self, active: usize, slots: usize) {
        self.core
            .observe_value("slot_occupancy", active as f64 / slots.max(1) as f64);
    }

    /// Record the kernel-pool lane occupancy (0..=1) observed at a
    /// prefill/decode step — makes thread-pool saturation visible in
    /// `bof4 serve` output next to `slot_occupancy`.
    pub fn record_pool_busy(&self, fraction: f64) {
        self.core.observe_value("pool_busy", fraction.clamp(0.0, 1.0));
    }

    /// `(bucket label, count)` pairs of the per-token latency histogram.
    pub fn token_latency_histogram(&self) -> Vec<(String, u64)> {
        let mut out = Vec::new();
        let mut lo = 0.0;
        for (i, &hi) in TOKEN_LATENCY_BOUNDS_MS.iter().enumerate() {
            out.push((
                format!("[{lo}, {hi}) ms"),
                self.buckets[i].load(Ordering::Relaxed),
            ));
            lo = hi;
        }
        out.push((
            format!(">= {lo} ms"),
            self.buckets[TOKEN_LATENCY_BOUNDS_MS.len()].load(Ordering::Relaxed),
        ));
        out
    }

    /// Render counters/latencies plus the queue-depth gauge, the
    /// prefill-vs-decode token split and the non-empty histogram buckets.
    pub fn summary(&self) -> String {
        let mut out = self.core.summary();
        out.push_str(&format!("queue depth: {}\n", self.queue_depth()));
        let pre = self.core.get("prefill_tokens");
        let dec = self.core.get("decode_tokens");
        if pre + dec > 0 {
            let pct = 100.0 * dec as f64 / (pre + dec) as f64;
            out.push_str(&format!(
                "token split: {pre} prefill / {dec} decode ({pct:.0}% decode)\n"
            ));
        }
        for (label, n) in self.token_latency_histogram() {
            if n > 0 {
                out.push_str(&format!("token_latency {label}: {n}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let m = Metrics::new();
        m.inc("a");
        m.add("a", 4);
        assert_eq!(m.get("a"), 5);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("op", Duration::from_millis(i));
        }
        let s = m.latency_stats("op").unwrap();
        assert_eq!(s.count, 100);
        assert!((s.p50_ms - 50.0).abs() <= 1.5);
        assert!((s.p95_ms - 95.0).abs() <= 1.5);
        assert_eq!(s.max_ms, 100.0);
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.inc("x");
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.get("x"), 4000);
    }

    #[test]
    fn series_are_bounded() {
        let m = Metrics::new();
        for i in 0..(SERIES_CAP + 10) {
            m.observe_value("tok", i as f64);
        }
        let s = m.latency_stats("tok").unwrap();
        assert!(s.count <= SERIES_CAP, "series grew past cap: {}", s.count);
        // recent samples survive the halving
        assert_eq!(s.max_ms, (SERIES_CAP + 9) as f64);
    }

    /// Regression (ISSUE 8): the SERIES_CAP halving silently discarded
    /// the oldest half, so long-run percentiles described an undocumented
    /// window. Overflow one series and verify the eviction is counted and
    /// reported.
    #[test]
    fn series_eviction_is_counted() {
        let m = Metrics::new();
        for i in 0..(SERIES_CAP + 10) {
            m.observe_value("tok", i as f64);
        }
        let s = m.latency_stats("tok").unwrap();
        assert_eq!(s.dropped, (SERIES_CAP / 2) as u64);
        assert_eq!(s.count, SERIES_CAP / 2 + 10);
        assert!(
            m.summary().contains(&format!("{} older samples evicted", SERIES_CAP / 2)),
            "summary must surface the eviction window"
        );
        // a series under the cap reports zero drops
        m.observe_value("small", 1.0);
        assert_eq!(m.latency_stats("small").unwrap().dropped, 0);
    }

    #[test]
    fn slo_gauges_and_counters() {
        let em = EngineMetrics::new();
        em.queue_enter();
        em.queue_enter();
        assert_eq!(em.queue_depth(), 2);
        em.queue_exit(Duration::from_millis(3));
        assert_eq!(em.queue_depth(), 1);
        em.queue_exit(Duration::from_millis(5));
        em.queue_exit(Duration::from_millis(1)); // saturates, never wraps
        assert_eq!(em.queue_depth(), 0);
        em.record_ttft(Duration::from_millis(8));
        em.record_inter_token(Duration::from_millis(2));
        em.record_deadline_overrun();
        assert_eq!(em.core.latency_stats("queue_wait").unwrap().count, 3);
        assert_eq!(em.core.latency_stats("ttft").unwrap().count, 1);
        assert_eq!(em.core.latency_stats("inter_token").unwrap().count, 1);
        assert_eq!(em.core.get("deadline_overruns"), 1);
        assert!(em.uptime() > Duration::ZERO);
        assert!(em.summary().contains("queue depth: 0"));
    }

    /// Fault-tolerance counters: shed totals split by policy, deadline
    /// cancellations, and the restart accessor over the raw counters.
    #[test]
    fn shed_restart_and_cancel_counters() {
        let em = EngineMetrics::new();
        assert_eq!(em.shed_total(), 0);
        assert_eq!(em.restart_count(), 0);
        assert_eq!(em.deadline_cancelled_count(), 0);
        em.record_shed_rejected();
        em.record_shed_rejected();
        em.record_shed_evicted();
        em.record_deadline_cancelled();
        em.core.inc("replica_restarts");
        em.core.inc("replica_exits");
        assert_eq!(em.shed_total(), 3);
        assert_eq!(em.core.get("sessions_shed_rejected"), 2);
        assert_eq!(em.core.get("sessions_shed_evicted"), 1);
        assert_eq!(em.deadline_cancelled_count(), 1);
        assert_eq!(em.restart_count(), 1);
        assert_eq!(em.core.get("replica_exits"), 1);
    }

    #[test]
    fn counter_and_series_snapshots() {
        let m = Metrics::new();
        m.add("b", 2);
        m.inc("a");
        m.observe_value("lat", 1.0);
        assert_eq!(
            m.counter_snapshot(),
            vec![("a".to_string(), 1), ("b".to_string(), 2)]
        );
        assert_eq!(m.series_names(), vec!["lat".to_string()]);
    }

    #[test]
    fn pool_busy_gauge_records_and_clamps() {
        let em = EngineMetrics::new();
        em.record_pool_busy(0.5);
        em.record_pool_busy(7.0); // clamped
        let s = em.core.latency_stats("pool_busy").unwrap();
        assert_eq!(s.count, 2);
        assert_eq!(s.max_ms, 1.0);
        assert!(em.summary().contains("pool_busy"));
    }

    #[test]
    fn engine_metrics_histogram_and_split() {
        let em = EngineMetrics::new();
        em.record_token_latency(Duration::from_millis(2));
        em.record_token_latency(Duration::from_micros(50));
        em.record_occupancy(4, 16);
        em.core.add("prefill_tokens", 10);
        em.core.add("decode_tokens", 30);
        let h = em.token_latency_histogram();
        assert_eq!(h.iter().map(|(_, n)| n).sum::<u64>(), 2);
        let s = em.summary();
        assert!(s.contains("token split: 10 prefill / 30 decode (75% decode)"), "{s}");
        assert!(s.contains("token_latency"), "{s}");
        let st = em.core.latency_stats("slot_occupancy").unwrap();
        assert!((st.mean_ms - 0.25).abs() < 1e-9);
    }

    #[test]
    fn summary_renders() {
        let m = Metrics::new();
        m.inc("jobs");
        m.observe("lat", Duration::from_millis(3));
        let s = m.summary();
        assert!(s.contains("jobs: 1"));
        assert!(s.contains("lat: n=1"));
    }

    /// Regression: a single NaN sample used to panic `latency_stats` via
    /// `partial_cmp(..).unwrap()` inside the sort, taking the replica
    /// worker down with it. Non-finite samples are now filtered out of
    /// the order statistics and flagged in `non_finite`.
    #[test]
    fn nan_sample_does_not_panic_stats() {
        let m = Metrics::new();
        m.observe_value("op", 1.0);
        m.observe_value("op", f64::NAN);
        m.observe_value("op", 3.0);
        m.observe_value("op", f64::INFINITY);
        let s = m.latency_stats("op").unwrap();
        assert_eq!(s.count, 2, "finite samples only");
        assert_eq!(s.non_finite, 2);
        assert_eq!(s.max_ms, 3.0);
        assert!((s.mean_ms - 2.0).abs() < 1e-12);
        assert!(m.summary().contains("dropped 2 non-finite samples"));
        // an all-NaN series yields no stats instead of garbage
        m.observe_value("bad", f64::NAN);
        assert!(m.latency_stats("bad").is_none());
    }

    /// Regression: the percentile index used to truncate
    /// (`((len-1) as f64 * p) as usize`), so p50 of 2 samples read the
    /// *minimum* and p99 of 50 samples read index 48. Pin the
    /// nearest-rank indices for the counts named in the issue.
    #[test]
    fn percentile_index_is_nearest_rank() {
        // len = 1: everything is the single sample
        assert_eq!(percentile_index(1, 0.5), 0);
        assert_eq!(percentile_index(1, 0.99), 0);
        // len = 2: p50 rounds up to the larger sample (truncation gave 0)
        assert_eq!(percentile_index(2, 0.5), 1);
        // len = 50
        assert_eq!(percentile_index(50, 0.5), 25);
        assert_eq!(percentile_index(50, 0.95), 47);
        assert_eq!(percentile_index(50, 0.99), 49); // truncation gave 48
        // len = 100
        assert_eq!(percentile_index(100, 0.5), 50);
        assert_eq!(percentile_index(100, 0.95), 94);
        assert_eq!(percentile_index(100, 0.99), 98);

        // end-to-end through latency_stats: two samples, p50 is the max
        let m = Metrics::new();
        m.observe_value("two", 1.0);
        m.observe_value("two", 9.0);
        let s = m.latency_stats("two").unwrap();
        assert_eq!(s.p50_ms, 9.0);
        assert_eq!(s.p99_ms, 9.0);
    }

    /// Regression: every lock site used `.lock().unwrap()`, so one
    /// panicking engine thread poisoned the mutex and cascaded panics
    /// into every other replica's `record_*`/`summary` call. Mirrors the
    /// `kernels/pool.rs` poisoned-lock test: poison both mutexes by
    /// panicking while holding them, then verify the registry still
    /// works.
    #[test]
    fn poisoned_locks_recover() {
        let m = std::sync::Arc::new(Metrics::new());
        m.add("n", 1);
        m.observe_value("lat", 5.0);
        let mc = m.clone();
        let _ = std::thread::spawn(move || {
            let _c = mc.counters.lock().unwrap(); // lint: allow(lock-unwrap)
            let _l = mc.latencies.lock().unwrap(); // lint: allow(lock-unwrap)
            panic!("poison the metrics locks");
        })
        .join();
        assert!(m.counters.lock().is_err(), "counters mutex must be poisoned");
        assert!(m.latencies.lock().is_err(), "latencies mutex must be poisoned");
        // all paths still function on the poisoned mutexes
        m.add("n", 2);
        assert_eq!(m.get("n"), 3);
        m.observe_value("lat", 7.0);
        let s = m.latency_stats("lat").unwrap();
        assert_eq!(s.count, 2);
        assert!(m.summary().contains("n: 3"));
    }
}
