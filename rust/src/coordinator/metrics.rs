//! Lightweight metrics: atomic counters + lock-protected latency
//! reservoirs, shared across coordinator threads.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Process-local metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    latencies: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, v: u64) {
        let mut g = self.counters.lock().unwrap();
        g.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn observe(&self, name: &str, d: Duration) {
        self.latencies
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(d.as_secs_f64() * 1e3);
    }

    /// (count, mean_ms, p50_ms, p95_ms, max_ms) for a latency series.
    pub fn latency_stats(&self, name: &str) -> Option<LatencyStats> {
        let g = self.latencies.lock().unwrap();
        let xs = g.get(name)?;
        if xs.is_empty() {
            return None;
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| sorted[((sorted.len() - 1) as f64 * p) as usize];
        Some(LatencyStats {
            count: sorted.len(),
            mean_ms: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50_ms: pct(0.5),
            p95_ms: pct(0.95),
            max_ms: *sorted.last().unwrap(),
        })
    }

    /// Render all metrics for reports.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {}\n", v.load(Ordering::Relaxed)));
        }
        let names: Vec<String> = self.latencies.lock().unwrap().keys().cloned().collect();
        for k in names {
            if let Some(s) = self.latency_stats(&k) {
                out.push_str(&format!(
                    "{k}: n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms max={:.2}ms\n",
                    s.count, s.mean_ms, s.p50_ms, s.p95_ms, s.max_ms
                ));
            }
        }
        out
    }
}

#[derive(Clone, Copy, Debug)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub max_ms: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let m = Metrics::new();
        m.inc("a");
        m.add("a", 4);
        assert_eq!(m.get("a"), 5);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("op", Duration::from_millis(i));
        }
        let s = m.latency_stats("op").unwrap();
        assert_eq!(s.count, 100);
        assert!((s.p50_ms - 50.0).abs() <= 1.5);
        assert!((s.p95_ms - 95.0).abs() <= 1.5);
        assert_eq!(s.max_ms, 100.0);
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.inc("x");
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.get("x"), 4000);
    }

    #[test]
    fn summary_renders() {
        let m = Metrics::new();
        m.inc("jobs");
        m.observe("lat", Duration::from_millis(3));
        let s = m.summary();
        assert!(s.contains("jobs: 1"));
        assert!(s.contains("lat: n=1"));
    }
}
