//! Session-based serving engine: KV-cached incremental decoding with
//! multi-replica continuous batching (the shape of a vLLM-style serving
//! stack, scaled to this testbed).
//!
//! [`Engine::start`] spins up N model replicas. Each replica owns a
//! KV-cache slab sized for one graph batch (`batch` slots x `seq_len`
//! positions) plus a private admission queue; the engine routes new
//! sessions round-robin. A replica worker alternates between two moves:
//!
//! 1. **Admit**: pull queued sessions into free batch slots, run one
//!    `lm_prefill` over the right-padded prompts, scatter the returned
//!    per-layer K/V rows into the slab, and stream each session's first
//!    token. When the replica is idle it waits up to
//!    [`EngineConfig::window`] for batch-mates; while sessions are
//!    mid-decode it admits instantly between steps (continuous batching —
//!    a late-arriving session never waits for the batch to drain).
//! 2. **Decode**: run one `lm_decode_step` over all active slots — one
//!    token in per slot, one K/V column appended, attention over
//!    `cache_len + 1` positions instead of a `seq_len^2` recompute — and
//!    stream one token to every active session. On backends that support
//!    the in-place cache protocol (the CPU backend does), the per-layer
//!    cache slabs live in a backend-resident
//!    [`crate::runtime::DecodeState`] and the step mutates them in place
//!    — no per-step slab round-trip through `HostTensor` args/results;
//!    other backends keep the clone-based path.
//!
//! Sessions end when their token budget is exhausted or the KV cache is
//! full (`seq_len` positions). Quantized serving uses the `*_q4` graphs:
//! 4-bit codes with 8-bit double-quantized block constants end-to-end,
//! dequantized inside the fused matmul, with OPQ outliers served from a
//! bf16-precision side-table patched inside the same kernels (see
//! [`EngineParams::QuantizedQ4`]). On backends without the KV serving
//! graphs (the XLA artifact ABI stops at the eval forwards), the engine
//! transparently serves the same sessions full-context through
//! `lm_logits_all` (see [`Engine::start_full_context`]) — identical
//! token streams, quadratic decode cost.
//!
//! Invariants (integration-tested): every session streams its tokens
//! exactly once and then closes; greedy tokens are bit-identical to
//! full-context re-execution through `lm_logits_all`/`lm_logits_last`;
//! batch size never exceeds the graph batch; a lone request is answered
//! within ~the admission window.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{EngineError, Error, Result};

use super::metrics::{EngineMetrics, Metrics};
use crate::models::corpus::TOK_SPACE;
use crate::obs::tracer::{self, TraceLevel};
use crate::runtime::{DecodeState, HostTensor, KvFormat, Runtime};

/// Process-wide session-id source, so trace spans from different engines
/// (tests spin several up) never collide.
static NEXT_SESSION_ID: AtomicU64 = AtomicU64::new(1);

/// One streamed token: the greedy argmax and its logit value.
#[derive(Clone, Debug, PartialEq)]
pub struct InferenceResponse {
    /// Greedy argmax token at this position.
    pub next_token: u8,
    /// Its logit value.
    pub logit: f32,
}

/// Batching policy of the legacy [`BatchedLm`] shim.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Max time a request waits for batch-mates.
    pub window: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            window: Duration::from_millis(5),
        }
    }
}

/// Serving-engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Number of model replicas. Every replica reads the one shared
    /// immutable weight set ([`SharedWeights`]) — adding replicas adds
    /// only a private KV-cache slab of `batch` slots and an admission
    /// queue per replica, not another parameter copy; sessions are
    /// routed round-robin. See [`Engine::memory_profile`].
    pub replicas: usize,
    /// How long an **idle** replica waits for batch-mates before
    /// prefilling. Replicas with sessions mid-decode admit new sessions
    /// instantly between decode steps.
    pub window: Duration,
    /// Default per-session token budget for [`Engine::session`]
    /// ([`Engine::session_with`] overrides it). Independent of the
    /// budget, a session's context can never exceed the model's
    /// `seq_len`: once `prompt + generated` fills the KV cache, the
    /// stream ends — the maximum session length is
    /// `1 + seq_len - prompt_len` tokens.
    pub max_session_tokens: usize,
    /// Storage format of the per-session KV caches (defaults from the
    /// `BOF4_KV` env knob; see [`crate::quant::KvFormat`]). `F32` keeps
    /// streams bit-identical to the pre-knob engine; `Q8`/`Q4` hold
    /// block-quantized resident slabs, quantized at append and
    /// dequantized fused inside the decode attention — deterministic
    /// across `BOF4_THREADS × BOF4_SIMD`, at a small, format-dependent
    /// accuracy cost. Quantized formats require a backend with in-place
    /// decode support (the CPU backend has it); engine start fails
    /// rather than silently serving f32. Irrelevant in full-context
    /// mode, which keeps no KV cache at all.
    pub kv_format: KvFormat,
    /// Per-session latency SLO, now *enforced*: a session whose wall
    /// time (from [`Engine::session`]) exceeds this budget is cancelled
    /// at the next decode-step boundary — its slot is freed, the
    /// `deadline_cancelled` counter bumps, a `deadline_cancelled` trace
    /// instant fires and the caller receives
    /// [`EngineError::DeadlineExceeded`] mid-stream. Sessions that
    /// merely *finish* past the budget still bump the observational
    /// `deadline_overruns` counter (cancellations are a subset of
    /// overruns). `None` (the default) disables both.
    pub session_deadline: Option<Duration>,
    /// Admission control: refuse new sessions once the engine-wide
    /// queue-depth gauge ([`EngineMetrics::queue_depth`]) reaches this
    /// limit, per [`EngineConfig::shed_policy`]. `None` (the default)
    /// keeps the pre-fault-tolerance unbounded queueing.
    pub max_queue_depth: Option<usize>,
    /// Liveness bound on session streams: [`DecodeSession::next_token`]
    /// waits at most this long for a token before returning
    /// [`EngineError::Timeout`] — a wedged or stalled engine yields a
    /// typed error instead of hanging callers forever.
    pub admission_timeout: Duration,
    /// What happens to the excess session when the queue is full.
    pub shed_policy: ShedPolicy,
    /// How many times a replica whose worker panicked (or hit a backend
    /// fault) is rebuilt from [`SharedWeights`] before the engine gives
    /// it up and degrades capacity, re-routing admissions to survivors.
    pub max_replica_restarts: u32,
    /// Base of the exponential restart backoff: attempt `k` sleeps
    /// `restart_backoff * 2^k` before rebuilding.
    pub restart_backoff: Duration,
}

/// Load-shedding policy once `max_queue_depth` is reached.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Refuse the *new* session: [`Engine::session`] returns
    /// [`EngineError::Overloaded`] immediately (retryable).
    Reject,
    /// Shed the *oldest still-queued* session in the new one's favour —
    /// the victim's stream fails with [`EngineError::Overloaded`]. Falls
    /// back to `Reject` when nothing is left in the queue to shed.
    Oldest,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            replicas: 1,
            window: Duration::from_millis(5),
            max_session_tokens: usize::MAX,
            kv_format: KvFormat::from_env(),
            session_deadline: None,
            max_queue_depth: None,
            admission_timeout: Duration::from_secs(60),
            shed_policy: ShedPolicy::Reject,
            max_replica_restarts: 2,
            restart_backoff: Duration::from_millis(10),
        }
    }
}

/// Parameters the engine serves.
#[derive(Clone, Debug)]
pub enum EngineParams {
    /// The 16 dense f32 tensors in canonical ABI order (what
    /// `init_params` returns / `ParamSet::to_tensors` produces). Served
    /// through `lm_prefill` / `lm_decode_step`.
    Dense(Vec<HostTensor>),
    /// Argument prefix for the `lm_prefill_q4` / `lm_decode_step_q4`
    /// graphs: non-matmul f32 params, unpacked 4-bit codes, 8-bit
    /// double-quantized block constants, per-matrix OPQ outlier
    /// side-tables (sorted u32 indices + bf16-rounded f32 values, empty
    /// when OPQ is off) and the codebook levels, in ABI order. Block
    /// constants stay 8-bit end-to-end and are dequantized inside the
    /// fused CPU matmul; outliers are patched sparsely inside the same
    /// kernels, so OPQ models serve 4-bit at rest with a 16-bit
    /// side-channel. Build with [`crate::eval::quantize_for_serving`].
    QuantizedQ4(Vec<HostTensor>),
}

impl From<Vec<HostTensor>> for EngineParams {
    fn from(v: Vec<HostTensor>) -> Self {
        EngineParams::Dense(v)
    }
}

/// The engine's one immutable weight set: the graph-argument prefix
/// (dense params or the q4 prefix incl. OPQ side-tables), shared by
/// every replica. `HostTensor` clones share their buffers, so each
/// replica's persistent prefill/decode argument vectors are cheap handle
/// views over this set — replica count scales scheduling, not parameter
/// memory.
pub type SharedWeights = Arc<Vec<HostTensor>>;

/// Resident-memory accounting of a running engine, measured by
/// deduplicating tensor buffers by identity
/// ([`crate::runtime::host::unique_resident_bytes`]) so shared storage
/// is counted exactly once.
#[derive(Clone, Debug)]
pub struct EngineMemoryProfile {
    pub replicas: usize,
    /// Bytes of the shared parameter set — counted once no matter how
    /// many replicas hold views over it.
    pub shared_param_bytes: usize,
    /// Per-replica private bytes: KV-cache slabs (backend-resident or
    /// in-args), token/position placeholders — storage not shared with
    /// the weight set or any other replica.
    pub per_replica_bytes: Vec<usize>,
    /// Unique bytes across the weight set and every replica:
    /// `shared_param_bytes + sum(per_replica_bytes)`.
    pub total_resident_bytes: usize,
    /// Active KV-cache storage format (`"f32" | "q8" | "q4"` — the
    /// [`EngineConfig::kv_format`] knob).
    pub kv_format: &'static str,
    /// Resident KV-cache bytes one session (one batch slot) costs under
    /// that format. `0` in full-context mode, which keeps no KV cache.
    pub session_kv_bytes: usize,
}

impl EngineMemoryProfile {
    /// Concurrent sessions one GiB of KV-cache memory holds under the
    /// active format — the serving-capacity headline `bof4 serve`
    /// prints. `None` in full-context mode (no KV cache to size by).
    pub fn sessions_per_gb(&self) -> Option<f64> {
        (self.session_kv_bytes > 0).then(|| (1u64 << 30) as f64 / self.session_kv_bytes as f64)
    }
}

/// Greedy sampling helper: `(argmax index, max logit)`. Ties resolve to
/// the highest index (`Iterator::max_by` keeps the last maximum) — the
/// equivalence tests rely on the engine and the full-context oracle
/// sharing this exact rule. `total_cmp` keeps the comparison a total
/// order, so a NaN logit yields a deterministic pick instead of a panic
/// (and the engine and oracle agree on it, since both call this fn).
pub fn greedy_argmax(row: &[f32]) -> (u8, f32) {
    let (arg, max) = row
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .expect("non-empty logits row");
    (arg as u8, *max)
}

/// A queued session request.
struct SessionReq {
    /// Process-unique session id (trace-span correlation key).
    id: u64,
    prompt: Vec<u8>,
    max_tokens: usize,
    /// When [`Engine::submit`] enqueued the request — the anchor for the
    /// `queue_wait` span, time-to-first-token and the session deadline.
    queued_at: Instant,
    tx: mpsc::Sender<Result<InferenceResponse>>,
}

/// A live decoding session: a stream of greedy tokens. Iterate it (or
/// call [`DecodeSession::next_token`]) to receive tokens; the stream
/// closes when the token budget is exhausted or the KV cache fills.
/// Dropping the session cancels it — the replica frees its slot at the
/// next step.
pub struct DecodeSession {
    rx: mpsc::Receiver<Result<InferenceResponse>>,
    /// Per-token liveness bound ([`EngineConfig::admission_timeout`]).
    timeout: Duration,
}

impl DecodeSession {
    /// Block for the next token; `None` once the stream has closed.
    /// Waits at most [`EngineConfig::admission_timeout`]: a wedged
    /// engine yields [`EngineError::Timeout`] instead of hanging the
    /// caller forever.
    pub fn next_token(&mut self) -> Option<Result<InferenceResponse>> {
        match self.rx.recv_timeout(self.timeout) {
            Ok(ev) => Some(ev),
            Err(mpsc::RecvTimeoutError::Disconnected) => None,
            Err(mpsc::RecvTimeoutError::Timeout) => Some(Err(Error::engine(EngineError::Timeout {
                waited_ms: self.timeout.as_millis() as u64,
            }))),
        }
    }

    /// Drain the stream into the generated token vector.
    pub fn collect_tokens(self) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        for ev in self {
            out.push(ev?.next_token);
        }
        Ok(out)
    }
}

impl Iterator for DecodeSession {
    type Item = Result<InferenceResponse>;

    fn next(&mut self) -> Option<Self::Item> {
        self.next_token()
    }
}

struct ReplicaHandle {
    tx: Option<mpsc::Sender<SessionReq>>,
    worker: Option<JoinHandle<()>>,
}

/// Queued-session bookkeeping for the `Oldest` shed policy. mpsc
/// channels cannot un-send, so shedding a queued session means marking
/// its id here; the replica delivers the typed error when it pulls the
/// marked request (one lock guards both maps so a request can never be
/// half-shed).
#[derive(Default)]
struct AdmissionQueue {
    /// Session ids submitted but not yet pulled by a replica worker.
    queued: BTreeSet<u64>,
    /// Ids shed while queued, with the `(depth, limit)` observed at the
    /// shed decision (reported in the victim's `Overloaded` error).
    shed: BTreeMap<u64, (u64, u64)>,
}

/// State shared between the engine handle and every replica worker:
/// the shed registry and per-replica liveness (a replica whose restart
/// budget is exhausted flips its flag and admissions re-route to
/// survivors).
struct EngineShared {
    q: Mutex<AdmissionQueue>,
    alive: Vec<AtomicBool>,
}

impl EngineShared {
    fn new(replicas: usize) -> EngineShared {
        EngineShared {
            q: Mutex::new(AdmissionQueue::default()),
            alive: (0..replicas).map(|_| AtomicBool::new(true)).collect(),
        }
    }

    fn lock_q(&self) -> std::sync::MutexGuard<'_, AdmissionQueue> {
        crate::util::sync::lock_recover(&self.q)
    }

    /// Register a submitted session id (removed again at replica pull).
    fn register(&self, id: u64) {
        self.lock_q().queued.insert(id);
    }

    fn deregister(&self, id: u64) {
        let mut q = self.lock_q();
        q.queued.remove(&id);
        q.shed.remove(&id);
    }

    /// Shed the oldest still-queued session (session ids are monotonic,
    /// so the smallest id is the oldest). Returns the victim id, or
    /// `None` when nothing is queued to shed.
    fn shed_oldest(&self, depth: u64, limit: u64) -> Option<u64> {
        let mut q = self.lock_q();
        let id = q.queued.iter().next().copied()?;
        q.queued.remove(&id);
        q.shed.insert(id, (depth, limit));
        Some(id)
    }

    /// Replica-side pull filter: deregister the request; if it was shed
    /// while queued, deliver the typed error (with queue accounting)
    /// and swallow it.
    fn on_pull(&self, metrics: &EngineMetrics, req: SessionReq) -> Option<SessionReq> {
        let shed = {
            let mut q = self.lock_q();
            q.queued.remove(&req.id);
            q.shed.remove(&req.id)
        };
        match shed {
            None => Some(req),
            Some((depth, limit)) => {
                metrics.queue_exit(req.queued_at.elapsed());
                tracer::instant(
                    TraceLevel::Engine,
                    "shed_delivered",
                    &[("session", req.id as i64)],
                );
                let _ = req
                    .tx
                    .send(Err(Error::engine(EngineError::Overloaded { depth, limit })));
                None
            }
        }
    }
}

/// Handle to a running serving engine.
pub struct Engine {
    replicas: Vec<ReplicaHandle>,
    next: AtomicUsize,
    pub metrics: Arc<EngineMetrics>,
    max_session_tokens: usize,
    seq_len: usize,
    /// Admission-control knobs ([`EngineConfig`]).
    max_queue_depth: Option<usize>,
    admission_timeout: Duration,
    shed_policy: ShedPolicy,
    /// Shed registry + replica liveness, shared with the workers.
    shared: Arc<EngineShared>,
    /// The shared immutable weight set every replica reads through.
    weights: SharedWeights,
    memory: EngineMemoryProfile,
    /// Kept for observability: [`Engine::snapshot`] reads the backend's
    /// per-kernel profile through it.
    rt: Arc<Runtime>,
}

impl Engine {
    /// Start `cfg.replicas` replica workers over one parameter set.
    /// `params` is anything convertible into [`EngineParams`]; plain
    /// `Vec<HostTensor>` (the 16 dense tensors) converts to
    /// [`EngineParams::Dense`].
    pub fn start(
        rt: Arc<Runtime>,
        params: impl Into<EngineParams>,
        cfg: EngineConfig,
    ) -> Result<Engine> {
        Self::start_inner(rt, params.into(), cfg, false)
    }

    /// Start the engine in full-context fallback mode: identical session
    /// semantics (streaming, continuous batching, replicas), but every
    /// step re-executes the whole context through `lm_logits_all` instead
    /// of using KV caches. [`Engine::start`] selects this automatically
    /// when the backend's graph set lacks the KV serving graphs (the XLA
    /// artifact ABI stops at the eval forwards); this constructor forces
    /// it, which the equivalence tests use to pin both modes against each
    /// other on the CPU backend.
    pub fn start_full_context(
        rt: Arc<Runtime>,
        params: Vec<HostTensor>,
        cfg: EngineConfig,
    ) -> Result<Engine> {
        Self::start_inner(rt, EngineParams::Dense(params), cfg, true)
    }

    fn start_inner(
        rt: Arc<Runtime>,
        params: EngineParams,
        cfg: EngineConfig,
        force_full_context: bool,
    ) -> Result<Engine> {
        let (mode, prefill_graph, decode_graph, prefix) = match params {
            EngineParams::Dense(p) => {
                if !force_full_context && rt.meta.graphs.contains_key("lm_prefill") {
                    (ServingMode::KvCached, "lm_prefill", "lm_decode_step", p)
                } else {
                    // fallback: the eval forward exists on every backend
                    (
                        ServingMode::FullContext,
                        "lm_logits_all",
                        "lm_logits_all",
                        p,
                    )
                }
            }
            EngineParams::QuantizedQ4(p) => {
                if !rt.meta.graphs.contains_key("lm_prefill_q4") {
                    return Err(crate::err!(
                        "this backend's graph set has no q4 serving graphs; \
                         serve the exactly-dequantized weights instead \
                         (EngineParams::Dense(QuantizedServingParams::dense))"
                    ));
                }
                (
                    ServingMode::KvCached,
                    "lm_prefill_q4",
                    "lm_decode_step_q4",
                    p,
                )
            }
        };
        let gm = rt.meta.graph(prefill_graph)?;
        let tail_args = match mode {
            ServingMode::KvCached => 2, // tokens + lens
            ServingMode::FullContext => 1, // tokens
        };
        if prefix.len() + tail_args != gm.args.len() {
            return Err(crate::err!(
                "{prefill_graph} wants {} leading args, got {}",
                gm.args.len() - tail_args,
                prefix.len()
            ));
        }
        // Force compilation/warm-up up-front so the first session isn't
        // slow.
        rt.prepare(prefill_graph)?;
        rt.prepare(decode_graph)?;
        let metrics = Arc::new(EngineMetrics::new());
        let n_replicas = cfg.replicas.max(1);
        let shared = Arc::new(EngineShared::new(n_replicas));
        // One immutable weight set; every replica's persistent argument
        // vectors are handle views over it (buffer-sharing clones).
        let weights: SharedWeights = Arc::new(prefix);
        // Build every replica first so resident memory can be profiled
        // before the workers take ownership, then spawn.
        let mut built = Vec::with_capacity(n_replicas);
        for r in 0..n_replicas {
            built.push(Replica::new(
                rt.clone(),
                weights.clone(),
                mode,
                cfg.kv_format,
                prefill_graph,
                decode_graph,
                cfg.window,
                cfg.session_deadline,
                metrics.clone(),
                shared.clone(),
                r,
            )?);
        }
        let memory = Self::profile_memory(&weights, &built);
        let mut replicas = Vec::with_capacity(n_replicas);
        let (max_restarts, backoff) = (cfg.max_replica_restarts, cfg.restart_backoff);
        for (r, replica) in built.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<SessionReq>();
            let worker = std::thread::Builder::new()
                .name(format!("engine-replica-{r}"))
                .spawn(move || supervise(replica, rx, max_restarts, backoff))?;
            replicas.push(ReplicaHandle {
                tx: Some(tx),
                worker: Some(worker),
            });
        }
        Ok(Engine {
            replicas,
            next: AtomicUsize::new(0),
            metrics,
            max_session_tokens: cfg.max_session_tokens,
            seq_len: rt.meta.model.seq_len,
            max_queue_depth: cfg.max_queue_depth,
            admission_timeout: cfg.admission_timeout,
            shed_policy: cfg.shed_policy,
            shared,
            weights,
            memory,
            rt,
        })
    }

    /// Capture one observability snapshot: every SLO counter/series from
    /// [`EngineMetrics`], the backend's per-kernel profile and the
    /// engine's memory profile — the [`crate::obs::MetricsSnapshot`]
    /// `bof4 serve --metrics-file` renders as Prometheus text and JSON.
    pub fn snapshot(&self) -> crate::obs::MetricsSnapshot {
        crate::obs::MetricsSnapshot::collect(
            &self.metrics,
            self.rt.kernel_profile().unwrap_or_default(),
            Some(self.memory.clone()),
        )
    }

    /// Account resident memory by buffer identity: the weight set is
    /// counted once, then each replica contributes only storage not
    /// already seen (its KV slabs and small arg placeholders).
    fn profile_memory(weights: &SharedWeights, built: &[Replica]) -> EngineMemoryProfile {
        let mut seen = std::collections::HashSet::new();
        let shared_param_bytes =
            crate::runtime::host::unique_resident_bytes(weights.iter(), &mut seen);
        let per_replica_bytes: Vec<usize> =
            built.iter().map(|r| r.private_bytes(&mut seen)).collect();
        // replicas are homogeneous: format and per-session cost come
        // from the first one (start_inner builds at least one)
        let (kv_format, session_kv_bytes) = built
            .first()
            .map(|r| (r.kv.name(), r.session_kv_bytes()))
            .unwrap_or(("f32", 0));
        EngineMemoryProfile {
            replicas: built.len(),
            shared_param_bytes,
            total_resident_bytes: shared_param_bytes + per_replica_bytes.iter().sum::<usize>(),
            per_replica_bytes,
            kv_format,
            session_kv_bytes,
        }
    }

    /// Resident-memory accounting captured at start-up (weights counted
    /// once, per-replica private storage itemized).
    pub fn memory_profile(&self) -> &EngineMemoryProfile {
        &self.memory
    }

    /// The shared immutable weight set. While the engine runs, its
    /// strong count is `replicas + 1` (each worker holds one handle) —
    /// the sharing invariant the integration tests pin.
    pub fn shared_weights(&self) -> &SharedWeights {
        &self.weights
    }

    /// Open a streaming session with the default token budget
    /// ([`EngineConfig::max_session_tokens`]; the KV-cache capacity still
    /// bounds the stream).
    pub fn session(&self, prompt: &[u8]) -> Result<DecodeSession> {
        self.session_with(prompt, self.max_session_tokens)
    }

    /// Open a streaming session that emits at most `max_tokens` tokens.
    /// Under admission control ([`EngineConfig::max_queue_depth`]) this
    /// can fail fast with [`EngineError::Overloaded`].
    pub fn session_with(&self, prompt: &[u8], max_tokens: usize) -> Result<DecodeSession> {
        Ok(DecodeSession {
            rx: self.submit(prompt, max_tokens.max(1))?,
            timeout: self.admission_timeout,
        })
    }

    /// Greedy-decode `n` tokens from a prompt. When the context outgrows
    /// the KV cache, the session is transparently restarted over a
    /// truncated tail of the context: each restart leaves `seq_len / 4`
    /// positions of headroom so one prefill amortizes a whole chunk of
    /// decode steps (restarting over the full window would degenerate to
    /// one quadratic prefill per token), at the cost of a slightly
    /// shorter context for windowed continuations.
    pub fn generate(&self, prompt: &[u8], n: usize) -> Result<Vec<u8>> {
        let mut ctx = prompt.to_vec();
        let mut out = Vec::with_capacity(n);
        let headroom = (self.seq_len / 4).max(1);
        while out.len() < n {
            let window = if ctx.len() >= self.seq_len {
                &ctx[ctx.len() - (self.seq_len - headroom)..]
            } else {
                &ctx[..]
            };
            let mut sess = self.session_with(window, n - out.len())?;
            let mut progressed = false;
            while out.len() < n {
                match sess.next_token() {
                    Some(ev) => {
                        let ev = ev?;
                        out.push(ev.next_token);
                        ctx.push(ev.next_token);
                        progressed = true;
                    }
                    None => break,
                }
            }
            if !progressed {
                return Err(crate::err!("engine session made no progress"));
            }
        }
        Ok(out)
    }

    fn submit(
        &self,
        prompt: &[u8],
        max_tokens: usize,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse>>> {
        let (tx, rx) = mpsc::channel();
        // Route round-robin over *live* replicas; a replica whose
        // restart budget is exhausted no longer receives admissions.
        let n = self.replicas.len();
        let mut target = None;
        for _ in 0..n {
            let i = self.next.fetch_add(1, Ordering::Relaxed) % n;
            if self.shared.alive[i].load(Ordering::Relaxed) {
                target = Some(i);
                break;
            }
        }
        let Some(i) = target else {
            return Err(Error::engine(EngineError::Stopped));
        };
        // Admission control: consult the queue-depth gauge before
        // enqueueing (the telemetry PR 8 landed; this acts on it).
        if let Some(limit) = self.max_queue_depth {
            let depth = self.metrics.queue_depth();
            if depth >= limit as u64 {
                let victim = match self.shed_policy {
                    ShedPolicy::Reject => None,
                    ShedPolicy::Oldest => self.shared.shed_oldest(depth, limit as u64),
                };
                match victim {
                    Some(v) => {
                        self.metrics.record_shed_evicted();
                        tracer::instant(
                            TraceLevel::Engine,
                            "shed",
                            &[("victim", v as i64), ("depth", depth as i64)],
                        );
                    }
                    None => {
                        // Reject policy, or nothing queued left to shed.
                        self.metrics.record_shed_rejected();
                        tracer::instant(
                            TraceLevel::Engine,
                            "shed",
                            &[("depth", depth as i64), ("limit", limit as i64)],
                        );
                        return Err(Error::engine(EngineError::Overloaded {
                            depth,
                            limit: limit as u64,
                        }));
                    }
                }
            }
        }
        let id = NEXT_SESSION_ID.fetch_add(1, Ordering::Relaxed);
        self.shared.register(id);
        self.metrics.queue_enter();
        tracer::instant(
            TraceLevel::Engine,
            "submit",
            &[
                ("session", id as i64),
                ("replica", i as i64),
                ("prompt_len", prompt.len() as i64),
            ],
        );
        self.replicas[i]
            .tx
            .as_ref()
            .expect("engine running")
            .send(SessionReq {
                id,
                prompt: prompt.to_vec(),
                max_tokens,
                queued_at: Instant::now(),
                tx,
            })
            .map_err(|_| {
                self.shared.deregister(id);
                self.metrics.queue_exit(Duration::ZERO);
                Error::engine(EngineError::Stopped)
            })?;
        Ok(rx)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // close every admission queue, then join the workers (they finish
        // in-flight sessions first)
        for r in &mut self.replicas {
            r.tx.take();
        }
        for r in &mut self.replicas {
            if let Some(h) = r.worker.take() {
                let _ = h.join();
            }
        }
    }
}

/// How a replica executes its sessions.
#[derive(Clone, Copy, Debug, PartialEq)]
enum ServingMode {
    /// Prefill once, then incremental decode over per-session KV caches.
    KvCached,
    /// Re-execute the full context through `lm_logits_all` every step —
    /// the fallback for backends whose graph set stops at the eval
    /// forwards. Same session semantics, O(seq_len^2) decode cost.
    FullContext,
}

/// One live batch slot: a session mid-decode.
struct Slot {
    /// Process-unique session id (trace-span correlation key).
    id: u64,
    /// Positions filled in the KV cache (prompt + already-placed tokens).
    /// In full-context mode this is `ctx.len() - 1`: the last streamed
    /// token is in `ctx` but its K/V column is "not placed yet".
    len: usize,
    /// Last streamed token — the next decode step's input.
    last: u8,
    /// Tokens still owed to the session.
    remaining: usize,
    /// Full context (prompt tail + streamed tokens); maintained only in
    /// [`ServingMode::FullContext`], empty under KV caching.
    ctx: Vec<u8>,
    /// When the session was submitted — anchors the `session` trace span
    /// and the [`EngineConfig::session_deadline`] check.
    queued_at: Instant,
    /// When the previous token was streamed (the first token at
    /// admission) — the inter-token latency anchor.
    last_emit: Instant,
    tx: mpsc::Sender<Result<InferenceResponse>>,
}

/// Session close-out: deadline-overrun accounting plus the session-long
/// trace span. Free function (not a `Replica` method) so the decode
/// loops can call it while iterating `self.slots` mutably.
fn finish_session(
    metrics: &EngineMetrics,
    deadline: Option<Duration>,
    id: u64,
    queued_at: Instant,
) {
    let now = Instant::now();
    if let Some(dl) = deadline {
        if now.saturating_duration_since(queued_at) > dl {
            metrics.record_deadline_overrun();
            tracer::instant(
                TraceLevel::Engine,
                "deadline_overrun",
                &[("session", id as i64)],
            );
        }
    }
    tracer::span_at(
        TraceLevel::Engine,
        "session",
        queued_at,
        now,
        &[("session", id as i64)],
    );
}

/// Why a replica worker's serve loop returned.
enum ExitReason {
    /// The admission queue closed and every in-flight session drained —
    /// the engine is shutting down.
    Shutdown,
    /// A backend fault (prefill/decode error). The replica's KV state
    /// is suspect; the supervisor tears it down and rebuilds.
    Fatal(Error),
}

/// Best-effort text of a `catch_unwind` payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| (*s).to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Fail every request still queued on a permanently-dead replica with a
/// typed error, until the engine closes the channel (so `Engine::drop`
/// still joins this worker cleanly and no sender ever hangs).
fn drain_dead_queue(
    rx: &mpsc::Receiver<SessionReq>,
    shared: &EngineShared,
    metrics: &EngineMetrics,
    index: usize,
) {
    while let Ok(req) = rx.recv() {
        if let Some(req) = shared.on_pull(metrics, req) {
            metrics.queue_exit(req.queued_at.elapsed());
            let _ = req
                .tx
                .send(Err(Error::engine(EngineError::ReplicaDead { replica: index })));
        }
    }
}

/// Replica worker body: run the serve loop under `catch_unwind`,
/// convert panics and backend faults into supervisor events, fail the
/// dead replica's in-flight sessions with a typed error (never a hang),
/// and either rebuild the replica from [`SharedWeights`] (bounded
/// restarts, exponential backoff) or mark it dead so admissions
/// re-route to survivors.
fn supervise(
    mut replica: Replica,
    rx: mpsc::Receiver<SessionReq>,
    max_restarts: u32,
    backoff: Duration,
) {
    let index = replica.index;
    let mut restarts: u32 = 0;
    loop {
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| replica.run(&rx)));
        let cause = match outcome {
            Ok(ExitReason::Shutdown) => {
                crate::debug!("engine-replica-{index}: clean shutdown (queue closed)");
                replica.metrics.core.inc("replica_exits");
                tracer::instant(
                    TraceLevel::Engine,
                    "replica_exit",
                    &[("replica", index as i64), ("fatal", 0)],
                );
                return;
            }
            Ok(ExitReason::Fatal(e)) => format!("backend fault: {e:#}"),
            Err(p) => format!("panic: {}", panic_message(p.as_ref())),
        };
        replica.metrics.core.inc("replica_exits");
        tracer::instant(
            TraceLevel::Engine,
            "replica_exit",
            &[("replica", index as i64), ("fatal", 1)],
        );
        let in_flight = replica.slots.iter().filter(|s| s.is_some()).count();
        crate::warn!(
            "engine-replica-{index} died ({cause}); failing {in_flight} in-flight session(s)"
        );
        replica.fail_all_slots();
        if restarts >= max_restarts {
            crate::warn!(
                "engine-replica-{index}: restart budget ({max_restarts}) exhausted; \
                 degrading capacity and re-routing admissions to survivors"
            );
            replica.shared.alive[index].store(false, Ordering::Relaxed);
            drain_dead_queue(&rx, &replica.shared, &replica.metrics, index);
            return;
        }
        std::thread::sleep(backoff.saturating_mul(1u32 << restarts.min(16)));
        restarts += 1;
        replica.metrics.core.inc("replica_restarts");
        tracer::instant(
            TraceLevel::Engine,
            "replica_restart",
            &[("replica", index as i64), ("attempt", restarts as i64)],
        );
        // Rebuild from the same SharedWeights handle (moved, not
        // cloned: the `strong_count == replicas + 1` invariant holds
        // across restarts).
        let shared = replica.shared.clone();
        let metrics = replica.metrics.clone();
        replica = match replica.rebuild() {
            Ok(fresh) => fresh,
            Err(e) => {
                crate::warn!("engine-replica-{index}: rebuild failed ({e:#}); marking dead");
                shared.alive[index].store(false, Ordering::Relaxed);
                drain_dead_queue(&rx, &shared, &metrics, index);
                return;
            }
        };
        crate::info!("engine-replica-{index}: restarted (attempt {restarts}/{max_restarts})");
    }
}

/// Worker-thread state of one model replica. Holds a handle to the
/// engine's [`SharedWeights`]; its persistent argument vectors are
/// buffer-sharing views over that set, so the replica's only private
/// storage is its KV-cache slabs and the small token/position
/// placeholders.
struct Replica {
    rt: Arc<Runtime>,
    /// The engine-wide shared weight set (kept to hold the sharing
    /// invariant `Arc::strong_count == replicas + 1` and for
    /// accounting; the argument vectors below view its buffers).
    weights: SharedWeights,
    mode: ServingMode,
    /// KV-cache storage format of this replica's resident state
    /// ([`EngineConfig::kv_format`]).
    kv: KvFormat,
    prefill_graph: &'static str,
    decode_graph: &'static str,
    window: Duration,
    /// Per-session wall-time SLO ([`EngineConfig::session_deadline`]).
    deadline: Option<Duration>,
    metrics: Arc<EngineMetrics>,
    /// Engine-wide shed registry + liveness flags.
    shared: Arc<EngineShared>,
    /// This replica's index (liveness flag slot, error payloads).
    index: usize,
    slots: Vec<Option<Slot>>,
    /// Backend-resident KV caches (the in-place decode protocol): when
    /// the backend hands one out, the per-layer cache slabs live here and
    /// `lm_decode_step` mutates them without crossing the `HostTensor`
    /// ABI — no per-step slab memcpy. `None` on backends without support
    /// (then the caches ride inside `decode_args`, the clone path).
    kv_state: Option<Box<dyn DecodeState>>,
    /// Persistent decode args. In-place: `[prefix.., token, pos]` (the
    /// caches live in `kv_state`). Clone path: `[prefix.., k/v caches..,
    /// token, pos]` — the caches are moved out/in around each graph call
    /// so the engine side never re-clones parameters on the hot path, but
    /// the backend still copies the slab across the immutable
    /// `Backend::execute` ABI once per step.
    decode_args: Vec<HostTensor>,
    /// Persistent prefill args: `[prefix.., tokens, lens]`.
    prefill_args: Vec<HostTensor>,
    n_prefix: usize,
    n_layers: usize,
    batch: usize,
    seq: usize,
    d_model: usize,
    vocab: usize,
}

impl Replica {
    #[allow(clippy::too_many_arguments)]
    fn new(
        rt: Arc<Runtime>,
        weights: SharedWeights,
        mode: ServingMode,
        kv: KvFormat,
        prefill_graph: &'static str,
        decode_graph: &'static str,
        window: Duration,
        deadline: Option<Duration>,
        metrics: Arc<EngineMetrics>,
        shared: Arc<EngineShared>,
        index: usize,
    ) -> Result<Replica> {
        let m = rt.meta.model.clone();
        let (b, s, d) = (m.batch, m.seq_len, m.d_model);
        let n_prefix = weights.len();
        // Ok(None) means the backend has no in-place support (fall back
        // to the clone path, which always carries f32 slabs); an Err is
        // a real allocation failure — or a quantized-KV request the
        // backend cannot honour — and must surface rather than silently
        // degrade.
        let kv_state = if mode == ServingMode::KvCached {
            rt.alloc_decode_state_fmt(decode_graph, kv)?
        } else {
            None
        };
        // Handle views over the shared set — no parameter bytes are
        // copied here; only the KV slabs / placeholders below are
        // replica-private storage.
        let mut decode_args: Vec<HostTensor> = weights.as_ref().clone();
        if mode == ServingMode::KvCached {
            if kv_state.is_none() {
                for _ in 0..2 * m.n_layers {
                    decode_args.push(HostTensor::zeros_f32(vec![b, s, d]));
                }
            }
            decode_args.push(HostTensor::i32(vec![0; b], vec![b]));
            decode_args.push(HostTensor::i32(vec![-1; b], vec![b]));
        }
        let mut prefill_args: Vec<HostTensor> = weights.as_ref().clone();
        prefill_args.push(HostTensor::i32(vec![TOK_SPACE as i32; b * s], vec![b, s]));
        if mode == ServingMode::KvCached {
            prefill_args.push(HostTensor::i32(vec![1; b], vec![b]));
        }
        Ok(Replica {
            rt,
            weights,
            mode,
            kv,
            prefill_graph,
            decode_graph,
            window,
            deadline,
            metrics,
            shared,
            index,
            slots: (0..b).map(|_| None).collect(),
            kv_state,
            decode_args,
            prefill_args,
            n_prefix,
            n_layers: m.n_layers,
            batch: b,
            seq: s,
            d_model: d,
            vocab: m.vocab,
        })
    }

    /// Bytes of storage private to this replica: tensor buffers in its
    /// argument vectors not already accounted in `seen` (the weight set
    /// goes in first, so shared views contribute nothing) plus the
    /// backend-resident KV state.
    fn private_bytes(&self, seen: &mut std::collections::HashSet<usize>) -> usize {
        debug_assert!(
            self.weights
                .iter()
                .zip(&self.decode_args)
                .all(|(w, a)| w.byte_len() == 0 || w.shares_buffer(a)),
            "replica arg prefix must view the shared weight buffers"
        );
        crate::runtime::host::unique_resident_bytes(
            self.decode_args.iter().chain(self.prefill_args.iter()),
            seen,
        ) + self.kv_state.as_ref().map_or(0, |st| st.resident_bytes())
    }

    /// Resident KV-cache bytes one batch slot (one session) costs on
    /// this replica: the backend-resident state divided across its
    /// slots, or the clone-path f32 slab share; 0 in full-context mode.
    fn session_kv_bytes(&self) -> usize {
        match &self.kv_state {
            Some(st) => st.resident_bytes() / self.batch,
            None if self.mode == ServingMode::KvCached => {
                2 * self.n_layers * self.seq * self.d_model * 4
            }
            None => 0,
        }
    }

    /// Rebuild a fresh replica after a fault, reusing this one's
    /// `SharedWeights` handle (moved, never cloned — the engine-wide
    /// strong-count invariant survives restarts). The old KV state and
    /// argument vectors drop here; the rebuilt replica allocates fresh
    /// ones, so a panic mid-step can never leak corrupt cache rows into
    /// the next life.
    fn rebuild(self) -> Result<Replica> {
        Replica::new(
            self.rt,
            self.weights,
            self.mode,
            self.kv,
            self.prefill_graph,
            self.decode_graph,
            self.window,
            self.deadline,
            self.metrics,
            self.shared,
            self.index,
        )
    }

    /// Fail the active sessions after a backend fault mid-step: typed
    /// error with the backend cause attached, slots freed, session
    /// spans closed (the supervisor then restarts or retires the
    /// replica).
    fn fail_step(&mut self, e: &Error) {
        let msg = format!("{e:#}");
        let index = self.index;
        for slot in self.slots.iter_mut() {
            if let Some(sl) = slot.take() {
                let _ = sl.tx.send(Err(Error::wrap(
                    format!("decode step failed: {msg}"),
                    Error::engine(EngineError::ReplicaDead { replica: index }),
                )));
                finish_session(&self.metrics, self.deadline, sl.id, sl.queued_at);
            }
        }
    }

    /// Fail every in-flight session with a typed error (used by the
    /// supervisor after a panic or backend fault — callers must never
    /// hang on a dead replica).
    fn fail_all_slots(&mut self) {
        let index = self.index;
        for slot in self.slots.iter_mut() {
            if let Some(sl) = slot.take() {
                let _ = sl
                    .tx
                    .send(Err(Error::engine(EngineError::ReplicaDead { replica: index })));
                finish_session(&self.metrics, self.deadline, sl.id, sl.queued_at);
            }
        }
    }

    /// Deadline enforcement: evict any session whose wall time exceeds
    /// the budget at this decode-step boundary — slot freed, typed
    /// error streamed, `deadline_cancelled` counter + trace instant.
    fn cancel_overdue(&mut self) {
        let Some(dl) = self.deadline else { return };
        let now = Instant::now();
        for slot in self.slots.iter_mut() {
            let overdue = slot
                .as_ref()
                .is_some_and(|sl| now.saturating_duration_since(sl.queued_at) > dl);
            if overdue {
                let sl = slot.take().expect("checked above");
                self.metrics.record_deadline_cancelled();
                tracer::instant(
                    TraceLevel::Engine,
                    "deadline_cancelled",
                    &[("session", sl.id as i64)],
                );
                let _ = sl
                    .tx
                    .send(Err(Error::engine(EngineError::DeadlineExceeded {
                        elapsed_ms: now.saturating_duration_since(sl.queued_at).as_millis() as u64,
                        deadline_ms: dl.as_millis() as u64,
                    })));
                // also closes out the session span and (since elapsed >
                // deadline) bumps the observational overrun counter —
                // cancellations stay a subset of overruns
                finish_session(&self.metrics, self.deadline, sl.id, sl.queued_at);
            }
        }
    }

    /// Pull filter: deregister from the shed registry; shed victims get
    /// their typed error here and never occupy a slot.
    fn on_pull(&self, req: SessionReq) -> Option<SessionReq> {
        self.shared.on_pull(&self.metrics, req)
    }

    /// The serve loop. Returns the exit reason instead of silently
    /// breaking: the supervisor logs it, accounts `replica_exits`, and
    /// decides between restart and shutdown. Backend faults bubble out
    /// as [`ExitReason::Fatal`] (the KV state is suspect after a failed
    /// step); queue disconnects finish in-flight sessions first, then
    /// report [`ExitReason::Shutdown`].
    fn run(&mut self, rx: &mpsc::Receiver<SessionReq>) -> ExitReason {
        loop {
            let free: Vec<usize> = self
                .slots
                .iter()
                .enumerate()
                .filter(|(_, s)| s.is_none())
                .map(|(i, _)| i)
                .collect();
            let idle = free.len() == self.batch;
            let mut pending: Vec<SessionReq> = Vec::new();
            let mut closed = false;
            if idle {
                // block for the first session of a batch; a closed queue
                // with nothing in flight means shutdown
                match rx.recv() {
                    Ok(r) => {
                        if let Some(r) = self.on_pull(r) {
                            pending.push(r);
                        }
                    }
                    Err(mpsc::RecvError) => return ExitReason::Shutdown,
                }
                let deadline = Instant::now() + self.window;
                while pending.len() < free.len() {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => {
                            if let Some(r) = self.on_pull(r) {
                                pending.push(r);
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            closed = true;
                            break;
                        }
                    }
                }
            } else {
                // continuous batching: admit whatever is queued right
                // now, without stalling the sessions mid-decode
                while pending.len() < free.len() {
                    match rx.try_recv() {
                        Ok(r) => {
                            if let Some(r) = self.on_pull(r) {
                                pending.push(r);
                            }
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            closed = true;
                            break;
                        }
                    }
                }
            }
            if !pending.is_empty() {
                if let Err(e) = self.admit(pending, &free) {
                    return ExitReason::Fatal(e);
                }
            }
            self.cancel_overdue();
            if self.slots.iter().any(|s| s.is_some()) {
                if let Err(e) = self.decode_once() {
                    return ExitReason::Fatal(e);
                }
            }
            if closed && self.slots.iter().all(|s| s.is_none()) {
                return ExitReason::Shutdown;
            }
        }
    }

    /// Sample the backend's kernel-pool occupancy into the `pool_busy`
    /// gauge — the worker-saturation counterpart of `slot_occupancy`
    /// (no-op on backends without a thread pool).
    fn record_pool_busy(&self) {
        if let Some(f) = self.rt.pool_occupancy() {
            self.metrics.record_pool_busy(f);
        }
    }

    /// Prefill `pending` sessions into the given free slots and stream
    /// each one's first token. A backend fault fails the admitted batch
    /// and returns `Err` — the supervisor restarts the replica.
    fn admit(&mut self, pending: Vec<SessionReq>, free: &[usize]) -> Result<()> {
        let (b, s, v) = (self.batch, self.seq, self.vocab);
        // run() caps admissions at the free-slot count; n/take(n) only
        // defend against future edits breaking that invariant.
        debug_assert!(pending.len() <= free.len());
        let n = pending.len().min(free.len());
        // Queue accounting: each request leaves the admission queue now.
        let admitted_at = Instant::now();
        for req in &pending {
            self.metrics
                .queue_exit(admitted_at.saturating_duration_since(req.queued_at));
            tracer::span_at(
                TraceLevel::Engine,
                "queue_wait",
                req.queued_at,
                admitted_at,
                &[("session", req.id as i64)],
            );
        }
        // Right-pad: prompt tail at positions 0..len-1 (padding after the
        // prompt is causally invisible to it, so the prefilled rows are
        // bit-identical to running the bare context).
        let mut toks = vec![TOK_SPACE as i32; b * s];
        let mut lens = vec![1i32; b];
        for (i, req) in pending.iter().enumerate().take(n) {
            let p = &req.prompt;
            let take = p.len().min(s);
            let tail = &p[p.len() - take..];
            for (dst, &t) in toks[i * s..i * s + take].iter_mut().zip(tail) {
                *dst = t as i32;
            }
            lens[i] = take.max(1) as i32; // an empty prompt is one separator
        }
        self.prefill_args[self.n_prefix] = HostTensor::i32(toks, vec![b, s]);
        if self.mode == ServingMode::KvCached {
            self.prefill_args[self.n_prefix + 1] = HostTensor::i32(lens.clone(), vec![b]);
        }

        let prompt_tokens: u64 = lens[..n].iter().map(|&l| l as u64).sum();
        let t0 = Instant::now();
        let sw = crate::util::timer::Stopwatch::start();
        let out = match self.rt.run(self.prefill_graph, &self.prefill_args) {
            Ok(o) => o,
            Err(e) => {
                let msg = format!("{e:#}");
                for req in pending {
                    let _ = req.tx.send(Err(Error::wrap(
                        format!("prefill failed: {msg}"),
                        Error::engine(EngineError::ReplicaDead {
                            replica: self.index,
                        }),
                    )));
                }
                return Err(Error::wrap("prefill failed", e));
            }
        };
        let elapsed = sw.elapsed();
        tracer::span_at(
            TraceLevel::Engine,
            "prefill",
            t0,
            Instant::now(),
            &[("batch", n as i64), ("tokens", prompt_tokens as i64)],
        );
        self.metrics.core.inc("batches");
        self.metrics.core.add("batched_requests", n as u64);
        self.metrics.core.observe("prefill_exec", elapsed);
        self.record_pool_busy();
        self.metrics.core.add("prefill_tokens", prompt_tokens);

        let logits = out[0].as_f32().expect("prefill logits are f32");
        let row = s * self.d_model;
        for (i, req) in pending.into_iter().enumerate() {
            if i >= n {
                let _ = req.tx.send(Err(crate::err!("no free batch slot")));
                continue;
            }
            let slot = free[i];
            let len = lens[i] as usize;
            let (tok, logit) = match self.mode {
                ServingMode::KvCached => {
                    // scatter this session's K/V rows into the resident
                    // state (in-place protocol) or the replica slab;
                    // logits are already last-valid-position [B, V]
                    for c in 0..2 * self.n_layers {
                        let src = out[1 + c].as_f32().expect("prefill cache is f32");
                        let rows = &src[i * row..(i + 1) * row];
                        match self.kv_state.as_mut() {
                            Some(st) => st
                                .load_slot(c, slot, rows)
                                .expect("scatter prefill rows into resident cache"),
                            None => {
                                let dst = self.decode_args[self.n_prefix + c]
                                    .as_f32_mut()
                                    .expect("slab cache is f32");
                                dst[slot * row..(slot + 1) * row].copy_from_slice(rows);
                            }
                        }
                    }
                    greedy_argmax(&logits[i * v..(i + 1) * v])
                }
                ServingMode::FullContext => {
                    // lm_logits_all returns [B, S, V]: read position len-1
                    let ti = i * s + len - 1;
                    greedy_argmax(&logits[ti * v..(ti + 1) * v])
                }
            };
            self.metrics.core.inc("sessions");
            self.metrics.record_token_latency(elapsed);
            let mut ctx = Vec::new();
            if self.mode == ServingMode::FullContext {
                let take = req.prompt.len().min(s);
                ctx = req.prompt[req.prompt.len() - take..].to_vec();
                if ctx.is_empty() {
                    ctx.push(TOK_SPACE);
                }
                ctx.push(tok);
            }
            let alive = req
                .tx
                .send(Ok(InferenceResponse {
                    next_token: tok,
                    logit,
                }))
                .is_ok();
            let emitted_at = Instant::now();
            self.metrics
                .record_ttft(emitted_at.saturating_duration_since(req.queued_at));
            let remaining = req.max_tokens.saturating_sub(1);
            if alive && remaining > 0 && len < s {
                self.slots[slot] = Some(Slot {
                    id: req.id,
                    len,
                    last: tok,
                    remaining,
                    ctx,
                    queued_at: req.queued_at,
                    last_emit: emitted_at,
                    tx: req.tx,
                });
            } else {
                // budget spent, cache full, or the session was dropped —
                // closing the channel ends the stream
                finish_session(&self.metrics, self.deadline, req.id, req.queued_at);
            }
        }
        Ok(())
    }

    /// One decode step over every active slot. A backend fault fails
    /// the active sessions and returns `Err` for the supervisor.
    fn decode_once(&mut self) -> Result<()> {
        match self.mode {
            ServingMode::KvCached => self.decode_once_kv(),
            ServingMode::FullContext => self.decode_once_full(),
        }
    }

    /// Full-context fallback step: re-execute every active context
    /// through `lm_logits_all` and stream one token per slot.
    fn decode_once_full(&mut self) -> Result<()> {
        let (b, s, v) = (self.batch, self.seq, self.vocab);
        let mut toks = vec![TOK_SPACE as i32; b * s];
        let mut active = 0usize;
        for (i, slot) in self.slots.iter().enumerate() {
            if let Some(sl) = slot {
                for (j, &t) in sl.ctx.iter().enumerate().take(s) {
                    toks[i * s + j] = t as i32;
                }
                active += 1;
            }
        }
        self.prefill_args[self.n_prefix] = HostTensor::i32(toks, vec![b, s]);
        self.metrics.record_occupancy(active, b);

        let t0 = Instant::now();
        let sw = crate::util::timer::Stopwatch::start();
        let out = match self.rt.run(self.decode_graph, &self.prefill_args) {
            Ok(o) => o,
            Err(e) => {
                self.fail_step(&e);
                return Err(Error::wrap("decode step failed", e));
            }
        };
        let elapsed = sw.elapsed();
        tracer::span_at(
            TraceLevel::Engine,
            "decode_step",
            t0,
            Instant::now(),
            &[("active", active as i64)],
        );
        self.metrics.core.inc("decode_steps");
        self.metrics.core.add("decode_tokens", active as u64);
        self.metrics.core.observe("decode_step_exec", elapsed);

        let logits = out[0].as_f32().expect("logits are f32");
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if let Some(sl) = slot.as_mut() {
                // the next token lives at row position ctx.len()-1 == len
                let ti = i * s + sl.len;
                let (tok, logit) = greedy_argmax(&logits[ti * v..(ti + 1) * v]);
                sl.len += 1;
                sl.last = tok;
                sl.ctx.push(tok);
                sl.remaining -= 1;
                self.metrics.record_token_latency(elapsed);
                let alive = sl
                    .tx
                    .send(Ok(InferenceResponse {
                        next_token: tok,
                        logit,
                    }))
                    .is_ok();
                let emitted_at = Instant::now();
                self.metrics
                    .record_inter_token(emitted_at.saturating_duration_since(sl.last_emit));
                sl.last_emit = emitted_at;
                if !alive || sl.remaining == 0 || sl.len >= s {
                    finish_session(&self.metrics, self.deadline, sl.id, sl.queued_at);
                    *slot = None;
                }
            }
        }
        Ok(())
    }

    /// One incremental KV-cached decode step over every active slot.
    fn decode_once_kv(&mut self) -> Result<()> {
        let (b, s, v) = (self.batch, self.seq, self.vocab);
        let mut token = vec![0i32; b];
        let mut pos = vec![-1i32; b];
        let mut active = 0usize;
        for (i, slot) in self.slots.iter().enumerate() {
            if let Some(sl) = slot {
                token[i] = sl.last as i32;
                pos[i] = sl.len as i32;
                active += 1;
            }
        }
        let nt = self.decode_args.len();
        self.decode_args[nt - 2] = HostTensor::i32(token, vec![b]);
        self.decode_args[nt - 1] = HostTensor::i32(pos, vec![b]);
        self.metrics.record_occupancy(active, b);

        let t0 = Instant::now();
        let sw = crate::util::timer::Stopwatch::start();
        let run = match self.kv_state.as_mut() {
            // in-place: the caches stay resident in the backend state;
            // only [prefix.., token, pos] crosses the ABI and only the
            // logits come back
            Some(st) => self
                .rt
                .run_decode_step_inplace(self.decode_graph, st.as_mut(), &self.decode_args),
            None => self.rt.run(self.decode_graph, &self.decode_args),
        };
        let out = match run {
            Ok(o) => o,
            Err(e) => {
                self.fail_step(&e);
                return Err(Error::wrap("decode step failed", e));
            }
        };
        let elapsed = sw.elapsed();
        tracer::span_at(
            TraceLevel::Engine,
            "decode_step",
            t0,
            Instant::now(),
            &[("active", active as i64)],
        );
        self.metrics.core.inc("decode_steps");
        self.metrics.core.add("decode_tokens", active as u64);
        self.metrics.core.observe("decode_step_exec", elapsed);
        self.record_pool_busy();

        // clone path: move the updated caches back into the persistent args
        let mut outs = out.into_iter();
        let logits_t = outs.next().expect("decode logits");
        if self.kv_state.is_none() {
            for c in 0..2 * self.n_layers {
                self.decode_args[self.n_prefix + c] = outs.next().expect("decode cache");
            }
        }
        let logits = logits_t.as_f32().expect("decode logits are f32");
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if let Some(sl) = slot.as_mut() {
                let (tok, logit) = greedy_argmax(&logits[i * v..(i + 1) * v]);
                sl.len += 1;
                sl.last = tok;
                sl.remaining -= 1;
                self.metrics.record_token_latency(elapsed);
                let alive = sl
                    .tx
                    .send(Ok(InferenceResponse {
                        next_token: tok,
                        logit,
                    }))
                    .is_ok();
                let emitted_at = Instant::now();
                self.metrics
                    .record_inter_token(emitted_at.saturating_duration_since(sl.last_emit));
                sl.last_emit = emitted_at;
                if !alive || sl.remaining == 0 || sl.len >= s {
                    finish_session(&self.metrics, self.deadline, sl.id, sl.queued_at);
                    *slot = None;
                }
            }
        }
        Ok(())
    }
}

/// **Deprecated** single-shot service facade, kept for compatibility:
/// a thin shim over [`Engine`] (one replica, one-token sessions). New
/// code should use [`Engine::session`] / [`Engine::generate`] directly —
/// they expose streaming, KV-cached decoding and continuous batching
/// that this request/response API cannot.
pub struct BatchedLm {
    engine: Engine,
    /// The engine's shared counter registry (`batches`,
    /// `batched_requests`, ... — see [`EngineMetrics`]).
    pub metrics: Arc<Metrics>,
}

impl BatchedLm {
    /// Start the service over a fixed parameter set. `params` must match
    /// the dense ABI prefix (16 f32 tensors).
    pub fn start(
        rt: Arc<Runtime>,
        params: Vec<HostTensor>,
        cfg: ServiceConfig,
    ) -> Result<BatchedLm> {
        let engine = Engine::start(
            rt,
            params,
            EngineConfig {
                window: cfg.window,
                ..EngineConfig::default()
            },
        )?;
        let metrics = engine.metrics.core.clone();
        Ok(BatchedLm { engine, metrics })
    }

    /// The underlying engine (escape hatch for migration).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Submit a request; blocks until the engine answers.
    pub fn infer(&self, prompt: &[u8]) -> Result<InferenceResponse> {
        self.infer_async(prompt)?
            .recv()
            .map_err(|_| crate::err!("service dropped request"))?
    }

    /// Submit asynchronously; returns the response receiver (a one-token
    /// session's stream).
    pub fn infer_async(&self, prompt: &[u8]) -> Result<mpsc::Receiver<Result<InferenceResponse>>> {
        self.engine.submit(prompt, 1)
    }

    /// Greedy-decode `n` tokens from a prompt.
    pub fn generate(&self, prompt: &[u8], n: usize) -> Result<Vec<u8>> {
        self.engine.generate(prompt, n)
    }
}

// Runtime-dependent behaviour is covered by
// rust/tests/coordinator_integration.rs and rust/tests/runtime_e2e.rs;
// unit tests here cover the pure pieces.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_window() {
        assert_eq!(ServiceConfig::default().window, Duration::from_millis(5));
        let e = EngineConfig::default();
        assert_eq!(e.replicas, 1);
        assert_eq!(e.window, Duration::from_millis(5));
        assert_eq!(e.max_session_tokens, usize::MAX);
        // fault-tolerance defaults: unbounded queue (pre-existing
        // behaviour), generous liveness bound, reject-new shedding,
        // bounded restarts
        assert_eq!(e.max_queue_depth, None);
        assert_eq!(e.admission_timeout, Duration::from_secs(60));
        assert_eq!(e.shed_policy, ShedPolicy::Reject);
        assert_eq!(e.max_replica_restarts, 2);
        assert_eq!(e.restart_backoff, Duration::from_millis(10));
    }

    #[test]
    fn shed_registry_marks_and_delivers_oldest() {
        let shared = EngineShared::new(2);
        let metrics = EngineMetrics::new();
        shared.register(10);
        shared.register(11);
        // oldest = smallest id
        assert_eq!(shared.shed_oldest(5, 4), Some(10));
        // pulling the victim delivers Overloaded on its channel
        let (tx, rx) = mpsc::channel();
        let victim = SessionReq {
            id: 10,
            prompt: vec![1],
            max_tokens: 1,
            queued_at: Instant::now(),
            tx,
        };
        assert!(shared.on_pull(&metrics, victim).is_none());
        let err = rx.recv().unwrap().unwrap_err();
        assert_eq!(
            err.engine_error(),
            Some(EngineError::Overloaded { depth: 5, limit: 4 })
        );
        // the un-shed request passes through
        let (tx, _rx) = mpsc::channel();
        let ok = SessionReq {
            id: 11,
            prompt: vec![1],
            max_tokens: 1,
            queued_at: Instant::now(),
            tx,
        };
        assert!(shared.on_pull(&metrics, ok).is_some());
        // nothing left to shed
        assert_eq!(shared.shed_oldest(5, 4), None);
    }

    #[test]
    fn panic_message_extracts_payloads() {
        let p = std::panic::catch_unwind(|| panic!("boom {}", 7)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "boom 7");
        let p = std::panic::catch_unwind(|| std::panic::panic_any(42i32)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "non-string panic payload");
    }

    #[test]
    fn request_response_types() {
        let r = InferenceResponse {
            next_token: 3,
            logit: 0.5,
        };
        assert_eq!(
            r,
            InferenceResponse {
                next_token: 3,
                logit: 0.5
            }
        );
    }

    #[test]
    fn greedy_argmax_takes_last_max_on_ties() {
        assert_eq!(greedy_argmax(&[0.0, 2.0, 2.0, 1.0]), (2, 2.0));
        assert_eq!(greedy_argmax(&[-1.0]), (0, -1.0));
    }

    #[test]
    fn engine_params_from_dense_vec() {
        let p: EngineParams = vec![HostTensor::scalar_u32(1)].into();
        match p {
            EngineParams::Dense(v) => assert_eq!(v.len(), 1),
            _ => panic!("expected dense"),
        }
    }
}
