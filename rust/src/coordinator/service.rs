//! Batched inference service: a request router + dynamic batcher over the
//! AOT'd `lm_logits_last` graph (the shape of a vLLM-style router, scaled
//! to this testbed: one model replica, fixed-shape batches).
//!
//! Requests carry a prompt (≤ seq_len tokens); the batcher collects up to
//! the graph's batch size B within a deadline window, left-aligns pads
//! with the corpus separator token, executes one XLA call, and answers
//! every request with its greedy next token + logit. Invariants
//! (integration-tested): every request answered exactly once; batch size
//! never exceeds B; a lone request is answered within ~the window.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::Result;

use super::metrics::Metrics;
use crate::models::corpus::TOK_SPACE;
use crate::runtime::{HostTensor, Runtime};

/// One inference request.
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub prompt: Vec<u8>,
}

/// The service's answer.
#[derive(Clone, Debug, PartialEq)]
pub struct InferenceResponse {
    /// Greedy argmax token at the last position.
    pub next_token: u8,
    /// Its logit value.
    pub logit: f32,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Max time a request waits for batch-mates.
    pub window: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            window: Duration::from_millis(5),
        }
    }
}

type Pending = (InferenceRequest, mpsc::Sender<Result<InferenceResponse>>);

/// Handle to the running service.
pub struct BatchedLm {
    tx: Option<mpsc::Sender<Pending>>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl BatchedLm {
    /// Start the service thread over a fixed parameter set. `params` must
    /// match the `lm_logits_last` ABI prefix (16 f32 tensors).
    pub fn start(
        rt: Arc<Runtime>,
        params: Vec<HostTensor>,
        cfg: ServiceConfig,
    ) -> Result<BatchedLm> {
        let gm = rt.meta.graph("lm_logits_last")?;
        if params.len() + 1 != gm.args.len() {
            return Err(crate::err!(
                "lm_logits_last wants {} params, got {}",
                gm.args.len() - 1,
                params.len()
            ));
        }
        // Force compilation/warm-up up-front so the first request isn't slow.
        rt.prepare("lm_logits_last")?;
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<Pending>();
        let m = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("batcher".into())
            .spawn(move || Self::worker_loop(rt, params, cfg, rx, m))?;
        Ok(BatchedLm {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
        })
    }

    /// Submit a request; blocks until the batcher answers.
    pub fn infer(&self, prompt: &[u8]) -> Result<InferenceResponse> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("service running")
            .send((
                InferenceRequest {
                    prompt: prompt.to_vec(),
                },
                rtx,
            ))
            .map_err(|_| crate::err!("service stopped"))?;
        rrx.recv()
            .map_err(|_| crate::err!("service dropped request"))?
    }

    /// Submit asynchronously; returns the response receiver.
    pub fn infer_async(&self, prompt: &[u8]) -> Result<mpsc::Receiver<Result<InferenceResponse>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("service running")
            .send((
                InferenceRequest {
                    prompt: prompt.to_vec(),
                },
                rtx,
            ))
            .map_err(|_| crate::err!("service stopped"))?;
        Ok(rrx)
    }

    fn worker_loop(
        rt: Arc<Runtime>,
        params: Vec<HostTensor>,
        cfg: ServiceConfig,
        rx: mpsc::Receiver<Pending>,
        metrics: Arc<Metrics>,
    ) {
        let b = rt.meta.model.batch;
        loop {
            // block for the first request of a batch
            let first = match rx.recv() {
                Ok(p) => p,
                Err(_) => break, // all senders dropped: shut down
            };
            let mut batch = vec![first];
            let deadline = Instant::now() + cfg.window;
            while batch.len() < b {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(p) => batch.push(p),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            metrics.inc("batches");
            metrics.add("batched_requests", batch.len() as u64);
            let sw = crate::util::timer::Stopwatch::start();
            let result = Self::run_batch(&rt, &params, &batch);
            metrics.observe("batch_exec", sw.elapsed());
            match result {
                Ok(responses) => {
                    for ((_, rtx), resp) in batch.into_iter().zip(responses) {
                        let _ = rtx.send(Ok(resp));
                    }
                }
                Err(e) => {
                    let msg = format!("{e}");
                    for (_, rtx) in batch {
                        let _ = rtx.send(Err(crate::err!("{msg}")));
                    }
                }
            }
        }
    }

    fn run_batch(
        rt: &Runtime,
        params: &[HostTensor],
        batch: &[Pending],
    ) -> Result<Vec<InferenceResponse>> {
        let m = &rt.meta.model;
        let (bsz, seq, vocab) = (m.batch, m.seq_len, m.vocab);
        // Left-align pad with the separator token so every prompt *ends*
        // at the final position (the graph returns last-position logits).
        let mut toks = vec![TOK_SPACE as i32; bsz * seq];
        for (i, (req, _)) in batch.iter().enumerate() {
            let p = &req.prompt;
            let take = p.len().min(seq);
            let tail = &p[p.len() - take..];
            let row = &mut toks[i * seq..(i + 1) * seq];
            for (dst, &t) in row[seq - take..].iter_mut().zip(tail) {
                *dst = t as i32;
            }
        }
        let mut args: Vec<HostTensor> = params.to_vec();
        args.push(HostTensor::i32(toks, vec![bsz, seq]));
        let out = rt.run("lm_logits_last", &args)?;
        let logits = out[0].as_f32()?;
        let mut responses = Vec::with_capacity(batch.len());
        for i in 0..batch.len() {
            let row = &logits[i * vocab..(i + 1) * vocab];
            let (arg, max) = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            responses.push(InferenceResponse {
                next_token: arg as u8,
                logit: *max,
            });
        }
        Ok(responses)
    }

    /// Greedy-decode `n` tokens from a prompt (serving example / fine-tune
    /// task evaluation).
    pub fn generate(&self, prompt: &[u8], n: usize) -> Result<Vec<u8>> {
        let mut ctx = prompt.to_vec();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let resp = self.infer(&ctx)?;
            out.push(resp.next_token);
            ctx.push(resp.next_token);
        }
        Ok(out)
    }
}

impl Drop for BatchedLm {
    fn drop(&mut self) {
        // close the channel, then join the worker
        self.tx.take();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

// Runtime-dependent behaviour is covered by
// rust/tests/coordinator_integration.rs; unit tests here cover padding.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_window() {
        assert_eq!(ServiceConfig::default().window, Duration::from_millis(5));
    }

    #[test]
    fn request_response_types() {
        let r = InferenceResponse {
            next_token: 3,
            logit: 0.5,
        };
        assert_eq!(
            r,
            InferenceResponse {
                next_token: 3,
                logit: 0.5
            }
        );
    }
}
