//! Layer-3 coordinator: the system around the algorithm.
//!
//! - [`scheduler`]: multithreaded tensor-quantization pipeline (work
//!   queue with backpressure, deterministic result order)
//! - [`service`]: the session-based serving engine — KV-cached
//!   incremental decoding behind [`Engine`]/[`DecodeSession`], with
//!   multi-replica continuous batching (plus the deprecated
//!   [`BatchedLm`] single-shot shim)
//! - [`metrics`]: counters/latency histograms shared by both, plus the
//!   engine's [`EngineMetrics`]

pub mod metrics;
pub mod scheduler;
pub mod service;

pub use crate::error::EngineError;
pub use metrics::{EngineMetrics, LatencyStats, Metrics};
pub use scheduler::{QuantJob, QuantScheduler};
pub use service::{
    greedy_argmax, BatchedLm, DecodeSession, Engine, EngineConfig, EngineMemoryProfile,
    EngineParams, InferenceResponse, ServiceConfig, SharedWeights, ShedPolicy,
};
