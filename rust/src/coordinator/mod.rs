//! Layer-3 coordinator: the system around the algorithm.
//!
//! - [`scheduler`]: multithreaded tensor-quantization pipeline (work
//!   queue with backpressure, deterministic result order)
//! - [`service`]: batched inference service — request router + dynamic
//!   batcher over the AOT'd `lm_logits_last` graph (vLLM-router-shaped,
//!   scaled to this testbed)
//! - [`metrics`]: counters/latency histograms shared by both

pub mod metrics;
pub mod scheduler;
pub mod service;

pub use metrics::Metrics;
pub use scheduler::{QuantJob, QuantScheduler};
pub use service::{BatchedLm, InferenceRequest, ServiceConfig};
