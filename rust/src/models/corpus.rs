//! Deterministic synthetic corpus: a small formal language with enough
//! structure (copy dependencies, nesting, local n-gram statistics) that a
//! small LM learns a sharply non-uniform distribution — which is exactly
//! what makes held-out perplexity sensitive to weight quantization noise
//! (our stand-in for WikiText-2 / LAMBADA; DESIGN.md §3).
//!
//! Vocabulary (64 tokens): 26 letters, 10 digits, and punctuation /
//! structure tokens. Sentences are drawn from templates:
//!
//! - assignment:  `Kab = ( d1 + d2 ) ;`   — arithmetic with a value echo
//! - recall:      `Kab -> d1 d2 ;`         — the key's digits echoed later
//! - nesting:     `[ [ x y ] z ]`-style balanced brackets, depth ≤ 4
//!
//! Key-recall pairs force long-range dependencies; nesting forces a stack;
//! digit echoes give deterministic continuations a trained model predicts
//! with high confidence (and a quantized model measurably less so).

use crate::util::rng::Pcg64;

/// Vocabulary size (matches the AOT'd model's `vocab`).
pub const VOCAB: usize = 64;

// token layout
const LETTER0: u8 = 0; // 26 letters: 0..26
const DIGIT0: u8 = 26; // 10 digits: 26..36
pub const TOK_EQ: u8 = 36;
pub const TOK_ARROW: u8 = 37;
pub const TOK_SEMI: u8 = 38;
pub const TOK_LPAR: u8 = 39;
pub const TOK_RPAR: u8 = 40;
pub const TOK_PLUS: u8 = 41;
pub const TOK_LBRK: u8 = 42;
pub const TOK_RBRK: u8 = 43;
pub const TOK_KEY: u8 = 44;
pub const TOK_SPACE: u8 = 45; // separator
pub const TOK_FN: u8 = 46;
pub const TOK_COLON: u8 = 47;
// 48..64 reserved / rare filler tokens

/// A generated token stream with deterministic seeding.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub tokens: Vec<u8>,
}

impl Corpus {
    /// Generate `n_tokens` of corpus text from `seed`.
    pub fn generate(n_tokens: usize, seed: u64) -> Corpus {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut out = Vec::with_capacity(n_tokens + 64);
        // live key table: key letters -> 2 digits
        let mut keys: Vec<(u8, u8, [u8; 2])> = Vec::new();
        while out.len() < n_tokens {
            match rng.next_below(10) {
                0..=3 => Self::emit_assignment(&mut rng, &mut out, &mut keys),
                4..=6 => Self::emit_recall(&mut rng, &mut out, &keys),
                7..=8 => Self::emit_nesting(&mut rng, &mut out, 0),
                _ => Self::emit_fn(&mut rng, &mut out),
            }
            out.push(TOK_SPACE);
        }
        out.truncate(n_tokens);
        Corpus { tokens: out }
    }

    fn letter(rng: &mut Pcg64) -> u8 {
        LETTER0 + rng.next_below(26) as u8
    }

    fn digit(rng: &mut Pcg64) -> u8 {
        DIGIT0 + rng.next_below(10) as u8
    }

    /// `K a b = ( d1 + d2 ) ;` and remember (a, b) -> digits.
    fn emit_assignment(
        rng: &mut Pcg64,
        out: &mut Vec<u8>,
        keys: &mut Vec<(u8, u8, [u8; 2])>,
    ) {
        let (a, b) = (Self::letter(rng), Self::letter(rng));
        let d = [Self::digit(rng), Self::digit(rng)];
        out.extend_from_slice(&[TOK_KEY, a, b, TOK_EQ, TOK_LPAR, d[0], TOK_PLUS, d[1], TOK_RPAR, TOK_SEMI]);
        // Reassignment replaces the old entry (recalls must always echo
        // the *most recent* assignment), and the live-key table stays
        // small so assignment->recall distances fit inside the model's
        // 64-token context window (recall must be *learnable* from
        // context for the induction tasks to be sound).
        keys.retain(|&(ka, kb, _)| (ka, kb) != (a, b));
        if keys.len() >= 3 {
            keys.remove(0);
        }
        keys.push((a, b, d));
    }

    /// `K a b -> d1 d2 ;` — echoes a previously assigned key's digits.
    fn emit_recall(rng: &mut Pcg64, out: &mut Vec<u8>, keys: &[(u8, u8, [u8; 2])]) {
        if keys.is_empty() {
            return;
        }
        let (a, b, d) = keys[rng.next_below(keys.len() as u64) as usize];
        out.extend_from_slice(&[TOK_KEY, a, b, TOK_ARROW, d[0], d[1], TOK_SEMI]);
    }

    /// Balanced brackets with letters inside, recursion depth ≤ 4.
    fn emit_nesting(rng: &mut Pcg64, out: &mut Vec<u8>, depth: usize) {
        out.push(TOK_LBRK);
        let items = 1 + rng.next_below(3);
        for _ in 0..items {
            if depth < 3 && rng.next_below(3) == 0 {
                Self::emit_nesting(rng, out, depth + 1);
            } else {
                out.push(Self::letter(rng));
            }
        }
        out.push(TOK_RBRK);
    }

    /// `F n : [ ... ]` — bracket sequence with depth matching the digit
    /// (the "code generation" fine-tune task shape).
    fn emit_fn(rng: &mut Pcg64, out: &mut Vec<u8>) {
        let n = 1 + rng.next_below(3) as usize;
        out.extend_from_slice(&[TOK_FN, DIGIT0 + n as u8, TOK_COLON]);
        for _ in 0..n {
            out.push(TOK_LBRK);
        }
        out.push(Self::letter(rng));
        for _ in 0..n {
            out.push(TOK_RBRK);
        }
    }

    /// Deterministic train/eval split: the first `frac` of the stream is
    /// training data, the rest held out.
    pub fn split(&self, frac: f64) -> (&[u8], &[u8]) {
        let cut = (self.tokens.len() as f64 * frac) as usize;
        self.tokens.split_at(cut)
    }

    /// Iterate `[batch, seq]` i32 batches over a token range (sequential
    /// windows, wrapping). `step` indexes the batch deterministically.
    pub fn batch(&self, range: &[u8], batch: usize, seq: usize, step: usize) -> Vec<i32> {
        assert!(range.len() > seq + 1, "corpus slice too small");
        let mut out = Vec::with_capacity(batch * seq);
        let stride = (range.len() - seq - 1) / batch.max(1);
        for b in 0..batch {
            let start = (b * stride + step * seq) % (range.len() - seq);
            for s in 0..seq {
                out.push(range[start + s] as i32);
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Corpus::generate(10_000, 7);
        let b = Corpus::generate(10_000, 7);
        assert_eq!(a.tokens, b.tokens);
        let c = Corpus::generate(10_000, 8);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn tokens_in_vocab() {
        let c = Corpus::generate(50_000, 1);
        assert!(c.tokens.iter().all(|&t| (t as usize) < VOCAB));
        assert_eq!(c.len(), 50_000);
    }

    #[test]
    fn has_structure_not_uniform() {
        let c = Corpus::generate(100_000, 2);
        let mut counts = [0usize; VOCAB];
        for &t in &c.tokens {
            counts[t as usize] += 1;
        }
        // structural tokens are much more common than any single letter
        assert!(counts[TOK_SPACE as usize] > counts[3]);
        // reserved tokens never appear
        assert!(counts[50..].iter().all(|&c| c == 0));
        // entropy is well below uniform (ln 64 = 4.16 nats)
        let n = c.len() as f64;
        let h: f64 = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum();
        assert!(h < 3.9, "unigram entropy {h}");
    }

    #[test]
    fn recall_pairs_are_consistent() {
        // every `K a b -> d1 d2` must match the most recent `K a b = (x+y)`
        let c = Corpus::generate(200_000, 3);
        let t = &c.tokens;
        let mut last: std::collections::HashMap<(u8, u8), (u8, u8)> =
            std::collections::HashMap::new();
        let mut checked = 0;
        let mut i = 0;
        while i + 9 < t.len() {
            if t[i] == TOK_KEY && t[i + 3] == TOK_EQ {
                last.insert((t[i + 1], t[i + 2]), (t[i + 5], t[i + 7]));
                i += 10;
            } else if t[i] == TOK_KEY && t[i + 3] == TOK_ARROW {
                if let Some(&(d1, d2)) = last.get(&(t[i + 1], t[i + 2])) {
                    assert_eq!((t[i + 4], t[i + 5]), (d1, d2), "recall at {i}");
                    checked += 1;
                }
                i += 7;
            } else {
                i += 1;
            }
        }
        assert!(checked > 100, "only {checked} recalls checked");
    }

    #[test]
    fn brackets_balanced() {
        let c = Corpus::generate(100_000, 4);
        let mut depth: i64 = 0;
        for &t in &c.tokens {
            if t == TOK_LBRK {
                depth += 1;
            } else if t == TOK_RBRK {
                depth -= 1;
            }
            // truncation can leave the final bracket open; never negative
            // beyond a truncated tail
        }
        assert!(depth.abs() <= 8, "unbalanced depth {depth}");
    }

    #[test]
    fn batches_shape_and_range() {
        let c = Corpus::generate(50_000, 5);
        let (train, eval) = c.split(0.9);
        assert!(train.len() > eval.len());
        let b = c.batch(train, 16, 64, 0);
        assert_eq!(b.len(), 16 * 64);
        assert!(b.iter().all(|&t| t >= 0 && t < VOCAB as i32));
        // different steps give different batches
        let b2 = c.batch(train, 16, 64, 1);
        assert_ne!(b, b2);
    }
}
