//! Named parameter sets + `.wbin` persistence.
//!
//! A [`ParamSet`] is the rust-side view of the model's flat parameter list
//! in `meta.json` order. The `.wbin` format is a minimal self-describing
//! binary container (magic, count, then per-tensor name/shape/f32 data,
//! little-endian) used to cache the trained model between benches.

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Context, Result};
use crate::runtime::{GraphMeta, HostTensor};

const MAGIC: &[u8; 8] = b"BOF4WBIN";

/// An ordered, named collection of f32 tensors.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSet {
    pub entries: Vec<(String, Vec<usize>, Vec<f32>)>,
}

impl ParamSet {
    pub fn new() -> Self {
        ParamSet {
            entries: Vec::new(),
        }
    }

    /// Build from runtime tensors using the first `n` args of a graph ABI
    /// for names/shapes.
    pub fn from_tensors(gm: &GraphMeta, tensors: &[HostTensor]) -> Result<ParamSet> {
        let mut entries = Vec::new();
        for (t, m) in tensors.iter().zip(&gm.args) {
            entries.push((m.name.clone(), m.shape.clone(), t.as_f32()?.to_vec()));
        }
        Ok(ParamSet { entries })
    }

    pub fn get(&self, name: &str) -> Option<(&[usize], &[f32])> {
        self.entries
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, s, d)| (s.as_slice(), d.as_slice()))
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Vec<f32>> {
        self.entries
            .iter_mut()
            .find(|(n, _, _)| n == name)
            .map(|(_, _, d)| d)
    }

    /// Convert to HostTensors in stored order.
    pub fn to_tensors(&self) -> Vec<HostTensor> {
        self.entries
            .iter()
            .map(|(_, s, d)| HostTensor::f32(d.clone(), s.clone()))
            .collect()
    }

    pub fn n_params(&self) -> usize {
        self.entries.iter().map(|(_, _, d)| d.len()).sum()
    }

    /// Save in `.wbin` format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        for (name, shape, data) in &self.entries {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(shape.len() as u32).to_le_bytes())?;
            for &d in shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            f.write_all(&(data.len() as u64).to_le_bytes())?;
            // little-endian f32s
            // SAFETY: viewing the f32 buffer as its raw bytes — exact
            // length `len * 4`, borrow scoped to the write below.
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    /// Load from `.wbin`.
    pub fn load(path: &Path) -> Result<ParamSet> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(crate::err!("{path:?}: bad magic"));
        }
        let mut u32buf = [0u8; 4];
        let mut u64buf = [0u8; 8];
        f.read_exact(&mut u32buf)?;
        let count = u32::from_le_bytes(u32buf) as usize;
        let mut entries = Vec::with_capacity(count);
        for _ in 0..count {
            f.read_exact(&mut u32buf)?;
            let name_len = u32::from_le_bytes(u32buf) as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            f.read_exact(&mut u32buf)?;
            let rank = u32::from_le_bytes(u32buf) as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                f.read_exact(&mut u64buf)?;
                shape.push(u64::from_le_bytes(u64buf) as usize);
            }
            f.read_exact(&mut u64buf)?;
            let n = u64::from_le_bytes(u64buf) as usize;
            if n != shape.iter().product::<usize>() {
                return Err(crate::err!("{path:?}: shape/data mismatch"));
            }
            let mut data = vec![0f32; n];
            // SAFETY: filling the freshly-allocated f32 buffer through
            // its byte view — exact length `n * 4`, any bit pattern is a
            // valid f32, and the borrow ends at the read below.
            let bytes = unsafe {
                std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, n * 4)
            };
            f.read_exact(bytes)?;
            entries.push((String::from_utf8(name)?, shape, data));
        }
        Ok(ParamSet { entries })
    }
}

impl Default for ParamSet {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ParamSet {
        ParamSet {
            entries: vec![
                ("embed".into(), vec![4, 2], (0..8).map(|i| i as f32).collect()),
                ("head".into(), vec![3], vec![1.5, -2.5, 0.0]),
            ],
        }
    }

    #[test]
    fn roundtrip_wbin() {
        let dir = std::env::temp_dir().join("bof4_test_wbin");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("params.wbin");
        let p = sample();
        p.save(&path).unwrap();
        let q = ParamSet::load(&path).unwrap();
        assert_eq!(p, q);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn accessors() {
        let p = sample();
        assert_eq!(p.n_params(), 11);
        let (shape, data) = p.get("head").unwrap();
        assert_eq!(shape, &[3]);
        assert_eq!(data[1], -2.5);
        assert!(p.get("missing").is_none());
        let t = p.to_tensors();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].shape(), &[4, 2]);
    }

    #[test]
    fn rejects_corrupt_file() {
        let dir = std::env::temp_dir().join("bof4_test_wbin2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.wbin");
        std::fs::write(&path, b"NOTMAGIC------").unwrap();
        assert!(ParamSet::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
