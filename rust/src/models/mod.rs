//! Model-side substrates: the synthetic training corpus, parameter
//! containers + persistence, and synthetic LLM-like weight generation for
//! the quantization-error experiments.
//!
//! - [`corpus`]: a deterministic formal-language corpus (the pre-training
//!   and evaluation data for the in-repo LM; DESIGN.md §3 Substitutions)
//! - [`params`]: named parameter sets matching `artifacts/meta.json` order,
//!   with a `.wbin` binary store
//! - [`synthetic`]: LLM-shaped weight tensors (near-Gaussian blocks with
//!   sparse super-Gaussian outliers) standing in for Llama/Qwen/Mistral
//!   checkpoints in Tables 1/9

pub mod corpus;
pub mod params;
pub mod synthetic;

pub use corpus::Corpus;
pub use params::ParamSet;
pub use synthetic::SyntheticModel;
