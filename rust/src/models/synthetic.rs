//! Synthetic LLM-like weight sets — the stand-in for Llama/Qwen/Mistral
//! checkpoints in the Table-1/9 experiments (DESIGN.md §3 Substitutions).
//!
//! What matters for quantizer *ordering* is the distribution shape the
//! paper itself identifies (App. E.1): per-row near-Gaussian weights whose
//! scale varies across tensors, with a sparse set of super-Gaussian
//! outliers concentrated in a few blocks ("most rows ... are very close to
//! Gaussian, whereas only some blocks follow a super-Gaussian distribution
//! with a small number of large-magnitude outlier weights", also Dettmers
//! et al.). We synthesize exactly that, per named tensor, with
//! deterministic seeding.

use crate::util::rng::Pcg64;

/// Description of one synthetic weight tensor.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// Per-tensor base std (LLM layers differ by ~an order of magnitude).
    pub scale: f32,
}

/// A synthetic "LLM checkpoint": named tensors with LLM-like statistics.
#[derive(Clone, Debug)]
pub struct SyntheticModel {
    pub name: String,
    pub tensors: Vec<(TensorSpec, Vec<f32>)>,
}

/// Outlier-injection profile.
#[derive(Clone, Copy, Debug)]
pub struct OutlierProfile {
    /// Fraction of weights turned into outliers (e.g. 4e-5).
    pub fraction: f64,
    /// Outlier magnitude multiple of the tensor scale (e.g. 12–30×).
    pub magnitude: f32,
}

impl Default for OutlierProfile {
    fn default() -> Self {
        OutlierProfile {
            fraction: 5e-5,
            magnitude: 18.0,
        }
    }
}

impl SyntheticModel {
    /// A transformer-shaped tensor inventory (d_model × multiples), scaled
    /// like 1/sqrt(fan_in) layers plus embeddings; `layers` controls size.
    pub fn llm_like(name: &str, d_model: usize, layers: usize, seed: u64) -> SyntheticModel {
        let mut specs = Vec::new();
        specs.push(TensorSpec {
            name: "embed".into(),
            rows: 4 * d_model, // vocab stand-in
            cols: d_model,
            scale: 0.02,
        });
        for l in 0..layers {
            let s_attn = 1.0 / (d_model as f32).sqrt();
            let s_mlp = 1.0 / (2.0 * d_model as f32).sqrt();
            specs.push(TensorSpec {
                name: format!("l{l}.wqkv"),
                rows: d_model,
                cols: 3 * d_model,
                scale: s_attn,
            });
            specs.push(TensorSpec {
                name: format!("l{l}.wo"),
                rows: d_model,
                cols: d_model,
                scale: s_attn * 0.7,
            });
            specs.push(TensorSpec {
                name: format!("l{l}.win"),
                rows: d_model,
                cols: 4 * d_model,
                scale: s_attn,
            });
            specs.push(TensorSpec {
                name: format!("l{l}.wout"),
                rows: 4 * d_model,
                cols: d_model,
                scale: s_mlp,
            });
        }
        Self::from_specs(name, specs, seed, OutlierProfile::default())
    }

    /// Generate from explicit specs.
    pub fn from_specs(
        name: &str,
        specs: Vec<TensorSpec>,
        seed: u64,
        outliers: OutlierProfile,
    ) -> SyntheticModel {
        let mut tensors = Vec::with_capacity(specs.len());
        for (i, spec) in specs.into_iter().enumerate() {
            let mut rng = Pcg64::seed_with_stream(seed, i as u64 + 1);
            let n = spec.rows * spec.cols;
            let mut data = vec![0.0f32; n];
            rng.fill_gaussian_f32(&mut data, spec.scale);
            // inject sparse super-Gaussian outliers
            let n_out = (n as f64 * outliers.fraction).round() as usize;
            for _ in 0..n_out {
                let idx = rng.next_below(n as u64) as usize;
                let sign = if rng.next_f32() < 0.5 { -1.0 } else { 1.0 };
                let mag = outliers.magnitude * (1.0 + rng.next_f32());
                data[idx] = sign * spec.scale * mag;
            }
            tensors.push((spec, data));
        }
        SyntheticModel {
            name: name.to_string(),
            tensors,
        }
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(|(_, d)| d.len()).sum()
    }

    /// Flat concatenated view (for whole-model error metrics).
    pub fn flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n_params());
        for (_, d) in &self.tensors {
            out.extend_from_slice(d);
        }
        out
    }

    /// The paper's three evaluation models, scaled down to this testbed.
    pub fn paper_suite() -> Vec<SyntheticModel> {
        vec![
            SyntheticModel::llm_like("llama-like", 256, 4, 101),
            SyntheticModel::llm_like("qwen-like", 192, 5, 202),
            SyntheticModel::llm_like("mistral-like", 320, 3, 303),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let a = SyntheticModel::llm_like("m", 64, 2, 1);
        let b = SyntheticModel::llm_like("m", 64, 2, 1);
        assert_eq!(a.tensors[0].1, b.tensors[0].1);
        let c = SyntheticModel::llm_like("m", 64, 2, 2);
        assert_ne!(a.tensors[0].1, c.tensors[0].1);
    }

    #[test]
    fn shapes_and_count() {
        let m = SyntheticModel::llm_like("m", 64, 2, 3);
        // embed + 4 per layer * 2
        assert_eq!(m.tensors.len(), 9);
        let n = m.n_params();
        assert_eq!(n, m.flat().len());
        assert!(n > 100_000);
    }

    #[test]
    fn per_tensor_scales_differ() {
        let m = SyntheticModel::llm_like("m", 128, 1, 4);
        let std = |d: &[f32]| {
            let mu = d.iter().sum::<f32>() / d.len() as f32;
            (d.iter().map(|x| (x - mu).powi(2)).sum::<f32>() / d.len() as f32).sqrt()
        };
        let s_embed = std(&m.tensors[0].1);
        let s_qkv = std(&m.tensors[1].1);
        assert!((s_embed - 0.02).abs() < 0.005, "{s_embed}");
        assert!(s_qkv > s_embed * 2.0);
    }

    #[test]
    fn outliers_present_and_sparse() {
        let m = SyntheticModel::from_specs(
            "o",
            vec![TensorSpec {
                name: "w".into(),
                rows: 512,
                cols: 512,
                scale: 0.05,
            }],
            5,
            OutlierProfile {
                fraction: 1e-4,
                magnitude: 20.0,
            },
        );
        let d = &m.tensors[0].1;
        let big = d.iter().filter(|&&x| x.abs() > 0.05 * 10.0).count();
        let expect = (d.len() as f64 * 1e-4) as usize;
        assert!(big >= expect / 2 && big <= expect * 3, "{big} vs {expect}");
    }

    #[test]
    fn paper_suite_models_distinct() {
        let suite = SyntheticModel::paper_suite();
        assert_eq!(suite.len(), 3);
        let names: Vec<_> = suite.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, ["llama-like", "qwen-like", "mistral-like"]);
        assert!(suite.iter().all(|m| m.n_params() > 500_000));
    }
}
