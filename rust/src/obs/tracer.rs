//! Process-wide span tracer with a bounded, lock-recovering ring buffer.
//!
//! The tracer records *where a request's time went*: admission, queue
//! wait, prefill, every decode step, session completion — and, at the
//! `kernel` level, every top-level thread-pool dispatch tagged with its
//! kernel phase (dense / q4 / attention / KV / …). Events are buffered
//! in a fixed-capacity ring ([`RING_CAP`]) guarded by a poisoning-immune
//! mutex (the [`crate::util::sync::lock_recover`] policy shared with
//! `coordinator::metrics` and the kernel pool), then exported as
//! Chrome-trace-event JSON by [`crate::obs::export::chrome_trace`].
//!
//! ## Cost model
//!
//! The gate is a single relaxed atomic load ([`enabled`]), so with
//! `BOF4_TRACE=0` (the default) every instrumentation site costs one
//! branch. Tracing **never** enters a kernel's reduction path: spans wrap
//! kernel *dispatch* (entry/exit of `ThreadPool::run`), so the engine's
//! bit-identical determinism contract is untouched at any level — pinned
//! by `rust/tests/obs_integration.rs`.
//!
//! ## Levels
//!
//! | `BOF4_TRACE` | level | records |
//! |--------------|-------|---------|
//! | unset / `0`  | [`TraceLevel::Off`]    | nothing |
//! | `1`          | [`TraceLevel::Engine`] | request lifecycle spans |
//! | `kernel`     | [`TraceLevel::Kernel`] | \+ per-dispatch kernel spans |
//!
//! `BOF4_LOG=trace` is an alias that enables level `1` (see
//! [`crate::util::log::init_from_env`]).

use std::cell::Cell;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::sync::lock_recover;

/// Maximum number of buffered events; the oldest are evicted beyond this.
pub const RING_CAP: usize = 65_536;

/// Tracing verbosity. Ordered: `Kernel` implies `Engine`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum TraceLevel {
    /// Tracing disabled (the default); every probe is one branch.
    Off = 0,
    /// Request-lifecycle spans: queue wait, prefill, decode steps,
    /// session completion, log mirrors.
    Engine = 1,
    /// Engine spans plus one span per top-level kernel-pool dispatch,
    /// tagged with the kernel phase.
    Kernel = 2,
}

impl TraceLevel {
    fn from_u8(v: u8) -> TraceLevel {
        match v {
            2 => TraceLevel::Kernel,
            1 => TraceLevel::Engine,
            _ => TraceLevel::Off,
        }
    }
}

/// The process-wide trace level. Relaxed ordering is deliberate: the gate
/// needs no synchronization with the events themselves (the ring mutex
/// provides that); it only needs to be cheap.
static LEVEL: AtomicU8 = AtomicU8::new(0);

/// One relaxed load + compare: the entire cost of a disabled probe.
#[inline]
pub fn enabled(lv: TraceLevel) -> bool {
    LEVEL.load(Ordering::Relaxed) >= lv as u8
}

/// Current process-wide trace level.
pub fn level() -> TraceLevel {
    TraceLevel::from_u8(LEVEL.load(Ordering::Relaxed))
}

/// Set the process-wide trace level. Tests and benches use this instead
/// of mutating `BOF4_TRACE` (env mutation is racy under the threaded
/// test harness).
pub fn set_level(lv: TraceLevel) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

/// Parse a `BOF4_TRACE` value. `None` means unrecognized.
pub fn parse_trace_level(s: &str) -> Option<TraceLevel> {
    match s.trim().to_ascii_lowercase().as_str() {
        "" | "0" | "off" | "false" => Some(TraceLevel::Off),
        "1" | "on" | "true" | "engine" => Some(TraceLevel::Engine),
        "2" | "kernel" => Some(TraceLevel::Kernel),
        _ => None,
    }
}

/// Initialize the trace level from `BOF4_TRACE`. Unknown values warn to
/// stderr and leave the level unchanged (so a `BOF4_LOG=trace` alias set
/// earlier survives a typo here).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("BOF4_TRACE") {
        match parse_trace_level(&v) {
            Some(lv) => set_level(lv),
            // lint: allow(stdout-in-lib): documented warn-to-stderr on bad env
            None => eprintln!(
                "bof4: unknown BOF4_TRACE value '{v}' (expected 0|1|kernel); ignored"
            ),
        }
    }
}

/// Event flavor, mapped to Chrome trace-event phases on export.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A completed span (`ph: "X"`): `ts_us` start + `dur_us` duration.
    /// Spans are recorded whole at end-of-scope, so ring eviction can
    /// never orphan a begin without its end.
    Span,
    /// A point-in-time marker (`ph: "i"`).
    Instant,
}

/// One buffered trace event. Timestamps are microseconds since the
/// tracer's epoch (first use in the process).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Static event name (span/instant label in the trace viewer).
    pub name: &'static str,
    /// Span or instant.
    pub kind: EventKind,
    /// Start time, µs since the tracer epoch.
    pub ts_us: u64,
    /// Duration in µs (0 for instants).
    pub dur_us: u64,
    /// Recording thread (dense ids assigned per thread at first event).
    pub tid: u64,
    /// Small integer arguments (session id, step, batch size, …).
    pub args: Vec<(&'static str, i64)>,
    /// Optional free-text payload (log-record mirrors).
    pub text: Option<Box<str>>,
}

struct Ring {
    events: VecDeque<TraceEvent>,
    dropped: u64,
    /// tid -> thread name, for `thread_name` metadata on export.
    threads: BTreeMap<u64, String>,
}

/// Bounded event buffer behind a poisoning-immune mutex.
pub struct Tracer {
    epoch: Instant,
    inner: Mutex<Ring>,
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: Cell<u64> = const { Cell::new(0) };
}

fn current_tid() -> u64 {
    TID.with(|t| {
        let v = t.get();
        if v != 0 {
            v
        } else {
            let fresh = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            t.set(fresh);
            fresh
        }
    })
}

impl Tracer {
    fn new() -> Tracer {
        Tracer {
            epoch: Instant::now(),
            inner: Mutex::new(Ring {
                events: VecDeque::new(),
                dropped: 0,
                threads: BTreeMap::new(),
            }),
        }
    }

    /// The instant all timestamps are relative to.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    fn ts_us(&self, t: Instant) -> u64 {
        // Saturate to 0 for instants that (in tests) precede the epoch.
        t.checked_duration_since(self.epoch)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0)
    }

    fn push(&self, ev: TraceEvent) {
        let tid = ev.tid;
        let mut ring = lock_recover(&self.inner);
        if !ring.threads.contains_key(&tid) {
            let name = std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| format!("thread-{tid}"));
            ring.threads.insert(tid, name);
        }
        if ring.events.len() >= RING_CAP {
            ring.events.pop_front();
            ring.dropped += 1;
        }
        ring.events.push_back(ev);
    }

    /// Record a completed span from explicit start/end instants. Used for
    /// retroactive intervals (queue wait measured at admission).
    pub fn span_at(
        &self,
        name: &'static str,
        start: Instant,
        end: Instant,
        args: &[(&'static str, i64)],
    ) {
        let ts_us = self.ts_us(start);
        let dur_us = end
            .checked_duration_since(start)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        self.push(TraceEvent {
            name,
            kind: EventKind::Span,
            ts_us,
            dur_us,
            tid: current_tid(),
            args: args.to_vec(),
            text: None,
        });
    }

    /// Record an instant event.
    pub fn instant(&self, name: &'static str, args: &[(&'static str, i64)]) {
        let ts_us = self.ts_us(Instant::now());
        self.push(TraceEvent {
            name,
            kind: EventKind::Instant,
            ts_us,
            dur_us: 0,
            tid: current_tid(),
            args: args.to_vec(),
            text: None,
        });
    }

    /// Record an instant event carrying free text (log-record mirrors).
    pub fn instant_msg(&self, name: &'static str, text: &str) {
        let ts_us = self.ts_us(Instant::now());
        self.push(TraceEvent {
            name,
            kind: EventKind::Instant,
            ts_us,
            dur_us: 0,
            tid: current_tid(),
            args: Vec::new(),
            text: Some(text.into()),
        });
    }

    /// Copy out the buffered events, the eviction count, and the thread
    /// name table. Does not drain the ring.
    pub fn snapshot(&self) -> TraceSnapshot {
        let ring = lock_recover(&self.inner);
        TraceSnapshot {
            events: ring.events.iter().cloned().collect(),
            dropped: ring.dropped,
            threads: ring.threads.clone(),
        }
    }

    /// Number of buffered events.
    pub fn len(&self) -> usize {
        lock_recover(&self.inner).events.len()
    }

    /// True when no events are buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all buffered events and reset the eviction counter (tests,
    /// and benches that re-measure from a clean ring).
    pub fn clear(&self) {
        let mut ring = lock_recover(&self.inner);
        ring.events.clear();
        ring.dropped = 0;
    }
}

/// A copied-out view of the ring (events + eviction count + thread names).
#[derive(Clone, Debug)]
pub struct TraceSnapshot {
    /// Buffered events, oldest first.
    pub events: Vec<TraceEvent>,
    /// Events evicted from the ring since the last [`Tracer::clear`].
    pub dropped: u64,
    /// tid -> thread name.
    pub threads: BTreeMap<u64, String>,
}

/// The process-wide tracer.
pub fn tracer() -> &'static Tracer {
    static TRACER: OnceLock<Tracer> = OnceLock::new();
    TRACER.get_or_init(Tracer::new)
}

/// RAII span: records one [`EventKind::Span`] event from construction to
/// drop. Only constructed when its level was enabled (see [`span`]).
pub struct SpanGuard {
    name: &'static str,
    start: Instant,
    args: Vec<(&'static str, i64)>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let t = tracer();
        t.span_at(self.name, self.start, Instant::now(), &self.args);
    }
}

/// Open a span if `lv` is enabled; one branch otherwise. Bind the result
/// (`let _span = span(..)`) so the guard lives to the end of the scope.
#[inline]
pub fn span(
    lv: TraceLevel,
    name: &'static str,
    args: &[(&'static str, i64)],
) -> Option<SpanGuard> {
    if !enabled(lv) {
        return None;
    }
    Some(SpanGuard {
        name,
        start: Instant::now(),
        args: args.to_vec(),
    })
}

/// Record a retroactive span if `lv` is enabled; one branch otherwise.
#[inline]
pub fn span_at(
    lv: TraceLevel,
    name: &'static str,
    start: Instant,
    end: Instant,
    args: &[(&'static str, i64)],
) {
    if enabled(lv) {
        tracer().span_at(name, start, end, args);
    }
}

/// Record an instant event if `lv` is enabled; one branch otherwise.
#[inline]
pub fn instant(lv: TraceLevel, name: &'static str, args: &[(&'static str, i64)]) {
    if enabled(lv) {
        tracer().instant(name, args);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    // Tests that flip the global level serialize on this (the unit-test
    // harness runs tests on concurrent threads).
    fn level_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        lock_recover(&LOCK)
    }

    #[test]
    fn parse_levels() {
        assert_eq!(parse_trace_level("0"), Some(TraceLevel::Off));
        assert_eq!(parse_trace_level("off"), Some(TraceLevel::Off));
        assert_eq!(parse_trace_level("1"), Some(TraceLevel::Engine));
        assert_eq!(parse_trace_level("engine"), Some(TraceLevel::Engine));
        assert_eq!(parse_trace_level(" KERNEL "), Some(TraceLevel::Kernel));
        assert_eq!(parse_trace_level("2"), Some(TraceLevel::Kernel));
        assert_eq!(parse_trace_level("verbose"), None);
    }

    #[test]
    fn disabled_probes_record_nothing() {
        let _g = level_lock();
        set_level(TraceLevel::Off);
        let before = tracer().len();
        instant(TraceLevel::Engine, "nope", &[]);
        let s = span(TraceLevel::Engine, "nope", &[]);
        assert!(s.is_none());
        drop(s);
        assert_eq!(tracer().len(), before);
    }

    #[test]
    fn span_guard_records_duration() {
        let _g = level_lock();
        set_level(TraceLevel::Engine);
        tracer().clear();
        {
            let _span = span(TraceLevel::Engine, "unit_span", &[("k", 7)]);
            std::thread::sleep(Duration::from_millis(2));
        }
        instant(TraceLevel::Engine, "unit_instant", &[]);
        // Kernel-level probe must stay silent at Engine level.
        instant(TraceLevel::Kernel, "kernel_only", &[]);
        set_level(TraceLevel::Off);
        let snap = tracer().snapshot();
        let sp = snap
            .events
            .iter()
            .find(|e| e.name == "unit_span")
            .expect("span recorded");
        assert_eq!(sp.kind, EventKind::Span);
        assert!(sp.dur_us >= 1_000, "slept 2ms, got {}us", sp.dur_us);
        assert_eq!(sp.args, vec![("k", 7)]);
        assert!(snap.events.iter().any(|e| e.name == "unit_instant"));
        assert!(!snap.events.iter().any(|e| e.name == "kernel_only"));
        assert!(snap.threads.contains_key(&sp.tid));
        tracer().clear();
    }

    #[test]
    fn ring_stays_bounded() {
        let _g = level_lock();
        set_level(TraceLevel::Engine);
        tracer().clear();
        for _ in 0..RING_CAP + 100 {
            tracer().instant("flood", &[]);
        }
        set_level(TraceLevel::Off);
        let snap = tracer().snapshot();
        assert_eq!(snap.events.len(), RING_CAP);
        assert!(snap.dropped >= 100);
        tracer().clear();
        assert!(tracer().is_empty());
    }
}
