//! Observability: request-scoped span tracing, SLO telemetry snapshots
//! and exporters for the serving engine — zero new dependencies.
//!
//! Three pieces (ISSUE 8):
//!
//! - [`tracer`] — a process-wide span tracer behind a relaxed-atomic
//!   `BOF4_TRACE=0|1|kernel` gate (off cost: one branch). The engine
//!   instruments admission → queue wait → prefill → every decode step →
//!   completion; at the `kernel` level the thread pool adds one span per
//!   top-level dispatch, tagged with its
//!   [`crate::runtime::kernels::KernelPhase`]. Events live in a bounded
//!   lock-recovering ring; spans are recorded whole ("X" complete
//!   events), so eviction never orphans a begin/end pair.
//! - [`export`] — Chrome-trace-event JSON (open `results/trace.json` in
//!   [Perfetto](https://ui.perfetto.dev) or `chrome://tracing`) and
//!   [`MetricsSnapshot`], rendered as Prometheus text exposition or
//!   JSON.
//! - SLO metrics — time-to-first-token, inter-token latency, queue
//!   depth, per-session deadline overruns and tokens/sec live in
//!   [`crate::coordinator::EngineMetrics`]; the snapshot joins them with
//!   the engine's memory profile and the pool's per-kernel profile.
//!
//! Wired to `bof4 serve --trace <path> --metrics-file <path>` with
//! periodic dumps. Determinism contract: tracing never enters a kernel's
//! reduction path, and engine token streams are bit-identical with
//! tracing off/on/kernel (pinned by `rust/tests/obs_integration.rs`).

pub mod export;
pub mod tracer;

pub use export::{chrome_trace, documented_metrics, MetricsSnapshot};
pub use tracer::{tracer, TraceLevel, Tracer};
