//! Exporters: Chrome-trace-event JSON for the span tracer and a
//! [`MetricsSnapshot`] rendered as Prometheus text exposition or JSON.
//!
//! Both serialize through [`crate::util::json`] (zero new deps) and are
//! round-trip tested in `rust/tests/obs_integration.rs`: the trace JSON
//! parses back cleanly and loads in Perfetto / `chrome://tracing`, and
//! the Prometheus text names every metric in [`documented_metrics`].

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;

use crate::coordinator::metrics::TOKEN_LATENCY_BOUNDS_MS;
use crate::coordinator::{EngineMemoryProfile, EngineMetrics, LatencyStats};
use crate::runtime::kernels::KernelStat;
use crate::util::json::{obj, Json};

use super::tracer::{EventKind, TraceSnapshot};

/// Counter names every snapshot carries (zero-valued when the engine has
/// not touched them yet), so scrapers see a stable series set.
const KNOWN_COUNTERS: [&str; 13] = [
    "batches",
    "batched_requests",
    "sessions",
    "prefill_tokens",
    "decode_tokens",
    "decode_steps",
    "deadline_overruns",
    "deadline_cancelled",
    "sessions_shed",
    "sessions_shed_rejected",
    "sessions_shed_evicted",
    "replica_exits",
    "replica_restarts",
];

/// Value-series names every snapshot carries (summaries render empty —
/// `_count 0` — before the first sample).
const KNOWN_SERIES: [&str; 8] = [
    "prefill_exec",
    "decode_step_exec",
    "token_latency",
    "ttft",
    "inter_token",
    "queue_wait",
    "slot_occupancy",
    "pool_busy",
];

/// Series recorded as unit-free fractions rather than milliseconds.
fn is_ratio_series(name: &str) -> bool {
    matches!(name, "slot_occupancy" | "pool_busy")
}

fn series_metric_name(name: &str) -> String {
    if is_ratio_series(name) {
        format!("bof4_{name}_ratio")
    } else {
        format!("bof4_{name}_ms")
    }
}

/// Every metric name the Prometheus exposition documents (README's
/// metric table and the golden export test both pin this list).
pub fn documented_metrics() -> &'static [&'static str] {
    &[
        "bof4_uptime_seconds",
        "bof4_queue_depth",
        "bof4_tokens_per_sec",
        "bof4_batches_total",
        "bof4_batched_requests_total",
        "bof4_sessions_total",
        "bof4_prefill_tokens_total",
        "bof4_decode_tokens_total",
        "bof4_decode_steps_total",
        "bof4_deadline_overruns_total",
        "bof4_deadline_cancelled_total",
        "bof4_sessions_shed_total",
        "bof4_sessions_shed_rejected_total",
        "bof4_sessions_shed_evicted_total",
        "bof4_replica_exits_total",
        "bof4_replica_restarts_total",
        "bof4_prefill_exec_ms",
        "bof4_decode_step_exec_ms",
        "bof4_token_latency_ms",
        "bof4_ttft_ms",
        "bof4_inter_token_ms",
        "bof4_queue_wait_ms",
        "bof4_slot_occupancy_ratio",
        "bof4_pool_busy_ratio",
        "bof4_kernel_seconds_total",
        "bof4_kernel_calls_total",
        "bof4_replicas",
        "bof4_shared_param_bytes",
        "bof4_resident_bytes",
        "bof4_session_kv_bytes",
    ]
}

/// A point-in-time copy of the engine's SLO metrics, kernel profile and
/// memory accounting — the unit both exporters render. Build one with
/// [`MetricsSnapshot::collect`] (or [`crate::coordinator::Engine::snapshot`],
/// which also fills in the kernel profile and memory).
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Seconds since the engine's metrics started.
    pub uptime_s: f64,
    /// Sessions submitted but not yet admitted (gauge).
    pub queue_depth: u64,
    /// Decode tokens streamed per second of uptime.
    pub tokens_per_sec: f64,
    /// All counters, zero-filled over [`KNOWN_COUNTERS`], sorted by name.
    pub counters: Vec<(String, u64)>,
    /// All value series with their order statistics (`None` = no samples
    /// yet), over the union of live series and [`KNOWN_SERIES`].
    pub series: Vec<(String, Option<LatencyStats>)>,
    /// Per-token latency histogram counts, aligned to
    /// [`TOKEN_LATENCY_BOUNDS_MS`] plus the overflow bucket.
    pub token_latency_counts: Vec<u64>,
    /// Per-kernel-phase wall time + dispatch counts (empty on backends
    /// without a thread pool).
    pub kernels: Vec<KernelStat>,
    /// Engine resident-memory accounting, when the snapshot came from a
    /// running engine.
    pub memory: Option<EngineMemoryProfile>,
}

impl MetricsSnapshot {
    /// Snapshot an [`EngineMetrics`] registry plus (optionally) a kernel
    /// profile and a memory profile.
    pub fn collect(
        m: &EngineMetrics,
        kernels: Vec<KernelStat>,
        memory: Option<EngineMemoryProfile>,
    ) -> MetricsSnapshot {
        let mut counters: BTreeMap<String, u64> = m.core.counter_snapshot().into_iter().collect();
        for k in KNOWN_COUNTERS {
            counters.entry(k.to_string()).or_insert(0);
        }
        let mut names: BTreeSet<String> = m.core.series_names().into_iter().collect();
        for k in KNOWN_SERIES {
            names.insert(k.to_string());
        }
        let series = names
            .into_iter()
            .map(|n| {
                let s = m.core.latency_stats(&n);
                (n, s)
            })
            .collect();
        MetricsSnapshot {
            uptime_s: m.uptime().as_secs_f64(),
            queue_depth: m.queue_depth(),
            tokens_per_sec: m.tokens_per_sec(),
            counters: counters.into_iter().collect(),
            series,
            token_latency_counts: m
                .token_latency_histogram()
                .into_iter()
                .map(|(_, n)| n)
                .collect(),
            kernels,
            memory,
        }
    }

    /// Render as Prometheus text exposition (version 0.0.4): gauges for
    /// the SLO signals, `_total` counters, summaries with `quantile`
    /// labels for every value series, the cumulative `le` histogram for
    /// per-token latency, and the kernel profile as `kernel`-labelled
    /// counters.
    pub fn to_prometheus(&self) -> String {
        let mut o = String::new();
        let _ = writeln!(o, "# HELP bof4_uptime_seconds Engine uptime.");
        let _ = writeln!(o, "# TYPE bof4_uptime_seconds gauge");
        let _ = writeln!(o, "bof4_uptime_seconds {}", fmt_num(self.uptime_s));
        let _ = writeln!(
            o,
            "# HELP bof4_queue_depth Sessions submitted but not yet admitted."
        );
        let _ = writeln!(o, "# TYPE bof4_queue_depth gauge");
        let _ = writeln!(o, "bof4_queue_depth {}", self.queue_depth);
        let _ = writeln!(
            o,
            "# HELP bof4_tokens_per_sec Decode tokens streamed per second of uptime."
        );
        let _ = writeln!(o, "# TYPE bof4_tokens_per_sec gauge");
        let _ = writeln!(o, "bof4_tokens_per_sec {}", fmt_num(self.tokens_per_sec));

        for (name, v) in &self.counters {
            let _ = writeln!(o, "# TYPE bof4_{name}_total counter");
            let _ = writeln!(o, "bof4_{name}_total {v}");
        }

        for (name, stats) in &self.series {
            let metric = series_metric_name(name);
            let _ = writeln!(o, "# TYPE {metric} summary");
            match stats {
                Some(s) => {
                    let _ = writeln!(o, "{metric}{{quantile=\"0.5\"}} {}", fmt_num(s.p50_ms));
                    let _ = writeln!(o, "{metric}{{quantile=\"0.95\"}} {}", fmt_num(s.p95_ms));
                    let _ = writeln!(o, "{metric}{{quantile=\"0.99\"}} {}", fmt_num(s.p99_ms));
                    let _ = writeln!(o, "{metric}_sum {}", fmt_num(s.mean_ms * s.count as f64));
                    let _ = writeln!(o, "{metric}_count {}", s.count);
                    let _ = writeln!(o, "{metric}_dropped_total {}", s.dropped);
                }
                None => {
                    let _ = writeln!(o, "{metric}_sum 0");
                    let _ = writeln!(o, "{metric}_count 0");
                    let _ = writeln!(o, "{metric}_dropped_total 0");
                }
            }
        }

        let _ = writeln!(
            o,
            "# HELP bof4_token_latency_ms Wall time of the step that produced each token."
        );
        let _ = writeln!(o, "# TYPE bof4_token_latency_ms histogram");
        let mut cum = 0u64;
        for (i, bound) in TOKEN_LATENCY_BOUNDS_MS.iter().enumerate() {
            cum += self.token_latency_counts.get(i).copied().unwrap_or(0);
            let _ = writeln!(o, "bof4_token_latency_ms_bucket{{le=\"{bound}\"}} {cum}");
        }
        cum += self
            .token_latency_counts
            .get(TOKEN_LATENCY_BOUNDS_MS.len())
            .copied()
            .unwrap_or(0);
        let _ = writeln!(o, "bof4_token_latency_ms_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(o, "bof4_token_latency_ms_count {cum}");

        let _ = writeln!(
            o,
            "# HELP bof4_kernel_seconds_total Wall time in top-level kernel-pool dispatches, by kernel phase."
        );
        let _ = writeln!(o, "# TYPE bof4_kernel_seconds_total counter");
        for k in &self.kernels {
            let _ = writeln!(
                o,
                "bof4_kernel_seconds_total{{kernel=\"{}\"}} {}",
                k.kernel,
                fmt_num(k.seconds())
            );
        }
        let _ = writeln!(
            o,
            "# HELP bof4_kernel_calls_total Top-level kernel-pool dispatches, by kernel phase."
        );
        let _ = writeln!(o, "# TYPE bof4_kernel_calls_total counter");
        for k in &self.kernels {
            let _ = writeln!(
                o,
                "bof4_kernel_calls_total{{kernel=\"{}\"}} {}",
                k.kernel, k.calls
            );
        }

        if let Some(mem) = &self.memory {
            let _ = writeln!(o, "# TYPE bof4_replicas gauge");
            let _ = writeln!(o, "bof4_replicas {}", mem.replicas);
            let _ = writeln!(o, "# TYPE bof4_shared_param_bytes gauge");
            let _ = writeln!(o, "bof4_shared_param_bytes {}", mem.shared_param_bytes);
            let _ = writeln!(o, "# TYPE bof4_resident_bytes gauge");
            let _ = writeln!(o, "bof4_resident_bytes {}", mem.total_resident_bytes);
            let _ = writeln!(
                o,
                "# HELP bof4_session_kv_bytes Resident KV-cache bytes one session costs ({} format).",
                mem.kv_format
            );
            let _ = writeln!(o, "# TYPE bof4_session_kv_bytes gauge");
            let _ = writeln!(o, "bof4_session_kv_bytes {}", mem.session_kv_bytes);
        }
        o
    }

    /// Render as a JSON object (the machine-readable sibling of the
    /// Prometheus text; `bof4 serve --metrics-file p` writes both).
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                .collect(),
        );
        let series = Json::Obj(
            self.series
                .iter()
                .map(|(k, s)| {
                    let v = match s {
                        Some(s) => obj(vec![
                            ("count", Json::Num(s.count as f64)),
                            ("non_finite", Json::Num(s.non_finite as f64)),
                            ("dropped", Json::Num(s.dropped as f64)),
                            ("mean", Json::Num(s.mean_ms)),
                            ("p50", Json::Num(s.p50_ms)),
                            ("p95", Json::Num(s.p95_ms)),
                            ("p99", Json::Num(s.p99_ms)),
                            ("max", Json::Num(s.max_ms)),
                        ]),
                        None => Json::Null,
                    };
                    (k.clone(), v)
                })
                .collect(),
        );
        let kernels = Json::Arr(
            self.kernels
                .iter()
                .map(|k| {
                    obj(vec![
                        ("kernel", Json::Str(k.kernel.to_string())),
                        ("calls", Json::Num(k.calls as f64)),
                        ("seconds", Json::Num(k.seconds())),
                    ])
                })
                .collect(),
        );
        let memory = match &self.memory {
            Some(m) => obj(vec![
                ("replicas", Json::Num(m.replicas as f64)),
                ("shared_param_bytes", Json::Num(m.shared_param_bytes as f64)),
                (
                    "total_resident_bytes",
                    Json::Num(m.total_resident_bytes as f64),
                ),
                ("kv_format", Json::Str(m.kv_format.to_string())),
                ("session_kv_bytes", Json::Num(m.session_kv_bytes as f64)),
            ]),
            None => Json::Null,
        };
        obj(vec![
            ("uptime_s", Json::Num(self.uptime_s)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("tokens_per_sec", Json::Num(self.tokens_per_sec)),
            ("counters", counters),
            ("series", series),
            (
                "token_latency_hist",
                obj(vec![
                    (
                        "bounds_ms",
                        crate::util::json::arr_f64(&TOKEN_LATENCY_BOUNDS_MS),
                    ),
                    (
                        "counts",
                        Json::Arr(
                            self.token_latency_counts
                                .iter()
                                .map(|&n| Json::Num(n as f64))
                                .collect(),
                        ),
                    ),
                ]),
            ),
            ("kernels", kernels),
            ("memory", memory),
        ])
    }
}

/// Plain `{}` float formatting, with non-finite values clamped to 0 (the
/// text exposition has no NaN story worth keeping).
fn fmt_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

/// Render a tracer snapshot as Chrome trace-event JSON (the "JSON Array
/// Format" with a `traceEvents` wrapper), loadable in Perfetto or
/// `chrome://tracing`. Spans are complete events (`ph: "X"`, µs
/// timestamps); instants are `ph: "i"` with thread scope; thread names
/// ride as `"M"` metadata.
pub fn chrome_trace(snap: &TraceSnapshot) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(snap.events.len() + snap.threads.len() + 1);
    events.push(obj(vec![
        ("name", Json::Str("process_name".to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(0.0)),
        (
            "args",
            obj(vec![("name", Json::Str("bof4 serving engine".to_string()))]),
        ),
    ]));
    for (tid, name) in &snap.threads {
        events.push(obj(vec![
            ("name", Json::Str("thread_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(0.0)),
            ("tid", Json::Num(*tid as f64)),
            ("args", obj(vec![("name", Json::Str(name.clone()))])),
        ]));
    }
    for ev in &snap.events {
        let mut args: Vec<(&str, Json)> = ev
            .args
            .iter()
            .map(|(k, v)| (*k, Json::Num(*v as f64)))
            .collect();
        if let Some(text) = &ev.text {
            args.push(("msg", Json::Str(text.to_string())));
        }
        let mut fields = vec![
            ("name", Json::Str(ev.name.to_string())),
            ("pid", Json::Num(0.0)),
            ("tid", Json::Num(ev.tid as f64)),
            ("ts", Json::Num(ev.ts_us as f64)),
            ("args", obj(args)),
        ];
        match ev.kind {
            EventKind::Span => {
                fields.push(("ph", Json::Str("X".to_string())));
                fields.push(("dur", Json::Num(ev.dur_us as f64)));
            }
            EventKind::Instant => {
                fields.push(("ph", Json::Str("i".to_string())));
                fields.push(("s", Json::Str("t".to_string())));
            }
        }
        events.push(obj(fields));
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        (
            "otherData",
            obj(vec![("dropped_events", Json::Num(snap.dropped as f64))]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::tracer::TraceEvent;
    use std::time::Duration;

    fn sample_snapshot() -> MetricsSnapshot {
        let em = EngineMetrics::new();
        em.core.inc("batches");
        em.core.add("decode_tokens", 40);
        em.record_token_latency(Duration::from_millis(2));
        em.record_ttft(Duration::from_millis(9));
        em.queue_enter();
        let kernels = vec![KernelStat {
            kernel: "dense",
            calls: 12,
            nanos: 3_400_000,
        }];
        MetricsSnapshot::collect(&em, kernels, None)
    }

    #[test]
    fn prometheus_names_every_documented_metric() {
        let mut snap = sample_snapshot();
        snap.memory = Some(EngineMemoryProfile {
            replicas: 2,
            shared_param_bytes: 1000,
            per_replica_bytes: vec![10, 10],
            total_resident_bytes: 1020,
            kv_format: "q8",
            session_kv_bytes: 64,
        });
        let text = snap.to_prometheus();
        for name in documented_metrics() {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        // summaries carry quantiles once samples exist
        assert!(text.contains("bof4_ttft_ms{quantile=\"0.99\"}"), "{text}");
        // histogram is cumulative and ends at +Inf
        assert!(text.contains("bof4_token_latency_ms_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("bof4_kernel_seconds_total{kernel=\"dense\"}"));
        assert!(text.contains("bof4_queue_depth 1"));
    }

    /// Fault-tolerance counters (shed/restart/deadline-cancel) must be
    /// present — zero-valued — in both exports before the engine ever
    /// sheds or restarts anything, so scrapers see a stable series set.
    #[test]
    fn fault_counters_zero_filled_in_exports() {
        let snap = MetricsSnapshot::collect(&EngineMetrics::new(), Vec::new(), None);
        let text = snap.to_prometheus();
        for line in [
            "bof4_sessions_shed_total 0",
            "bof4_sessions_shed_rejected_total 0",
            "bof4_sessions_shed_evicted_total 0",
            "bof4_deadline_cancelled_total 0",
            "bof4_replica_exits_total 0",
            "bof4_replica_restarts_total 0",
        ] {
            assert!(text.contains(line), "missing '{line}' in:\n{text}");
        }
        let j = Json::parse(&snap.to_json().to_string()).unwrap();
        for key in [
            "counters.sessions_shed",
            "counters.sessions_shed_rejected",
            "counters.sessions_shed_evicted",
            "counters.deadline_cancelled",
            "counters.replica_exits",
            "counters.replica_restarts",
        ] {
            assert_eq!(j.path(key).unwrap().as_f64(), Some(0.0), "{key}");
        }
    }

    #[test]
    fn json_export_parses_back() {
        let snap = sample_snapshot();
        let j = Json::parse(&snap.to_json().to_string()).unwrap();
        assert_eq!(j.path("counters.batches").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.path("queue_depth").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.path("kernels.0.kernel").unwrap().as_str(),
            Some("dense")
        );
        // series without samples render null, with samples an object
        assert_eq!(j.path("series.pool_busy").unwrap(), &Json::Null);
        assert_eq!(j.path("series.ttft.count").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn chrome_trace_shape() {
        let mut threads = std::collections::BTreeMap::new();
        threads.insert(3u64, "engine-replica-0".to_string());
        let snap = TraceSnapshot {
            events: vec![
                TraceEvent {
                    name: "prefill",
                    kind: EventKind::Span,
                    ts_us: 10,
                    dur_us: 250,
                    tid: 3,
                    args: vec![("batch", 4)],
                    text: None,
                },
                TraceEvent {
                    name: "log_warn",
                    kind: EventKind::Instant,
                    ts_us: 40,
                    dur_us: 0,
                    tid: 3,
                    args: vec![],
                    text: Some("queue nearly full".into()),
                },
            ],
            dropped: 7,
            threads,
        };
        let j = Json::parse(&chrome_trace(&snap).to_string()).unwrap();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // process_name + thread_name + 2 events
        assert_eq!(evs.len(), 4);
        let span = evs
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("prefill"))
            .unwrap();
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("dur").unwrap().as_f64(), Some(250.0));
        assert_eq!(span.path("args.batch").unwrap().as_f64(), Some(4.0));
        let inst = evs
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("log_warn"))
            .unwrap();
        assert_eq!(inst.get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(
            inst.path("args.msg").unwrap().as_str(),
            Some("queue nearly full")
        );
        assert_eq!(
            j.path("otherData.dropped_events").unwrap().as_f64(),
            Some(7.0)
        );
    }
}
