//! Pure-Rust CPU backend: an interpreter for every graph in the builtin
//! ABI ([`super::meta::Meta::builtin`]).
//!
//! This is the hermetic execution path — no Python, no AOT artifacts, no
//! network. It implements the exact semantics of the JAX graphs in
//! `python/compile/model.py`:
//!
//! - `init_params` / `init_lora`: deterministic scaled-normal init (the
//!   PRNG is [`Pcg64`] rather than Threefry, so *values* differ from the
//!   XLA artifacts, but shapes/scales/determinism match);
//! - `lm_nll`, `lm_logits_last`, `lm_logits_all` (+ `_lora` variants):
//!   the GPT-style forward — embedding gather, RMS-norm, causal
//!   multi-head attention, GELU MLP, tied-nothing head;
//! - `lm_nll_q4` and `dequant_matmul`: the 4-bit serving path, with the
//!   dequantization fused into the matmul inner loop (one LUT multiply
//!   per weight, per-block absmax hoisted);
//! - `lm_prefill` / `lm_decode_step` (+ `_q4` variants): the KV-cached
//!   serving pair — prefill returns per-layer K/V next to the last-valid
//!   logits; the decode step appends one K/V column per active row and
//!   attends over `pos+1` cached positions. Every per-row kernel runs in
//!   the full forward's exact loop order, so incremental logits are
//!   bit-identical to full-context re-execution; the `_q4` variants keep
//!   weights 4-bit with 8-bit double-quantized block constants,
//!   dequantized inside the fused matmul, plus per-matrix OPQ outlier
//!   side-tables (sorted flat u32 indices + bf16-rounded f32 values,
//!   empty when OPQ is off) patched sparsely inside the fused kernels so
//!   outlier weights serve at 16-bit precision;
//! - `quantize_blocks_{abs,signed}`: the block-wise encoder kernels;
//! - `train_step` / `lora_step`: full reverse-mode backprop through the
//!   model plus the AdamW update (global-norm clipping, bias correction,
//!   decoupled weight decay) — hand-derived, checked against finite
//!   differences in the tests below.
//!
//! Everything is plain `f32` loops over flat row-major buffers; the
//! layouts match the ABI exactly, so tensors cross [`HostTensor`]
//! unchanged. The hot paths (matmuls, attention, RMS-norm, the fused q4
//! kernels, AdamW) execute through [`super::kernels`] — a tiled,
//! thread-pooled, SIMD-vectorized kernel library whose results are
//! **bit-identical at every `(BOF4_THREADS, BOF4_SIMD)` setting**
//! (deterministic tile ownership, canonical 8-lane-strided reduction
//! order shared by the scalar/array/AVX2 paths). The KV decode step
//! additionally supports the in-place cache protocol
//! ([`Backend::alloc_decode_state`] / [`Backend::execute_decode_inplace`]):
//! the serving engine keeps the per-layer cache slabs resident in a
//! [`CpuDecodeState`] instead of round-tripping ~2 MB of `HostTensor`
//! per step, with the decode row loop fanned out across the pool.

// Index-heavy numeric kernels read better as explicit loops.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use std::sync::Arc;

use super::kernels::kv::{decode_attention_kv, KvView};
use super::kernels::{
    attention, phase_scope, q4, simd, tiling, KernelPhase, KernelStat, MatW, SimdPath, SyncSlice,
    ThreadPool,
};
use super::meta::{lora_specs, matmul_param_names, param_specs, GraphMeta, ModelMeta};
use super::{Backend, DecodeState, HostTensor};
use crate::error::Result;
use crate::quant::absmax::{block_constant, safe_constant};
use crate::quant::kv as kvq;
use crate::quant::{codebook_for, Codebook, KvFormat, Method, Norm};
use crate::util::rng::Pcg64;

// Optimizer / model hyper-parameters (ModelCfg defaults in model.py).
const LR: f32 = 1e-3;
const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
const WEIGHT_DECAY: f32 = 0.01;
const GRAD_CLIP: f32 = 1.0;
const LORA_ALPHA: f32 = 16.0;
const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)

/// The pure-Rust CPU interpreter backend.
pub struct CpuBackend {
    m: ModelMeta,
    pool: Arc<ThreadPool>,
}

impl CpuBackend {
    /// Backend over the process-wide kernel pool (sized by
    /// `BOF4_THREADS`, else the detected core count).
    pub fn new(m: ModelMeta) -> CpuBackend {
        CpuBackend {
            m,
            pool: super::kernels::default_pool(),
        }
    }

    /// Backend over a private pool of an explicit width — what the
    /// determinism tests and the thread-scaling benches use to compare
    /// thread counts within one process. The SIMD path still comes from
    /// `BOF4_SIMD` / runtime detection.
    pub fn with_threads(m: ModelMeta, threads: usize) -> CpuBackend {
        CpuBackend {
            m,
            pool: Arc::new(ThreadPool::with_threads(threads)),
        }
    }

    /// Backend with both kernel knobs explicit (pool width and SIMD
    /// path) — what the path-equality tests and the scalar-vs-SIMD
    /// benches use to compare configurations within one process.
    pub fn with_config(m: ModelMeta, threads: usize, simd_path: SimdPath) -> CpuBackend {
        CpuBackend {
            m,
            pool: Arc::new(ThreadPool::with_config(threads, simd_path)),
        }
    }

    /// The kernel pool this backend executes on.
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }
}

/// Resident KV-cache slabs for the in-place decode protocol: one
/// `[batch * seq_len * d_model]` f32 buffer per cache tensor (K and V per
/// layer), mutated by `lm_decode_step(_q4)` without crossing the
/// `HostTensor` ABI.
pub struct CpuDecodeState {
    caches: Vec<Vec<f32>>,
    /// Elements per batch slot (`seq_len * d_model`).
    slot_elems: usize,
}

impl CpuDecodeState {
    /// Read-only view of cache `c` (tests / diagnostics).
    pub fn cache(&self, c: usize) -> &[f32] {
        &self.caches[c]
    }
}

impl DecodeState for CpuDecodeState {
    fn load_slot(&mut self, c: usize, slot: usize, rows: &[f32]) -> Result<()> {
        if rows.len() != self.slot_elems {
            return Err(crate::err!(
                "load_slot: got {} elements, slot holds {}",
                rows.len(),
                self.slot_elems
            ));
        }
        let cache = self
            .caches
            .get_mut(c)
            .ok_or_else(|| crate::err!("load_slot: no cache {c}"))?;
        let lo = slot * rows.len();
        if lo + rows.len() > cache.len() {
            return Err(crate::err!("load_slot: slot {slot} out of range"));
        }
        cache[lo..lo + rows.len()].copy_from_slice(rows);
        Ok(())
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> usize {
        self.caches.iter().map(|c| 4 * c.len()).sum()
    }
}

/// Resident **block-quantized** KV-cache slabs (`BOF4_KV=q8|q4`): per
/// cache tensor, `[batch * seq_len * row_code_bytes]` packed codes plus
/// `[batch * seq_len * blocks_per_row]` f32 block constants. Rows are
/// quantized at append — the prefill scatter ([`DecodeState::load_slot`])
/// and each decode step's fresh K/V column — and read back fused inside
/// [`decode_attention_kv`], so a f32 row never materializes on the
/// decode path.
pub struct CpuDecodeStateQ {
    fmt: KvFormat,
    codes: Vec<Vec<u8>>,
    scales: Vec<Vec<f32>>,
    /// Quantization block (elements per constant): `m.block.min(d_model)`.
    block: usize,
    norm: Norm,
    /// BOF4 reconstruction levels (q4; all-zero for q8, unread).
    levels: [f32; 16],
    /// BOF4 codebook for q4 encode (`None` for q8).
    cb: Option<Codebook>,
    d: usize,
    seq: usize,
    /// Code bytes per cached row (`fmt.row_bytes` minus the constants).
    rcb: usize,
    /// Block constants per cached row.
    nb: usize,
}

impl CpuDecodeStateQ {
    /// The stored format (tests / diagnostics).
    pub fn format(&self) -> KvFormat {
        self.fmt
    }

    /// Dequantize cache `c` to f32 (slow path: tests / diagnostics).
    pub fn dequantized(&self, c: usize) -> Vec<f32> {
        let rows = self.codes[c].len() / self.rcb;
        let mut out = vec![0.0f32; rows * self.d];
        for t in 0..rows {
            let co = &self.codes[c][t * self.rcb..(t + 1) * self.rcb];
            let so = &self.scales[c][t * self.nb..(t + 1) * self.nb];
            let o = &mut out[t * self.d..(t + 1) * self.d];
            match self.fmt {
                KvFormat::Q8 => kvq::dequantize_row_q8(co, so, self.block, o),
                KvFormat::Q4 => kvq::dequantize_row_q4(co, so, self.block, &self.levels, o),
                KvFormat::F32 => unreachable!("f32 caches live in CpuDecodeState"),
            }
        }
        out
    }
}

/// Quantize one K/V row into its slab slices under `fmt` (shared by the
/// prefill scatter and the decode-step append).
fn quantize_kv_row(
    fmt: KvFormat,
    row: &[f32],
    block: usize,
    norm: Norm,
    cb: Option<&Codebook>,
    codes: &mut [u8],
    scales: &mut [f32],
) {
    match fmt {
        KvFormat::Q8 => kvq::quantize_row_q8(row, block, norm, codes, scales),
        KvFormat::Q4 => {
            kvq::quantize_row_q4(row, block, norm, cb.expect("q4 codebook"), codes, scales)
        }
        KvFormat::F32 => unreachable!("f32 caches live in CpuDecodeState"),
    }
}

impl DecodeState for CpuDecodeStateQ {
    fn load_slot(&mut self, c: usize, slot: usize, rows: &[f32]) -> Result<()> {
        let (s, d) = (self.seq, self.d);
        if rows.len() != s * d {
            return Err(crate::err!(
                "load_slot: got {} elements, slot holds {}",
                rows.len(),
                s * d
            ));
        }
        let (rcb, nb) = (self.rcb, self.nb);
        let codes = self
            .codes
            .get_mut(c)
            .ok_or_else(|| crate::err!("load_slot: no cache {c}"))?;
        let scales = &mut self.scales[c];
        if (slot + 1) * s * rcb > codes.len() {
            return Err(crate::err!("load_slot: slot {slot} out of range"));
        }
        for t in 0..s {
            quantize_kv_row(
                self.fmt,
                &rows[t * d..(t + 1) * d],
                self.block,
                self.norm,
                self.cb.as_ref(),
                &mut codes[(slot * s + t) * rcb..(slot * s + t + 1) * rcb],
                &mut scales[(slot * s + t) * nb..(slot * s + t + 1) * nb],
            );
        }
        Ok(())
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> usize {
        self.codes.iter().map(|c| c.len()).sum::<usize>()
            + self.scales.iter().map(|c| 4 * c.len()).sum::<usize>()
    }
}

impl Backend for CpuBackend {
    fn platform(&self) -> String {
        "cpu-interpreter".to_string()
    }

    fn compile(&self, _gm: &GraphMeta) -> Result<()> {
        Ok(()) // nothing to compile
    }

    fn alloc_decode_state(
        &self,
        gm: &GraphMeta,
        kv: KvFormat,
    ) -> Result<Option<Box<dyn DecodeState>>> {
        match gm.name.as_str() {
            "lm_decode_step" | "lm_decode_step_q4" => {
                let m = &self.m;
                let (b, s, d) = (m.batch, m.seq_len, m.d_model);
                match kv {
                    KvFormat::F32 => {
                        let slot_elems = s * d;
                        Ok(Some(Box::new(CpuDecodeState {
                            caches: vec![vec![0.0; b * slot_elems]; 2 * m.n_layers],
                            slot_elems,
                        })))
                    }
                    KvFormat::Q8 | KvFormat::Q4 => {
                        if kv == KvFormat::Q4 && d % 2 != 0 {
                            return Err(crate::err!(
                                "BOF4_KV=q4 needs an even d_model for nibble packing (got {d})"
                            ));
                        }
                        // K/V rows are activations: absmax for symmetric
                        // int8, the signed-absmax BOF4-S codebook for q4
                        // (the paper's best 4-bit variant).
                        let block = m.block.min(d).max(1);
                        let nb = d.div_ceil(block);
                        let (norm, rcb) = match kv {
                            KvFormat::Q8 => (Norm::Absmax, d),
                            _ => (Norm::SignedAbsmax, d / 2),
                        };
                        let (levels, cb) = if kv == KvFormat::Q4 {
                            let cb = codebook_for(&Method::Bof4 { mse: true }, norm, block);
                            let mut l = [0.0f32; 16];
                            for (i, lv) in l.iter_mut().enumerate() {
                                *lv = cb.decode1(i as u8);
                            }
                            (l, Some(cb))
                        } else {
                            ([0.0f32; 16], None)
                        };
                        Ok(Some(Box::new(CpuDecodeStateQ {
                            fmt: kv,
                            codes: vec![vec![0u8; b * s * rcb]; 2 * m.n_layers],
                            scales: vec![vec![0.0f32; b * s * nb]; 2 * m.n_layers],
                            block,
                            norm,
                            levels,
                            cb,
                            d,
                            seq: s,
                            rcb,
                            nb,
                        })))
                    }
                }
            }
            _ => Ok(None),
        }
    }

    fn execute_decode_inplace(
        &self,
        gm: &GraphMeta,
        state: &mut dyn DecodeState,
        args: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let q4 = match gm.name.as_str() {
            "lm_decode_step" => false,
            "lm_decode_step_q4" => true,
            other => return Err(crate::err!("cpu backend: no in-place decode for '{other}'")),
        };
        let (mw, tail) = if q4 {
            self.model_w_q4(args)?
        } else {
            self.model_w_dense(args)?
        };
        let token = args[tail].as_i32()?;
        let pos = args[tail + 1].as_i32()?;
        let shape = vec![self.m.batch, self.m.vocab];
        let any = state.as_any_mut();
        if let Some(st) = any.downcast_mut::<CpuDecodeState>() {
            let logits = self.decode_step_core(&mw, &mut st.caches, token, pos);
            return Ok(vec![HostTensor::f32(logits, shape)]);
        }
        let st = any
            .downcast_mut::<CpuDecodeStateQ>()
            .ok_or_else(|| crate::err!("decode state is not a CPU decode state"))?;
        let logits = self.decode_step_core_q(&mw, st, token, pos);
        Ok(vec![HostTensor::f32(logits, shape)])
    }

    fn pool_occupancy(&self) -> Option<f64> {
        Some(self.pool.occupancy())
    }

    fn pool_threads(&self) -> Option<usize> {
        Some(self.pool.threads())
    }

    fn simd_path(&self) -> Option<&'static str> {
        Some(self.pool.simd().name())
    }

    fn kernel_profile(&self) -> Option<Vec<KernelStat>> {
        Some(self.pool.kernel_profile())
    }

    fn execute(&self, gm: &GraphMeta, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        match gm.name.as_str() {
            "init_params" => self.init_params(args),
            "init_lora" => self.init_lora(args),
            "lm_nll" => self.lm_nll(args),
            "lm_logits_last" => self.lm_logits(args, false, true),
            "lm_logits_all" => self.lm_logits(args, false, false),
            "lm_logits_last_lora" => self.lm_logits(args, true, true),
            "lm_logits_all_lora" => self.lm_logits(args, true, false),
            "lm_nll_q4" => self.lm_nll_q4(args),
            "lm_prefill" => self.prefill(args, false),
            "lm_prefill_q4" => self.prefill(args, true),
            "lm_decode_step" => self.decode_step(args, false),
            "lm_decode_step_q4" => self.decode_step(args, true),
            "train_step" => self.train_step(args),
            "lora_step" => self.lora_step(args),
            "dequant_matmul" => self.dequant_matmul_graph(gm, args),
            "quantize_blocks_abs" => self.quantize_blocks(gm, args, Norm::Absmax),
            "quantize_blocks_signed" => self.quantize_blocks(gm, args, Norm::SignedAbsmax),
            other => Err(crate::err!("cpu backend: unknown graph '{other}'")),
        }
    }
}

// ---------------------------------------------------------------------
// small element-wise helpers (the tiled matmul/norm/attention kernels
// live in super::kernels)
// ---------------------------------------------------------------------

fn add_in_place(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

fn scale_in_place(v: &mut [f32], s: f32) {
    for x in v.iter_mut() {
        *x *= s;
    }
}

fn gelu(x: f32) -> f32 {
    let u = GELU_C * (x + 0.044715 * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

fn gelu_grad(x: f32) -> f32 {
    let x2 = x * x;
    let u = GELU_C * (x + 0.044715 * x * x2);
    let th = u.tanh();
    0.5 * (1.0 + th) + 0.5 * x * (1.0 - th * th) * GELU_C * (1.0 + 3.0 * 0.044715 * x2)
}

// ---------------------------------------------------------------------
// linear (+ optional LoRA adapter) forward/backward
// ---------------------------------------------------------------------

/// A LoRA adapter view: `y += scale * (x @ a) @ b`.
#[derive(Clone, Copy)]
struct Lora<'a> {
    a: &'a [f32],
    b: &'a [f32],
    r: usize,
    scale: f32,
}

/// `y = x @ w (+ lora)`; returns (y, cached `x @ a`).
fn lin_fwd(
    pool: &ThreadPool,
    x: &[f32],
    w: &[f32],
    t: usize,
    k: usize,
    n: usize,
    lora: Option<Lora<'_>>,
) -> (Vec<f32>, Option<Vec<f32>>) {
    let mut y = tiling::matmul(pool, x, w, t, k, n);
    let mut xa_cache = None;
    if let Some(l) = lora {
        let xa = tiling::matmul(pool, x, l.a, t, k, l.r);
        let mut delta = tiling::matmul(pool, &xa, l.b, t, l.r, n);
        scale_in_place(&mut delta, l.scale);
        add_in_place(&mut y, &delta);
        xa_cache = Some(xa);
    }
    (y, xa_cache)
}

/// Backward of [`lin_fwd`]: returns (dx, dw?, (da, db)?).
#[allow(clippy::too_many_arguments)]
fn lin_bwd(
    pool: &ThreadPool,
    x: &[f32],
    w: &[f32],
    xa: Option<&Vec<f32>>,
    lora: Option<Lora<'_>>,
    dy: &[f32],
    t: usize,
    k: usize,
    n: usize,
    want_dw: bool,
    want_dlora: bool,
) -> (Vec<f32>, Option<Vec<f32>>, Option<(Vec<f32>, Vec<f32>)>) {
    let mut dx = tiling::matmul_nt(pool, dy, w, t, k, n);
    let dw = if want_dw {
        Some(tiling::matmul_tn(pool, x, dy, t, k, n))
    } else {
        None
    };
    let mut dlora = None;
    if let Some(l) = lora {
        // dxa = scale * dy @ b^T  [t, r]
        let mut dxa = tiling::matmul_nt(pool, dy, l.b, t, l.r, n);
        scale_in_place(&mut dxa, l.scale);
        if want_dlora {
            let da = tiling::matmul_tn(pool, x, &dxa, t, k, l.r);
            let xa = xa.expect("lora forward cache");
            let mut db = tiling::matmul_tn(pool, xa, dy, t, l.r, n);
            scale_in_place(&mut db, l.scale);
            dlora = Some((da, db));
        }
        // dx += dxa @ a^T
        let dxl = tiling::matmul_nt(pool, &dxa, l.a, t, k, l.r);
        add_in_place(&mut dx, &dxl);
    }
    (dx, dw, dlora)
}

// ---------------------------------------------------------------------
// KV-cached serving kernels (lm_prefill / lm_decode_step)
// ---------------------------------------------------------------------

/// Per-layer weight views for the decode step.
struct LayerW<'a> {
    g1: &'a [f32],
    wqkv: MatW<'a>,
    wo: MatW<'a>,
    g2: &'a [f32],
    win: MatW<'a>,
    wout: MatW<'a>,
}

/// Whole-model weight views for the decode step (dense or q4).
struct ModelW<'a> {
    embed: &'a [f32],
    pos: &'a [f32],
    layers: Vec<LayerW<'a>>,
    lnf: &'a [f32],
    head: &'a [f32],
}

// ---------------------------------------------------------------------
// model forward/backward
// ---------------------------------------------------------------------

/// Per-layer activation cache for backprop.
struct LayerCache {
    x_in: Vec<f32>,
    rms1: Vec<f32>,
    a1: Vec<f32>,
    qkv: Vec<f32>,
    xa_qkv: Option<Vec<f32>>,
    att: Vec<f32>, // [B*H*S*S] softmax probabilities (0 where masked)
    y: Vec<f32>,   // attention mix, pre-wo
    xa_wo: Option<Vec<f32>>,
    x_mid: Vec<f32>,
    rms2: Vec<f32>,
    a2: Vec<f32>,
    h_pre: Vec<f32>,
    h: Vec<f32>,
    xa_win: Option<Vec<f32>>,
    xa_wout: Option<Vec<f32>>,
}

struct Cache {
    layers: Vec<LayerCache>,
    x_out: Vec<f32>,
    rmsf: Vec<f32>,
    xf: Vec<f32>,
}

/// Validate one OPQ outlier side-table against its matrix: equal
/// `idx`/`val` lengths, strictly ascending indices, and every index
/// within the matrix's `k * n` weights — so a malformed hand-built
/// serving prefix fails with a runtime error at weight-view assembly
/// instead of an out-of-bounds panic inside a pooled kernel.
fn check_side_table(name: &str, out_idx: &[u32], out_val: &[f32], elems: usize) -> Result<()> {
    if out_idx.len() != out_val.len() {
        return Err(crate::err!(
            "{name}: outlier_idx has {} entries but outlier_val has {}",
            out_idx.len(),
            out_val.len()
        ));
    }
    if !out_idx.windows(2).all(|p| p[0] < p[1]) {
        return Err(crate::err!(
            "{name}: outlier_idx must be strictly ascending"
        ));
    }
    if let Some(&last) = out_idx.last() {
        if last as usize >= elems {
            return Err(crate::err!(
                "{name}: outlier index {last} out of range ({elems} weights)"
            ));
        }
    }
    Ok(())
}

/// Base-parameter slice indices in the canonical flat order.
fn p_embed() -> usize {
    0
}
fn p_pos() -> usize {
    1
}
fn p_layer(l: usize) -> usize {
    2 + 6 * l // ln1, wqkv, wo, ln2, win, wout
}
fn p_lnf(n_layers: usize) -> usize {
    2 + 6 * n_layers
}
fn p_head(n_layers: usize) -> usize {
    3 + 6 * n_layers
}

impl CpuBackend {
    fn dims(&self) -> (usize, usize, usize, usize, usize, usize, usize) {
        let m = &self.m;
        (
            m.batch,
            m.seq_len,
            m.d_model,
            m.n_heads,
            m.d_model / m.n_heads,
            m.d_ff,
            m.vocab,
        )
    }

    fn lora_at<'a>(&self, lora: Option<&[&'a [f32]]>, layer: usize, slot: usize) -> Option<Lora<'a>> {
        lora.map(|l| Lora {
            a: l[8 * layer + 2 * slot],
            b: l[8 * layer + 2 * slot + 1],
            r: self.m.lora_rank,
            scale: LORA_ALPHA / self.m.lora_rank as f32,
        })
    }

    /// Full forward pass; returns (logits [B*S, V], cache).
    fn forward(&self, p: &[&[f32]], lora: Option<&[&[f32]]>, tokens: &[i32]) -> (Vec<f32>, Cache) {
        let (b, s, d, h, _hd, ff, v) = self.dims();
        let t = b * s;
        let nl = self.m.n_layers;
        let pool = &*self.pool;

        // embedding gather + positional, row-parallel
        let embed = p[p_embed()];
        let pos = p[p_pos()];
        let mut x = vec![0.0f32; t * d];
        {
            let xs = SyncSlice::new(&mut x);
            pool.run(t, |ti| {
                let si = ti % s;
                let tok = (tokens[ti].max(0) as usize).min(v - 1);
                // SAFETY: row ti is written only by task ti.
                let xr = unsafe { xs.slice_mut(ti * d, d) };
                let er = &embed[tok * d..(tok + 1) * d];
                let pr = &pos[si * d..(si + 1) * d];
                for j in 0..d {
                    xr[j] = er[j] + pr[j];
                }
            });
        }

        let mut layers = Vec::with_capacity(nl);
        for l in 0..nl {
            let base = p_layer(l);
            let (g1, wqkv, wo, g2, win, wout) = (
                p[base],
                p[base + 1],
                p[base + 2],
                p[base + 3],
                p[base + 4],
                p[base + 5],
            );
            let x_in = x.clone();
            let (a1, rms1) = tiling::rmsnorm(pool, &x, g1, d);
            let (qkv, xa_qkv) = lin_fwd(pool, &a1, wqkv, t, d, 3 * d, self.lora_at(lora, l, 0));

            // causal multi-head attention, fanned out over (row x head)
            let (att, y) = attention::mha_forward(pool, &qkv, b, h, s, d);

            let (attn_out, xa_wo) = lin_fwd(pool, &y, wo, t, d, d, self.lora_at(lora, l, 1));
            add_in_place(&mut x, &attn_out);
            let x_mid = x.clone();

            let (a2, rms2) = tiling::rmsnorm(pool, &x, g2, d);
            let (h_pre, xa_win) = lin_fwd(pool, &a2, win, t, d, ff, self.lora_at(lora, l, 2));
            let hact = tiling::par_map(pool, &h_pre, gelu);
            let (mlp_out, xa_wout) = lin_fwd(pool, &hact, wout, t, ff, d, self.lora_at(lora, l, 3));
            add_in_place(&mut x, &mlp_out);

            layers.push(LayerCache {
                x_in,
                rms1,
                a1,
                qkv,
                xa_qkv,
                att,
                y,
                xa_wo,
                x_mid,
                rms2,
                a2,
                h_pre,
                h: hact,
                xa_win,
                xa_wout,
            });
        }

        let x_out = x.clone();
        let (xf, rmsf) = tiling::rmsnorm(pool, &x, p[p_lnf(nl)], d);
        let logits = tiling::matmul(pool, &xf, p[p_head(nl)], t, d, v);
        (
            logits,
            Cache {
                layers,
                x_out,
                rmsf,
                xf,
            },
        )
    }

    /// Reverse-mode backprop from `dlogits`; returns (base grads in
    /// canonical order, lora grads in flat A/B order) per the flags.
    #[allow(clippy::type_complexity)]
    fn backward(
        &self,
        p: &[&[f32]],
        lora: Option<&[&[f32]]>,
        tokens: &[i32],
        cache: &Cache,
        dlogits: &[f32],
        want_base: bool,
        want_lora: bool,
    ) -> (Option<Vec<Vec<f32>>>, Option<Vec<Vec<f32>>>) {
        let (b, s, d, h, _hd, ff, v) = self.dims();
        let t = b * s;
        let nl = self.m.n_layers;
        let pool = &*self.pool;

        let mut base_grads: Vec<Vec<f32>> = if want_base {
            p.iter().map(|w| vec![0.0f32; w.len()]).collect()
        } else {
            Vec::new()
        };
        let mut lora_grads: Vec<Vec<f32>> = if want_lora {
            lora.expect("lora params for lora grads")
                .iter()
                .map(|w| vec![0.0f32; w.len()])
                .collect()
        } else {
            Vec::new()
        };

        // head + final norm
        let head = p[p_head(nl)];
        let mut dx = tiling::matmul_nt(pool, dlogits, head, t, d, v);
        if want_base {
            base_grads[p_head(nl)] = tiling::matmul_tn(pool, &cache.xf, dlogits, t, d, v);
        }
        let (dx_ln, dgf) =
            tiling::rmsnorm_bwd(pool, &cache.x_out, p[p_lnf(nl)], &cache.rmsf, &dx, d);
        dx = dx_ln;
        if want_base {
            base_grads[p_lnf(nl)] = dgf;
        }

        for l in (0..nl).rev() {
            let lc = &cache.layers[l];
            let base = p_layer(l);
            let (g1, wqkv, wo, g2, win, wout) = (
                p[base],
                p[base + 1],
                p[base + 2],
                p[base + 3],
                p[base + 4],
                p[base + 5],
            );

            // ---- MLP block: x = x_mid + wout(gelu(win(rmsnorm(x_mid)))) ----
            let (dh, dwout, dl_wout) = lin_bwd(
                pool,
                &lc.h,
                wout,
                lc.xa_wout.as_ref(),
                self.lora_at(lora, l, 3),
                &dx,
                t,
                ff,
                d,
                want_base,
                want_lora,
            );
            let mut dh_pre = dh;
            tiling::par_zip_apply(pool, &mut dh_pre, &lc.h_pre, |g, xp| g * gelu_grad(xp));
            let (da2, dwin, dl_win) = lin_bwd(
                pool,
                &lc.a2,
                win,
                lc.xa_win.as_ref(),
                self.lora_at(lora, l, 2),
                &dh_pre,
                t,
                d,
                ff,
                want_base,
                want_lora,
            );
            let (dx_ln2, dg2) = tiling::rmsnorm_bwd(pool, &lc.x_mid, g2, &lc.rms2, &da2, d);
            add_in_place(&mut dx, &dx_ln2); // residual: skip + norm path

            // ---- attention block ----
            let (dy, dwo, dl_wo) = lin_bwd(
                pool,
                &lc.y,
                wo,
                lc.xa_wo.as_ref(),
                self.lora_at(lora, l, 1),
                &dx,
                t,
                d,
                d,
                want_base,
                want_lora,
            );
            // backprop through softmax(QK^T/sqrt(hd)) V, per (row x head)
            let dqkv = attention::mha_backward(pool, &lc.qkv, &lc.att, &dy, b, h, s, d);
            let (da1, dwqkv, dl_qkv) = lin_bwd(
                pool,
                &lc.a1,
                wqkv,
                lc.xa_qkv.as_ref(),
                self.lora_at(lora, l, 0),
                &dqkv,
                t,
                d,
                3 * d,
                want_base,
                want_lora,
            );
            let (dx_ln1, dg1) = tiling::rmsnorm_bwd(pool, &lc.x_in, g1, &lc.rms1, &da1, d);
            add_in_place(&mut dx, &dx_ln1);

            if want_base {
                base_grads[base] = dg1;
                base_grads[base + 1] = dwqkv.expect("dwqkv");
                base_grads[base + 2] = dwo.expect("dwo");
                base_grads[base + 3] = dg2;
                base_grads[base + 4] = dwin.expect("dwin");
                base_grads[base + 5] = dwout.expect("dwout");
            }
            if want_lora {
                let sets = [dl_qkv, dl_wo, dl_win, dl_wout];
                for (slot, dl) in sets.into_iter().enumerate() {
                    let (da, db) = dl.expect("lora grads");
                    lora_grads[8 * l + 2 * slot] = da;
                    lora_grads[8 * l + 2 * slot + 1] = db;
                }
            }
        }

        // embedding + positional grads
        if want_base {
            let mut dembed = vec![0.0f32; v * d];
            let mut dpos = vec![0.0f32; s * d];
            for bi in 0..b {
                for si in 0..s {
                    let ti = bi * s + si;
                    let tok = (tokens[ti].max(0) as usize).min(v - 1);
                    let dxr = &dx[ti * d..(ti + 1) * d];
                    let er = &mut dembed[tok * d..(tok + 1) * d];
                    for j in 0..d {
                        er[j] += dxr[j];
                    }
                    let pr = &mut dpos[si * d..(si + 1) * d];
                    for j in 0..d {
                        pr[j] += dxr[j];
                    }
                }
            }
            base_grads[p_embed()] = dembed;
            base_grads[p_pos()] = dpos;
        }

        (
            if want_base { Some(base_grads) } else { None },
            if want_lora { Some(lora_grads) } else { None },
        )
    }

    /// Per-sequence NLL sums + (optionally) dlogits for the *mean* loss.
    fn nll_from_logits(
        &self,
        logits: &[f32],
        tokens: &[i32],
        want_grad: bool,
    ) -> (Vec<f32>, f32, Option<Vec<f32>>) {
        let (b, s, _, _, _, _, v) = self.dims();
        let supervised = (b * (s - 1)) as f32;
        let gs = 1.0 / supervised;
        let mut per_seq = vec![0.0f32; b];
        let mut dlogits = if want_grad {
            Some(vec![0.0f32; logits.len()])
        } else {
            None
        };
        // row-parallel softmax/NLL: sequence bi owns rows bi*s..(bi+1)*s
        // of dlogits and entry bi of per_seq; the per-sequence f64
        // accumulator keeps the serial summation order.
        {
            let ps = SyncSlice::new(&mut per_seq);
            let dls = dlogits.as_mut().map(|dl| SyncSlice::new(dl.as_mut_slice()));
            self.pool.run(b, |bi| {
                let mut acc = 0.0f64;
                // SAFETY: dlogits rows of sequence bi are written only by
                // task bi.
                let mut drows = dls
                    .as_ref()
                    .map(|dl| unsafe { dl.slice_mut(bi * s * v, s * v) });
                for si in 0..s - 1 {
                    let ti = bi * s + si;
                    let row = &logits[ti * v..(ti + 1) * v];
                    let tgt = (tokens[bi * s + si + 1].max(0) as usize).min(v - 1);
                    let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut denom = 0.0f32;
                    for &x in row {
                        denom += (x - maxv).exp();
                    }
                    let lse = maxv + denom.ln();
                    acc += (lse - row[tgt]) as f64;
                    if let Some(dl) = drows.as_mut() {
                        let drow = &mut dl[si * v..(si + 1) * v];
                        let inv = 1.0 / denom;
                        for (j, dv) in drow.iter_mut().enumerate() {
                            let p = (row[j] - maxv).exp() * inv;
                            *dv = (p - if j == tgt { 1.0 } else { 0.0 }) * gs;
                        }
                    }
                }
                // SAFETY: entry bi is written only by task bi.
                unsafe { ps.slice_mut(bi, 1) }[0] = acc as f32;
            });
        }
        let mean = per_seq.iter().map(|&x| x as f64).sum::<f64>() as f32 / supervised;
        (per_seq, mean, dlogits)
    }

    /// Mean loss + raw (clip-free, pre-Adam) grads; the unit the
    /// finite-difference tests check.
    #[allow(clippy::type_complexity)]
    fn loss_and_grads(
        &self,
        p: &[&[f32]],
        lora: Option<&[&[f32]]>,
        tokens: &[i32],
        want_base: bool,
        want_lora: bool,
    ) -> (f32, Option<Vec<Vec<f32>>>, Option<Vec<Vec<f32>>>) {
        let (logits, cache) = self.forward(p, lora, tokens);
        let (_, mean, dlogits) = self.nll_from_logits(&logits, tokens, true);
        let dl = dlogits.expect("grad requested");
        let (bg, lg) = self.backward(p, lora, tokens, &cache, &dl, want_base, want_lora);
        (mean, bg, lg)
    }

    // -----------------------------------------------------------------
    // optimizer
    // -----------------------------------------------------------------

    /// One AdamW step over flat parameter lists (mirrors `_adamw_update`).
    /// The global-norm reduction stays serial (fixed order, f64); the
    /// element-wise update fans out over fixed-size element chunks *within*
    /// each tensor — tensor sizes span orders of magnitude (embed/head vs
    /// the norm gains), so per-tensor tasks would leave most lanes idle
    /// behind the two big matrices. Each chunk has exactly one owner and
    /// every element's arithmetic is independent, so results are
    /// bit-identical at any thread count.
    #[allow(clippy::type_complexity)]
    fn adamw(
        &self,
        params: &[&[f32]],
        grads: &[Vec<f32>],
        m_in: &[&[f32]],
        v_in: &[&[f32]],
        step: i32,
        decay: &[bool],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>, i32) {
        let new_step = step + 1;
        let t = new_step as f32;
        let mut sq = 0.0f64;
        for g in grads {
            for &x in g {
                sq += (x as f64) * (x as f64);
            }
        }
        let gnorm = (sq + 1e-12).sqrt() as f32;
        let clip_scale = (GRAD_CLIP / gnorm).min(1.0);
        let bc1 = 1.0 - BETA1.powf(t);
        let bc2 = 1.0 - BETA2.powf(t);

        let mut new_p: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        let mut new_m: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        let mut new_v: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        {
            // (tensor, element lo, element hi) work items of bounded size
            const ELEM_CHUNK: usize = 8192;
            let mut work: Vec<(usize, usize, usize)> = Vec::new();
            for (i, p) in params.iter().enumerate() {
                let mut lo = 0;
                while lo < p.len() {
                    let hi = (lo + ELEM_CHUNK).min(p.len());
                    work.push((i, lo, hi));
                    lo = hi;
                }
            }
            let ps: Vec<SyncSlice<f32>> = new_p.iter_mut().map(|v| SyncSlice::new(v)).collect();
            let ms: Vec<SyncSlice<f32>> = new_m.iter_mut().map(|v| SyncSlice::new(v)).collect();
            let vs: Vec<SyncSlice<f32>> = new_v.iter_mut().map(|v| SyncSlice::new(v)).collect();
            self.pool.run(work.len(), |wi| {
                let (i, lo, hi) = work[wi];
                let (p, g, m0, v0) = (params[i], &grads[i], m_in[i], v_in[i]);
                // SAFETY: element range [lo, hi) of tensor i is written
                // only by work item wi.
                let pn = unsafe { ps[i].slice_mut(lo, hi - lo) };
                let mn = unsafe { ms[i].slice_mut(lo, hi - lo) };
                let vn = unsafe { vs[i].slice_mut(lo, hi - lo) };
                for j in lo..hi {
                    let gj = g[j] * clip_scale;
                    let mj = BETA1 * m0[j] + (1.0 - BETA1) * gj;
                    let vj = BETA2 * v0[j] + (1.0 - BETA2) * gj * gj;
                    let mhat = mj / bc1;
                    let vhat = vj / bc2;
                    let mut upd = mhat / (vhat.sqrt() + ADAM_EPS);
                    if decay[i] {
                        upd += WEIGHT_DECAY * p[j];
                    }
                    pn[j - lo] = p[j] - LR * upd;
                    mn[j - lo] = mj;
                    vn[j - lo] = vj;
                }
            });
        }
        (new_p, new_m, new_v, new_step)
    }

    // -----------------------------------------------------------------
    // graph entry points
    // -----------------------------------------------------------------

    fn init_params(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let seed = args[0].scalar_u32_value()? as u64;
        let mut out = Vec::new();
        for (idx, (name, shape)) in param_specs(&self.m).into_iter().enumerate() {
            let n: usize = shape.iter().product();
            let mut rng = Pcg64::seed_with_stream(seed, 0xB0F4_0000 + idx as u64);
            let data = if name.ends_with(".ln1") || name.ends_with(".ln2") || name == "lnf" {
                vec![1.0f32; n]
            } else if name == "embed" || name == "pos" {
                let mut v = vec![0.0f32; n];
                rng.fill_gaussian_f32(&mut v, 0.02);
                v
            } else {
                let std = 1.0 / (shape[0] as f32).sqrt();
                let mut v = vec![0.0f32; n];
                rng.fill_gaussian_f32(&mut v, std);
                v
            };
            out.push(HostTensor::f32(data, shape));
        }
        Ok(out)
    }

    fn init_lora(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let seed = args[0].scalar_u32_value()? as u64;
        let mut out = Vec::new();
        for (idx, (name, shape)) in lora_specs(&self.m).into_iter().enumerate() {
            let n: usize = shape.iter().product();
            let data = if name.ends_with(".lora_a") {
                let mut rng = Pcg64::seed_with_stream(seed, 0xB0F4_1000 + idx as u64);
                let std = 1.0 / (shape[0] as f32).sqrt();
                let mut v = vec![0.0f32; n];
                rng.fill_gaussian_f32(&mut v, std);
                v
            } else {
                vec![0.0f32; n] // B = 0: the adapter starts as identity
            };
            out.push(HostTensor::f32(data, shape));
        }
        Ok(out)
    }

    fn param_views<'a>(&self, args: &'a [HostTensor], lo: usize, n: usize) -> Result<Vec<&'a [f32]>> {
        args[lo..lo + n].iter().map(|t| t.as_f32()).collect()
    }

    fn lm_nll(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let np = param_specs(&self.m).len();
        let p = self.param_views(args, 0, np)?;
        let tokens = args[np].as_i32()?;
        let (logits, _) = self.forward(&p, None, tokens);
        let (per_seq, _, _) = self.nll_from_logits(&logits, tokens, false);
        Ok(vec![HostTensor::f32(per_seq, vec![self.m.batch])])
    }

    fn lm_logits(&self, args: &[HostTensor], lora: bool, last_only: bool) -> Result<Vec<HostTensor>> {
        let np = param_specs(&self.m).len();
        let nl = lora_specs(&self.m).len();
        let p = self.param_views(args, 0, np)?;
        let (lora_views, tok_idx) = if lora {
            (Some(self.param_views(args, np, nl)?), np + nl)
        } else {
            (None, np)
        };
        let tokens = args[tok_idx].as_i32()?;
        let (logits, _) = self.forward(&p, lora_views.as_deref(), tokens);
        let (b, s, _, _, _, _, v) = self.dims();
        if last_only {
            let mut out = vec![0.0f32; b * v];
            for bi in 0..b {
                let ti = bi * s + (s - 1);
                out[bi * v..(bi + 1) * v].copy_from_slice(&logits[ti * v..(ti + 1) * v]);
            }
            Ok(vec![HostTensor::f32(out, vec![b, v])])
        } else {
            Ok(vec![HostTensor::f32(logits, vec![b, s, v])])
        }
    }

    fn lm_nll_q4(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let pspecs = param_specs(&self.m);
        let mm = matmul_param_names(&self.m);
        let n_mm = mm.len();
        let n_f32 = pspecs.len() - n_mm;
        let block = self.m.block;

        let f32_views = self.param_views(args, 0, n_f32)?;
        let levels = args[n_f32 + 2 * n_mm].as_f32()?;
        let tokens = args[n_f32 + 2 * n_mm + 1].as_i32()?;

        // dequantize the matmul weights (codes + absmax -> f32)
        let shapes: std::collections::HashMap<String, Vec<usize>> =
            pspecs.iter().cloned().collect();
        let mut deq: Vec<Vec<f32>> = Vec::with_capacity(n_mm);
        for (i, name) in mm.iter().enumerate() {
            let codes = args[n_f32 + i].as_u8()?;
            let absmax = args[n_f32 + n_mm + i].as_f32()?;
            let shp = &shapes[name];
            let (k, n) = (shp[0], shp[1]);
            let nb = n / block;
            let path = self.pool.simd();
            let mut w = vec![0.0f32; k * n];
            for kk in 0..k {
                for jb in 0..nb {
                    let m = absmax[kk * nb + jb];
                    let crow = &codes[kk * n + jb * block..kk * n + (jb + 1) * block];
                    let wrow = &mut w[kk * n + jb * block..kk * n + (jb + 1) * block];
                    simd::q4_fill_dequant(path, wrow, m, crow, levels);
                }
            }
            deq.push(w);
        }

        // reassemble the full canonical parameter list
        let mut p: Vec<&[f32]> = Vec::with_capacity(pspecs.len());
        let mut fi = 0usize;
        let mut qi = 0usize;
        for (name, _) in &pspecs {
            if mm.contains(name) {
                p.push(&deq[qi]);
                qi += 1;
            } else {
                p.push(f32_views[fi]);
                fi += 1;
            }
        }
        let (logits, _) = self.forward(&p, None, tokens);
        let (per_seq, _, _) = self.nll_from_logits(&logits, tokens, false);
        Ok(vec![HostTensor::f32(per_seq, vec![self.m.batch])])
    }

    // -----------------------------------------------------------------
    // KV-cached serving: prefill + incremental decode
    // -----------------------------------------------------------------

    /// Assemble the 16 canonical dense parameter views from a q4 serving
    /// argument prefix, materializing the matmul weights with the OPQ
    /// side-table patched over them (prefill pays this once per admitted
    /// batch; the decode step stays fused).
    /// Returns (weight storage, index of the first tail argument).
    fn q4_dense_weights(&self, args: &[HostTensor]) -> Result<(Vec<Vec<f32>>, usize)> {
        let pspecs = param_specs(&self.m);
        let mm = matmul_param_names(&self.m);
        let (n_mm, n_f32) = (mm.len(), pspecs.len() - mm.len());
        let levels = args[n_f32 + 5 * n_mm].as_f32()?;
        let shapes: std::collections::HashMap<String, Vec<usize>> =
            pspecs.iter().cloned().collect();
        let mut deq = Vec::with_capacity(n_mm);
        for (i, name) in mm.iter().enumerate() {
            let shp = &shapes[name];
            let out_idx = args[n_f32 + 3 * n_mm + i].as_u32()?;
            let out_val = args[n_f32 + 4 * n_mm + i].as_f32()?;
            check_side_table(name, out_idx, out_val, shp[0] * shp[1])?;
            deq.push(q4::dequant_q4_weight(
                &self.pool,
                args[n_f32 + i].as_u8()?,
                args[n_f32 + n_mm + i].as_u8()?,
                args[n_f32 + 2 * n_mm + i].as_f32()?,
                levels,
                out_idx,
                out_val,
                shp[0],
                shp[1],
                self.m.block,
            ));
        }
        Ok((deq, n_f32 + 5 * n_mm + 1))
    }

    /// `lm_prefill` / `lm_prefill_q4`: full forward over a right-padded
    /// prompt batch; returns per-row logits at position `lens[b]-1` plus
    /// the per-layer K/V tensors extracted from the attention cache.
    fn prefill(&self, args: &[HostTensor], q4: bool) -> Result<Vec<HostTensor>> {
        crate::testkit::faults::prefill_hook()?;
        let (b, s, d, _, _, _, v) = self.dims();
        let nl = self.m.n_layers;
        let np = param_specs(&self.m).len();
        let deq_store;
        let (p, tail): (Vec<&[f32]>, usize) = if q4 {
            let (deq, tail) = self.q4_dense_weights(args)?;
            deq_store = deq;
            let pspecs = param_specs(&self.m);
            let mm = matmul_param_names(&self.m);
            let f32_views = self.param_views(args, 0, np - mm.len())?;
            let mut p = Vec::with_capacity(np);
            let (mut fi, mut qi) = (0usize, 0usize);
            for (name, _) in &pspecs {
                if mm.contains(name) {
                    p.push(deq_store[qi].as_slice());
                    qi += 1;
                } else {
                    p.push(f32_views[fi]);
                    fi += 1;
                }
            }
            (p, tail)
        } else {
            (self.param_views(args, 0, np)?, np)
        };
        let tokens = args[tail].as_i32()?;
        let lens = args[tail + 1].as_i32()?;

        let (logits, cache) = self.forward(&p, None, tokens);
        let mut last = vec![0.0f32; b * v];
        for bi in 0..b {
            let len = (lens[bi].max(1) as usize).min(s);
            let ti = bi * s + (len - 1);
            last[bi * v..(bi + 1) * v].copy_from_slice(&logits[ti * v..(ti + 1) * v]);
        }
        let mut out = vec![HostTensor::f32(last, vec![b, v])];
        for l in 0..nl {
            let qkv = &cache.layers[l].qkv;
            let mut kc = vec![0.0f32; b * s * d];
            let mut vc = vec![0.0f32; b * s * d];
            for t in 0..b * s {
                kc[t * d..(t + 1) * d].copy_from_slice(&qkv[t * 3 * d + d..t * 3 * d + 2 * d]);
                vc[t * d..(t + 1) * d]
                    .copy_from_slice(&qkv[t * 3 * d + 2 * d..t * 3 * d + 3 * d]);
            }
            out.push(HostTensor::f32(kc, vec![b, s, d]));
            out.push(HostTensor::f32(vc, vec![b, s, d]));
        }
        Ok(out)
    }

    /// Weight views for the decode step (dense variant).
    fn model_w_dense<'a>(&self, args: &'a [HostTensor]) -> Result<(ModelW<'a>, usize)> {
        let np = param_specs(&self.m).len();
        let p = self.param_views(args, 0, np)?;
        let nl = self.m.n_layers;
        let mut layers = Vec::with_capacity(nl);
        for l in 0..nl {
            let base = p_layer(l);
            layers.push(LayerW {
                g1: p[base],
                wqkv: MatW::Dense(p[base + 1]),
                wo: MatW::Dense(p[base + 2]),
                g2: p[base + 3],
                win: MatW::Dense(p[base + 4]),
                wout: MatW::Dense(p[base + 5]),
            });
        }
        Ok((
            ModelW {
                embed: p[p_embed()],
                pos: p[p_pos()],
                layers,
                lnf: p[p_lnf(nl)],
                head: p[p_head(nl)],
            },
            np,
        ))
    }

    /// Weight views for the decode step (q4 + double-quantized constants
    /// + per-matrix OPQ outlier side-tables, empty when OPQ is off).
    fn model_w_q4<'a>(&self, args: &'a [HostTensor]) -> Result<(ModelW<'a>, usize)> {
        let pspecs = param_specs(&self.m);
        let mm = matmul_param_names(&self.m);
        let n_mm = mm.len();
        let n_f32 = pspecs.len() - n_mm;
        let nl = self.m.n_layers;
        let f = self.param_views(args, 0, n_f32)?;
        let levels = args[n_f32 + 5 * n_mm].as_f32()?;
        let block = self.m.block;
        // The codes tensor's element count IS the matrix's k*n, so the
        // side-table bound check needs no shape lookup; the validation
        // itself is O(#outliers) per matrix — noise next to the step's
        // matmuls, and it is what turns a malformed prefix into an error
        // instead of an out-of-bounds panic inside a pooled kernel.
        fn matw<'a>(
            args: &'a [HostTensor],
            n_f32: usize,
            n_mm: usize,
            i: usize,
            levels: &'a [f32],
            block: usize,
            name: &str,
        ) -> Result<MatW<'a>> {
            let codes = args[n_f32 + i].as_u8()?;
            let out_idx = args[n_f32 + 3 * n_mm + i].as_u32()?;
            let out_val = args[n_f32 + 4 * n_mm + i].as_f32()?;
            check_side_table(name, out_idx, out_val, codes.len())?;
            Ok(MatW::Q4 {
                codes,
                am_codes: args[n_f32 + n_mm + i].as_u8()?,
                am_params: args[n_f32 + 2 * n_mm + i].as_f32()?,
                levels,
                block,
                out_idx,
                out_val,
            })
        }
        let mut layers = Vec::with_capacity(nl);
        for l in 0..nl {
            let w = |i: usize| matw(args, n_f32, n_mm, i, levels, block, &mm[i]);
            layers.push(LayerW {
                g1: f[2 + 2 * l],
                wqkv: w(4 * l)?,
                wo: w(4 * l + 1)?,
                g2: f[3 + 2 * l],
                win: w(4 * l + 2)?,
                wout: w(4 * l + 3)?,
            });
        }
        Ok((
            ModelW {
                embed: f[0],
                pos: f[1],
                layers,
                lnf: f[2 + 2 * nl],
                head: f[3 + 2 * nl],
            },
            n_f32 + 5 * n_mm + 1,
        ))
    }

    /// `lm_decode_step` / `lm_decode_step_q4` (clone-based cache path):
    /// parses the cache tensors out of `args`, runs the shared core, and
    /// returns the updated caches next to the logits.
    fn decode_step(&self, args: &[HostTensor], q4: bool) -> Result<Vec<HostTensor>> {
        let (b, s, d, _, _, _, v) = self.dims();
        let nl = self.m.n_layers;
        let (mw, tail) = if q4 {
            self.model_w_q4(args)?
        } else {
            self.model_w_dense(args)?
        };
        let mut caches: Vec<Vec<f32>> = (0..2 * nl)
            .map(|i| args[tail + i].as_f32().map(|x| x.to_vec()))
            .collect::<Result<_>>()?;
        let token = args[tail + 2 * nl].as_i32()?;
        let pos = args[tail + 2 * nl + 1].as_i32()?;
        let logits_out = self.decode_step_core(&mw, &mut caches, token, pos);
        let mut out = vec![HostTensor::f32(logits_out, vec![b, v])];
        for c in caches {
            out.push(HostTensor::f32(c, vec![b, s, d]));
        }
        Ok(out)
    }

    /// One decode step over the per-row weight views: one token per
    /// active row, appending one K/V column at `pos[bi]` and attending
    /// over `pos[bi]+1` cached positions. Rows with `pos < 0` (or past
    /// the cache) are inactive: zero logits, caches untouched.
    ///
    /// The row loop fans out across the kernel pool — each batch row owns
    /// its own cache rows and logits row, and runs the full forward's
    /// exact per-row loop order, so logits are bit-identical to
    /// full-context re-execution at every thread count. Shared by the
    /// clone-based [`CpuBackend::decode_step`] and the in-place
    /// [`Backend::execute_decode_inplace`] protocol (same core, so the
    /// two paths are bit-identical by construction).
    fn decode_step_core(
        &self,
        mw: &ModelW<'_>,
        caches: &mut [Vec<f32>],
        token: &[i32],
        pos: &[i32],
    ) -> Vec<f32> {
        crate::testkit::faults::decode_hook();
        let _phase = phase_scope(KernelPhase::Decode);
        let (b, s, d, h, _hd, ff, v) = self.dims();
        let pool = &*self.pool;
        let slot = s * d;

        let mut logits_out = vec![0.0f32; b * v];
        let ls = SyncSlice::new(&mut logits_out);
        let cs: Vec<SyncSlice<f32>> = caches.iter_mut().map(|c| SyncSlice::new(c)).collect();
        pool.run(b, |bi| {
            if pos[bi] < 0 || pos[bi] as usize >= s {
                return;
            }
            let p = pos[bi] as usize;
            let tok = (token[bi].max(0) as usize).min(v - 1);
            let mut x = vec![0.0f32; d];
            for j in 0..d {
                x[j] = mw.embed[tok * d + j] + mw.pos[p * d + j];
            }
            for (li, lw) in mw.layers.iter().enumerate() {
                let (a1, _) = tiling::rmsnorm(pool, &x, lw.g1, d);
                let qkv = q4::row_matmul(pool, &a1, &lw.wqkv, d, 3 * d);
                // SAFETY: batch row bi's cache slots are read and written
                // only by task bi.
                let kc = unsafe { cs[2 * li].slice_mut(bi * slot, slot) };
                let vc = unsafe { cs[2 * li + 1].slice_mut(bi * slot, slot) };
                kc[p * d..(p + 1) * d].copy_from_slice(&qkv[d..2 * d]);
                vc[p * d..(p + 1) * d].copy_from_slice(&qkv[2 * d..3 * d]);
                let y = attention::decode_attention(pool, &qkv, kc, vc, d, h, p);
                let attn_out = q4::row_matmul(pool, &y, &lw.wo, d, d);
                add_in_place(&mut x, &attn_out);
                let (a2, _) = tiling::rmsnorm(pool, &x, lw.g2, d);
                let h_pre = q4::row_matmul(pool, &a2, &lw.win, d, ff);
                let mut hact = vec![0.0f32; ff];
                for (o, &i) in hact.iter_mut().zip(&h_pre) {
                    *o = gelu(i);
                }
                let mlp_out = q4::row_matmul(pool, &hact, &lw.wout, ff, d);
                add_in_place(&mut x, &mlp_out);
            }
            let (xf, _) = tiling::rmsnorm(pool, &x, mw.lnf, d);
            let lrow = tiling::matmul(pool, &xf, mw.head, 1, d, v);
            // SAFETY: logits row bi is written only by task bi.
            unsafe { ls.slice_mut(bi * v, v) }.copy_from_slice(&lrow);
        });
        logits_out
    }

    /// [`CpuBackend::decode_step_core`] over **block-quantized** resident
    /// caches (`BOF4_KV=q8|q4`): same per-row loop order and kernels,
    /// except the fresh K/V column is quantized at the append position
    /// and attention reads the codes fused through
    /// [`decode_attention_kv`] — no f32 cache row ever materializes.
    /// Deliberately a separate loop body (not a branch inside the f32
    /// core) so the `BOF4_KV=f32` path stays byte-for-byte the
    /// pre-`BOF4_KV` code.
    fn decode_step_core_q(
        &self,
        mw: &ModelW<'_>,
        st: &mut CpuDecodeStateQ,
        token: &[i32],
        pos: &[i32],
    ) -> Vec<f32> {
        crate::testkit::faults::decode_hook();
        let _phase = phase_scope(KernelPhase::Kv);
        let (b, s, d, h, _hd, ff, v) = self.dims();
        let pool = &*self.pool;
        let (fmt, block, norm, rcb, nb) = (st.fmt, st.block, st.norm, st.rcb, st.nb);
        let levels = &st.levels;
        let cb = st.cb.as_ref();
        let slot_cb = s * rcb;
        let slot_nb = s * nb;

        let mut logits_out = vec![0.0f32; b * v];
        let ls = SyncSlice::new(&mut logits_out);
        let ccs: Vec<SyncSlice<u8>> = st.codes.iter_mut().map(|c| SyncSlice::new(c)).collect();
        let scs: Vec<SyncSlice<f32>> = st.scales.iter_mut().map(|c| SyncSlice::new(c)).collect();
        pool.run(b, |bi| {
            if pos[bi] < 0 || pos[bi] as usize >= s {
                return;
            }
            let p = pos[bi] as usize;
            let tok = (token[bi].max(0) as usize).min(v - 1);
            let mut x = vec![0.0f32; d];
            for j in 0..d {
                x[j] = mw.embed[tok * d + j] + mw.pos[p * d + j];
            }
            for (li, lw) in mw.layers.iter().enumerate() {
                let (a1, _) = tiling::rmsnorm(pool, &x, lw.g1, d);
                let qkv = q4::row_matmul(pool, &a1, &lw.wqkv, d, 3 * d);
                // SAFETY: batch row bi's slab regions are read and
                // written only by task bi.
                let kc_c = unsafe { ccs[2 * li].slice_mut(bi * slot_cb, slot_cb) };
                let kc_s = unsafe { scs[2 * li].slice_mut(bi * slot_nb, slot_nb) };
                let vc_c = unsafe { ccs[2 * li + 1].slice_mut(bi * slot_cb, slot_cb) };
                let vc_s = unsafe { scs[2 * li + 1].slice_mut(bi * slot_nb, slot_nb) };
                quantize_kv_row(
                    fmt,
                    &qkv[d..2 * d],
                    block,
                    norm,
                    cb,
                    &mut kc_c[p * rcb..(p + 1) * rcb],
                    &mut kc_s[p * nb..(p + 1) * nb],
                );
                quantize_kv_row(
                    fmt,
                    &qkv[2 * d..3 * d],
                    block,
                    norm,
                    cb,
                    &mut vc_c[p * rcb..(p + 1) * rcb],
                    &mut vc_s[p * nb..(p + 1) * nb],
                );
                let kview = KvView {
                    fmt,
                    codes: kc_c,
                    scales: kc_s,
                    block,
                    levels,
                };
                let vview = KvView {
                    fmt,
                    codes: vc_c,
                    scales: vc_s,
                    block,
                    levels,
                };
                let y = decode_attention_kv(pool, &qkv, kview, vview, d, h, p);
                let attn_out = q4::row_matmul(pool, &y, &lw.wo, d, d);
                add_in_place(&mut x, &attn_out);
                let (a2, _) = tiling::rmsnorm(pool, &x, lw.g2, d);
                let h_pre = q4::row_matmul(pool, &a2, &lw.win, d, ff);
                let mut hact = vec![0.0f32; ff];
                for (o, &i) in hact.iter_mut().zip(&h_pre) {
                    *o = gelu(i);
                }
                let mlp_out = q4::row_matmul(pool, &hact, &lw.wout, ff, d);
                add_in_place(&mut x, &mlp_out);
            }
            let (xf, _) = tiling::rmsnorm(pool, &x, mw.lnf, d);
            let lrow = tiling::matmul(pool, &xf, mw.head, 1, d, v);
            // SAFETY: logits row bi is written only by task bi.
            unsafe { ls.slice_mut(bi * v, v) }.copy_from_slice(&lrow);
        });
        logits_out
    }

    fn train_step(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let pspecs = param_specs(&self.m);
        let np = pspecs.len();
        let p = self.param_views(args, 0, np)?;
        let m_in = self.param_views(args, np, np)?;
        let v_in = self.param_views(args, 2 * np, np)?;
        let step = args[3 * np].scalar_i32_value()?;
        let tokens = args[3 * np + 1].as_i32()?;

        let (loss, grads, _) = self.loss_and_grads(&p, None, tokens, true, false);
        let grads = grads.expect("base grads");
        let decay: Vec<bool> = pspecs.iter().map(|(_, s)| s.len() >= 2).collect();
        let (new_p, new_m, new_v, new_step) = self.adamw(&p, &grads, &m_in, &v_in, step, &decay);

        let mut out = Vec::with_capacity(3 * np + 2);
        for (vals, (_, shape)) in new_p.into_iter().zip(&pspecs) {
            out.push(HostTensor::f32(vals, shape.clone()));
        }
        for (vals, (_, shape)) in new_m.into_iter().zip(&pspecs) {
            out.push(HostTensor::f32(vals, shape.clone()));
        }
        for (vals, (_, shape)) in new_v.into_iter().zip(&pspecs) {
            out.push(HostTensor::f32(vals, shape.clone()));
        }
        out.push(HostTensor::scalar_i32(new_step));
        out.push(HostTensor::f32(vec![loss], vec![]));
        Ok(out)
    }

    fn lora_step(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let np = param_specs(&self.m).len();
        let lspecs = lora_specs(&self.m);
        let nl = lspecs.len();
        let base = self.param_views(args, 0, np)?;
        let lora = self.param_views(args, np, nl)?;
        let m_in = self.param_views(args, np + nl, nl)?;
        let v_in = self.param_views(args, np + 2 * nl, nl)?;
        let step = args[np + 3 * nl].scalar_i32_value()?;
        let tokens = args[np + 3 * nl + 1].as_i32()?;

        let (loss, _, lgrads) = self.loss_and_grads(&base, Some(&lora), tokens, false, true);
        let lgrads = lgrads.expect("lora grads");
        let decay = vec![true; nl];
        let (new_l, new_m, new_v, new_step) =
            self.adamw(&lora, &lgrads, &m_in, &v_in, step, &decay);

        let mut out = Vec::with_capacity(3 * nl + 2);
        for (vals, (_, shape)) in new_l.into_iter().zip(&lspecs) {
            out.push(HostTensor::f32(vals, shape.clone()));
        }
        for (vals, (_, shape)) in new_m.into_iter().zip(&lspecs) {
            out.push(HostTensor::f32(vals, shape.clone()));
        }
        for (vals, (_, shape)) in new_v.into_iter().zip(&lspecs) {
            out.push(HostTensor::f32(vals, shape.clone()));
        }
        out.push(HostTensor::scalar_i32(new_step));
        out.push(HostTensor::f32(vec![loss], vec![]));
        Ok(out)
    }

    /// Standalone fused dequant-matmul: `y = x @ dequant(codes, absmax)`.
    /// The 4-bit weight never materializes: each inner block multiplies
    /// the activation by `levels[code] * absmax[block]` on the fly.
    fn dequant_matmul_graph(&self, gm: &GraphMeta, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let x = args[0].as_f32()?;
        let codes = args[1].as_u8()?;
        let absmax = args[2].as_f32()?;
        let levels = args[3].as_f32()?;
        let (mdim, kdim) = (gm.args[0].shape[0], gm.args[0].shape[1]);
        let ndim = gm.args[1].shape[1];
        let nb = gm.args[2].shape[1];
        let block = ndim / nb;

        let y = q4::q4_matmul(
            &self.pool,
            x,
            codes,
            absmax,
            levels,
            &[],
            &[],
            mdim,
            kdim,
            ndim,
            block,
        );
        Ok(vec![HostTensor::f32(y, vec![mdim, ndim])])
    }

    /// Block-wise encoder kernel: rows of `w` are blocks; `bounds` are the
    /// 15 decision boundaries (code = #bounds <= x, ties resolve upward).
    fn quantize_blocks(
        &self,
        gm: &GraphMeta,
        args: &[HostTensor],
        norm: Norm,
    ) -> Result<Vec<HostTensor>> {
        let _phase = phase_scope(KernelPhase::Quantize);
        let w = args[0].as_f32()?;
        let bounds = args[1].as_f32()?;
        let (rows, blk) = (gm.args[0].shape[0], gm.args[0].shape[1]);
        let mut codes = vec![0u8; rows * blk];
        let mut absmax = vec![0.0f32; rows];
        {
            // one block (row) per task: fully independent, so the encoder
            // is trivially bit-identical at any thread count
            let codes_s = SyncSlice::new(&mut codes);
            let am_s = SyncSlice::new(&mut absmax);
            self.pool.run(rows, |r| {
                let row = &w[r * blk..(r + 1) * blk];
                let m = block_constant(row, norm);
                // SAFETY: block r's outputs are written only by task r.
                unsafe { am_s.slice_mut(r, 1) }[0] = m;
                let inv = 1.0 / safe_constant(m);
                let crow = unsafe { codes_s.slice_mut(r * blk, blk) };
                for (c, &wv) in crow.iter_mut().zip(row) {
                    let x = wv * inv;
                    let mut code = 0u8;
                    for &bd in bounds {
                        if x >= bd {
                            code += 1;
                        }
                    }
                    *c = code;
                }
            });
        }
        Ok(vec![
            HostTensor::u8(codes, vec![rows, blk]),
            HostTensor::f32(absmax, vec![rows]),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A deliberately tiny model so finite differences are fast.
    fn tiny() -> CpuBackend {
        CpuBackend::new(ModelMeta {
            vocab: 11,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_ff: 12,
            seq_len: 5,
            batch: 2,
            lora_rank: 2,
            block: 4,
        })
    }

    fn tiny_params(be: &CpuBackend, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seed_from_u64(seed);
        param_specs(&be.m)
            .iter()
            .map(|(_, s)| {
                let n: usize = s.iter().product();
                let mut v = vec![0.0f32; n];
                rng.fill_gaussian_f32(&mut v, 0.3);
                v
            })
            .collect()
    }

    fn tiny_lora(be: &CpuBackend, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seed_from_u64(seed);
        lora_specs(&be.m)
            .iter()
            .map(|(_, s)| {
                let n: usize = s.iter().product();
                let mut v = vec![0.0f32; n];
                rng.fill_gaussian_f32(&mut v, 0.2);
                v
            })
            .collect()
    }

    fn tiny_tokens(be: &CpuBackend, seed: u64) -> Vec<i32> {
        let mut rng = Pcg64::seed_from_u64(seed);
        (0..be.m.batch * be.m.seq_len)
            .map(|_| rng.next_below(be.m.vocab as u64) as i32)
            .collect()
    }

    fn views(p: &[Vec<f32>]) -> Vec<&[f32]> {
        p.iter().map(|v| v.as_slice()).collect()
    }

    fn loss_of(be: &CpuBackend, p: &[Vec<f32>], lora: Option<&[Vec<f32>]>, toks: &[i32]) -> f32 {
        let pv = views(p);
        let lv = lora.map(views);
        let (logits, _) = be.forward(&pv, lv.as_deref(), toks);
        be.nll_from_logits(&logits, toks, false).1
    }

    /// Central-difference check of the analytic base-parameter gradients.
    #[test]
    fn base_gradients_match_finite_differences() {
        let be = tiny();
        let params = tiny_params(&be, 1);
        let toks = tiny_tokens(&be, 2);
        let pv = views(&params);
        let (_, grads, _) = be.loss_and_grads(&pv, None, &toks, true, false);
        let grads = grads.unwrap();

        let eps = 1e-3f32;
        let mut rng = Pcg64::seed_from_u64(3);
        let mut checked = 0;
        for (pi, g) in grads.iter().enumerate() {
            // probe a few entries of every tensor
            for _ in 0..3 {
                let j = rng.next_below(g.len() as u64) as usize;
                let mut plus = params.clone();
                plus[pi][j] += eps;
                let mut minus = params.clone();
                minus[pi][j] -= eps;
                let fd = (loss_of(&be, &plus, None, &toks) - loss_of(&be, &minus, None, &toks))
                    / (2.0 * eps);
                let an = g[j];
                let tol = 1e-3f32.max(0.06 * fd.abs().max(an.abs()));
                assert!(
                    (fd - an).abs() <= tol,
                    "param {pi} [{j}]: fd {fd} vs analytic {an}"
                );
                checked += 1;
            }
        }
        assert!(checked >= 40);
    }

    #[test]
    fn lora_gradients_match_finite_differences() {
        let be = tiny();
        let params = tiny_params(&be, 4);
        let lora = tiny_lora(&be, 5);
        let toks = tiny_tokens(&be, 6);
        let pv = views(&params);
        let lv = views(&lora);
        let (_, _, lgrads) = be.loss_and_grads(&pv, Some(&lv), &toks, false, true);
        let lgrads = lgrads.unwrap();

        let eps = 1e-3f32;
        let mut rng = Pcg64::seed_from_u64(7);
        for (pi, g) in lgrads.iter().enumerate() {
            for _ in 0..3 {
                let j = rng.next_below(g.len() as u64) as usize;
                let mut plus = lora.clone();
                plus[pi][j] += eps;
                let mut minus = lora.clone();
                minus[pi][j] -= eps;
                let fd = (loss_of(&be, &params, Some(&plus), &toks)
                    - loss_of(&be, &params, Some(&minus), &toks))
                    / (2.0 * eps);
                let an = g[j];
                let tol = 1e-3f32.max(0.06 * fd.abs().max(an.abs()));
                assert!(
                    (fd - an).abs() <= tol,
                    "lora {pi} [{j}]: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    /// Forward, NLL gradients, prefill/decode, and a training step on the
    /// tiny model must be bit-identical across kernel-pool widths and
    /// SIMD paths.
    #[test]
    fn tiny_model_bit_identical_across_thread_counts_and_simd() {
        let m = tiny().m.clone();
        let toks = tiny_tokens(&tiny(), 40);
        let params = tiny_params(&tiny(), 41);
        let lora = tiny_lora(&tiny(), 42);
        let mut configs = vec![];
        for path in simd::all_paths() {
            for threads in [1usize, 2, 8] {
                configs.push((threads, path));
            }
        }
        let mut base: Option<(Vec<f32>, f32, Vec<Vec<f32>>, Vec<Vec<f32>>)> = None;
        for (threads, path) in configs {
            let be = CpuBackend::with_config(m.clone(), threads, path);
            let pv = views(&params);
            let lv = views(&lora);
            let (logits, _) = be.forward(&pv, Some(&lv), &toks);
            let (loss, bg, lg) = be.loss_and_grads(&pv, Some(&lv), &toks, true, true);
            let got = (logits, loss, bg.unwrap(), lg.unwrap());
            match &base {
                None => base = Some(got),
                Some(want) => {
                    let tag = format!("{threads} threads, simd={}", path.name());
                    assert_eq!(got.0, want.0, "logits diverged at {tag}");
                    assert_eq!(got.1, want.1, "loss diverged at {tag}");
                    assert_eq!(got.2, want.2, "base grads diverged at {tag}");
                    assert_eq!(got.3, want.3, "lora grads diverged at {tag}");
                }
            }
        }
    }

    #[test]
    fn gelu_grad_matches_fd() {
        for &x in &[-3.0f32, -1.0, -0.1, 0.0, 0.2, 1.5, 4.0] {
            let eps = 1e-3;
            let fd = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((fd - gelu_grad(x)).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn rmsnorm_grads_match_fd() {
        let d = 6usize;
        let mut rng = Pcg64::seed_from_u64(9);
        let mut x = vec![0.0f32; 2 * d];
        let mut g = vec![0.0f32; d];
        let mut dy = vec![0.0f32; 2 * d];
        rng.fill_gaussian_f32(&mut x, 1.0);
        rng.fill_gaussian_f32(&mut g, 1.0);
        rng.fill_gaussian_f32(&mut dy, 1.0);
        let pool = ThreadPool::with_threads(2);
        let (_, rms) = tiling::rmsnorm(&pool, &x, &g, d);
        let (dx, dg) = tiling::rmsnorm_bwd(&pool, &x, &g, &rms, &dy, d);
        let loss = |x: &[f32], g: &[f32]| -> f32 {
            let (y, _) = tiling::rmsnorm(&pool, x, g, d);
            y.iter().zip(&dy).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-3;
        for j in 0..2 * d {
            let mut xp = x.clone();
            xp[j] += eps;
            let mut xm = x.clone();
            xm[j] -= eps;
            let fd = (loss(&xp, &g) - loss(&xm, &g)) / (2.0 * eps);
            assert!((fd - dx[j]).abs() < 2e-2, "dx[{j}]: {fd} vs {}", dx[j]);
        }
        for j in 0..d {
            let mut gp = g.clone();
            gp[j] += eps;
            let mut gm = g.clone();
            gm[j] -= eps;
            let fd = (loss(&x, &gp) - loss(&x, &gm)) / (2.0 * eps);
            assert!((fd - dg[j]).abs() < 2e-2, "dg[{j}]: {fd} vs {}", dg[j]);
        }
    }

    #[test]
    fn forward_is_deterministic_and_shaped() {
        let be = tiny();
        let params = tiny_params(&be, 10);
        let toks = tiny_tokens(&be, 11);
        let pv = views(&params);
        let (l1, _) = be.forward(&pv, None, &toks);
        let (l2, _) = be.forward(&pv, None, &toks);
        assert_eq!(l1, l2);
        assert_eq!(l1.len(), be.m.batch * be.m.seq_len * be.m.vocab);
        assert!(l1.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causality_future_tokens_do_not_affect_past_logits() {
        let be = tiny();
        let params = tiny_params(&be, 12);
        let pv = views(&params);
        let t1 = tiny_tokens(&be, 13);
        let mut t2 = t1.clone();
        // change the last token of each sequence only
        let (b, s) = (be.m.batch, be.m.seq_len);
        for bi in 0..b {
            t2[bi * s + s - 1] = (t1[bi * s + s - 1] + 1) % be.m.vocab as i32;
        }
        let (l1, _) = be.forward(&pv, None, &t1);
        let (l2, _) = be.forward(&pv, None, &t2);
        let v = be.m.vocab;
        for bi in 0..b {
            for si in 0..s - 1 {
                let ti = bi * s + si;
                for j in 0..v {
                    assert_eq!(l1[ti * v + j], l2[ti * v + j], "b={bi} s={si}");
                }
            }
        }
    }

    /// Unit-level KV equivalence on the tiny model: prefill logits and a
    /// decode step must be bit-identical to the full forward.
    #[test]
    fn prefill_decode_matches_forward_on_tiny_model() {
        let be = tiny();
        let (b, s, v) = (be.m.batch, be.m.seq_len, be.m.vocab);
        let params = tiny_params(&be, 20);
        let toks = tiny_tokens(&be, 21);
        let specs = param_specs(&be.m);
        let param_tensors = |p: &[Vec<f32>]| -> Vec<HostTensor> {
            specs
                .iter()
                .zip(p)
                .map(|((_, shp), data)| HostTensor::f32(data.clone(), shp.clone()))
                .collect()
        };

        // right-padded prompts of length 3 in every row
        let plen = 3usize;
        let mut ptoks = vec![0i32; b * s];
        for bi in 0..b {
            for j in 0..plen {
                ptoks[bi * s + j] = toks[bi * s + j];
            }
        }
        let mut args = param_tensors(&params);
        args.push(HostTensor::i32(ptoks.clone(), vec![b, s]));
        args.push(HostTensor::i32(vec![plen as i32; b], vec![b]));
        let out = be.prefill(&args, false).unwrap();

        let pv = views(&params);
        let (logits, _) = be.forward(&pv, None, &ptoks);
        let pre = out[0].as_f32().unwrap();
        for bi in 0..b {
            let ti = bi * s + plen - 1;
            assert_eq!(&pre[bi * v..(bi + 1) * v], &logits[ti * v..(ti + 1) * v]);
        }

        // one decode step at position plen for every row
        let mut dargs = param_tensors(&params);
        dargs.extend(out[1..].iter().cloned());
        let token: Vec<i32> = (0..b).map(|bi| toks[bi * s + plen]).collect();
        dargs.push(HostTensor::i32(token, vec![b]));
        dargs.push(HostTensor::i32(vec![plen as i32; b], vec![b]));
        let dout = be.decode_step(&dargs, false).unwrap();

        let mut ftoks = ptoks;
        for bi in 0..b {
            ftoks[bi * s + plen] = toks[bi * s + plen];
        }
        let (flogits, _) = be.forward(&pv, None, &ftoks);
        let dl = dout[0].as_f32().unwrap();
        for bi in 0..b {
            let ti = bi * s + plen;
            assert_eq!(
                &dl[bi * v..(bi + 1) * v],
                &flogits[ti * v..(ti + 1) * v],
                "row {bi}"
            );
        }
    }

    /// The in-place decode protocol must match the clone-based
    /// `decode_step` bit-for-bit: same logits each step, same final
    /// caches.
    #[test]
    fn decode_inplace_matches_clone_on_tiny_model() {
        let be = tiny();
        let (b, s, d, v) = (be.m.batch, be.m.seq_len, be.m.d_model, be.m.vocab);
        let nl = be.m.n_layers;
        let params = tiny_params(&be, 30);
        let toks = tiny_tokens(&be, 31);
        let specs = param_specs(&be.m);
        let ptensors: Vec<HostTensor> = specs
            .iter()
            .zip(&params)
            .map(|((_, shp), data)| HostTensor::f32(data.clone(), shp.clone()))
            .collect();

        // prefill prompts of length 2 in every row
        let plen = 2usize;
        let mut ptoks = vec![0i32; b * s];
        for bi in 0..b {
            for j in 0..plen {
                ptoks[bi * s + j] = toks[bi * s + j];
            }
        }
        let mut pargs = ptensors.clone();
        pargs.push(HostTensor::i32(ptoks, vec![b, s]));
        pargs.push(HostTensor::i32(vec![plen as i32; b], vec![b]));
        let out = be.prefill(&pargs, false).unwrap();

        // the state only keys off the graph name
        let gm = GraphMeta {
            name: "lm_decode_step".into(),
            file: std::path::PathBuf::new(),
            args: Vec::new(),
            results: Vec::new(),
        };
        let mut state = be
            .alloc_decode_state(&gm, KvFormat::F32)
            .unwrap()
            .expect("cpu in-place");
        let row = s * d;
        for c in 0..2 * nl {
            let src = out[1 + c].as_f32().unwrap();
            for slot in 0..b {
                state
                    .load_slot(c, slot, &src[slot * row..(slot + 1) * row])
                    .unwrap();
            }
        }

        let mut caches: Vec<HostTensor> = out[1..].to_vec();
        let mut token: Vec<i32> = (0..b).map(|bi| toks[bi * s + plen]).collect();
        for step in 0..3usize {
            let pos = vec![(plen + step) as i32; b];
            let mut dargs = ptensors.clone();
            dargs.extend(caches.iter().cloned());
            dargs.push(HostTensor::i32(token.clone(), vec![b]));
            dargs.push(HostTensor::i32(pos.clone(), vec![b]));
            let dout = be.decode_step(&dargs, false).unwrap();

            let mut iargs = ptensors.clone();
            iargs.push(HostTensor::i32(token.clone(), vec![b]));
            iargs.push(HostTensor::i32(pos, vec![b]));
            let iout = be.execute_decode_inplace(&gm, state.as_mut(), &iargs).unwrap();
            assert_eq!(iout.len(), 1);
            assert_eq!(dout[0], iout[0], "step {step}: logits diverged");

            caches = dout[1..].to_vec();
            let lg = dout[0].as_f32().unwrap();
            token = (0..b)
                .map(|bi| {
                    let r = &lg[bi * v..(bi + 1) * v];
                    let mut best = 0usize;
                    for j in 1..v {
                        if r[j] >= r[best] {
                            best = j;
                        }
                    }
                    best as i32
                })
                .collect();
        }
        // the resident slabs ended bit-identical to the cloned caches
        let st = state.as_any_mut().downcast_mut::<CpuDecodeState>().unwrap();
        for c in 0..2 * nl {
            assert_eq!(st.cache(c), caches[c].as_f32().unwrap(), "cache {c}");
        }
    }

    /// Quantized resident caches (`BOF4_KV=q8|q4`): the in-place decode
    /// step must be bit-identical across thread count × SIMD path, stay
    /// numerically close to the f32 path, and the resident slabs must
    /// shrink by exactly the format's row-byte accounting.
    #[test]
    fn decode_inplace_quantized_deterministic_and_smaller() {
        let be0 = tiny();
        let (b, s, d, v) = (be0.m.batch, be0.m.seq_len, be0.m.d_model, be0.m.vocab);
        let nl = be0.m.n_layers;
        let params = tiny_params(&be0, 50);
        let toks = tiny_tokens(&be0, 51);
        let specs = param_specs(&be0.m);
        let ptensors: Vec<HostTensor> = specs
            .iter()
            .zip(&params)
            .map(|((_, shp), data)| HostTensor::f32(data.clone(), shp.clone()))
            .collect();

        let plen = 2usize;
        let mut ptoks = vec![0i32; b * s];
        for bi in 0..b {
            for j in 0..plen {
                ptoks[bi * s + j] = toks[bi * s + j];
            }
        }
        let mut pargs = ptensors.clone();
        pargs.push(HostTensor::i32(ptoks, vec![b, s]));
        pargs.push(HostTensor::i32(vec![plen as i32; b], vec![b]));
        let out = be0.prefill(&pargs, false).unwrap();
        let row = s * d;

        let gm = GraphMeta {
            name: "lm_decode_step".into(),
            file: std::path::PathBuf::new(),
            args: Vec::new(),
            results: Vec::new(),
        };

        // 3 teacher-forced steps per config; configs must agree bitwise.
        let run_steps = |be: &CpuBackend, fmt: KvFormat| -> (Vec<Vec<f32>>, usize) {
            let mut state = be.alloc_decode_state(&gm, fmt).unwrap().expect("cpu in-place");
            for c in 0..2 * nl {
                let src = out[1 + c].as_f32().unwrap();
                for slot in 0..b {
                    state
                        .load_slot(c, slot, &src[slot * row..(slot + 1) * row])
                        .unwrap();
                }
            }
            let bytes = state.resident_bytes();
            let mut logits = Vec::new();
            for step in 0..3usize {
                let token: Vec<i32> = (0..b).map(|bi| toks[bi * s + plen + step]).collect();
                let pos = vec![(plen + step) as i32; b];
                let mut iargs = ptensors.clone();
                iargs.push(HostTensor::i32(token, vec![b]));
                iargs.push(HostTensor::i32(pos, vec![b]));
                let iout = be.execute_decode_inplace(&gm, state.as_mut(), &iargs).unwrap();
                logits.push(iout[0].as_f32().unwrap().to_vec());
            }
            (logits, bytes)
        };

        let (f32_logits, f32_bytes) = run_steps(&be0, KvFormat::F32);
        assert_eq!(f32_bytes, 2 * nl * b * s * d * 4);
        // tolerance per format: q8 keeps logits within a hair of f32 on
        // the tiny model (~0.4% per-element quant error), q4 within the
        // much coarser BOF4 bound — generous margins, but an
        // indexing/scale bug lands orders of magnitude outside them
        for (fmt, tol) in [(KvFormat::Q8, 0.5f32), (KvFormat::Q4, 2.0)] {
            let mut want: Option<Vec<Vec<f32>>> = None;
            for path in simd::all_paths() {
                for threads in [1usize, 8] {
                    let be = CpuBackend::with_config(be0.m.clone(), threads, path);
                    let (logits, bytes) = run_steps(&be, fmt);
                    assert_eq!(
                        bytes,
                        2 * nl * b * s * fmt.row_bytes(d, be0.m.block.min(d)),
                        "{fmt} resident bytes"
                    );
                    assert!(bytes < f32_bytes, "{fmt} must shrink the slabs");
                    match &want {
                        None => {
                            for (step, l) in logits.iter().enumerate() {
                                for (a, wv) in l.iter().zip(&f32_logits[step]) {
                                    assert!(
                                        (a - wv).abs() <= tol,
                                        "{fmt} step {step}: {a} vs f32 {wv}"
                                    );
                                }
                            }
                            want = Some(logits);
                        }
                        Some(w) => {
                            assert_eq!(&logits, w, "{fmt} threads={threads} {path:?}");
                        }
                    }
                }
            }
        }
    }

    /// Malformed OPQ side-tables must fail weight-view assembly with an
    /// error, not an out-of-bounds panic inside a pooled kernel.
    #[test]
    fn side_table_validation_rejects_malformed() {
        assert!(check_side_table("w", &[1, 2], &[1.0, 2.0], 10).is_ok());
        assert!(check_side_table("w", &[], &[], 0).is_ok());
        // idx/val length mismatch
        assert!(check_side_table("w", &[1], &[], 10).is_err());
        // unsorted / duplicate indices
        assert!(check_side_table("w", &[2, 1], &[0.0, 0.0], 10).is_err());
        assert!(check_side_table("w", &[3, 3], &[0.0, 0.0], 10).is_err());
        // index out of range
        assert!(check_side_table("w", &[10], &[0.0], 10).is_err());
    }

    #[test]
    fn adamw_moves_against_gradient() {
        let p = vec![vec![1.0f32, -1.0]];
        let g = vec![vec![0.5f32, -0.5]];
        let m = vec![vec![0.0f32, 0.0]];
        let v = vec![vec![0.0f32, 0.0]];
        let pv: Vec<&[f32]> = p.iter().map(|x| x.as_slice()).collect();
        let mv: Vec<&[f32]> = m.iter().map(|x| x.as_slice()).collect();
        let vv: Vec<&[f32]> = v.iter().map(|x| x.as_slice()).collect();
        let (np, nm, nv, step) = tiny().adamw(&pv, &g, &mv, &vv, 0, &[false]);
        assert_eq!(step, 1);
        assert!(np[0][0] < 1.0); // positive grad -> parameter decreases
        assert!(np[0][1] > -1.0);
        assert!(nm[0][0] > 0.0);
        assert!(nv[0][0] > 0.0);
    }
}
