//! Graph ABI metadata: every graph's argument/result names, shapes and
//! dtypes, plus the model hyper-parameters.
//!
//! Two sources produce a [`Meta`]:
//!
//! - [`Meta::builtin`] — constructed directly in Rust from the canonical
//!   model configuration. This is the hermetic path the CPU backend uses:
//!   no files, no Python, no network. It mirrors `python/compile/aot.py`
//!   exactly (same graph names, same flat positional ABI).
//! - [`Meta::load`] — parse `artifacts/meta.json` written by `aot.py`
//!   (`make artifacts`), used by the XLA backend which also needs the
//!   lowered `*.hlo.txt` files next to it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Context, Result};
use crate::quant::double_quant::CHUNK as DQ_CHUNK;
use crate::util::json::Json;

/// One graph argument/result descriptor.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgMeta {
    pub name: String,
    pub shape: Vec<usize>,
    /// numpy dtype string: "float32", "int32", "uint8", "uint32".
    pub dtype: String,
}

impl ArgMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Whether this argument is a KV-cache tensor of the decode-step
    /// graphs (the tensors an in-place backend keeps resident; see
    /// [`super::Backend::alloc_decode_state`]). The declared ABI dtype
    /// stays `float32` regardless of the `BOF4_KV` knob: quantized
    /// (q8/q4) storage exists only inside backend-resident
    /// [`super::DecodeState`]s and never crosses the `HostTensor` ABI —
    /// the clone-based fallback path always carries f32 slabs.
    pub fn is_cache(&self) -> bool {
        is_cache_name(&self.name)
    }

    /// Whether this argument has a data-dependent length (the OPQ
    /// outlier side-tables of the q4 serving graphs: one entry per
    /// preserved outlier, zero when OPQ is off). ABI validation checks
    /// dtype and rank for dynamic args but not the element count; the
    /// declared shape is a `[0]` placeholder.
    pub fn is_dynamic(&self) -> bool {
        is_outlier_name(&self.name)
    }
}

/// Cache-tensor naming convention of the KV serving graphs
/// (`l{layer}.k_cache` / `l{layer}.v_cache`).
pub fn is_cache_name(name: &str) -> bool {
    name.ends_with(".k_cache") || name.ends_with(".v_cache")
}

/// Outlier side-table naming convention of the q4 serving graphs
/// (`{matrix}.outlier_idx` / `{matrix}.outlier_val`): per-matrix sorted
/// flat u32 indices + bf16-rounded f32 values of the OPQ-preserved
/// weights. These are the only variable-length tensors in the ABI.
pub fn is_outlier_name(name: &str) -> bool {
    name.ends_with(".outlier_idx") || name.ends_with(".outlier_val")
}

/// One lowered graph.
#[derive(Clone, Debug)]
pub struct GraphMeta {
    pub name: String,
    pub file: PathBuf,
    pub args: Vec<ArgMeta>,
    pub results: Vec<String>,
}

impl GraphMeta {
    pub fn arg_index(&self, name: &str) -> Option<usize> {
        self.args.iter().position(|a| a.name == name)
    }

    pub fn result_index(&self, name: &str) -> Option<usize> {
        self.results.iter().position(|r| r == name)
    }

    /// The argument list with the KV-cache tensors removed — the ABI of
    /// an in-place decode call ([`super::Runtime::run_decode_step_inplace`]).
    pub fn non_cache_args(&self) -> Vec<&ArgMeta> {
        self.args.iter().filter(|a| !a.is_cache()).collect()
    }
}

/// Model hyper-parameters (mirrors `ModelCfg` in python/compile/model.py).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub lora_rank: usize,
    pub block: usize,
}

impl ModelMeta {
    /// The canonical configuration baked into the AOT artifacts and the
    /// CPU backend (ModelCfg defaults + BLOCK in aot.py).
    pub fn canonical() -> ModelMeta {
        ModelMeta {
            vocab: 64,
            d_model: 128,
            n_layers: 2,
            n_heads: 4,
            d_ff: 256,
            seq_len: 64,
            batch: 16,
            lora_rank: 8,
            block: 64,
        }
    }
}

/// Canonical flat parameter order with shapes (the rust<->python ABI;
/// mirrors `param_names` + `param_shapes` in model.py).
pub fn param_specs(m: &ModelMeta) -> Vec<(String, Vec<usize>)> {
    let (d, ff, v, s) = (m.d_model, m.d_ff, m.vocab, m.seq_len);
    let mut out: Vec<(String, Vec<usize>)> =
        vec![("embed".into(), vec![v, d]), ("pos".into(), vec![s, d])];
    for layer in 0..m.n_layers {
        out.push((format!("l{layer}.ln1"), vec![d]));
        out.push((format!("l{layer}.wqkv"), vec![d, 3 * d]));
        out.push((format!("l{layer}.wo"), vec![d, d]));
        out.push((format!("l{layer}.ln2"), vec![d]));
        out.push((format!("l{layer}.win"), vec![d, ff]));
        out.push((format!("l{layer}.wout"), vec![ff, d]));
    }
    out.push(("lnf".into(), vec![d]));
    out.push(("head".into(), vec![d, v]));
    out
}

/// Names of the weight matrices quantized in the q4 serving graph and
/// LoRA-adapted during fine-tuning (mirrors `matmul_param_names`).
pub fn matmul_param_names(m: &ModelMeta) -> Vec<String> {
    let mut out = Vec::new();
    for layer in 0..m.n_layers {
        for k in ["wqkv", "wo", "win", "wout"] {
            out.push(format!("l{layer}.{k}"));
        }
    }
    out
}

/// Flat LoRA parameter order with shapes: for each adapted matrix, A
/// `[k, r]` then B `[r, n]` (mirrors `lora_names` + `lora_shapes`).
pub fn lora_specs(m: &ModelMeta) -> Vec<(String, Vec<usize>)> {
    let shapes: std::collections::HashMap<String, Vec<usize>> =
        param_specs(m).into_iter().collect();
    let mut out = Vec::new();
    for nm in matmul_param_names(m) {
        let shp = &shapes[&nm];
        let (k, n) = (shp[0], shp[1]);
        out.push((format!("{nm}.lora_a"), vec![k, m.lora_rank]));
        out.push((format!("{nm}.lora_b"), vec![m.lora_rank, n]));
    }
    out
}

/// The whole artifact manifest.
#[derive(Clone, Debug)]
pub struct Meta {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub graphs: BTreeMap<String, GraphMeta>,
}

impl Meta {
    /// Build the full graph ABI in Rust, without any artifact files —
    /// the hermetic path the CPU backend uses. For every graph that
    /// `aot.py::lower_graphs` lowers, names, argument order, shapes and
    /// dtypes match it exactly; the KV-cached serving graphs
    /// (`lm_prefill*`/`lm_decode_step*`) are builtin-only extensions the
    /// XLA artifact set does not carry.
    pub fn builtin() -> Meta {
        let m = ModelMeta::canonical();
        let dir = Self::default_dir();
        let mut graphs = BTreeMap::new();

        let f32s = "float32".to_string();
        let pspecs = param_specs(&m);
        let lspecs = lora_specs(&m);
        let mm = matmul_param_names(&m);
        let arg = |name: &str, shape: Vec<usize>, dtype: &str| ArgMeta {
            name: name.to_string(),
            shape,
            dtype: dtype.to_string(),
        };
        let params_args = |prefix: &str| -> Vec<ArgMeta> {
            pspecs
                .iter()
                .map(|(n, s)| arg(&format!("{prefix}{n}"), s.clone(), &f32s))
                .collect()
        };
        let lora_args = |prefix: &str| -> Vec<ArgMeta> {
            lspecs
                .iter()
                .map(|(n, s)| arg(&format!("{prefix}{n}"), s.clone(), &f32s))
                .collect()
        };
        let tokens_arg = || arg("tokens", vec![m.batch, m.seq_len], "int32");
        let step_arg = || arg("step", vec![], "int32");
        let seed_arg = || arg("seed", vec![], "uint32");
        let pnames: Vec<String> = pspecs.iter().map(|(n, _)| n.clone()).collect();
        let lnames: Vec<String> = lspecs.iter().map(|(n, _)| n.clone()).collect();

        let mut add = |name: &str, args: Vec<ArgMeta>, results: Vec<String>| {
            graphs.insert(
                name.to_string(),
                GraphMeta {
                    name: name.to_string(),
                    file: dir.join(format!("{name}.hlo.txt")),
                    args,
                    results,
                },
            );
        };

        // --- init ------------------------------------------------------
        add("init_params", vec![seed_arg()], pnames.clone());
        add("init_lora", vec![seed_arg()], lnames.clone());

        // --- eval forwards ----------------------------------------------
        let mut a = params_args("");
        a.push(tokens_arg());
        add("lm_nll", a.clone(), vec!["nll_per_seq".into()]);
        add("lm_logits_last", a.clone(), vec!["logits_last".into()]);
        add("lm_logits_all", a, vec!["logits".into()]);

        // --- quantized serving forward ----------------------------------
        let pshapes: std::collections::HashMap<String, Vec<usize>> =
            pspecs.iter().cloned().collect();
        let mut q4 = Vec::new();
        for (n, s) in &pspecs {
            if !mm.contains(n) {
                q4.push(arg(n, s.clone(), &f32s));
            }
        }
        for n in &mm {
            q4.push(arg(&format!("{n}.codes"), pshapes[n].clone(), "uint8"));
        }
        for n in &mm {
            let s = &pshapes[n];
            q4.push(arg(
                &format!("{n}.absmax"),
                vec![s[0], s[1] / m.block],
                &f32s,
            ));
        }
        q4.push(arg("levels", vec![16], &f32s));
        q4.push(tokens_arg());
        add("lm_nll_q4", q4, vec!["nll_per_seq".into()]);

        // --- training ---------------------------------------------------
        let mut t = params_args("");
        t.extend(params_args("m."));
        t.extend(params_args("v."));
        t.push(step_arg());
        t.push(tokens_arg());
        let mut tres = pnames.clone();
        tres.extend(pnames.iter().map(|n| format!("m.{n}")));
        tres.extend(pnames.iter().map(|n| format!("v.{n}")));
        tres.push("step".into());
        tres.push("loss".into());
        add("train_step", t, tres);

        let mut l = params_args("");
        l.extend(lora_args(""));
        l.extend(lora_args("m."));
        l.extend(lora_args("v."));
        l.push(step_arg());
        l.push(tokens_arg());
        let mut lres = lnames.clone();
        lres.extend(lnames.iter().map(|n| format!("m.{n}")));
        lres.extend(lnames.iter().map(|n| format!("v.{n}")));
        lres.push("step".into());
        lres.push("loss".into());
        add("lora_step", l, lres);

        let mut ll = params_args("");
        ll.extend(lora_args(""));
        ll.push(tokens_arg());
        add("lm_logits_last_lora", ll.clone(), vec!["logits_last".into()]);
        add("lm_logits_all_lora", ll, vec!["logits".into()]);

        // --- KV-cached serving (prefill + incremental decode) -----------
        //
        // These graphs exist only in the builtin (CPU) ABI: the XLA
        // artifact set stops at the eval forwards, so on that backend the
        // session engine falls back to full-context serving through
        // `lm_logits_all`, and `lm_logits_last`/`lm_logits_all` double as
        // the equivalence oracles for these kernels.
        //
        // `lm_prefill` runs the full forward over a right-padded prompt
        // batch and returns the last-valid-position logits per row plus
        // the per-layer K/V tensors; `lm_decode_step` consumes one token
        // per row, appends one K/V column at `pos` and attends over
        // `pos+1` cached positions instead of recomputing `seq_len^2`.
        // Rows with `pos < 0` are inactive (logits zero, cache untouched).
        let cache_shape = vec![m.batch, m.seq_len, m.d_model];
        let cache_args = |v: &mut Vec<ArgMeta>| {
            for l in 0..m.n_layers {
                v.push(arg(&format!("l{l}.k_cache"), cache_shape.clone(), &f32s));
                v.push(arg(&format!("l{l}.v_cache"), cache_shape.clone(), &f32s));
            }
        };
        let cache_results = || -> Vec<String> {
            (0..m.n_layers)
                .flat_map(|l| [format!("l{l}.k_cache"), format!("l{l}.v_cache")])
                .collect()
        };
        let prefill_tail = |v: &mut Vec<ArgMeta>| {
            v.push(tokens_arg());
            v.push(arg("lens", vec![m.batch], "int32"));
        };
        let decode_tail = |v: &mut Vec<ArgMeta>| {
            cache_args(v);
            v.push(arg("token", vec![m.batch], "int32"));
            v.push(arg("pos", vec![m.batch], "int32"));
        };

        let mut pf = params_args("");
        prefill_tail(&mut pf);
        let mut pf_res = vec!["logits_last".to_string()];
        pf_res.extend(cache_results());
        add("lm_prefill", pf, pf_res.clone());

        let mut ds = params_args("");
        decode_tail(&mut ds);
        let mut ds_res = vec!["logits".to_string()];
        ds_res.extend(cache_results());
        add("lm_decode_step", ds, ds_res.clone());

        // Quantized-serving variants: matmul weights as 4-bit codes with
        // the per-block constants stored 8-bit (double-quantized) and
        // dequantized inside the fused matmul — the end-to-end DQ path.
        // Each matrix additionally carries an OPQ outlier side-table
        // (sorted flat u32 indices + bf16-rounded f32 values, patched
        // inside the fused kernels); the two side-table args are
        // dynamic-length ([`ArgMeta::is_dynamic`]) and empty when OPQ is
        // off, so the ABI is uniform across OPQ on/off.
        let q4_serving_prefix = || -> Vec<ArgMeta> {
            let mut v = Vec::new();
            for (n, s) in &pspecs {
                if !mm.contains(n) {
                    v.push(arg(n, s.clone(), &f32s));
                }
            }
            for n in &mm {
                v.push(arg(&format!("{n}.codes"), pshapes[n].clone(), "uint8"));
            }
            for n in &mm {
                let s = &pshapes[n];
                v.push(arg(
                    &format!("{n}.absmax_codes"),
                    vec![s[0], s[1] / m.block],
                    "uint8",
                ));
            }
            for n in &mm {
                let s = &pshapes[n];
                let nchunks = (s[0] * s[1] / m.block).div_ceil(DQ_CHUNK);
                v.push(arg(&format!("{n}.absmax_params"), vec![nchunks, 2], &f32s));
            }
            for n in &mm {
                v.push(arg(&format!("{n}.outlier_idx"), vec![0], "uint32"));
            }
            for n in &mm {
                v.push(arg(&format!("{n}.outlier_val"), vec![0], &f32s));
            }
            v.push(arg("levels", vec![16], &f32s));
            v
        };
        let mut pfq = q4_serving_prefix();
        prefill_tail(&mut pfq);
        add("lm_prefill_q4", pfq, pf_res);
        let mut dsq = q4_serving_prefix();
        decode_tail(&mut dsq);
        add("lm_decode_step_q4", dsq, ds_res);

        // --- standalone kernels -----------------------------------------
        let (mk, kk, nn) = (128usize, 256usize, 256usize);
        add(
            "dequant_matmul",
            vec![
                arg("x", vec![mk, kk], &f32s),
                arg("codes", vec![kk, nn], "uint8"),
                arg("absmax", vec![kk, nn / m.block], &f32s),
                arg("levels", vec![16], &f32s),
            ],
            vec!["y".into()],
        );
        for suffix in ["abs", "signed"] {
            add(
                &format!("quantize_blocks_{suffix}"),
                vec![
                    arg("w", vec![1024, m.block], &f32s),
                    arg("bounds", vec![15], &f32s),
                ],
                vec!["codes".into(), "absmax".into()],
            );
        }

        Meta {
            dir,
            model: m,
            graphs,
        }
    }

    /// Load `meta.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Meta> {
        let path = dir.join("meta.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&src).map_err(|e| crate::err!("parsing meta.json: {e}"))?;

        let m = j
            .get("model")
            .ok_or_else(|| crate::err!("meta.json: no model"))?;
        let get = |k: &str| -> Result<usize> {
            m.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| crate::err!("meta.json model.{k} missing"))
        };
        let model = ModelMeta {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            seq_len: get("seq_len")?,
            batch: get("batch")?,
            lora_rank: get("lora_rank")?,
            block: get("block")?,
        };

        let mut graphs = BTreeMap::new();
        let gobj = match j.get("graphs") {
            Some(Json::Obj(o)) => o,
            _ => return Err(crate::err!("meta.json: no graphs object")),
        };
        for (name, g) in gobj {
            let file = g
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| crate::err!("graph {name}: no file"))?;
            let args = g
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| crate::err!("graph {name}: no args"))?
                .iter()
                .map(|a| -> Result<ArgMeta> {
                    Ok(ArgMeta {
                        name: a
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| crate::err!("arg name"))?
                            .to_string(),
                        shape: a
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| crate::err!("arg shape"))?
                            .iter()
                            .map(|d| d.as_usize().unwrap_or(0))
                            .collect(),
                        dtype: a
                            .get("dtype")
                            .and_then(Json::as_str)
                            .ok_or_else(|| crate::err!("arg dtype"))?
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let results = g
                .get("results")
                .and_then(Json::as_arr)
                .ok_or_else(|| crate::err!("graph {name}: no results"))?
                .iter()
                .map(|r| r.as_str().unwrap_or("").to_string())
                .collect();
            graphs.insert(
                name.clone(),
                GraphMeta {
                    name: name.clone(),
                    file: dir.join(file),
                    args,
                    results,
                },
            );
        }
        Ok(Meta {
            dir: dir.to_path_buf(),
            model,
            graphs,
        })
    }

    pub fn graph(&self, name: &str) -> Result<&GraphMeta> {
        self.graphs
            .get(name)
            .ok_or_else(|| crate::err!("graph '{name}' not in meta"))
    }

    /// Default artifact dir: $BOF4_ARTIFACTS, or an existing ./artifacts
    /// (searching up from the current dir so tests/benches work from any
    /// workspace cwd), or — when none exists yet, the common hermetic
    /// case — a stable workspace-anchored `artifacts/` next to the crate,
    /// so caches like `trained_model.wbin` do not depend on the cwd.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("BOF4_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = dir.join("artifacts");
            if cand.join("meta.json").exists() {
                return cand;
            }
            if !dir.pop() {
                // fall back to <workspace root>/artifacts, anchored at
                // compile time (the crate lives in <workspace>/rust)
                return PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts"));
            }
        }
    }

    pub fn load_default() -> Result<Meta> {
        Self::load(&Self::default_dir())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Meta::default_dir().join("meta.json").exists()
    }

    #[test]
    fn loads_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let meta = Meta::load_default().unwrap();
        assert_eq!(meta.model.vocab, 64);
        assert_eq!(meta.model.block, 64);
        for g in [
            "init_params",
            "lm_nll",
            "train_step",
            "lora_step",
            "dequant_matmul",
        ] {
            let gm = meta.graph(g).unwrap();
            assert!(gm.file.exists(), "{:?}", gm.file);
            assert!(!gm.args.is_empty());
        }
    }

    #[test]
    fn builtin_train_step_abi_symmetry() {
        let meta = Meta::builtin();
        let g = meta.graph("train_step").unwrap();
        // 16 params * 3 + step + tokens
        assert_eq!(g.args.len(), 50);
        assert_eq!(g.results.len(), 50);
        assert_eq!(g.args[0].name, g.results[0]);
        assert_eq!(g.arg_index("tokens"), Some(49));
        assert_eq!(g.result_index("loss"), Some(49));
    }

    #[test]
    fn builtin_matches_aot_graph_set() {
        let meta = Meta::builtin();
        for g in [
            "init_params",
            "init_lora",
            "lm_nll",
            "lm_logits_last",
            "lm_logits_all",
            "lm_nll_q4",
            "train_step",
            "lora_step",
            "lm_logits_last_lora",
            "lm_logits_all_lora",
            "dequant_matmul",
            "quantize_blocks_abs",
            "quantize_blocks_signed",
        ] {
            assert!(meta.graphs.contains_key(g), "missing graph {g}");
        }
        // param ABI: 16 tensors, embed first, head last
        let nll = meta.graph("lm_nll").unwrap();
        assert_eq!(nll.args.len(), 17);
        assert_eq!(nll.args[0].name, "embed");
        assert_eq!(nll.args[0].shape, vec![64, 128]);
        assert_eq!(nll.args[15].name, "head");
        assert_eq!(nll.args[15].shape, vec![128, 64]);
        assert_eq!(nll.args[16].name, "tokens");
        assert_eq!(nll.args[16].dtype, "int32");
        // lora ABI: 16 adapters (2 layers x 4 matrices x A/B)
        let il = meta.graph("init_lora").unwrap();
        assert_eq!(il.results.len(), 16);
        assert_eq!(il.results[0], "l0.wqkv.lora_a");
        // lora_step: 16 base + 3*16 lora + step + tokens
        let ls = meta.graph("lora_step").unwrap();
        assert_eq!(ls.args.len(), 16 + 3 * 16 + 2);
        assert_eq!(ls.results.len(), 3 * 16 + 2);
        // q4: 8 f32 + 8 codes + 8 absmax + levels + tokens
        let q4 = meta.graph("lm_nll_q4").unwrap();
        assert_eq!(q4.args.len(), 8 + 8 + 8 + 2);
        assert_eq!(q4.arg_index("l0.wqkv.codes"), Some(8));
        let am = &q4.args[q4.arg_index("l0.wqkv.absmax").unwrap()];
        assert_eq!(am.shape, vec![128, 6]);
    }

    #[test]
    fn builtin_kv_serving_graphs() {
        let meta = Meta::builtin();
        let pf = meta.graph("lm_prefill").unwrap();
        // 16 params + tokens + lens
        assert_eq!(pf.args.len(), 18);
        assert_eq!(pf.args[16].name, "tokens");
        assert_eq!(pf.args[17].name, "lens");
        assert_eq!(pf.args[17].shape, vec![16]);
        assert_eq!(pf.results[0], "logits_last");
        assert_eq!(pf.results.len(), 1 + 2 * meta.model.n_layers);
        let ds = meta.graph("lm_decode_step").unwrap();
        // 16 params + 4 caches + token + pos
        assert_eq!(ds.args.len(), 16 + 4 + 2);
        assert_eq!(ds.args[16].name, "l0.k_cache");
        assert_eq!(ds.args[16].shape, vec![16, 64, 128]);
        assert_eq!(ds.args[20].name, "token");
        assert_eq!(ds.args[21].name, "pos");
        assert_eq!(ds.results[0], "logits");
        // q4: 8 f32 + 8 codes + 8 absmax_codes + 8 absmax_params +
        // 8 outlier_idx + 8 outlier_val + levels
        let pq = meta.graph("lm_prefill_q4").unwrap();
        assert_eq!(pq.args.len(), 8 + 5 * 8 + 1 + 2);
        let amp = &pq.args[pq.arg_index("l0.wqkv.absmax_params").unwrap()];
        assert_eq!(amp.shape, vec![3, 2]); // 768 constants in 256-chunks
        let amc = &pq.args[pq.arg_index("l0.wqkv.absmax_codes").unwrap()];
        assert_eq!(amc.shape, vec![128, 6]);
        assert_eq!(amc.dtype, "uint8");
        // OPQ side-table args: dynamic-length, u32 indices + f32 values
        let oi = &pq.args[pq.arg_index("l0.wqkv.outlier_idx").unwrap()];
        assert_eq!(oi.dtype, "uint32");
        assert_eq!(oi.shape, vec![0]);
        assert!(oi.is_dynamic() && !oi.is_cache());
        let ov = &pq.args[pq.arg_index("l1.wout.outlier_val").unwrap()];
        assert_eq!(ov.dtype, "float32");
        assert!(ov.is_dynamic());
        assert!(!amc.is_dynamic(), "fixed-shape args stay static");
        let dq = meta.graph("lm_decode_step_q4").unwrap();
        assert_eq!(dq.args.len(), 8 + 5 * 8 + 1 + 4 + 2);
        assert_eq!(dq.results.len(), 5);
        // the outlier args ride along in the in-place (non-cache) ABI
        let nc = dq.non_cache_args();
        assert_eq!(nc.len(), dq.args.len() - 4);
        assert!(nc.iter().any(|a| a.name == "l0.wqkv.outlier_idx"));
        assert!(is_outlier_name("l1.win.outlier_val"));
        assert!(!is_outlier_name("l1.win.absmax_codes"));
    }

    #[test]
    fn non_cache_args_strip_kv_tensors() {
        let meta = Meta::builtin();
        let ds = meta.graph("lm_decode_step").unwrap();
        let nc = ds.non_cache_args();
        // 16 params + token + pos (the 4 cache args removed)
        assert_eq!(nc.len(), ds.args.len() - 2 * meta.model.n_layers);
        assert!(nc.iter().all(|a| !a.is_cache()));
        assert_eq!(nc[nc.len() - 2].name, "token");
        assert_eq!(nc[nc.len() - 1].name, "pos");
        assert!(is_cache_name("l0.k_cache") && is_cache_name("l1.v_cache"));
        assert!(!is_cache_name("tokens"));
        // a graph without caches is untouched
        let nll = meta.graph("lm_nll").unwrap();
        assert_eq!(nll.non_cache_args().len(), nll.args.len());
    }

    #[test]
    fn arg_meta_helpers() {
        let a = ArgMeta {
            name: "x".into(),
            shape: vec![2, 3, 4],
            dtype: "float32".into(),
        };
        assert_eq!(a.elements(), 24);
    }

    #[test]
    fn spec_helpers_consistent() {
        let m = ModelMeta::canonical();
        let p = param_specs(&m);
        assert_eq!(p.len(), 16);
        assert_eq!(matmul_param_names(&m).len(), 8);
        let l = lora_specs(&m);
        assert_eq!(l.len(), 16);
        assert_eq!(l[0].1, vec![128, 8]); // wqkv.lora_a
        assert_eq!(l[1].1, vec![8, 384]); // wqkv.lora_b
        assert_eq!(l[7].1, vec![8, 128]); // wout.lora_b
    }
}
