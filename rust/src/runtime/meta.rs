//! `artifacts/meta.json` — the python→rust ABI contract.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context};

use crate::util::json::Json;

/// One graph argument/result descriptor.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgMeta {
    pub name: String,
    pub shape: Vec<usize>,
    /// numpy dtype string: "float32", "int32", "uint8", "uint32".
    pub dtype: String,
}

impl ArgMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered graph.
#[derive(Clone, Debug)]
pub struct GraphMeta {
    pub name: String,
    pub file: PathBuf,
    pub args: Vec<ArgMeta>,
    pub results: Vec<String>,
}

impl GraphMeta {
    pub fn arg_index(&self, name: &str) -> Option<usize> {
        self.args.iter().position(|a| a.name == name)
    }

    pub fn result_index(&self, name: &str) -> Option<usize> {
        self.results.iter().position(|r| r == name)
    }
}

/// Model hyper-parameters recorded by aot.py.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub lora_rank: usize,
    pub block: usize,
}

/// The whole artifact manifest.
#[derive(Clone, Debug)]
pub struct Meta {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub graphs: BTreeMap<String, GraphMeta>,
}

impl Meta {
    /// Load `meta.json` from an artifact directory.
    pub fn load(dir: &Path) -> anyhow::Result<Meta> {
        let path = dir.join("meta.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&src).map_err(|e| anyhow!("parsing meta.json: {e}"))?;

        let m = j.get("model").ok_or_else(|| anyhow!("meta.json: no model"))?;
        let get = |k: &str| -> anyhow::Result<usize> {
            m.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("meta.json model.{k} missing"))
        };
        let model = ModelMeta {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            seq_len: get("seq_len")?,
            batch: get("batch")?,
            lora_rank: get("lora_rank")?,
            block: get("block")?,
        };

        let mut graphs = BTreeMap::new();
        let gobj = match j.get("graphs") {
            Some(Json::Obj(o)) => o,
            _ => return Err(anyhow!("meta.json: no graphs object")),
        };
        for (name, g) in gobj {
            let file = g
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("graph {name}: no file"))?;
            let args = g
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("graph {name}: no args"))?
                .iter()
                .map(|a| -> anyhow::Result<ArgMeta> {
                    Ok(ArgMeta {
                        name: a
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("arg name"))?
                            .to_string(),
                        shape: a
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow!("arg shape"))?
                            .iter()
                            .map(|d| d.as_usize().unwrap_or(0))
                            .collect(),
                        dtype: a
                            .get("dtype")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("arg dtype"))?
                            .to_string(),
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let results = g
                .get("results")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("graph {name}: no results"))?
                .iter()
                .map(|r| r.as_str().unwrap_or("").to_string())
                .collect();
            graphs.insert(
                name.clone(),
                GraphMeta {
                    name: name.clone(),
                    file: dir.join(file),
                    args,
                    results,
                },
            );
        }
        Ok(Meta {
            dir: dir.to_path_buf(),
            model,
            graphs,
        })
    }

    pub fn graph(&self, name: &str) -> anyhow::Result<&GraphMeta> {
        self.graphs
            .get(name)
            .ok_or_else(|| anyhow!("graph '{name}' not in meta.json"))
    }

    /// Default artifact dir: $BOF4_ARTIFACTS or ./artifacts (searching up
    /// from the current dir so tests/benches work from any workspace cwd).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("BOF4_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = dir.join("artifacts");
            if cand.join("meta.json").exists() {
                return cand;
            }
            if !dir.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    pub fn load_default() -> anyhow::Result<Meta> {
        Self::load(&Self::default_dir())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Meta::default_dir().join("meta.json").exists()
    }

    #[test]
    fn loads_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let meta = Meta::load_default().unwrap();
        assert_eq!(meta.model.vocab, 64);
        assert_eq!(meta.model.block, 64);
        for g in [
            "init_params",
            "lm_nll",
            "train_step",
            "lora_step",
            "dequant_matmul",
        ] {
            let gm = meta.graph(g).unwrap();
            assert!(gm.file.exists(), "{:?}", gm.file);
            assert!(!gm.args.is_empty());
        }
    }

    #[test]
    fn train_step_abi_symmetry() {
        if !have_artifacts() {
            return;
        }
        let meta = Meta::load_default().unwrap();
        let g = meta.graph("train_step").unwrap();
        // 16 params * 3 + step + tokens
        assert_eq!(g.args.len(), 50);
        assert_eq!(g.results.len(), 50);
        assert_eq!(g.args[0].name, g.results[0]);
        assert_eq!(g.arg_index("tokens"), Some(49));
        assert_eq!(g.result_index("loss"), Some(49));
    }

    #[test]
    fn arg_meta_helpers() {
        let a = ArgMeta {
            name: "x".into(),
            shape: vec![2, 3, 4],
            dtype: "float32".into(),
        };
        assert_eq!(a.elements(), 24);
    }
}
