//! PJRT client wrapper: compile-once executable cache + host marshalling.
//!
//! HLO **text** is the interchange format (not serialized protos): jax≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use super::meta::{GraphMeta, Meta};

/// A host-side tensor in one of the dtypes crossing the ABI.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    U8(Vec<u8>, Vec<usize>),
    U32(Vec<u32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_u32(v: u32) -> Self {
        HostTensor::U32(vec![v], vec![])
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32(data, shape)
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32(data, shape)
    }

    pub fn u8(data: Vec<u8>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::U8(data, shape)
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s)
            | HostTensor::I32(_, s)
            | HostTensor::U8(_, s)
            | HostTensor::U32(_, s) => s,
        }
    }

    pub fn dtype_str(&self) -> &'static str {
        match self {
            HostTensor::F32(..) => "float32",
            HostTensor::I32(..) => "int32",
            HostTensor::U8(..) => "uint8",
            HostTensor::U32(..) => "uint32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            other => Err(anyhow!("expected f32 tensor, got {}", other.dtype_str())),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            other => Err(anyhow!("expected f32 tensor, got {}", other.dtype_str())),
        }
    }

    pub fn scalar_f32_value(&self) -> Result<f32> {
        Ok(self.as_f32()?[0])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        fn bytes<T: Copy>(v: &[T]) -> &[u8] {
            unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
            }
        }
        let (ty, dims, raw): (xla::ElementType, &Vec<usize>, &[u8]) = match self {
            HostTensor::F32(d, s) => (xla::ElementType::F32, s, bytes(d)),
            HostTensor::I32(d, s) => (xla::ElementType::S32, s, bytes(d)),
            HostTensor::U8(d, s) => (xla::ElementType::U8, s, bytes(d)),
            HostTensor::U32(d, s) => (xla::ElementType::U32, s, bytes(d)),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, dims, raw)
            .map_err(|e| anyhow!("literal creation failed: {e:?}"))
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let ty = lit.ty().map_err(|e| anyhow!("literal ty: {e:?}"))?;
        Ok(match ty {
            xla::ElementType::F32 => {
                HostTensor::F32(lit.to_vec().map_err(|e| anyhow!("{e:?}"))?, dims)
            }
            xla::ElementType::S32 => {
                HostTensor::I32(lit.to_vec().map_err(|e| anyhow!("{e:?}"))?, dims)
            }
            xla::ElementType::U8 => {
                HostTensor::U8(lit.to_vec().map_err(|e| anyhow!("{e:?}"))?, dims)
            }
            xla::ElementType::U32 => {
                HostTensor::U32(lit.to_vec().map_err(|e| anyhow!("{e:?}"))?, dims)
            }
            other => return Err(anyhow!("unsupported result element type {other:?}")),
        })
    }
}

/// Compiled-executable cache over the PJRT CPU client.
pub struct Runtime {
    pub meta: Meta,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// The PJRT client/executable handles are internally synchronized for our
// single-client, execute-only usage; Runtime is shared behind &self.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Build from the default artifacts directory.
    pub fn new() -> Result<Runtime> {
        Self::with_meta(Meta::load_default()?)
    }

    pub fn with_meta(meta: Meta) -> Result<Runtime> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu failed: {e:?}"))?;
        crate::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            meta,
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Compile (or fetch the cached) executable for a graph.
    pub fn executable(&self, graph: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(graph) {
            return Ok(exe.clone());
        }
        let gm = self.meta.graph(graph)?;
        let sw = crate::util::timer::Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(
            gm.file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {:?}", gm.file))?,
        )
        .map_err(|e| anyhow!("parsing {:?}: {e:?}", gm.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {graph}: {e:?}"))?;
        crate::info!("compiled graph '{graph}' in {:.1} ms", sw.elapsed_ms());
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(graph.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a graph with ABI validation against meta.json.
    pub fn run(&self, graph: &str, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let gm = self.meta.graph(graph)?.clone();
        self.validate_args(&gm, args)?;
        let exe = self.executable(graph)?;
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {graph}: {e:?}"))?;
        let first = result
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("{graph}: empty result"))?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {graph}: {e:?}"))?;
        // Graphs are lowered with return_tuple=True.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {graph}: {e:?}"))?;
        if parts.len() != gm.results.len() {
            return Err(anyhow!(
                "{graph}: expected {} results, got {}",
                gm.results.len(),
                parts.len()
            ));
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }

    fn validate_args(&self, gm: &GraphMeta, args: &[HostTensor]) -> Result<()> {
        if args.len() != gm.args.len() {
            return Err(anyhow!(
                "{}: expected {} args, got {}",
                gm.name,
                gm.args.len(),
                args.len()
            ));
        }
        for (i, (a, m)) in args.iter().zip(&gm.args).enumerate() {
            if a.shape() != m.shape.as_slice() {
                return Err(anyhow!(
                    "{} arg {i} ({}): shape {:?} != expected {:?}",
                    gm.name,
                    m.name,
                    a.shape(),
                    m.shape
                ));
            }
            if a.dtype_str() != m.dtype {
                return Err(anyhow!(
                    "{} arg {i} ({}): dtype {} != expected {}",
                    gm.name,
                    m.name,
                    a.dtype_str(),
                    m.dtype
                ));
            }
        }
        Ok(())
    }

    /// Map result names to tensors.
    pub fn run_named(
        &self,
        graph: &str,
        args: &[HostTensor],
    ) -> Result<Vec<(String, HostTensor)>> {
        let names = self.meta.graph(graph)?.results.clone();
        let vals = self.run(graph, args)?;
        Ok(names.into_iter().zip(vals).collect())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Runtime(platform={}, graphs={})",
            self.client.platform_name(),
            self.meta.graphs.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::f32(vec![1.0; 6], vec![2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype_str(), "float32");
        assert!(t.as_f32().is_ok());
        let t = HostTensor::scalar_i32(5);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert!(t.as_f32().is_err());
    }

    #[test]
    #[should_panic]
    fn host_tensor_rejects_shape_mismatch() {
        HostTensor::f32(vec![1.0; 5], vec![2, 3]);
    }

    // Full round-trip through PJRT is covered by rust/tests/runtime_e2e.rs
    // (integration test, requires artifacts).
}
