//! PJRT/XLA backend (behind the `xla` cargo feature): compile-once
//! executable cache + literal marshalling.
//!
//! HLO **text** is the interchange format (not serialized protos): jax≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Enabling this module requires the `xla` crate to be provided
//! out-of-band (vendored + `[patch]`), plus `make artifacts` for the
//! lowered `*.hlo.txt` files referenced by `meta.json`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::meta::GraphMeta;
use super::{Backend, HostTensor};
use crate::error::Result;
use crate::util::sync::lock_recover;

fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    fn bytes<T: Copy>(v: &[T]) -> &[u8] {
        // SAFETY: reinterpreting a &[T] of plain-old-data as raw bytes;
        // `size_of_val` gives the exact byte length and the output borrow
        // is tied to `v` by the signature.
        unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
    }
    let (ty, dims, raw): (xla::ElementType, &Vec<usize>, &[u8]) = match t {
        HostTensor::F32(d, s) => (xla::ElementType::F32, s, bytes(d.as_slice())),
        HostTensor::I32(d, s) => (xla::ElementType::S32, s, bytes(d.as_slice())),
        HostTensor::U8(d, s) => (xla::ElementType::U8, s, bytes(d.as_slice())),
        HostTensor::U32(d, s) => (xla::ElementType::U32, s, bytes(d.as_slice())),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, dims, raw)
        .map_err(|e| crate::err!("literal creation failed: {e:?}"))
}

fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| crate::err!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = lit.ty().map_err(|e| crate::err!("literal ty: {e:?}"))?;
    Ok(match ty {
        xla::ElementType::F32 => {
            HostTensor::f32(lit.to_vec().map_err(|e| crate::err!("{e:?}"))?, dims)
        }
        xla::ElementType::S32 => {
            HostTensor::i32(lit.to_vec().map_err(|e| crate::err!("{e:?}"))?, dims)
        }
        xla::ElementType::U8 => {
            HostTensor::u8(lit.to_vec().map_err(|e| crate::err!("{e:?}"))?, dims)
        }
        xla::ElementType::U32 => {
            HostTensor::u32(lit.to_vec().map_err(|e| crate::err!("{e:?}"))?, dims)
        }
        other => return Err(crate::err!("unsupported result element type {other:?}")),
    })
}

/// Compiled-executable cache over the PJRT CPU client.
pub struct XlaBackend {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: the PJRT client/executable handles are internally synchronized
// for our single-client, execute-only usage; XlaBackend is shared behind
// &self and never hands out raw pointers.
unsafe impl Send for XlaBackend {}
// SAFETY: see the `Send` justification above — all &self entry points go
// through the internally-synchronized PJRT API or the `cache` mutex.
unsafe impl Sync for XlaBackend {}

impl XlaBackend {
    pub fn new() -> Result<XlaBackend> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| crate::err!("PjRtClient::cpu failed: {e:?}"))?;
        crate::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(XlaBackend {
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Compile (or fetch the cached) executable for a graph.
    fn executable(&self, gm: &GraphMeta) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = lock_recover(&self.cache).get(&gm.name) {
            return Ok(exe.clone());
        }
        let sw = crate::util::timer::Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(
            gm.file
                .to_str()
                .ok_or_else(|| crate::err!("non-utf8 path {:?}", gm.file))?,
        )
        .map_err(|e| crate::err!("parsing {:?}: {e:?}", gm.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| crate::err!("compiling {}: {e:?}", gm.name))?;
        crate::info!("compiled graph '{}' in {:.1} ms", gm.name, sw.elapsed_ms());
        let exe = Arc::new(exe);
        lock_recover(&self.cache).insert(gm.name.clone(), exe.clone());
        Ok(exe)
    }
}

impl Backend for XlaBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, gm: &GraphMeta) -> Result<()> {
        self.executable(gm).map(|_| ())
    }

    fn execute(&self, gm: &GraphMeta, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let exe = self.executable(gm)?;
        let literals: Vec<xla::Literal> =
            args.iter().map(to_literal).collect::<Result<Vec<_>>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| crate::err!("executing {}: {e:?}", gm.name))?;
        let first = result
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| crate::err!("{}: empty result", gm.name))?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| crate::err!("fetching result of {}: {e:?}", gm.name))?;
        // Graphs are lowered with return_tuple=True.
        let parts = lit
            .to_tuple()
            .map_err(|e| crate::err!("untupling result of {}: {e:?}", gm.name))?;
        if parts.len() != gm.results.len() {
            return Err(crate::err!(
                "{}: expected {} results, got {}",
                gm.name,
                gm.results.len(),
                parts.len()
            ));
        }
        parts.iter().map(from_literal).collect()
    }
}
