//! Cache-blocked dense kernels, parallelized over deterministic tiles,
//! with the inner loops vectorized through [`super::simd`].
//!
//! Every kernel here is **bit-identical across every thread count and
//! SIMD path**: output rows/columns are partitioned into tiles with
//! exactly one owning task, element-wise accumulations keep the serial
//! `k`-ascending per-element order, and every inner-`k` reduction
//! (the `matmul_nt` dot products, the RMS-norm sums) runs in the
//! canonical 8-lane-strided order of [`super::simd`] — the same
//! schedule in the scalar, array and AVX2 arms. Cross-row reductions
//! (`rmsnorm_bwd`'s gain gradient) are staged per row and summed
//! serially in row order, so the grouping never depends on the thread
//! count.

// Index-heavy numeric kernels read better as explicit loops.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use super::pool::{phase_scope, KernelPhase, SyncSlice, ThreadPool};
use super::simd::{self, SimdPath};

/// Column-tile width for the dense matmul inner loops: 256 f32 output
/// columns (1 KiB of `y` plus 1 KiB of each visited `w` row) keeps a tile
/// resident in L1 while the `k` loop streams over it.
pub const COL_TILE: usize = 256;

const NORM_EPS: f32 = 1e-6;

/// `y = x @ w` with `x [t,k]`, `w [k,n]`, parallel over rows (or over
/// column tiles when `t == 1`, the decode-row case).
pub fn matmul(pool: &ThreadPool, x: &[f32], w: &[f32], t: usize, k: usize, n: usize) -> Vec<f32> {
    let _phase = phase_scope(KernelPhase::Dense);
    let path = pool.simd();
    let mut y = vec![0.0f32; t * n];
    let ys = SyncSlice::new(&mut y);
    if t == 1 {
        let tiles = n.div_ceil(COL_TILE);
        pool.run(tiles, |jb| {
            let (jlo, jhi) = (jb * COL_TILE, ((jb + 1) * COL_TILE).min(n));
            // SAFETY: column tile jb is written only by task jb.
            let yr = unsafe { ys.slice_mut(jlo, jhi - jlo) };
            matmul_row_tile(path, x, w, n, jlo, jhi, yr);
        });
    } else {
        pool.run(t, |i| {
            // SAFETY: output row i is written only by task i.
            let yr = unsafe { ys.slice_mut(i * n, n) };
            matmul_row(path, &x[i * k..(i + 1) * k], w, n, yr);
        });
    }
    y
}

/// One output row, column-tiled; per-element accumulation order is `kk`
/// ascending — identical to the untiled scalar loop.
fn matmul_row(path: SimdPath, xr: &[f32], w: &[f32], n: usize, yr: &mut [f32]) {
    let mut jlo = 0;
    while jlo < n {
        let jhi = (jlo + COL_TILE).min(n);
        matmul_row_tile(path, xr, w, n, jlo, jhi, &mut yr[jlo..jhi]);
        jlo = jhi;
    }
}

/// Accumulate one `[jlo, jhi)` column tile of one output row: for each
/// `kk` (ascending) the tile does `y += xv * w_row` — an element-wise
/// axpy, vectorized across the 8-column lanes with a scalar tail, so
/// every `y[j]` sees the exact serial accumulation order.
fn matmul_row_tile(
    path: SimdPath,
    xr: &[f32],
    w: &[f32],
    n: usize,
    jlo: usize,
    jhi: usize,
    yt: &mut [f32],
) {
    for (kk, &xv) in xr.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let wr = &w[kk * n + jlo..kk * n + jhi];
        simd::axpy(path, yt, xv, wr);
    }
}

/// `dx = dy @ w^T` with `dy [t,n]`, `w [k,n]` -> `[t,k]`; parallel over
/// rows, each element an independent dot product in the canonical
/// 8-lane-strided reduction order.
pub fn matmul_nt(
    pool: &ThreadPool,
    dy: &[f32],
    w: &[f32],
    t: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let _phase = phase_scope(KernelPhase::Dense);
    let path = pool.simd();
    let mut dx = vec![0.0f32; t * k];
    let dxs = SyncSlice::new(&mut dx);
    pool.run(t, |i| {
        let dyr = &dy[i * n..(i + 1) * n];
        // SAFETY: output row i is written only by task i.
        let dxr = unsafe { dxs.slice_mut(i * k, k) };
        for (kk, dv) in dxr.iter_mut().enumerate() {
            let wr = &w[kk * n..(kk + 1) * n];
            *dv = simd::dot(path, dyr, wr);
        }
    });
    dx
}

/// `dw = x^T @ dy` with `x [t,k]`, `dy [t,n]` -> `[k,n]`; parallel over
/// the `k` output rows. For a fixed `dw[kk][j]` the `t` contributions
/// arrive in ascending `i` order — the serial loop's exact order.
pub fn matmul_tn(
    pool: &ThreadPool,
    x: &[f32],
    dy: &[f32],
    t: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let _phase = phase_scope(KernelPhase::Dense);
    let path = pool.simd();
    let mut dw = vec![0.0f32; k * n];
    let dws = SyncSlice::new(&mut dw);
    pool.run(k, |kk| {
        // SAFETY: output row kk is written only by task kk.
        let dwr = unsafe { dws.slice_mut(kk * n, n) };
        for i in 0..t {
            let xv = x[i * k + kk];
            if xv == 0.0 {
                continue;
            }
            let dyr = &dy[i * n..(i + 1) * n];
            simd::axpy(path, dwr, xv, dyr);
        }
    });
    dw
}

/// Row-wise RMS norm `y = x / rms * g`, parallel over rows; returns
/// `(y, rms per row)`. The mean-square reduction runs in the canonical
/// 8-lane-strided order; the normalize map is element-wise.
pub fn rmsnorm(pool: &ThreadPool, x: &[f32], g: &[f32], d: usize) -> (Vec<f32>, Vec<f32>) {
    let _phase = phase_scope(KernelPhase::Norm);
    let path = pool.simd();
    let rows = x.len() / d;
    let mut y = vec![0.0f32; x.len()];
    let mut rms = vec![0.0f32; rows];
    let ys = SyncSlice::new(&mut y);
    let rs = SyncSlice::new(&mut rms);
    pool.run(rows, |i| {
        let xr = &x[i * d..(i + 1) * d];
        let ms = simd::sum_squares(path, xr) / d as f32;
        let r = (ms + NORM_EPS).sqrt();
        // SAFETY: row i of y and entry i of rms are written only by task i.
        unsafe { rs.slice_mut(i, 1) }[0] = r;
        let yr = unsafe { ys.slice_mut(i * d, d) };
        simd::norm_apply(path, yr, xr, r, g);
    });
    (y, rms)
}

/// Backward of [`rmsnorm`]: returns `(dx, dg)`. `dx` rows are computed in
/// parallel (inner sum in the canonical 8-lane-strided order); the
/// cross-row `dg` reduction is staged per row and then summed serially in
/// ascending row order, so the result is independent of the thread count
/// and SIMD path.
pub fn rmsnorm_bwd(
    pool: &ThreadPool,
    x: &[f32],
    g: &[f32],
    rms: &[f32],
    dy: &[f32],
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let _phase = phase_scope(KernelPhase::Norm);
    let path = pool.simd();
    let rows = x.len() / d;
    let mut dx = vec![0.0f32; x.len()];
    let mut stage = vec![0.0f32; x.len()]; // per-row dg contributions
    let dxs = SyncSlice::new(&mut dx);
    let sts = SyncSlice::new(&mut stage);
    pool.run(rows, |i| {
        let xr = &x[i * d..(i + 1) * d];
        let dyr = &dy[i * d..(i + 1) * d];
        let r = rms[i];
        // SAFETY: row i of dx and of the staging buffer are written only
        // by task i.
        let sg = unsafe { sts.slice_mut(i * d, d) };
        simd::stage_apply(path, sg, dyr, xr, r);
        let s = simd::dot3(path, dyr, g, xr);
        let c = s / (d as f32 * r * r * r);
        // SAFETY: as above — row i of dx is written only by task i.
        let dxr = unsafe { dxs.slice_mut(i * d, d) };
        simd::norm_bwd_apply(path, dxr, g, dyr, r, xr, c);
    });
    let mut dg = vec![0.0f32; d];
    for i in 0..rows {
        let sg = &stage[i * d..(i + 1) * d];
        for j in 0..d {
            dg[j] += sg[j];
        }
    }
    (dx, dg)
}

/// Element-wise map into a fresh buffer, parallel over fixed-size chunks
/// (8-lane blocked through [`simd::apply_unary`]).
pub fn par_map(pool: &ThreadPool, src: &[f32], f: impl Fn(f32) -> f32 + Sync) -> Vec<f32> {
    const CHUNK: usize = 4096;
    let _phase = phase_scope(KernelPhase::Map);
    let path = pool.simd();
    let mut out = vec![0.0f32; src.len()];
    let os = SyncSlice::new(&mut out);
    pool.run(src.len().div_ceil(CHUNK), |c| {
        let (lo, hi) = (c * CHUNK, ((c + 1) * CHUNK).min(src.len()));
        // SAFETY: chunk c is written only by task c.
        let dst = unsafe { os.slice_mut(lo, hi - lo) };
        simd::apply_unary(path, dst, &src[lo..hi], &f);
    });
    out
}

/// Element-wise `dst[i] = f(dst[i], src[i])`, parallel over chunks
/// (8-lane blocked through [`simd::apply_zip`]).
pub fn par_zip_apply(
    pool: &ThreadPool,
    dst: &mut [f32],
    src: &[f32],
    f: impl Fn(f32, f32) -> f32 + Sync,
) {
    const CHUNK: usize = 4096;
    let _phase = phase_scope(KernelPhase::Map);
    let path = pool.simd();
    let len = dst.len();
    let ds = SyncSlice::new(dst);
    pool.run(len.div_ceil(CHUNK), |c| {
        let (lo, hi) = (c * CHUNK, ((c + 1) * CHUNK).min(len));
        // SAFETY: chunk c is written only by task c.
        let d = unsafe { ds.slice_mut(lo, hi - lo) };
        simd::apply_zip(path, d, &src[lo..hi], &f);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn serial_matmul(x: &[f32], w: &[f32], t: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; t * n];
        for i in 0..t {
            for (kk, &xv) in x[i * k..(i + 1) * k].iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                for j in 0..n {
                    y[i * n + j] += xv * w[kk * n + j];
                }
            }
        }
        y
    }

    /// The canonical serial reference for `matmul_nt`: each element is a
    /// dot product in the 8-lane-strided reduction order (this replaced
    /// the old sequential-`j` order when the SIMD layer landed).
    fn serial_matmul_nt(dy: &[f32], w: &[f32], t: usize, k: usize, n: usize) -> Vec<f32> {
        let mut dx = vec![0.0f32; t * k];
        for i in 0..t {
            for kk in 0..k {
                dx[i * k + kk] = simd::dot(
                    SimdPath::None,
                    &dy[i * n..(i + 1) * n],
                    &w[kk * n..(kk + 1) * n],
                );
            }
        }
        dx
    }

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_gaussian_f32(&mut v, 1.0);
        v
    }

    /// One pool per `(executable path, thread count)` combination — the
    /// grid every bitwise-equality test below sweeps.
    fn sweep_pools() -> Vec<ThreadPool> {
        let mut pools = Vec::new();
        for path in simd::all_paths() {
            for threads in [1usize, 8] {
                pools.push(ThreadPool::with_config(threads, path));
            }
        }
        pools
    }

    #[test]
    fn matmul_matches_serial_bitwise_across_thread_counts() {
        let (t, k, n) = (13usize, 17usize, 300usize); // spans >1 col tile
        let x = rand(t * k, 1);
        let w = rand(k * n, 2);
        let want = serial_matmul(&x, &w, t, k, n);
        for threads in [1usize, 2, 5] {
            let pool = ThreadPool::with_threads(threads);
            assert_eq!(matmul(&pool, &x, &w, t, k, n), want, "threads={threads}");
            // the t == 1 column-tiled path too
            let w1 = serial_matmul(&x[..k], &w, 1, k, n);
            assert_eq!(matmul(&pool, &x[..k], &w, 1, k, n), w1, "row, threads={threads}");
        }
        // the element-wise accumulation order is identical in every SIMD
        // path, so the plain serial loop stays the exact reference
        for pool in sweep_pools() {
            assert_eq!(matmul(&pool, &x, &w, t, k, n), want, "{pool:?}");
        }
    }

    #[test]
    fn matmul_nt_tn_match_brute_force() {
        let (t, k, n) = (5usize, 7usize, 9usize);
        let x = rand(t * k, 3);
        let w = rand(k * n, 4);
        let dy = rand(t * n, 5);
        let pool = ThreadPool::with_threads(3);
        let dx = matmul_nt(&pool, &dy, &w, t, k, n);
        // exact vs the canonical strided serial reference...
        assert_eq!(dx, serial_matmul_nt(&dy, &w, t, k, n));
        // ...and near the naive sequential sum (different grouping)
        for i in 0..t {
            for kk in 0..k {
                let mut s = 0.0f32;
                for j in 0..n {
                    s += dy[i * n + j] * w[kk * n + j];
                }
                assert!((dx[i * k + kk] - s).abs() < 1e-5);
            }
        }
        let dw = matmul_tn(&pool, &x, &dy, t, k, n);
        let dw1 = matmul_tn(&ThreadPool::with_threads(1), &x, &dy, t, k, n);
        assert_eq!(dw, dw1, "dw must not depend on thread count");
        for kk in 0..k {
            for j in 0..n {
                let mut s = 0.0f32;
                for i in 0..t {
                    s += x[i * k + kk] * dy[i * n + j];
                }
                assert!((dw[kk * n + j] - s).abs() < 1e-5);
            }
        }
    }

    /// The tentpole contract: every dense kernel is bit-identical across
    /// `SIMD path × thread count`, including shapes with remainder lanes
    /// (`k, n` not multiples of 8).
    #[test]
    fn dense_kernels_bitwise_equal_across_simd_paths_and_threads() {
        let sizes = [1usize, 7, 8, 9, 31, 64];
        let t = 3usize;
        let reference = ThreadPool::with_config(1, SimdPath::None);
        let pools = sweep_pools();
        for &k in &sizes {
            for &n in &sizes {
                let seed = (k * 1000 + n) as u64;
                let x = rand(t * k, seed);
                let w = rand(k * n, seed + 1);
                let dy = rand(t * n, seed + 2);
                let want_mm = matmul(&reference, &x, &w, t, k, n);
                let want_row = matmul(&reference, &x[..k], &w, 1, k, n);
                let want_nt = matmul_nt(&reference, &dy, &w, t, k, n);
                let want_tn = matmul_tn(&reference, &x, &dy, t, k, n);
                for pool in &pools {
                    let tag = format!("k={k} n={n} {pool:?}");
                    assert_eq!(matmul(pool, &x, &w, t, k, n), want_mm, "matmul {tag}");
                    assert_eq!(matmul(pool, &x[..k], &w, 1, k, n), want_row, "row matmul {tag}");
                    assert_eq!(matmul_nt(pool, &dy, &w, t, k, n), want_nt, "matmul_nt {tag}");
                    assert_eq!(matmul_tn(pool, &x, &dy, t, k, n), want_tn, "matmul_tn {tag}");
                }
            }
        }
    }

    #[test]
    fn rmsnorm_bitwise_equal_across_simd_paths_and_threads() {
        let rows = 5usize;
        let reference = ThreadPool::with_config(1, SimdPath::None);
        let pools = sweep_pools();
        for &d in &[1usize, 7, 8, 9, 31, 64] {
            let x = rand(rows * d, 70 + d as u64);
            let g = rand(d, 71 + d as u64);
            let dy = rand(rows * d, 72 + d as u64);
            let (want_y, want_r) = rmsnorm(&reference, &x, &g, d);
            let (want_dx, want_dg) = rmsnorm_bwd(&reference, &x, &g, &want_r, &dy, d);
            for pool in &pools {
                let tag = format!("d={d} {pool:?}");
                let (y, r) = rmsnorm(pool, &x, &g, d);
                assert_eq!(y, want_y, "rmsnorm y {tag}");
                assert_eq!(r, want_r, "rmsnorm rms {tag}");
                let (dx, dg) = rmsnorm_bwd(pool, &x, &g, &r, &dy, d);
                assert_eq!(dx, want_dx, "rmsnorm_bwd dx {tag}");
                assert_eq!(dg, want_dg, "rmsnorm_bwd dg {tag}");
            }
        }
    }

    #[test]
    fn rmsnorm_fwd_bwd_thread_invariant() {
        let d = 24usize;
        let rows = 11usize;
        let x = rand(rows * d, 6);
        let g = rand(d, 7);
        let dy = rand(rows * d, 8);
        let p1 = ThreadPool::with_threads(1);
        let p4 = ThreadPool::with_threads(4);
        let (y1, r1) = rmsnorm(&p1, &x, &g, d);
        let (y4, r4) = rmsnorm(&p4, &x, &g, d);
        assert_eq!(y1, y4);
        assert_eq!(r1, r4);
        let (dx1, dg1) = rmsnorm_bwd(&p1, &x, &g, &r1, &dy, d);
        let (dx4, dg4) = rmsnorm_bwd(&p4, &x, &g, &r4, &dy, d);
        assert_eq!(dx1, dx4);
        assert_eq!(dg1, dg4);
    }

    #[test]
    fn par_map_and_zip_apply() {
        let src = rand(10_000, 9);
        let pool = ThreadPool::with_threads(4);
        let doubled = par_map(&pool, &src, |v| v * 2.0);
        for (a, b) in doubled.iter().zip(&src) {
            assert_eq!(*a, b * 2.0);
        }
        let mut dst = src.clone();
        par_zip_apply(&pool, &mut dst, &doubled, |a, b| a + b);
        for (d, s) in dst.iter().zip(&src) {
            assert_eq!(*d, s + s * 2.0);
        }
        // element-wise maps are bit-identical across every path too
        for p in sweep_pools() {
            assert_eq!(par_map(&p, &src, |v| v * 2.0), doubled, "{p:?}");
            let mut d2 = src.clone();
            par_zip_apply(&p, &mut d2, &doubled, |a, b| a + b);
            assert_eq!(d2, dst, "{p:?}");
        }
    }
}
