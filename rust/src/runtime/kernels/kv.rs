//! Fused dequant decode attention over block-quantized KV caches (the
//! kernel half of the `BOF4_KV` subsystem; the storage half lives in
//! [`crate::quant::kv`]).
//!
//! [`decode_attention_kv`] mirrors [`super::attention::decode_attention`]
//! exactly — same `(head)` fan-out, same serial softmax row pass, same
//! per-head output stripes — but its score dots and weighted-V axpys
//! read q8/q4 codes directly through [`super::simd`]'s fused KV
//! primitives (`kv_dot_*`/`kv_axpy_*`). Each primitive dequantizes
//! `code * scale` (q8) or `levels[code] * scale` (q4) per element with
//! the identical scalar expression on every path and reduces in the
//! canonical 8-lane-strided order, so quantized decode output is
//! bit-identical across `BOF4_THREADS × BOF4_SIMD` — and bit-identical
//! to running the f32 kernel over an explicitly dequantized cache
//! (pinned by the tests below). Dequantization never materializes a
//! f32 row: the cache stays quantized end-to-end through attention.

#![allow(clippy::too_many_arguments)]

use super::pool::{SyncSlice, ThreadPool};
use super::simd::{self, SimdPath};
use crate::quant::kv::KvFormat;

/// Borrowed view of one quantized cache slab (`[seq, d]` elements,
/// row-major; positions `0..=p` valid at read time).
///
/// `codes` holds `seq` rows of `fmt.row_code_bytes`-many bytes (q8: one
/// signed byte per element; q4: nibble-packed, low nibble = even
/// element). `scales` holds `seq` rows of `d.div_ceil(block)` per-block
/// constants. `levels` is the BOF4 reconstruction table (q4 only;
/// ignored — typically all zeros — for q8).
#[derive(Clone, Copy)]
pub struct KvView<'a> {
    /// `Q8` or `Q4` — f32 caches take the unquantized
    /// [`super::attention::decode_attention`] path instead.
    pub fmt: KvFormat,
    pub codes: &'a [u8],
    pub scales: &'a [f32],
    pub block: usize,
    pub levels: &'a [f32; 16],
}

impl KvView<'_> {
    /// Code bytes per `d`-element row under this view's format.
    pub fn row_code_bytes(&self, d: usize) -> usize {
        match self.fmt {
            KvFormat::Q8 => d,
            KvFormat::Q4 => d.div_ceil(2),
            KvFormat::F32 => unreachable!("f32 caches use attention::decode_attention"),
        }
    }

    /// Canonical-order dot of `q1` against columns
    /// `hoff..hoff+q1.len()` of cached row `s2`.
    fn dot_row(&self, path: SimdPath, q1: &[f32], s2: usize, hoff: usize, d: usize) -> f32 {
        let nb = d.div_ceil(self.block);
        let scales = &self.scales[s2 * nb..(s2 + 1) * nb];
        let rcb = self.row_code_bytes(d);
        let codes = &self.codes[s2 * rcb..(s2 + 1) * rcb];
        match self.fmt {
            KvFormat::Q8 => simd::kv_dot_q8(path, q1, codes, scales, hoff, self.block),
            KvFormat::Q4 => {
                simd::kv_dot_q4(path, q1, codes, &self.levels[..], scales, hoff, self.block)
            }
            KvFormat::F32 => unreachable!("f32 caches use attention::decode_attention"),
        }
    }

    /// Serial-order `acc += s * row` over columns `hoff..hoff+acc.len()`
    /// of cached row `s2`.
    fn axpy_row(
        &self,
        path: SimdPath,
        acc: &mut [f32],
        s: f32,
        s2: usize,
        hoff: usize,
        d: usize,
    ) {
        let nb = d.div_ceil(self.block);
        let scales = &self.scales[s2 * nb..(s2 + 1) * nb];
        let rcb = self.row_code_bytes(d);
        let codes = &self.codes[s2 * rcb..(s2 + 1) * rcb];
        match self.fmt {
            KvFormat::Q8 => simd::kv_axpy_q8(path, acc, s, codes, scales, hoff, self.block),
            KvFormat::Q4 => {
                simd::kv_axpy_q4(path, acc, s, codes, &self.levels[..], scales, hoff, self.block)
            }
            KvFormat::F32 => unreachable!("f32 caches use attention::decode_attention"),
        }
    }
}

/// One incremental decode-step attention for a single batch row over
/// **quantized** caches: query from the fresh f32 `qkv [3d]` row,
/// keys/values read fused from the `kc`/`vc` views (positions `0..=p`
/// valid). Fanned out over heads; returns the attention mix `y [d]`.
///
/// Structurally identical to [`super::attention::decode_attention`]
/// (score dot → serial softmax → weighted-V accumulation), with every
/// K/V element dequantized inside the canonical-order primitives — so
/// the result equals the f32 kernel over an explicitly dequantized
/// cache, bit for bit, on every `(threads, SIMD path)` combination.
pub fn decode_attention_kv(
    pool: &ThreadPool,
    qkv: &[f32],
    kc: KvView<'_>,
    vc: KvView<'_>,
    d: usize,
    h: usize,
    p: usize,
) -> Vec<f32> {
    let path = pool.simd();
    let hd = d / h;
    let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();
    let mut y = vec![0.0f32; d];
    let y_s = SyncSlice::new(&mut y);
    pool.run(h, |hi| {
        let hoff = hi * hd;
        let q1 = &qkv[hoff..hoff + hd];
        let mut row = vec![0.0f32; p + 1];
        let mut maxv = f32::NEG_INFINITY;
        for (s2, rv) in row.iter_mut().enumerate() {
            let sc = kc.dot_row(path, q1, s2, hoff, d) * inv_sqrt_hd;
            *rv = sc;
            if sc > maxv {
                maxv = sc;
            }
        }
        let mut denom = 0.0f32;
        for rv in row.iter_mut() {
            *rv = (*rv - maxv).exp();
            denom += *rv;
        }
        let inv = 1.0 / denom;
        let mut acc = vec![0.0f32; hd];
        for (s2, rv) in row.iter().enumerate() {
            vc.axpy_row(path, &mut acc, rv * inv, s2, hoff, d);
        }
        // SAFETY: y columns [hoff, hoff+hd) are written only by task hi.
        let yr = unsafe { y_s.slice_mut(hoff, hd) };
        yr.copy_from_slice(&acc);
    });
    y
}

#[cfg(test)]
mod tests {
    use super::super::attention::decode_attention;
    use super::*;
    use crate::quant::absmax::Norm;
    use crate::quant::kv::{dequantize_row_q4, dequantize_row_q8, quantize_row_q4, quantize_row_q8};
    use crate::quant::{codebook_for, Method};
    use crate::util::rng::Pcg64;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_gaussian_f32(&mut v, 0.5);
        v
    }

    /// Quantize a `[s, d]` f32 slab row-wise; returns
    /// `(codes, scales, dequantized reference slab)`.
    fn quantize_slab(
        slab: &[f32],
        s: usize,
        d: usize,
        block: usize,
        fmt: KvFormat,
        norm: Norm,
        levels: &[f32; 16],
    ) -> (Vec<u8>, Vec<f32>, Vec<f32>) {
        let nb = d.div_ceil(block);
        let rcb = match fmt {
            KvFormat::Q8 => d,
            KvFormat::Q4 => d.div_ceil(2),
            KvFormat::F32 => unreachable!(),
        };
        let cb = codebook_for(&Method::Bof4 { mse: true }, norm, block);
        let mut codes = vec![0u8; s * rcb];
        let mut scales = vec![0.0f32; s * nb];
        let mut deq = vec![0.0f32; s * d];
        for t in 0..s {
            let row = &slab[t * d..(t + 1) * d];
            let c = &mut codes[t * rcb..(t + 1) * rcb];
            let sc = &mut scales[t * nb..(t + 1) * nb];
            let o = &mut deq[t * d..(t + 1) * d];
            match fmt {
                KvFormat::Q8 => {
                    quantize_row_q8(row, block, norm, c, sc);
                    dequantize_row_q8(c, sc, block, o);
                }
                KvFormat::Q4 => {
                    quantize_row_q4(row, block, norm, &cb, c, sc);
                    dequantize_row_q4(c, sc, block, levels, o);
                }
                KvFormat::F32 => unreachable!(),
            }
        }
        (codes, scales, deq)
    }

    fn levels_for(norm: Norm, block: usize) -> [f32; 16] {
        let cb = codebook_for(&Method::Bof4 { mse: true }, norm, block);
        let mut l = [0.0f32; 16];
        for (i, v) in l.iter_mut().enumerate() {
            *v = cb.decode1(i as u8);
        }
        l
    }

    /// The fused kernel must equal the f32 kernel run over an explicitly
    /// dequantized cache — bit for bit — and be bit-identical across
    /// every `(threads, SIMD path)` combination, for both formats, with
    /// ragged quant blocks and odd head dims (odd q4 nibble offsets).
    #[test]
    fn fused_kv_attention_matches_dequantized_reference_bitwise() {
        let reference = ThreadPool::with_config(1, SimdPath::None);
        let mut pools = Vec::new();
        for path in simd::all_paths() {
            for threads in [1usize, 8] {
                pools.push(ThreadPool::with_config(threads, path));
            }
        }
        let s = 5usize;
        // (h, d): hd in {3, 8, 5}; blocks both dividing and ragged vs d
        for &(h, d, block) in &[(2usize, 6usize, 4usize), (2, 16, 8), (2, 10, 3)] {
            let seed = (h * 1000 + d * 10 + block) as u64;
            let qkv = rand(3 * d, seed);
            let kc_f = rand(s * d, seed + 1);
            let vc_f = rand(s * d, seed + 2);
            for (fmt, norm) in [(KvFormat::Q8, Norm::Absmax), (KvFormat::Q4, Norm::SignedAbsmax)] {
                let lv = levels_for(norm, block);
                let (k_codes, k_scales, k_deq) =
                    quantize_slab(&kc_f, s, d, block, fmt, norm, &lv);
                let (v_codes, v_scales, v_deq) =
                    quantize_slab(&vc_f, s, d, block, fmt, norm, &lv);
                let kv = KvView {
                    fmt,
                    codes: &k_codes,
                    scales: &k_scales,
                    block,
                    levels: &lv,
                };
                let vv = KvView {
                    fmt,
                    codes: &v_codes,
                    scales: &v_scales,
                    block,
                    levels: &lv,
                };
                for p in [0usize, 2, s - 1] {
                    let want = decode_attention(&reference, &qkv, &k_deq, &v_deq, d, h, p);
                    for pool in &pools {
                        let got = decode_attention_kv(pool, &qkv, kv, vv, d, h, p);
                        assert_eq!(
                            got, want,
                            "fmt={fmt} h={h} d={d} block={block} p={p} {pool:?}"
                        );
                    }
                }
            }
        }
    }
}
