//! Causal multi-head attention kernels, fanned out over
//! `(batch row x head)` tasks.
//!
//! Each `(bi, hi)` task owns a disjoint region of every output buffer
//! (its head's column stripe of `y`/`dqkv`, its own `[s, s]` probability
//! block of `att`), and runs the serial loop body with the inner `hd`
//! loops vectorized through [`super::simd`]: every q·k / dy·v score dot
//! runs in the canonical 8-lane-strided reduction order, and the
//! weighted-V / gradient accumulations are element-wise axpys (exact
//! serial per-element order) — so results are bit-identical at every
//! thread count and on every SIMD path. The softmax row pass (max, exp,
//! denominator) stays serial per row. The packed layout is the model's:
//! `qkv [t, 3d]` with Q at column offset `0`, K at `d`, V at `2d`, and
//! head `hi` owning columns `hi*hd .. (hi+1)*hd` of each.

#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use super::pool::{phase_scope, KernelPhase, SyncSlice, ThreadPool};
use super::simd;

/// Forward causal MHA over packed `qkv [b*s, 3d]`; returns
/// `(att [b*h*s*s] softmax probabilities, y [b*s, d] attention mix)`.
pub fn mha_forward(
    pool: &ThreadPool,
    qkv: &[f32],
    b: usize,
    h: usize,
    s: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let _phase = phase_scope(KernelPhase::Attention);
    let path = pool.simd();
    let hd = d / h;
    let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();
    let mut att = vec![0.0f32; b * h * s * s];
    let mut y = vec![0.0f32; b * s * d];
    let att_s = SyncSlice::new(&mut att);
    let y_s = SyncSlice::new(&mut y);
    pool.run(b * h, |bh| {
        let (bi, hi) = (bh / h, bh % h);
        let hoff = hi * hd;
        // SAFETY: probability block bh is written only by task bh.
        let ab = unsafe { att_s.slice_mut(bh * s * s, s * s) };
        for s1 in 0..s {
            let t1 = bi * s + s1;
            let q1 = &qkv[t1 * 3 * d + hoff..t1 * 3 * d + hoff + hd];
            let mut row = vec![0.0f32; s1 + 1];
            let mut maxv = f32::NEG_INFINITY;
            for (s2, rv) in row.iter_mut().enumerate() {
                let t2 = bi * s + s2;
                let k2 = &qkv[t2 * 3 * d + d + hoff..t2 * 3 * d + d + hoff + hd];
                let sc = simd::dot(path, q1, k2) * inv_sqrt_hd;
                *rv = sc;
                if sc > maxv {
                    maxv = sc;
                }
            }
            let mut denom = 0.0f32;
            for rv in row.iter_mut() {
                *rv = (*rv - maxv).exp();
                denom += *rv;
            }
            let inv = 1.0 / denom;
            let mut acc = vec![0.0f32; hd];
            for (s2, rv) in row.iter().enumerate() {
                let prob = rv * inv;
                ab[s1 * s + s2] = prob;
                let t2 = bi * s + s2;
                let v2 = &qkv[t2 * 3 * d + 2 * d + hoff..t2 * 3 * d + 2 * d + hoff + hd];
                simd::axpy(path, &mut acc, prob, v2);
            }
            // SAFETY: y columns [hoff, hoff+hd) of row t1 belong to head
            // hi of batch row bi — written only by task bh.
            let yr = unsafe { y_s.slice_mut(t1 * d + hoff, hd) };
            yr.copy_from_slice(&acc);
        }
    });
    (att, y)
}

/// Backward of [`mha_forward`]: given the cached probabilities and the
/// gradient `dy [b*s, d]` of the attention mix, returns
/// `dqkv [b*s, 3d]`.
pub fn mha_backward(
    pool: &ThreadPool,
    qkv: &[f32],
    att: &[f32],
    dy: &[f32],
    b: usize,
    h: usize,
    s: usize,
    d: usize,
) -> Vec<f32> {
    let _phase = phase_scope(KernelPhase::Attention);
    let path = pool.simd();
    let hd = d / h;
    let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();
    let mut dqkv = vec![0.0f32; b * s * 3 * d];
    let dq_s = SyncSlice::new(&mut dqkv);
    pool.run(b * h, |bh| {
        let (bi, hi) = (bh / h, bh % h);
        let hoff = hi * hd;
        let aoff = bh * s * s;
        for s1 in 0..s {
            let t1 = bi * s + s1;
            let dy1 = &dy[t1 * d + hoff..t1 * d + hoff + hd];
            let mut datt = vec![0.0f32; s1 + 1];
            for (s2, da) in datt.iter_mut().enumerate() {
                let t2 = bi * s + s2;
                let prob = att[aoff + s1 * s + s2];
                let v2 = &qkv[t2 * 3 * d + 2 * d + hoff..t2 * 3 * d + 2 * d + hoff + hd];
                *da = simd::dot(path, dy1, v2);
                // SAFETY: the V-column stripe of head hi, batch row bi is
                // written only by task bh (borrow ends this iteration).
                let dv2 = unsafe { dq_s.slice_mut(t2 * 3 * d + 2 * d + hoff, hd) };
                simd::axpy(path, dv2, prob, dy1);
            }
            // canonical strided reduction over the (contiguous) causal
            // probability row
            let dot = simd::dot(path, &datt, &att[aoff + s1 * s..aoff + s1 * s + s1 + 1]);
            let q1: Vec<f32> = qkv[t1 * 3 * d + hoff..t1 * 3 * d + hoff + hd].to_vec();
            let mut dq1 = vec![0.0f32; hd];
            for (s2, &da) in datt.iter().enumerate() {
                let prob = att[aoff + s1 * s + s2];
                let dscore = prob * (da - dot) * inv_sqrt_hd;
                if dscore == 0.0 {
                    continue;
                }
                let t2 = bi * s + s2;
                let k2 = &qkv[t2 * 3 * d + d + hoff..t2 * 3 * d + d + hoff + hd];
                simd::axpy(path, &mut dq1, dscore, k2);
                // SAFETY: the K-column stripe of head hi, batch row bi is
                // written only by task bh (borrow ends this iteration).
                let dk2 = unsafe { dq_s.slice_mut(t2 * 3 * d + d + hoff, hd) };
                simd::axpy(path, dk2, dscore, &q1);
            }
            // SAFETY: the Q-column stripe of head hi at row t1 is written
            // only by task bh.
            let dq = unsafe { dq_s.slice_mut(t1 * 3 * d + hoff, hd) };
            for e in 0..hd {
                dq[e] += dq1[e];
            }
        }
    });
    dqkv
}

/// One incremental decode-step attention for a single batch row: query
/// from the fresh `qkv [3d]` row, keys/values from that row's cache
/// slices `kc`/`vc` (`[s, d]`, positions `0..=p` valid). Fanned out over
/// heads; returns the attention mix `y [d]`.
pub fn decode_attention(
    pool: &ThreadPool,
    qkv: &[f32],
    kc: &[f32],
    vc: &[f32],
    d: usize,
    h: usize,
    p: usize,
) -> Vec<f32> {
    let path = pool.simd();
    let hd = d / h;
    let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();
    let mut y = vec![0.0f32; d];
    let y_s = SyncSlice::new(&mut y);
    pool.run(h, |hi| {
        let hoff = hi * hd;
        let q1 = &qkv[hoff..hoff + hd];
        let mut row = vec![0.0f32; p + 1];
        let mut maxv = f32::NEG_INFINITY;
        for (s2, rv) in row.iter_mut().enumerate() {
            let k2 = &kc[s2 * d + hoff..s2 * d + hoff + hd];
            let sc = simd::dot(path, q1, k2) * inv_sqrt_hd;
            *rv = sc;
            if sc > maxv {
                maxv = sc;
            }
        }
        let mut denom = 0.0f32;
        for rv in row.iter_mut() {
            *rv = (*rv - maxv).exp();
            denom += *rv;
        }
        let inv = 1.0 / denom;
        let mut acc = vec![0.0f32; hd];
        for (s2, rv) in row.iter().enumerate() {
            let prob = rv * inv;
            let v2 = &vc[s2 * d + hoff..s2 * d + hoff + hd];
            simd::axpy(path, &mut acc, prob, v2);
        }
        // SAFETY: y columns [hoff, hoff+hd) are written only by task hi.
        let yr = unsafe { y_s.slice_mut(hoff, hd) };
        yr.copy_from_slice(&acc);
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_gaussian_f32(&mut v, 0.5);
        v
    }

    #[test]
    fn mha_forward_thread_invariant_and_causal() {
        let (b, h, s, d) = (2usize, 2usize, 6usize, 8usize);
        let qkv = rand(b * s * 3 * d, 1);
        let p1 = ThreadPool::with_threads(1);
        let p4 = ThreadPool::with_threads(4);
        let (a1, y1) = mha_forward(&p1, &qkv, b, h, s, d);
        let (a4, y4) = mha_forward(&p4, &qkv, b, h, s, d);
        assert_eq!(a1, a4);
        assert_eq!(y1, y4);
        // causal: probabilities above the diagonal stay zero, rows sum to 1
        for bh in 0..b * h {
            for s1 in 0..s {
                let row = &a1[bh * s * s + s1 * s..bh * s * s + (s1 + 1) * s];
                for (s2, &p) in row.iter().enumerate() {
                    if s2 > s1 {
                        assert_eq!(p, 0.0);
                    }
                }
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn mha_backward_thread_invariant() {
        let (b, h, s, d) = (2usize, 2usize, 5usize, 8usize);
        let qkv = rand(b * s * 3 * d, 2);
        let dy = rand(b * s * d, 3);
        let p1 = ThreadPool::with_threads(1);
        let p4 = ThreadPool::with_threads(4);
        let (att, _) = mha_forward(&p1, &qkv, b, h, s, d);
        let g1 = mha_backward(&p1, &qkv, &att, &dy, b, h, s, d);
        let g4 = mha_backward(&p4, &qkv, &att, &dy, b, h, s, d);
        assert_eq!(g1, g4);
    }

    /// Forward, backward, and the decode step must be bit-identical
    /// across `SIMD path × thread count`, with head dims covering
    /// sub-lane, exact-lane and remainder-lane shapes.
    #[test]
    fn attention_bitwise_equal_across_simd_paths_and_threads() {
        use super::super::simd::{self, SimdPath};
        use super::super::ThreadPool;
        let reference = ThreadPool::with_config(1, SimdPath::None);
        let mut pools = Vec::new();
        for path in simd::all_paths() {
            for threads in [1usize, 8] {
                pools.push(ThreadPool::with_config(threads, path));
            }
        }
        let (b, s) = (2usize, 5usize);
        // (h, d) -> head dim hd = d/h in {1, 7, 8, 9, 31}
        for &(h, d) in &[(2usize, 2usize), (1, 7), (2, 16), (3, 27), (1, 31)] {
            let seed = (h * 100 + d) as u64;
            let qkv = rand(b * s * 3 * d, seed);
            let dy = rand(b * s * d, seed + 1);
            let kc = rand(s * d, seed + 2);
            let vc = rand(s * d, seed + 3);
            let (want_att, want_y) = mha_forward(&reference, &qkv, b, h, s, d);
            let want_g = mha_backward(&reference, &qkv, &want_att, &dy, b, h, s, d);
            let want_d0 = decode_attention(&reference, &qkv[..3 * d], &kc, &vc, d, h, 0);
            let want_dp = decode_attention(&reference, &qkv[..3 * d], &kc, &vc, d, h, s - 1);
            for pool in &pools {
                let tag = format!("h={h} d={d} {pool:?}");
                let (att, y) = mha_forward(pool, &qkv, b, h, s, d);
                assert_eq!(att, want_att, "mha_forward att {tag}");
                assert_eq!(y, want_y, "mha_forward y {tag}");
                assert_eq!(
                    mha_backward(pool, &qkv, &att, &dy, b, h, s, d),
                    want_g,
                    "mha_backward {tag}"
                );
                assert_eq!(
                    decode_attention(pool, &qkv[..3 * d], &kc, &vc, d, h, 0),
                    want_d0,
                    "decode p=0 {tag}"
                );
                assert_eq!(
                    decode_attention(pool, &qkv[..3 * d], &kc, &vc, d, h, s - 1),
                    want_dp,
                    "decode p={} {tag}",
                    s - 1
                );
            }
        }
    }

    #[test]
    fn decode_attention_matches_forward_last_row() {
        // one batch row, context p+1: the decode kernel over a cache must
        // equal the full forward's last row for that head layout
        let (h, s, d) = (2usize, 5usize, 8usize);
        let qkv = rand(s * 3 * d, 4);
        let p1 = ThreadPool::with_threads(1);
        let (_, y_full) = mha_forward(&p1, &qkv, 1, h, s, d);
        // build the cache layout: kc/vc [s, d]
        let mut kc = vec![0.0f32; s * d];
        let mut vc = vec![0.0f32; s * d];
        for t in 0..s {
            kc[t * d..(t + 1) * d].copy_from_slice(&qkv[t * 3 * d + d..t * 3 * d + 2 * d]);
            vc[t * d..(t + 1) * d].copy_from_slice(&qkv[t * 3 * d + 2 * d..t * 3 * d + 3 * d]);
        }
        let last = &qkv[(s - 1) * 3 * d..(s - 1) * 3 * d + 3 * d];
        for threads in [1usize, 3] {
            let pool = ThreadPool::with_threads(threads);
            let y = decode_attention(&pool, last, &kc, &vc, d, h, s - 1);
            assert_eq!(&y[..], &y_full[(s - 1) * d..s * d], "threads={threads}");
        }
    }
}
