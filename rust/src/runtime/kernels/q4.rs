//! Fused 4-bit dequant-matmul kernels: the weight stays 4-bit codes with
//! (optionally double-quantized) per-block constants; each tile
//! dequantizes one BOF4 block at a time inside the inner loop — one LUT
//! multiply per weight, with the block constant hoisted. The 16-entry
//! LUT gather and the dequant-constant scale are vectorized 8 columns
//! at a time through [`super::simd`] (with a same-expression scalar
//! tail for block widths that are not multiples of 8).
//!
//! Parallel tiles are aligned to quantization-block boundaries and the
//! accumulation is element-wise (vector lanes never regroup a
//! reduction), so every `y` element keeps the serial kernel's exact
//! `kk`-ascending accumulation order: results are bit-identical at
//! every thread count and on every SIMD path.
//!
//! OPQ outliers ride in a per-matrix sorted flat-index side-table
//! (`out_idx`/`out_val`): each `(kk, block)` step binary-searches its
//! flat range and splits the element-wise axpy at outlier columns,
//! substituting `xv * out_val` — exactly the dense path's contribution
//! over a restore-patched weight, in the same accumulation slot — so
//! the fused OPQ decode stays bit-identical to the patched dense
//! oracle. An empty table short-circuits to the unpatched axpy.

#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use super::pool::{phase_scope, KernelPhase, SyncSlice, ThreadPool};
use super::simd;
use super::tiling;

/// One matmul weight on the serving decode path: dense f32 rows, or 4-bit
/// codes whose per-block constants are stored 8-bit (double-quantized)
/// and dequantized inside the fused inner loop, plus an optional OPQ
/// outlier side-table patched in sparsely (empty when OPQ is off).
pub enum MatW<'a> {
    Dense(&'a [f32]),
    Q4 {
        /// Unpacked codes, `[k, n]`.
        codes: &'a [u8],
        /// 8-bit constant codes, `[k * n / block]` flat.
        am_codes: &'a [u8],
        /// Flattened per-chunk `(min, scale)` pairs.
        am_params: &'a [f32],
        levels: &'a [f32],
        block: usize,
        /// Sorted flat indices (`kk * n + j`) of OPQ-preserved weights.
        out_idx: &'a [u32],
        /// bf16-rounded outlier values, aligned with `out_idx`.
        out_val: &'a [f32],
    },
}

/// Reconstruct one double-quantized block constant (shares the exact
/// expression of [`crate::quant::DoubleQuant::dequantize`] via
/// [`crate::quant::double_quant::reconstruct`]).
#[inline]
pub fn dq_constant(am_codes: &[u8], am_params: &[f32], idx: usize) -> f32 {
    let chunk = idx / crate::quant::double_quant::CHUNK;
    crate::quant::double_quant::reconstruct(
        am_params[2 * chunk],
        am_params[2 * chunk + 1],
        am_codes[idx],
    )
}

/// Subrange `[lo, hi)` of a sorted flat-index side-table that falls in
/// the flat range `[a, b)` — the per-row/per-block binary search the
/// fused kernels use to locate outliers.
#[inline]
fn outlier_span(idx: &[u32], a: usize, b: usize) -> (usize, usize) {
    let lo = idx.partition_point(|&i| (i as usize) < a);
    let hi = lo + idx[lo..].partition_point(|&i| (i as usize) < b);
    (lo, hi)
}

/// One `(kk, block)` axpy of the fused dequant-matmul with the sparse
/// outlier patch: at outlier columns the contribution is `xv * out_val`
/// (exactly what the dense path computes over the restore-patched
/// weight) instead of `xv * (levels[c] * am)`. The block axpy is split
/// at outlier columns — every lane op is element-wise, so splitting
/// changes no per-element expression and the result stays bit-identical
/// to the unsplit dense accumulation at every SIMD path.
#[inline]
#[allow(clippy::too_many_arguments)]
fn q4_axpy_dequant_patched(
    path: simd::SimdPath,
    yblk: &mut [f32],
    xv: f32,
    am: f32,
    cblk: &[u8],
    levels: &[f32],
    base: usize,
    out_idx: &[u32],
    out_val: &[f32],
) {
    if out_idx.is_empty() {
        simd::q4_axpy_dequant(path, yblk, xv, am, cblk, levels);
        return;
    }
    let (lo, hi) = outlier_span(out_idx, base, base + cblk.len());
    if lo == hi {
        simd::q4_axpy_dequant(path, yblk, xv, am, cblk, levels);
        return;
    }
    let mut j0 = 0usize;
    for t in lo..hi {
        let j = out_idx[t] as usize - base;
        if j > j0 {
            simd::q4_axpy_dequant(path, &mut yblk[j0..j], xv, am, &cblk[j0..j], levels);
        }
        yblk[j] += xv * out_val[t];
        j0 = j + 1;
    }
    if j0 < yblk.len() {
        simd::q4_axpy_dequant(path, &mut yblk[j0..], xv, am, &cblk[j0..], levels);
    }
}

/// The scaled-form counterpart of [`q4_axpy_dequant_patched`] for the
/// f32-constant batched kernel (`s = xv * am` hoisted by the caller; the
/// outlier contribution is still `xv * out_val`).
#[inline]
#[allow(clippy::too_many_arguments)]
fn q4_axpy_scaled_patched(
    path: simd::SimdPath,
    yblk: &mut [f32],
    xv: f32,
    s: f32,
    cblk: &[u8],
    levels: &[f32],
    base: usize,
    out_idx: &[u32],
    out_val: &[f32],
) {
    if out_idx.is_empty() {
        simd::q4_axpy_scaled(path, yblk, s, cblk, levels);
        return;
    }
    let (lo, hi) = outlier_span(out_idx, base, base + cblk.len());
    if lo == hi {
        simd::q4_axpy_scaled(path, yblk, s, cblk, levels);
        return;
    }
    let mut j0 = 0usize;
    for t in lo..hi {
        let j = out_idx[t] as usize - base;
        if j > j0 {
            simd::q4_axpy_scaled(path, &mut yblk[j0..j], s, &cblk[j0..j], levels);
        }
        yblk[j] += xv * out_val[t];
        j0 = j + 1;
    }
    if j0 < yblk.len() {
        simd::q4_axpy_scaled(path, &mut yblk[j0..], s, &cblk[j0..], levels);
    }
}

/// `y = x @ w` for a single activation row (`x [k]`). The dense arm
/// reuses the tiled [`tiling::matmul`] so decode logits are bit-identical
/// to the full forward; the q4 arm multiplies in the exact order
/// `xv * (levels[c] * am)` — with OPQ outliers patched sparsely as
/// `xv * out_val` — so it is bit-identical to the dense path over
/// pre-dequantized, outlier-restored weights. Parallel over
/// quantization-block columns.
pub fn row_matmul(pool: &ThreadPool, x: &[f32], w: &MatW<'_>, k: usize, n: usize) -> Vec<f32> {
    match w {
        MatW::Dense(w) => tiling::matmul(pool, x, w, 1, k, n),
        MatW::Q4 {
            codes,
            am_codes,
            am_params,
            levels,
            block,
            out_idx,
            out_val,
        } => {
            let _phase = phase_scope(KernelPhase::Q4);
            let path = pool.simd();
            let nb = n / block;
            // per-row binary search into the sorted side-table, hoisted
            // out of the column-block tasks: each (kk, block) step then
            // searches only its row's (tiny) subrange
            let row_spans: Vec<(u32, u32)> = if out_idx.is_empty() {
                Vec::new()
            } else {
                (0..k)
                    .map(|kk| {
                        let (lo, hi) = outlier_span(out_idx, kk * n, (kk + 1) * n);
                        (lo as u32, hi as u32)
                    })
                    .collect()
            };
            let mut y = vec![0.0f32; n];
            let ys = SyncSlice::new(&mut y);
            pool.run(nb, |jb| {
                // SAFETY: column block jb is written only by task jb.
                let yblk = unsafe { ys.slice_mut(jb * block, *block) };
                for (kk, &xv) in x.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let am = dq_constant(am_codes, am_params, kk * nb + jb);
                    let base = kk * n + jb * block;
                    let cblk = &codes[base..base + block];
                    let (ri, rv) = if row_spans.is_empty() {
                        (&out_idx[..0], &out_val[..0])
                    } else {
                        let (lo, hi) = row_spans[kk];
                        (
                            &out_idx[lo as usize..hi as usize],
                            &out_val[lo as usize..hi as usize],
                        )
                    };
                    q4_axpy_dequant_patched(path, yblk, xv, am, cblk, levels, base, ri, rv);
                }
            });
            y
        }
    }
}

/// Batched fused dequant-matmul `y = x @ dequant(codes, absmax)` with f32
/// per-block constants (`x [t, k]`, `codes [k, n]`, `absmax [k, n/block]`)
/// and an optional OPQ side-table (`out_idx`/`out_val`, empty when OPQ is
/// off) — the standalone `dequant_matmul` graph kernel, parallel over
/// rows.
pub fn q4_matmul(
    pool: &ThreadPool,
    x: &[f32],
    codes: &[u8],
    absmax: &[f32],
    levels: &[f32],
    out_idx: &[u32],
    out_val: &[f32],
    t: usize,
    k: usize,
    n: usize,
    block: usize,
) -> Vec<f32> {
    let _phase = phase_scope(KernelPhase::Q4);
    let path = pool.simd();
    let nb = n / block;
    let mut y = vec![0.0f32; t * n];
    let ys = SyncSlice::new(&mut y);
    pool.run(t, |i| {
        let xr = &x[i * k..(i + 1) * k];
        // SAFETY: output row i is written only by task i.
        let yr = unsafe { ys.slice_mut(i * n, n) };
        for (kk, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            // per-row binary search; blocks subdivide the row subrange
            let (ri, rv) = if out_idx.is_empty() {
                (&out_idx[..0], &out_val[..0])
            } else {
                let (lo, hi) = outlier_span(out_idx, kk * n, (kk + 1) * n);
                (&out_idx[lo..hi], &out_val[lo..hi])
            };
            let crow = &codes[kk * n..(kk + 1) * n];
            let arow = &absmax[kk * nb..(kk + 1) * nb];
            for (jb, &am) in arow.iter().enumerate() {
                let s = xv * am;
                let base = kk * n + jb * block;
                let cblk = &crow[jb * block..(jb + 1) * block];
                let yblk = &mut yr[jb * block..(jb + 1) * block];
                q4_axpy_scaled_patched(path, yblk, xv, s, cblk, levels, base, ri, rv);
            }
        }
    });
    y
}

/// Materialize a q4 weight back to f32 with the same expression the fused
/// kernel uses (`levels[c] * am`), patching the OPQ side-table over the
/// result (the kernel-side [`crate::quant::opq::restore_outliers`]), so
/// prefill (dense forward over these) and decode (fused) stay
/// bit-identical. Parallel over the `k` rows.
pub fn dequant_q4_weight(
    pool: &ThreadPool,
    codes: &[u8],
    am_codes: &[u8],
    am_params: &[f32],
    levels: &[f32],
    out_idx: &[u32],
    out_val: &[f32],
    k: usize,
    n: usize,
    block: usize,
) -> Vec<f32> {
    let _phase = phase_scope(KernelPhase::Q4);
    let path = pool.simd();
    let nb = n / block;
    let mut w = vec![0.0f32; k * n];
    let ws = SyncSlice::new(&mut w);
    pool.run(k, |kk| {
        // SAFETY: weight row kk is written only by task kk.
        let wr = unsafe { ws.slice_mut(kk * n, n) };
        for jb in 0..nb {
            let am = dq_constant(am_codes, am_params, kk * nb + jb);
            let crow = &codes[kk * n + jb * block..kk * n + (jb + 1) * block];
            let wrow = &mut wr[jb * block..(jb + 1) * block];
            simd::q4_fill_dequant(path, wrow, am, crow, levels);
        }
        if !out_idx.is_empty() {
            let (lo, hi) = outlier_span(out_idx, kk * n, (kk + 1) * n);
            for t in lo..hi {
                wr[out_idx[t] as usize - kk * n] = out_val[t];
            }
        }
    });
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn q4_matmul_thread_invariant_and_matches_dense() {
        let (t, k, n, block) = (4usize, 8usize, 16usize, 4usize);
        let mut rng = Pcg64::seed_from_u64(11);
        let mut x = vec![0.0f32; t * k];
        rng.fill_gaussian_f32(&mut x, 1.0);
        let codes: Vec<u8> = (0..k * n).map(|i| (i % 16) as u8).collect();
        let absmax: Vec<f32> = (0..k * n / block).map(|i| 0.1 + (i % 5) as f32 * 0.3).collect();
        let levels: Vec<f32> = (0..16).map(|i| (i as f32 - 7.5) / 7.5).collect();

        let p1 = ThreadPool::with_threads(1);
        let p4 = ThreadPool::with_threads(4);
        let y1 = q4_matmul(&p1, &x, &codes, &absmax, &levels, &[], &[], t, k, n, block);
        let y4 = q4_matmul(&p4, &x, &codes, &absmax, &levels, &[], &[], t, k, n, block);
        assert_eq!(y1, y4);
        // parity vs dense matmul over explicitly dequantized weights
        let nb = n / block;
        let mut w = vec![0.0f32; k * n];
        for kk in 0..k {
            for j in 0..n {
                w[kk * n + j] = levels[codes[kk * n + j] as usize] * absmax[kk * nb + j / block];
            }
        }
        let yd = tiling::matmul(&p1, &x, &w, t, k, n);
        for (a, b) in y1.iter().zip(&yd) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn row_matmul_q4_thread_invariant() {
        let (k, n, block) = (8usize, 16usize, 4usize);
        let mut rng = Pcg64::seed_from_u64(12);
        let mut x = vec![0.0f32; k];
        rng.fill_gaussian_f32(&mut x, 1.0);
        let codes: Vec<u8> = (0..k * n).map(|i| ((i * 7) % 16) as u8).collect();
        let nblocks = k * n / block;
        // double-quantized constants: one chunk, identity-ish mapping
        let am_codes: Vec<u8> = (0..nblocks).map(|i| (i % 250) as u8).collect();
        let am_params = vec![0.05f32, 0.01]; // (min, scale) for chunk 0
        let levels: Vec<f32> = (0..16).map(|i| (i as f32 - 7.5) / 7.5).collect();
        let w = MatW::Q4 {
            codes: &codes,
            am_codes: &am_codes,
            am_params: &am_params,
            levels: &levels,
            block,
            out_idx: &[],
            out_val: &[],
        };
        let y1 = row_matmul(&ThreadPool::with_threads(1), &x, &w, k, n);
        let y4 = row_matmul(&ThreadPool::with_threads(4), &x, &w, k, n);
        assert_eq!(y1, y4);
        // the dense arm routes through the tiled matmul
        let dense: Vec<f32> = (0..k * n).map(|i| i as f32 * 0.01).collect();
        let wd = MatW::Dense(&dense);
        let yd1 = row_matmul(&ThreadPool::with_threads(1), &x, &wd, k, n);
        let yd4 = row_matmul(&ThreadPool::with_threads(4), &x, &wd, k, n);
        assert_eq!(yd1, yd4);
    }

    /// All three fused q4 kernels must be bit-identical across
    /// `SIMD path × thread count`, including block widths with remainder
    /// lanes (block % 8 != 0) and k values off the lane grid.
    #[test]
    fn q4_kernels_bitwise_equal_across_simd_paths_and_threads() {
        use super::super::simd::{self, SimdPath};
        let levels: Vec<f32> = (0..16).map(|i| (i as f32 - 7.5) / 7.5).collect();
        let reference = ThreadPool::with_config(1, SimdPath::None);
        let mut pools = Vec::new();
        for path in simd::all_paths() {
            for threads in [1usize, 8] {
                pools.push(ThreadPool::with_config(threads, path));
            }
        }
        let t = 2usize;
        for &k in &[1usize, 7, 8, 9, 31, 64] {
            for &(n, block) in &[(4usize, 4usize), (12, 4), (8, 8), (16, 8), (7, 7), (64, 16)] {
                let seed = (k * 1000 + n * 10 + block) as u64;
                let mut rng = Pcg64::seed_from_u64(seed);
                let mut x = vec![0.0f32; t * k];
                rng.fill_gaussian_f32(&mut x, 1.0);
                let codes: Vec<u8> = (0..k * n).map(|i| ((i * 7 + k) % 16) as u8).collect();
                let nblocks = k * n / block;
                let absmax: Vec<f32> =
                    (0..nblocks).map(|i| 0.05 + (i % 7) as f32 * 0.03).collect();
                let am_codes: Vec<u8> = (0..nblocks).map(|i| ((i * 11) % 250) as u8).collect();
                let am_params = vec![0.02f32, 0.004]; // one DQ chunk
                // outlier side-table: every 5th position, incl. block
                // edges and lane remainders
                let out_idx: Vec<u32> = (0..k * n).step_by(5).map(|i| i as u32).collect();
                let out_val: Vec<f32> =
                    out_idx.iter().map(|&i| 2.0 + (i % 9) as f32 * 0.25).collect();
                let mw = MatW::Q4 {
                    codes: &codes,
                    am_codes: &am_codes,
                    am_params: &am_params,
                    levels: &levels,
                    block,
                    out_idx: &out_idx,
                    out_val: &out_val,
                };

                let want_batch = q4_matmul(
                    &reference, &x, &codes, &absmax, &levels, &out_idx, &out_val, t, k, n,
                    block,
                );
                let want_row = row_matmul(&reference, &x[..k], &mw, k, n);
                let want_w = dequant_q4_weight(
                    &reference, &codes, &am_codes, &am_params, &levels, &out_idx, &out_val,
                    k, n, block,
                );
                for pool in &pools {
                    let tag = format!("k={k} n={n} block={block} {pool:?}");
                    let got = q4_matmul(
                        pool, &x, &codes, &absmax, &levels, &out_idx, &out_val, t, k, n,
                        block,
                    );
                    assert_eq!(got, want_batch, "q4_matmul {tag}");
                    let got = row_matmul(pool, &x[..k], &mw, k, n);
                    assert_eq!(got, want_row, "row_matmul {tag}");
                    let got = dequant_q4_weight(
                        pool, &codes, &am_codes, &am_params, &levels, &out_idx, &out_val,
                        k, n, block,
                    );
                    assert_eq!(got, want_w, "dequant_q4_weight {tag}");
                }
            }
        }
    }

    #[test]
    fn dequant_q4_weight_thread_invariant() {
        let (k, n, block) = (6usize, 12usize, 4usize);
        let codes: Vec<u8> = (0..k * n).map(|i| ((i * 3) % 16) as u8).collect();
        let nblocks = k * n / block;
        let am_codes: Vec<u8> = (0..nblocks).map(|i| (10 + i % 100) as u8).collect();
        let am_params = vec![0.02f32, 0.004];
        let levels: Vec<f32> = (0..16).map(|i| (i as f32 - 7.5) / 7.5).collect();
        let w1 = dequant_q4_weight(
            &ThreadPool::with_threads(1),
            &codes,
            &am_codes,
            &am_params,
            &levels,
            &[],
            &[],
            k,
            n,
            block,
        );
        let w4 = dequant_q4_weight(
            &ThreadPool::with_threads(4),
            &codes,
            &am_codes,
            &am_params,
            &levels,
            &[],
            &[],
            k,
            n,
            block,
        );
        assert_eq!(w1, w4);
        assert_eq!(w1.len(), k * n);
    }

    /// The OPQ contract: the fused row kernel over a q4 weight with an
    /// outlier side-table must be bit-identical to the tiled dense
    /// matmul over the materialized, outlier-patched weight — at every
    /// SIMD path and thread count (this is what makes OPQ decode match
    /// the dense prefill oracle exactly).
    #[test]
    fn outlier_patched_row_matmul_bitwise_matches_patched_dense() {
        let (k, n, block) = (16usize, 24usize, 8usize);
        let mut rng = Pcg64::seed_from_u64(77);
        let mut x = vec![0.0f32; k];
        rng.fill_gaussian_f32(&mut x, 1.0);
        x[3] = 0.0; // exercise the shared zero-skip
        let codes: Vec<u8> = (0..k * n).map(|i| ((i * 13 + 5) % 16) as u8).collect();
        let nblocks = k * n / block;
        let am_codes: Vec<u8> = (0..nblocks).map(|i| ((i * 7) % 250) as u8).collect();
        let am_params = vec![-0.03f32, 0.002]; // signed constants occur too
        let levels: Vec<f32> = (0..16).map(|i| (i as f32 - 7.5) / 7.5).collect();
        // outliers at block edges, lane remainders, adjacent columns,
        // and in the zero-activation row
        let out_idx: Vec<u32> = vec![0, 7, 8, 9, 3 * n as u32, 3 * n as u32 + 1, (k * n - 1) as u32];
        let out_val: Vec<f32> = out_idx.iter().map(|&i| 5.0 + i as f32 * 0.125).collect();
        let mw = MatW::Q4 {
            codes: &codes,
            am_codes: &am_codes,
            am_params: &am_params,
            levels: &levels,
            block,
            out_idx: &out_idx,
            out_val: &out_val,
        };
        let reference = ThreadPool::with_threads(1);
        // materialized + patched weight: outliers land verbatim
        let w = dequant_q4_weight(
            &reference, &codes, &am_codes, &am_params, &levels, &out_idx, &out_val, k, n,
            block,
        );
        for (t, &i) in out_idx.iter().enumerate() {
            assert_eq!(w[i as usize], out_val[t], "patch at flat {i}");
        }
        use super::super::simd;
        for path in simd::all_paths() {
            for threads in [1usize, 4, 8] {
                let pool = ThreadPool::with_config(threads, path);
                let got = row_matmul(&pool, &x, &mw, k, n);
                let want = tiling::matmul(&pool, &x, &w, 1, k, n);
                assert_eq!(got, want, "threads={threads} path={path:?}");
            }
        }
    }

}
