//! Portable 8-lane SIMD layer for the tiled kernels, std-only.
//!
//! Three interchangeable execution paths implement every primitive:
//!
//! - **scalar** ([`SimdPath::None`]): plain loops — the reference
//!   implementation and the `BOF4_SIMD=0` escape hatch;
//! - **array** ([`SimdPath::Array`]): the same loops expressed over
//!   [`F32x8`], a `[f32; 8]` newtype whose lane-wise ops LLVM
//!   autovectorizes on any architecture — the universal fallback;
//! - **avx2** ([`SimdPath::Avx2`]): explicit `std::arch` x86_64
//!   intrinsics, selected at runtime via `is_x86_feature_detected!`.
//!
//! **Bit-exactness contract.** All three paths produce bit-identical
//! results for every primitive. Element-wise ops (axpy, the q4
//! dequant forms, the norm maps) evaluate the exact same scalar
//! expression per element, and IEEE-754 single ops (`mul`/`add`/`sub`/
//! `div`) round identically whether issued as scalars or as vector
//! lanes — no FMA is ever emitted (the fused rounding would diverge
//! from the scalar path), and `mul_add` below is a *separate* multiply
//! then add by construction.
//!
//! Reductions are pinned to one **canonical 8-lane-strided order**,
//! shared verbatim by all paths (see [`combine8`]): 8 independent lane
//! accumulators where lane `l` owns elements `i ≡ l (mod 8)` of the
//! first `len - len % 8` elements (one vector step per 8 elements);
//! the `len % 8` tail elements are added scalar-wise into lanes
//! `0..len % 8` of the spilled accumulators; finally the 8 lanes
//! combine in the fixed tree `((l0+l4) + (l2+l6)) + ((l1+l5) + (l3+l7))`.
//! The scalar path executes this same schedule with plain loops, so
//! `BOF4_SIMD` — like `BOF4_THREADS` — is a pure performance knob.
//!
//! Path selection: [`path_from_env`] honours `BOF4_SIMD`
//! (`0`/`off`/`none`/`scalar` force the scalar loops; `1`/`on` or unset
//! pick the best detected path; `array`/`avx2` force a specific
//! vectorized path, with `avx2` degrading to `array` on hosts without
//! it). Kernels read the path from their [`super::pool::ThreadPool`],
//! so tests and benches can pin a path per pool without touching the
//! process environment.

// Fixed-width lane loops over [f32; 8] read better (and autovectorize
// reliably) as explicit index loops.
#![allow(clippy::needless_range_loop)]

use std::sync::OnceLock;

/// Vector width of the portable layer (f32 lanes).
pub const LANES: usize = 8;

/// Which implementation of the shared inner-kernel schedule runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdPath {
    /// Scalar loops (the canonical schedule, plain Rust).
    None,
    /// [`F32x8`] array ops — LLVM-autovectorized, any architecture.
    Array,
    /// x86_64 AVX2 intrinsics (runtime-detected).
    Avx2,
}

impl SimdPath {
    /// Stable lowercase tag (`none` | `array` | `avx2`) — what benches
    /// record and `Backend::simd_path` reports.
    pub fn name(self) -> &'static str {
        match self {
            SimdPath::None => "none",
            SimdPath::Array => "array",
            SimdPath::Avx2 => "avx2",
        }
    }

    /// Clamp to what this host can execute: [`SimdPath::Avx2`] degrades
    /// to [`SimdPath::Array`] when the CPU (or architecture) lacks AVX2.
    /// Constructing a pool sanitizes its path, so a forced `avx2` is
    /// never dispatched onto a host that would fault on it.
    pub fn sanitize(self) -> SimdPath {
        if self == SimdPath::Avx2 && detect_best() != SimdPath::Avx2 {
            SimdPath::Array
        } else {
            self
        }
    }
}

/// Best vectorized path this host supports: AVX2 when detected at
/// runtime, else the portable array path.
pub fn detect_best() -> SimdPath {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return SimdPath::Avx2;
        }
    }
    SimdPath::Array
}

/// Process-wide default path from `BOF4_SIMD` (cached at first use):
/// `0`/`off`/`none`/`scalar` force scalar, `array`/`avx2` force a
/// vectorized path (sanitized), anything else — including unset and
/// `1`/`on` — selects [`detect_best`].
pub fn path_from_env() -> SimdPath {
    static PATH: OnceLock<SimdPath> = OnceLock::new();
    *PATH.get_or_init(|| {
        match std::env::var("BOF4_SIMD")
            .ok()
            .as_deref()
            .map(|s| s.trim().to_ascii_lowercase())
            .as_deref()
        {
            Some("0") | Some("off") | Some("none") | Some("scalar") => SimdPath::None,
            Some("array") => SimdPath::Array,
            Some("avx2") => SimdPath::Avx2.sanitize(),
            _ => detect_best(),
        }
    })
}

/// Every path executable on this host (scalar and array always, AVX2
/// when detected) — what the bitwise-equality tests and benches sweep.
pub fn all_paths() -> Vec<SimdPath> {
    let mut v = vec![SimdPath::None, SimdPath::Array];
    if detect_best() == SimdPath::Avx2 {
        v.push(SimdPath::Avx2);
    }
    v
}

// ---------------------------------------------------------------------
// F32x8: the portable vector newtype (array path)
// ---------------------------------------------------------------------

/// Eight f32 lanes. All ops are lane-wise single IEEE-754 operations —
/// written as fixed-width loops LLVM turns into vector instructions —
/// and therefore round bit-identically to the scalar path. There is
/// deliberately no fused multiply-add.
#[derive(Clone, Copy, Debug, PartialEq)]
#[repr(transparent)]
pub struct F32x8(pub [f32; LANES]);

impl F32x8 {
    pub const ZERO: F32x8 = F32x8([0.0; LANES]);

    #[inline(always)]
    pub fn splat(v: f32) -> F32x8 {
        F32x8([v; LANES])
    }

    /// Load the first 8 elements of `s` (panics if `s.len() < 8`).
    #[inline(always)]
    pub fn load(s: &[f32]) -> F32x8 {
        let mut a = [0.0f32; LANES];
        a.copy_from_slice(&s[..LANES]);
        F32x8(a)
    }

    /// Store into the first 8 elements of `d`.
    #[inline(always)]
    pub fn store(self, d: &mut [f32]) {
        d[..LANES].copy_from_slice(&self.0);
    }

    /// `self * a + b` as a separately-rounded multiply then add (never
    /// a fused FMA — fusion would break the bit-exactness contract).
    #[inline(always)]
    // lint: allow(fma-in-kernels): two separately-rounded ops, not a fusion
    pub fn mul_add(self, a: F32x8, b: F32x8) -> F32x8 {
        self * a + b
    }
}

impl std::ops::Add for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn add(self, o: F32x8) -> F32x8 {
        let mut r = self.0;
        for l in 0..LANES {
            r[l] += o.0[l];
        }
        F32x8(r)
    }
}

impl std::ops::AddAssign for F32x8 {
    #[inline(always)]
    fn add_assign(&mut self, o: F32x8) {
        for l in 0..LANES {
            self.0[l] += o.0[l];
        }
    }
}

impl std::ops::Sub for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn sub(self, o: F32x8) -> F32x8 {
        let mut r = self.0;
        for l in 0..LANES {
            r[l] -= o.0[l];
        }
        F32x8(r)
    }
}

impl std::ops::Mul for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn mul(self, o: F32x8) -> F32x8 {
        let mut r = self.0;
        for l in 0..LANES {
            r[l] *= o.0[l];
        }
        F32x8(r)
    }
}

impl std::ops::Div for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn div(self, o: F32x8) -> F32x8 {
        let mut r = self.0;
        for l in 0..LANES {
            r[l] /= o.0[l];
        }
        F32x8(r)
    }
}

/// Combine 8 lane accumulators in the canonical fixed tree order:
/// `((l0+l4) + (l2+l6)) + ((l1+l5) + (l3+l7))`. Every reduction in
/// every path funnels through this one function, so the combine step
/// can never diverge between paths.
#[inline(always)]
pub fn combine8(a: [f32; LANES]) -> f32 {
    let b0 = a[0] + a[4];
    let b1 = a[1] + a[5];
    let b2 = a[2] + a[6];
    let b3 = a[3] + a[7];
    (b0 + b2) + (b1 + b3)
}

/// Gather 8 dequant levels for 8 codes (low nibble indexes `levels`).
/// `codes.len() >= 8`, `levels.len() >= 16`.
#[inline(always)]
fn gather8(codes: &[u8], levels: &[f32]) -> [f32; LANES] {
    let mut g = [0.0f32; LANES];
    for l in 0..LANES {
        g[l] = levels[(codes[l] & 0x0f) as usize];
    }
    g
}

// ---------------------------------------------------------------------
// reductions (canonical 8-lane-strided order in every path)
// ---------------------------------------------------------------------

/// Scalar tail + canonical combine shared by all dot-style reductions:
/// `acc` holds the lane accumulators after the full 8-wide chunks
/// (elements `0..c`); the remaining elements land in lanes `0..n-c`.
#[inline(always)]
fn tail_combine(mut acc: [f32; LANES], c: usize, prod: impl Fn(usize) -> f32, n: usize) -> f32 {
    for j in c..n {
        acc[j - c] += prod(j);
    }
    combine8(acc)
}

/// Canonical strided dot product `sum_i a[i] * b[i]`
/// (`a.len() == b.len()`).
#[inline]
pub fn dot(path: SimdPath, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match path {
        SimdPath::None => dot_scalar(a, b),
        SimdPath::Array => dot_array(a, b),
        SimdPath::Avx2 => dot_avx2(a, b),
    }
}

fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let c = n - n % LANES;
    let mut acc = [0.0f32; LANES];
    let mut i = 0;
    while i < c {
        for l in 0..LANES {
            acc[l] += a[i + l] * b[i + l];
        }
        i += LANES;
    }
    tail_combine(acc, c, |j| a[j] * b[j], n)
}

fn dot_array(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let c = n - n % LANES;
    let mut acc = F32x8::ZERO;
    let mut i = 0;
    while i < c {
        acc += F32x8::load(&a[i..]) * F32x8::load(&b[i..]);
        i += LANES;
    }
    tail_combine(acc.0, c, |j| a[j] * b[j], n)
}

/// Canonical strided triple-product reduction
/// `sum_i (a[i] * b[i]) * c[i]` — the `rmsnorm_bwd` inner sum.
#[inline]
pub fn dot3(path: SimdPath, a: &[f32], b: &[f32], c: &[f32]) -> f32 {
    debug_assert!(a.len() == b.len() && b.len() == c.len());
    match path {
        SimdPath::None => dot3_scalar(a, b, c),
        SimdPath::Array => dot3_array(a, b, c),
        SimdPath::Avx2 => dot3_avx2(a, b, c),
    }
}

fn dot3_scalar(a: &[f32], b: &[f32], c: &[f32]) -> f32 {
    let n = a.len();
    let cc = n - n % LANES;
    let mut acc = [0.0f32; LANES];
    let mut i = 0;
    while i < cc {
        for l in 0..LANES {
            acc[l] += (a[i + l] * b[i + l]) * c[i + l];
        }
        i += LANES;
    }
    tail_combine(acc, cc, |j| (a[j] * b[j]) * c[j], n)
}

fn dot3_array(a: &[f32], b: &[f32], c: &[f32]) -> f32 {
    let n = a.len();
    let cc = n - n % LANES;
    let mut acc = F32x8::ZERO;
    let mut i = 0;
    while i < cc {
        let p = F32x8::load(&a[i..]) * F32x8::load(&b[i..]);
        acc += p * F32x8::load(&c[i..]);
        i += LANES;
    }
    tail_combine(acc.0, cc, |j| (a[j] * b[j]) * c[j], n)
}

/// Canonical strided sum of squares `sum_i a[i]^2` — the `rmsnorm`
/// mean-square numerator.
#[inline]
pub fn sum_squares(path: SimdPath, a: &[f32]) -> f32 {
    match path {
        SimdPath::None => sumsq_scalar(a),
        SimdPath::Array => sumsq_array(a),
        SimdPath::Avx2 => sumsq_avx2(a),
    }
}

fn sumsq_scalar(a: &[f32]) -> f32 {
    let n = a.len();
    let c = n - n % LANES;
    let mut acc = [0.0f32; LANES];
    let mut i = 0;
    while i < c {
        for l in 0..LANES {
            acc[l] += a[i + l] * a[i + l];
        }
        i += LANES;
    }
    tail_combine(acc, c, |j| a[j] * a[j], n)
}

fn sumsq_array(a: &[f32]) -> f32 {
    let n = a.len();
    let c = n - n % LANES;
    let mut acc = F32x8::ZERO;
    let mut i = 0;
    while i < c {
        let v = F32x8::load(&a[i..]);
        acc += v * v;
        i += LANES;
    }
    tail_combine(acc.0, c, |j| a[j] * a[j], n)
}

// ---------------------------------------------------------------------
// element-wise kernels (identical per-element expression in every path)
// ---------------------------------------------------------------------

/// `y[i] += s * x[i]` — the accumulate step of the dense matmuls, the
/// attention weighted-V mix, and the attention gradient scatters.
#[inline]
pub fn axpy(path: SimdPath, y: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    match path {
        SimdPath::None => {
            for (yv, &xv) in y.iter_mut().zip(x) {
                *yv += s * xv;
            }
        }
        SimdPath::Array => {
            let n = y.len();
            let c = n - n % LANES;
            let vs = F32x8::splat(s);
            let mut i = 0;
            while i < c {
                (F32x8::load(&y[i..]) + vs * F32x8::load(&x[i..])).store(&mut y[i..]);
                i += LANES;
            }
            for j in c..n {
                y[j] += s * x[j];
            }
        }
        SimdPath::Avx2 => axpy_avx2(y, s, x),
    }
}

/// RMS-norm forward map `y[i] = x[i] / r * g[i]`.
#[inline]
pub fn norm_apply(path: SimdPath, y: &mut [f32], x: &[f32], r: f32, g: &[f32]) {
    match path {
        SimdPath::None => {
            for i in 0..y.len() {
                y[i] = x[i] / r * g[i];
            }
        }
        SimdPath::Array => {
            let n = y.len();
            let c = n - n % LANES;
            let vr = F32x8::splat(r);
            let mut i = 0;
            while i < c {
                (F32x8::load(&x[i..]) / vr * F32x8::load(&g[i..])).store(&mut y[i..]);
                i += LANES;
            }
            for j in c..n {
                y[j] = x[j] / r * g[j];
            }
        }
        SimdPath::Avx2 => norm_apply_avx2(y, x, r, g),
    }
}

/// RMS-norm backward staging map `sg[i] = dy[i] * x[i] / r` (the
/// per-row gain-gradient contribution).
#[inline]
pub fn stage_apply(path: SimdPath, sg: &mut [f32], dy: &[f32], x: &[f32], r: f32) {
    match path {
        SimdPath::None => {
            for i in 0..sg.len() {
                sg[i] = dy[i] * x[i] / r;
            }
        }
        SimdPath::Array => {
            let n = sg.len();
            let c = n - n % LANES;
            let vr = F32x8::splat(r);
            let mut i = 0;
            while i < c {
                (F32x8::load(&dy[i..]) * F32x8::load(&x[i..]) / vr).store(&mut sg[i..]);
                i += LANES;
            }
            for j in c..n {
                sg[j] = dy[j] * x[j] / r;
            }
        }
        SimdPath::Avx2 => stage_apply_avx2(sg, dy, x, r),
    }
}

/// RMS-norm backward input-gradient map
/// `dx[i] = g[i] * dy[i] / r - x[i] * c`.
#[inline]
pub fn norm_bwd_apply(
    path: SimdPath,
    dx: &mut [f32],
    g: &[f32],
    dy: &[f32],
    r: f32,
    x: &[f32],
    c: f32,
) {
    match path {
        SimdPath::None => {
            for i in 0..dx.len() {
                dx[i] = g[i] * dy[i] / r - x[i] * c;
            }
        }
        SimdPath::Array => {
            let n = dx.len();
            let cc = n - n % LANES;
            let vr = F32x8::splat(r);
            let vc = F32x8::splat(c);
            let mut i = 0;
            while i < cc {
                let lhs = F32x8::load(&g[i..]) * F32x8::load(&dy[i..]) / vr;
                (lhs - F32x8::load(&x[i..]) * vc).store(&mut dx[i..]);
                i += LANES;
            }
            for j in cc..n {
                dx[j] = g[j] * dy[j] / r - x[j] * c;
            }
        }
        SimdPath::Avx2 => norm_bwd_apply_avx2(dx, g, dy, r, x, c),
    }
}

// ---------------------------------------------------------------------
// fused q4 dequant forms (16-entry LUT gather, 8 columns at a time)
// ---------------------------------------------------------------------

/// `y[i] += xv * (levels[codes[i] & 0xf] * am)` — the decode-row fused
/// dequant-matmul form (matches the dense path over a weight
/// materialized as `levels * am`, element for element).
#[inline]
pub fn q4_axpy_dequant(
    path: SimdPath,
    y: &mut [f32],
    xv: f32,
    am: f32,
    codes: &[u8],
    levels: &[f32],
) {
    debug_assert_eq!(y.len(), codes.len());
    match path {
        SimdPath::None => {
            for (yv, &c) in y.iter_mut().zip(codes) {
                *yv += xv * (levels[(c & 0x0f) as usize] * am);
            }
        }
        SimdPath::Array => {
            let n = y.len();
            let c = n - n % LANES;
            let vx = F32x8::splat(xv);
            let va = F32x8::splat(am);
            let mut i = 0;
            while i < c {
                let w = F32x8(gather8(&codes[i..], levels)) * va;
                (F32x8::load(&y[i..]) + vx * w).store(&mut y[i..]);
                i += LANES;
            }
            for j in c..n {
                y[j] += xv * (levels[(codes[j] & 0x0f) as usize] * am);
            }
        }
        SimdPath::Avx2 => q4_axpy_dequant_avx2(y, xv, am, codes, levels),
    }
}

/// `y[i] += s * levels[codes[i] & 0xf]` — the batched fused
/// dequant-matmul form (`s = xv * am` hoisted by the caller).
#[inline]
pub fn q4_axpy_scaled(path: SimdPath, y: &mut [f32], s: f32, codes: &[u8], levels: &[f32]) {
    debug_assert_eq!(y.len(), codes.len());
    match path {
        SimdPath::None => {
            for (yv, &c) in y.iter_mut().zip(codes) {
                *yv += s * levels[(c & 0x0f) as usize];
            }
        }
        SimdPath::Array => {
            let n = y.len();
            let c = n - n % LANES;
            let vs = F32x8::splat(s);
            let mut i = 0;
            while i < c {
                let w = F32x8(gather8(&codes[i..], levels));
                (F32x8::load(&y[i..]) + vs * w).store(&mut y[i..]);
                i += LANES;
            }
            for j in c..n {
                y[j] += s * levels[(codes[j] & 0x0f) as usize];
            }
        }
        SimdPath::Avx2 => q4_axpy_scaled_avx2(y, s, codes, levels),
    }
}

/// `w[i] = levels[codes[i] & 0xf] * am` — the weight materializer (same
/// expression the fused kernels multiply by, so prefill over the
/// materialized weight stays bit-identical to fused decode).
#[inline]
pub fn q4_fill_dequant(path: SimdPath, w: &mut [f32], am: f32, codes: &[u8], levels: &[f32]) {
    debug_assert_eq!(w.len(), codes.len());
    match path {
        SimdPath::None => {
            for (wv, &c) in w.iter_mut().zip(codes) {
                *wv = levels[(c & 0x0f) as usize] * am;
            }
        }
        SimdPath::Array => {
            let n = w.len();
            let c = n - n % LANES;
            let va = F32x8::splat(am);
            let mut i = 0;
            while i < c {
                (F32x8(gather8(&codes[i..], levels)) * va).store(&mut w[i..]);
                i += LANES;
            }
            for j in c..n {
                w[j] = levels[(codes[j] & 0x0f) as usize] * am;
            }
        }
        SimdPath::Avx2 => q4_fill_dequant_avx2(w, am, codes, levels),
    }
}

// ---------------------------------------------------------------------
// fused KV-cache dequant forms (`BOF4_KV` q8/q4 rows; decode attention
// reads quantized K/V blocks without materializing f32 rows)
// ---------------------------------------------------------------------
//
// Element `e` of a quantized KV row dequantizes as
//   q8: w(e) = (codes[e] as i8 as f32) * scales[e / block]
//   q4: w(e) = levels[nibble(codes, e)] * scales[e / block]
// (`codes`/`scales` cover the full `d_model` row; `base` is the head's
// column offset, so per-head reads need no slice re-alignment and the
// nibble/scale indices stay global). Every arm evaluates that exact
// per-element expression — the vector arms gather the 8 dequantized
// values with the same scalar ops, then multiply/accumulate lane-wise —
// so the reductions stay in the canonical 8-lane-strided order and the
// results are bit-identical across paths.

/// One dequantized q8 KV element (shared by every arm).
#[inline(always)]
fn kv1_q8(codes: &[u8], scales: &[f32], e: usize, block: usize) -> f32 {
    (codes[e] as i8) as f32 * scales[e / block]
}

/// One dequantized q4 KV element (nibble-packed codes, low nibble =
/// even element; shared by every arm).
#[inline(always)]
fn kv1_q4(codes: &[u8], levels: &[f32], scales: &[f32], e: usize, block: usize) -> f32 {
    let b = codes[e / 2];
    let code = if e % 2 == 0 { b & 0x0f } else { b >> 4 };
    levels[code as usize] * scales[e / block]
}

/// Gather 8 dequantized q8 KV elements starting at global element `e0`.
#[inline(always)]
fn kv_gather8_q8(codes: &[u8], scales: &[f32], e0: usize, block: usize) -> [f32; LANES] {
    let mut g = [0.0f32; LANES];
    for l in 0..LANES {
        g[l] = kv1_q8(codes, scales, e0 + l, block);
    }
    g
}

/// Gather 8 dequantized q4 KV elements starting at global element `e0`.
#[inline(always)]
fn kv_gather8_q4(
    codes: &[u8],
    levels: &[f32],
    scales: &[f32],
    e0: usize,
    block: usize,
) -> [f32; LANES] {
    let mut g = [0.0f32; LANES];
    for l in 0..LANES {
        g[l] = kv1_q4(codes, levels, scales, e0 + l, block);
    }
    g
}

/// Canonical strided dot of a query slice against a quantized q8 KV row
/// segment: `sum_j q[j] * w(base + j)` — the fused score dot of
/// `BOF4_KV=q8` decode attention.
#[inline]
pub fn kv_dot_q8(
    path: SimdPath,
    q: &[f32],
    codes: &[u8],
    scales: &[f32],
    base: usize,
    block: usize,
) -> f32 {
    debug_assert!(base + q.len() <= codes.len());
    let n = q.len();
    let c = n - n % LANES;
    match path {
        SimdPath::None => {
            let mut acc = [0.0f32; LANES];
            let mut i = 0;
            while i < c {
                for l in 0..LANES {
                    acc[l] += q[i + l] * kv1_q8(codes, scales, base + i + l, block);
                }
                i += LANES;
            }
            tail_combine(acc, c, |j| q[j] * kv1_q8(codes, scales, base + j, block), n)
        }
        SimdPath::Array => {
            let mut acc = F32x8::ZERO;
            let mut i = 0;
            while i < c {
                let w = F32x8(kv_gather8_q8(codes, scales, base + i, block));
                acc += F32x8::load(&q[i..]) * w;
                i += LANES;
            }
            tail_combine(acc.0, c, |j| q[j] * kv1_q8(codes, scales, base + j, block), n)
        }
        SimdPath::Avx2 => kv_dot_q8_avx2(q, codes, scales, base, block),
    }
}

/// `acc[j] += s * w(base + j)` over a quantized q8 KV row segment — the
/// fused weighted-V accumulation of `BOF4_KV=q8` decode attention.
#[inline]
pub fn kv_axpy_q8(
    path: SimdPath,
    acc: &mut [f32],
    s: f32,
    codes: &[u8],
    scales: &[f32],
    base: usize,
    block: usize,
) {
    debug_assert!(base + acc.len() <= codes.len());
    let n = acc.len();
    let c = n - n % LANES;
    match path {
        SimdPath::None => {
            for (j, a) in acc.iter_mut().enumerate() {
                *a += s * kv1_q8(codes, scales, base + j, block);
            }
        }
        SimdPath::Array => {
            let vs = F32x8::splat(s);
            let mut i = 0;
            while i < c {
                let w = F32x8(kv_gather8_q8(codes, scales, base + i, block));
                (F32x8::load(&acc[i..]) + vs * w).store(&mut acc[i..]);
                i += LANES;
            }
            for j in c..n {
                acc[j] += s * kv1_q8(codes, scales, base + j, block);
            }
        }
        SimdPath::Avx2 => kv_axpy_q8_avx2(acc, s, codes, scales, base, block),
    }
}

/// Canonical strided dot of a query slice against a quantized q4 KV row
/// segment (nibble-packed codes, 16-entry `levels` LUT).
#[inline]
pub fn kv_dot_q4(
    path: SimdPath,
    q: &[f32],
    codes: &[u8],
    levels: &[f32],
    scales: &[f32],
    base: usize,
    block: usize,
) -> f32 {
    debug_assert!((base + q.len()).div_ceil(2) <= codes.len());
    let n = q.len();
    let c = n - n % LANES;
    match path {
        SimdPath::None => {
            let mut acc = [0.0f32; LANES];
            let mut i = 0;
            while i < c {
                for l in 0..LANES {
                    acc[l] += q[i + l] * kv1_q4(codes, levels, scales, base + i + l, block);
                }
                i += LANES;
            }
            tail_combine(
                acc,
                c,
                |j| q[j] * kv1_q4(codes, levels, scales, base + j, block),
                n,
            )
        }
        SimdPath::Array => {
            let mut acc = F32x8::ZERO;
            let mut i = 0;
            while i < c {
                let w = F32x8(kv_gather8_q4(codes, levels, scales, base + i, block));
                acc += F32x8::load(&q[i..]) * w;
                i += LANES;
            }
            tail_combine(
                acc.0,
                c,
                |j| q[j] * kv1_q4(codes, levels, scales, base + j, block),
                n,
            )
        }
        SimdPath::Avx2 => kv_dot_q4_avx2(q, codes, levels, scales, base, block),
    }
}

/// `acc[j] += s * w(base + j)` over a quantized q4 KV row segment.
#[inline]
pub fn kv_axpy_q4(
    path: SimdPath,
    acc: &mut [f32],
    s: f32,
    codes: &[u8],
    levels: &[f32],
    scales: &[f32],
    base: usize,
    block: usize,
) {
    debug_assert!((base + acc.len()).div_ceil(2) <= codes.len());
    let n = acc.len();
    let c = n - n % LANES;
    match path {
        SimdPath::None => {
            for (j, a) in acc.iter_mut().enumerate() {
                *a += s * kv1_q4(codes, levels, scales, base + j, block);
            }
        }
        SimdPath::Array => {
            let vs = F32x8::splat(s);
            let mut i = 0;
            while i < c {
                let w = F32x8(kv_gather8_q4(codes, levels, scales, base + i, block));
                (F32x8::load(&acc[i..]) + vs * w).store(&mut acc[i..]);
                i += LANES;
            }
            for j in c..n {
                acc[j] += s * kv1_q4(codes, levels, scales, base + j, block);
            }
        }
        SimdPath::Avx2 => kv_axpy_q4_avx2(acc, s, codes, levels, scales, base, block),
    }
}

// ---------------------------------------------------------------------
// generic element-wise maps (par_map / par_zip_apply)
// ---------------------------------------------------------------------

/// `dst[i] = f(src[i])`. The vector paths walk 8-lane blocks (giving
/// LLVM a fixed-width unit to vectorize simple `f` over); results are
/// bit-identical across paths because `f` runs once per element either
/// way.
#[inline]
pub fn apply_unary(path: SimdPath, dst: &mut [f32], src: &[f32], f: impl Fn(f32) -> f32) {
    debug_assert_eq!(dst.len(), src.len());
    if path == SimdPath::None {
        for (o, &v) in dst.iter_mut().zip(src) {
            *o = f(v);
        }
        return;
    }
    let n = dst.len();
    let c = n - n % LANES;
    let mut i = 0;
    while i < c {
        let mut v = F32x8::load(&src[i..]);
        for l in 0..LANES {
            v.0[l] = f(v.0[l]);
        }
        v.store(&mut dst[i..]);
        i += LANES;
    }
    for j in c..n {
        dst[j] = f(src[j]);
    }
}

/// `dst[i] = f(dst[i], src[i])`, same blocking as [`apply_unary`].
#[inline]
pub fn apply_zip(path: SimdPath, dst: &mut [f32], src: &[f32], f: impl Fn(f32, f32) -> f32) {
    debug_assert_eq!(dst.len(), src.len());
    if path == SimdPath::None {
        for (o, &v) in dst.iter_mut().zip(src) {
            *o = f(*o, v);
        }
        return;
    }
    let n = dst.len();
    let c = n - n % LANES;
    let mut i = 0;
    while i < c {
        let mut d = F32x8::load(&dst[i..]);
        let s = F32x8::load(&src[i..]);
        for l in 0..LANES {
            d.0[l] = f(d.0[l], s.0[l]);
        }
        d.store(&mut dst[i..]);
        i += LANES;
    }
    for j in c..n {
        dst[j] = f(dst[j], src[j]);
    }
}

// ---------------------------------------------------------------------
// AVX2 arms (x86_64; fall back to the array arm elsewhere). The
// wrappers isolate the `unsafe` + cfg plumbing: SimdPath::Avx2 is only
// constructible after runtime detection (`sanitize` enforces this for
// pool construction), which is what makes the calls sound.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[inline]
fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::dot(a, b) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    dot_array(a, b)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn dot3_avx2(a: &[f32], b: &[f32], c: &[f32]) -> f32 {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::dot3(a, b, c) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn dot3_avx2(a: &[f32], b: &[f32], c: &[f32]) -> f32 {
    dot3_array(a, b, c)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn sumsq_avx2(a: &[f32]) -> f32 {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::sumsq(a) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn sumsq_avx2(a: &[f32]) -> f32 {
    sumsq_array(a)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn axpy_avx2(y: &mut [f32], s: f32, x: &[f32]) {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::axpy(y, s, x) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn axpy_avx2(y: &mut [f32], s: f32, x: &[f32]) {
    axpy(SimdPath::Array, y, s, x)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn norm_apply_avx2(y: &mut [f32], x: &[f32], r: f32, g: &[f32]) {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::norm_apply(y, x, r, g) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn norm_apply_avx2(y: &mut [f32], x: &[f32], r: f32, g: &[f32]) {
    norm_apply(SimdPath::Array, y, x, r, g)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn stage_apply_avx2(sg: &mut [f32], dy: &[f32], x: &[f32], r: f32) {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::stage_apply(sg, dy, x, r) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn stage_apply_avx2(sg: &mut [f32], dy: &[f32], x: &[f32], r: f32) {
    stage_apply(SimdPath::Array, sg, dy, x, r)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn norm_bwd_apply_avx2(dx: &mut [f32], g: &[f32], dy: &[f32], r: f32, x: &[f32], c: f32) {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::norm_bwd_apply(dx, g, dy, r, x, c) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn norm_bwd_apply_avx2(dx: &mut [f32], g: &[f32], dy: &[f32], r: f32, x: &[f32], c: f32) {
    norm_bwd_apply(SimdPath::Array, dx, g, dy, r, x, c)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn q4_axpy_dequant_avx2(y: &mut [f32], xv: f32, am: f32, codes: &[u8], levels: &[f32]) {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::q4_axpy_dequant(y, xv, am, codes, levels) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn q4_axpy_dequant_avx2(y: &mut [f32], xv: f32, am: f32, codes: &[u8], levels: &[f32]) {
    q4_axpy_dequant(SimdPath::Array, y, xv, am, codes, levels)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn q4_axpy_scaled_avx2(y: &mut [f32], s: f32, codes: &[u8], levels: &[f32]) {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::q4_axpy_scaled(y, s, codes, levels) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn q4_axpy_scaled_avx2(y: &mut [f32], s: f32, codes: &[u8], levels: &[f32]) {
    q4_axpy_scaled(SimdPath::Array, y, s, codes, levels)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn q4_fill_dequant_avx2(w: &mut [f32], am: f32, codes: &[u8], levels: &[f32]) {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::q4_fill_dequant(w, am, codes, levels) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn q4_fill_dequant_avx2(w: &mut [f32], am: f32, codes: &[u8], levels: &[f32]) {
    q4_fill_dequant(SimdPath::Array, w, am, codes, levels)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn kv_dot_q8_avx2(q: &[f32], codes: &[u8], scales: &[f32], base: usize, block: usize) -> f32 {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::kv_dot_q8(q, codes, scales, base, block) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn kv_dot_q8_avx2(q: &[f32], codes: &[u8], scales: &[f32], base: usize, block: usize) -> f32 {
    kv_dot_q8(SimdPath::Array, q, codes, scales, base, block)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn kv_axpy_q8_avx2(acc: &mut [f32], s: f32, codes: &[u8], scales: &[f32], base: usize, block: usize) {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::kv_axpy_q8(acc, s, codes, scales, base, block) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn kv_axpy_q8_avx2(acc: &mut [f32], s: f32, codes: &[u8], scales: &[f32], base: usize, block: usize) {
    kv_axpy_q8(SimdPath::Array, acc, s, codes, scales, base, block)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn kv_dot_q4_avx2(
    q: &[f32],
    codes: &[u8],
    levels: &[f32],
    scales: &[f32],
    base: usize,
    block: usize,
) -> f32 {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::kv_dot_q4(q, codes, levels, scales, base, block) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn kv_dot_q4_avx2(
    q: &[f32],
    codes: &[u8],
    levels: &[f32],
    scales: &[f32],
    base: usize,
    block: usize,
) -> f32 {
    kv_dot_q4(SimdPath::Array, q, codes, levels, scales, base, block)
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn kv_axpy_q4_avx2(
    acc: &mut [f32],
    s: f32,
    codes: &[u8],
    levels: &[f32],
    scales: &[f32],
    base: usize,
    block: usize,
) {
    // SAFETY: Avx2 paths are sanitized against runtime detection.
    unsafe { avx2::kv_axpy_q4(acc, s, codes, levels, scales, base, block) }
}
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn kv_axpy_q4_avx2(
    acc: &mut [f32],
    s: f32,
    codes: &[u8],
    levels: &[f32],
    scales: &[f32],
    base: usize,
    block: usize,
) {
    kv_axpy_q4(SimdPath::Array, acc, s, codes, levels, scales, base, block)
}

/// The intrinsic implementations. Every function here uses only
/// separately-rounded `mul`/`add`/`sub`/`div` vector ops (no FMA),
/// the exact canonical chunk/tail/combine schedule of the scalar
/// arms, and unaligned loads/stores — so results are bit-identical to
/// the other two paths.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{gather8, kv1_q4, kv1_q8, kv_gather8_q4, kv_gather8_q8, tail_combine, LANES};
    use std::arch::x86_64::*;

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let c = n - n % LANES;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        // unrolled by two chunks; each step still accumulates chunks in
        // ascending order into the same lane accumulators
        while i + 2 * LANES <= c {
            let p0 = _mm256_mul_ps(
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            acc = _mm256_add_ps(acc, p0);
            let p1 = _mm256_mul_ps(
                _mm256_loadu_ps(a.as_ptr().add(i + LANES)),
                _mm256_loadu_ps(b.as_ptr().add(i + LANES)),
            );
            acc = _mm256_add_ps(acc, p1);
            i += 2 * LANES;
        }
        while i < c {
            let p = _mm256_mul_ps(
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            acc = _mm256_add_ps(acc, p);
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        tail_combine(lanes, c, |j| a[j] * b[j], n)
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot3(a: &[f32], b: &[f32], c: &[f32]) -> f32 {
        let n = a.len();
        let cc = n - n % LANES;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < cc {
            let p = _mm256_mul_ps(
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            let p = _mm256_mul_ps(p, _mm256_loadu_ps(c.as_ptr().add(i)));
            acc = _mm256_add_ps(acc, p);
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        tail_combine(lanes, cc, |j| (a[j] * b[j]) * c[j], n)
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sumsq(a: &[f32]) -> f32 {
        let n = a.len();
        let c = n - n % LANES;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < c {
            let v = _mm256_loadu_ps(a.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(v, v));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        tail_combine(lanes, c, |j| a[j] * a[j], n)
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(y: &mut [f32], s: f32, x: &[f32]) {
        let n = y.len();
        let c = n - n % LANES;
        let vs = _mm256_set1_ps(s);
        let mut i = 0;
        // element-wise: unrolling freely is fine (no cross-lane order)
        while i + 2 * LANES <= c {
            let y0 = _mm256_add_ps(
                _mm256_loadu_ps(y.as_ptr().add(i)),
                _mm256_mul_ps(vs, _mm256_loadu_ps(x.as_ptr().add(i))),
            );
            _mm256_storeu_ps(y.as_mut_ptr().add(i), y0);
            let y1 = _mm256_add_ps(
                _mm256_loadu_ps(y.as_ptr().add(i + LANES)),
                _mm256_mul_ps(vs, _mm256_loadu_ps(x.as_ptr().add(i + LANES))),
            );
            _mm256_storeu_ps(y.as_mut_ptr().add(i + LANES), y1);
            i += 2 * LANES;
        }
        while i < c {
            let yv = _mm256_add_ps(
                _mm256_loadu_ps(y.as_ptr().add(i)),
                _mm256_mul_ps(vs, _mm256_loadu_ps(x.as_ptr().add(i))),
            );
            _mm256_storeu_ps(y.as_mut_ptr().add(i), yv);
            i += LANES;
        }
        for j in c..n {
            y[j] += s * x[j];
        }
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn norm_apply(y: &mut [f32], x: &[f32], r: f32, g: &[f32]) {
        let n = y.len();
        let c = n - n % LANES;
        let vr = _mm256_set1_ps(r);
        let mut i = 0;
        while i < c {
            let v = _mm256_div_ps(_mm256_loadu_ps(x.as_ptr().add(i)), vr);
            let v = _mm256_mul_ps(v, _mm256_loadu_ps(g.as_ptr().add(i)));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), v);
            i += LANES;
        }
        for j in c..n {
            y[j] = x[j] / r * g[j];
        }
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn stage_apply(sg: &mut [f32], dy: &[f32], x: &[f32], r: f32) {
        let n = sg.len();
        let c = n - n % LANES;
        let vr = _mm256_set1_ps(r);
        let mut i = 0;
        while i < c {
            let v = _mm256_mul_ps(
                _mm256_loadu_ps(dy.as_ptr().add(i)),
                _mm256_loadu_ps(x.as_ptr().add(i)),
            );
            _mm256_storeu_ps(sg.as_mut_ptr().add(i), _mm256_div_ps(v, vr));
            i += LANES;
        }
        for j in c..n {
            sg[j] = dy[j] * x[j] / r;
        }
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn norm_bwd_apply(dx: &mut [f32], g: &[f32], dy: &[f32], r: f32, x: &[f32], c: f32) {
        let n = dx.len();
        let cc = n - n % LANES;
        let vr = _mm256_set1_ps(r);
        let vc = _mm256_set1_ps(c);
        let mut i = 0;
        while i < cc {
            let num = _mm256_mul_ps(
                _mm256_loadu_ps(g.as_ptr().add(i)),
                _mm256_loadu_ps(dy.as_ptr().add(i)),
            );
            let lhs = _mm256_div_ps(num, vr);
            let rhs = _mm256_mul_ps(_mm256_loadu_ps(x.as_ptr().add(i)), vc);
            _mm256_storeu_ps(dx.as_mut_ptr().add(i), _mm256_sub_ps(lhs, rhs));
            i += LANES;
        }
        for j in cc..n {
            dx[j] = g[j] * dy[j] / r - x[j] * c;
        }
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn q4_axpy_dequant(y: &mut [f32], xv: f32, am: f32, codes: &[u8], levels: &[f32]) {
        let n = y.len();
        let c = n - n % LANES;
        let vx = _mm256_set1_ps(xv);
        let va = _mm256_set1_ps(am);
        let mut i = 0;
        while i < c {
            let g = gather8(&codes[i..], levels);
            let w = _mm256_mul_ps(_mm256_loadu_ps(g.as_ptr()), va);
            let xw = _mm256_mul_ps(vx, w);
            let yv = _mm256_add_ps(_mm256_loadu_ps(y.as_ptr().add(i)), xw);
            _mm256_storeu_ps(y.as_mut_ptr().add(i), yv);
            i += LANES;
        }
        for j in c..n {
            y[j] += xv * (levels[(codes[j] & 0x0f) as usize] * am);
        }
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn q4_axpy_scaled(y: &mut [f32], s: f32, codes: &[u8], levels: &[f32]) {
        let n = y.len();
        let c = n - n % LANES;
        let vs = _mm256_set1_ps(s);
        let mut i = 0;
        while i < c {
            let g = gather8(&codes[i..], levels);
            let sw = _mm256_mul_ps(vs, _mm256_loadu_ps(g.as_ptr()));
            let yv = _mm256_add_ps(_mm256_loadu_ps(y.as_ptr().add(i)), sw);
            _mm256_storeu_ps(y.as_mut_ptr().add(i), yv);
            i += LANES;
        }
        for j in c..n {
            y[j] += s * levels[(codes[j] & 0x0f) as usize];
        }
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn q4_fill_dequant(w: &mut [f32], am: f32, codes: &[u8], levels: &[f32]) {
        let n = w.len();
        let c = n - n % LANES;
        let va = _mm256_set1_ps(am);
        let mut i = 0;
        while i < c {
            let g = gather8(&codes[i..], levels);
            let w8 = _mm256_mul_ps(_mm256_loadu_ps(g.as_ptr()), va);
            _mm256_storeu_ps(w.as_mut_ptr().add(i), w8);
            i += LANES;
        }
        for j in c..n {
            w[j] = levels[(codes[j] & 0x0f) as usize] * am;
        }
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn kv_dot_q8(
        q: &[f32],
        codes: &[u8],
        scales: &[f32],
        base: usize,
        block: usize,
    ) -> f32 {
        let n = q.len();
        let c = n - n % LANES;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < c {
            let g = kv_gather8_q8(codes, scales, base + i, block);
            let p = _mm256_mul_ps(_mm256_loadu_ps(q.as_ptr().add(i)), _mm256_loadu_ps(g.as_ptr()));
            acc = _mm256_add_ps(acc, p);
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        tail_combine(lanes, c, |j| q[j] * kv1_q8(codes, scales, base + j, block), n)
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn kv_axpy_q8(
        acc: &mut [f32],
        s: f32,
        codes: &[u8],
        scales: &[f32],
        base: usize,
        block: usize,
    ) {
        let n = acc.len();
        let c = n - n % LANES;
        let vs = _mm256_set1_ps(s);
        let mut i = 0;
        while i < c {
            let g = kv_gather8_q8(codes, scales, base + i, block);
            let sw = _mm256_mul_ps(vs, _mm256_loadu_ps(g.as_ptr()));
            let av = _mm256_add_ps(_mm256_loadu_ps(acc.as_ptr().add(i)), sw);
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), av);
            i += LANES;
        }
        for j in c..n {
            acc[j] += s * kv1_q8(codes, scales, base + j, block);
        }
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn kv_dot_q4(
        q: &[f32],
        codes: &[u8],
        levels: &[f32],
        scales: &[f32],
        base: usize,
        block: usize,
    ) -> f32 {
        let n = q.len();
        let c = n - n % LANES;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < c {
            let g = kv_gather8_q4(codes, levels, scales, base + i, block);
            let p = _mm256_mul_ps(_mm256_loadu_ps(q.as_ptr().add(i)), _mm256_loadu_ps(g.as_ptr()));
            acc = _mm256_add_ps(acc, p);
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        tail_combine(
            lanes,
            c,
            |j| q[j] * kv1_q4(codes, levels, scales, base + j, block),
            n,
        )
    }

    /// # Safety
    /// Requires AVX2 (callers dispatch behind `is_x86_feature_detected!`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn kv_axpy_q4(
        acc: &mut [f32],
        s: f32,
        codes: &[u8],
        levels: &[f32],
        scales: &[f32],
        base: usize,
        block: usize,
    ) {
        let n = acc.len();
        let c = n - n % LANES;
        let vs = _mm256_set1_ps(s);
        let mut i = 0;
        while i < c {
            let g = kv_gather8_q4(codes, levels, scales, base + i, block);
            let sw = _mm256_mul_ps(vs, _mm256_loadu_ps(g.as_ptr()));
            let av = _mm256_add_ps(_mm256_loadu_ps(acc.as_ptr().add(i)), sw);
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), av);
            i += LANES;
        }
        for j in c..n {
            acc[j] += s * kv1_q4(codes, levels, scales, base + j, block);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_gaussian_f32(&mut v, 1.0);
        v
    }

    /// Lengths spanning empty, sub-lane, exact-lane, and remainder-lane
    /// shapes (the k/n sweep the kernel-level tests mirror).
    const LENS: [usize; 9] = [0, 1, 7, 8, 9, 16, 31, 64, 67];

    #[test]
    fn path_names_and_sanitize() {
        assert_eq!(SimdPath::None.name(), "none");
        assert_eq!(SimdPath::Array.name(), "array");
        assert_eq!(SimdPath::Avx2.name(), "avx2");
        // sanitize never yields an unexecutable path
        for p in [SimdPath::None, SimdPath::Array, SimdPath::Avx2] {
            let s = p.sanitize();
            assert!(all_paths().contains(&s), "{s:?} not executable");
        }
        assert_eq!(SimdPath::None.sanitize(), SimdPath::None);
        assert_eq!(SimdPath::Array.sanitize(), SimdPath::Array);
        // env-derived path is stable and executable
        assert_eq!(path_from_env(), path_from_env());
        assert!(all_paths().contains(&path_from_env().sanitize()));
    }

    #[test]
    fn reductions_bitwise_equal_across_paths() {
        for &n in &LENS {
            let a = rand(n, 1000 + n as u64);
            let b = rand(n, 2000 + n as u64);
            let c = rand(n, 3000 + n as u64);
            let want_dot = dot(SimdPath::None, &a, &b);
            let want_dot3 = dot3(SimdPath::None, &a, &b, &c);
            let want_sq = sum_squares(SimdPath::None, &a);
            for path in all_paths() {
                assert_eq!(dot(path, &a, &b).to_bits(), want_dot.to_bits(), "dot n={n} {path:?}");
                assert_eq!(
                    dot3(path, &a, &b, &c).to_bits(),
                    want_dot3.to_bits(),
                    "dot3 n={n} {path:?}"
                );
                assert_eq!(
                    sum_squares(path, &a).to_bits(),
                    want_sq.to_bits(),
                    "sumsq n={n} {path:?}"
                );
            }
        }
    }

    #[test]
    fn canonical_dot_order_is_8_lane_strided() {
        // reproduce the documented schedule by hand for a remainder shape
        let n = 19usize;
        let a = rand(n, 42);
        let b = rand(n, 43);
        let c = n - n % LANES;
        let mut acc = [0.0f32; LANES];
        let mut i = 0;
        while i < c {
            for l in 0..LANES {
                acc[l] += a[i + l] * b[i + l];
            }
            i += LANES;
        }
        for j in c..n {
            acc[j - c] += a[j] * b[j];
        }
        let want = combine8(acc);
        for path in all_paths() {
            assert_eq!(dot(path, &a, &b).to_bits(), want.to_bits(), "{path:?}");
        }
        assert_eq!(dot(SimdPath::None, &a, &b).to_bits(), want.to_bits());
    }

    #[test]
    fn elementwise_ops_bitwise_equal_across_paths() {
        for &n in &LENS {
            let x = rand(n, 4000 + n as u64);
            let g = rand(n, 5000 + n as u64);
            let dy = rand(n, 6000 + n as u64);
            let (s, r, c) = (0.37f32, 1.73f32, -0.11f32);

            let mut want_axpy = rand(n, 7000 + n as u64);
            let base = want_axpy.clone();
            axpy(SimdPath::None, &mut want_axpy, s, &x);
            let mut want_norm = vec![0.0f32; n];
            norm_apply(SimdPath::None, &mut want_norm, &x, r, &g);
            let mut want_stage = vec![0.0f32; n];
            stage_apply(SimdPath::None, &mut want_stage, &dy, &x, r);
            let mut want_bwd = vec![0.0f32; n];
            norm_bwd_apply(SimdPath::None, &mut want_bwd, &g, &dy, r, &x, c);

            for path in all_paths() {
                let mut y = base.clone();
                axpy(path, &mut y, s, &x);
                assert_eq!(y, want_axpy, "axpy n={n} {path:?}");
                let mut y = vec![0.0f32; n];
                norm_apply(path, &mut y, &x, r, &g);
                assert_eq!(y, want_norm, "norm_apply n={n} {path:?}");
                let mut y = vec![0.0f32; n];
                stage_apply(path, &mut y, &dy, &x, r);
                assert_eq!(y, want_stage, "stage_apply n={n} {path:?}");
                let mut y = vec![0.0f32; n];
                norm_bwd_apply(path, &mut y, &g, &dy, r, &x, c);
                assert_eq!(y, want_bwd, "norm_bwd_apply n={n} {path:?}");
            }
        }
    }

    #[test]
    fn q4_forms_bitwise_equal_across_paths() {
        let levels: Vec<f32> = (0..16).map(|i| (i as f32 - 7.5) / 7.5).collect();
        for &n in &LENS {
            let codes: Vec<u8> = (0..n).map(|i| ((i * 5 + 3) % 16) as u8).collect();
            let base = rand(n, 8000 + n as u64);
            let (xv, am, s) = (0.83f32, 0.021f32, 0.0174f32);

            let mut want_dq = base.clone();
            q4_axpy_dequant(SimdPath::None, &mut want_dq, xv, am, &codes, &levels);
            let mut want_sc = base.clone();
            q4_axpy_scaled(SimdPath::None, &mut want_sc, s, &codes, &levels);
            let mut want_fill = vec![0.0f32; n];
            q4_fill_dequant(SimdPath::None, &mut want_fill, am, &codes, &levels);

            for path in all_paths() {
                let mut y = base.clone();
                q4_axpy_dequant(path, &mut y, xv, am, &codes, &levels);
                assert_eq!(y, want_dq, "q4_axpy_dequant n={n} {path:?}");
                let mut y = base.clone();
                q4_axpy_scaled(path, &mut y, s, &codes, &levels);
                assert_eq!(y, want_sc, "q4_axpy_scaled n={n} {path:?}");
                let mut y = vec![0.0f32; n];
                q4_fill_dequant(path, &mut y, am, &codes, &levels);
                assert_eq!(y, want_fill, "q4_fill_dequant n={n} {path:?}");
            }
        }
    }

    /// The fused KV dequant forms: bit-identical across paths for q8
    /// and q4, at even and odd head-column offsets (`base`), aligned and
    /// ragged quantization blocks, against a reference evaluated through
    /// the plain canonical dot/axpy over the dequantized f32 segment.
    #[test]
    fn kv_forms_bitwise_equal_across_paths() {
        let levels: Vec<f32> = (0..16).map(|i| (i as f32 - 7.5) / 7.5).collect();
        for &n in &LENS {
            for base in [0usize, 1, 3, 8] {
                let d = base + n;
                for block in [4usize, 8, 13] {
                    let nb = d.div_ceil(block).max(1);
                    let scales: Vec<f32> = (0..nb).map(|b| 0.013 * (b as f32 + 1.0)).collect();
                    let codes8: Vec<u8> = (0..d).map(|i| ((i * 37 + 11) % 251) as u8).collect();
                    let codes4: Vec<u8> =
                        (0..d.div_ceil(2)).map(|i| ((i * 73 + 5) % 256) as u8).collect();
                    let q = rand(n, 11_000 + (n + base * 17 + block) as u64);
                    let acc0 = rand(n, 12_000 + (n + base * 17 + block) as u64);
                    let s = 0.217f32;

                    // reference: dequantize the segment, then the plain
                    // canonical dot/axpy — the fused forms must match it
                    // bit for bit on the None path (same schedule, same
                    // per-element expressions)
                    let w8: Vec<f32> = (base..d)
                        .map(|e| (codes8[e] as i8) as f32 * scales[e / block])
                        .collect();
                    let want_dot8 = dot(SimdPath::None, &q, &w8);
                    assert_eq!(
                        kv_dot_q8(SimdPath::None, &q, &codes8, &scales, base, block).to_bits(),
                        want_dot8.to_bits(),
                        "kv_dot_q8 vs dequant+dot n={n} base={base} block={block}"
                    );
                    let mut want_axpy8 = acc0.clone();
                    axpy(SimdPath::None, &mut want_axpy8, s, &w8);
                    let mut a = acc0.clone();
                    kv_axpy_q8(SimdPath::None, &mut a, s, &codes8, &scales, base, block);
                    assert_eq!(a, want_axpy8, "kv_axpy_q8 vs dequant+axpy");

                    let want_dot4 = kv_dot_q4(SimdPath::None, &q, &codes4, &levels, &scales, base, block);
                    let mut want_axpy4 = acc0.clone();
                    kv_axpy_q4(
                        SimdPath::None,
                        &mut want_axpy4,
                        s,
                        &codes4,
                        &levels,
                        &scales,
                        base,
                        block,
                    );
                    for path in all_paths() {
                        assert_eq!(
                            kv_dot_q8(path, &q, &codes8, &scales, base, block).to_bits(),
                            want_dot8.to_bits(),
                            "kv_dot_q8 n={n} base={base} block={block} {path:?}"
                        );
                        let mut y = acc0.clone();
                        kv_axpy_q8(path, &mut y, s, &codes8, &scales, base, block);
                        assert_eq!(y, want_axpy8, "kv_axpy_q8 n={n} base={base} {path:?}");
                        assert_eq!(
                            kv_dot_q4(path, &q, &codes4, &levels, &scales, base, block).to_bits(),
                            want_dot4.to_bits(),
                            "kv_dot_q4 n={n} base={base} block={block} {path:?}"
                        );
                        let mut y = acc0.clone();
                        kv_axpy_q4(path, &mut y, s, &codes4, &levels, &scales, base, block);
                        assert_eq!(y, want_axpy4, "kv_axpy_q4 n={n} base={base} {path:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn generic_maps_bitwise_equal_across_paths() {
        for &n in &LENS {
            let src = rand(n, 9000 + n as u64);
            let base = rand(n, 9500 + n as u64);
            let f = |v: f32| v * 1.5 + 0.25;
            let z = |a: f32, b: f32| a * 0.9 + b;
            let mut want_u = vec![0.0f32; n];
            apply_unary(SimdPath::None, &mut want_u, &src, f);
            let mut want_z = base.clone();
            apply_zip(SimdPath::None, &mut want_z, &src, z);
            for path in all_paths() {
                let mut d = vec![0.0f32; n];
                apply_unary(path, &mut d, &src, f);
                assert_eq!(d, want_u, "apply_unary n={n} {path:?}");
                let mut d = base.clone();
                apply_zip(path, &mut d, &src, z);
                assert_eq!(d, want_z, "apply_zip n={n} {path:?}");
            }
        }
    }

    #[test]
    fn f32x8_ops_are_lane_wise() {
        let a = F32x8([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let b = F32x8::splat(2.0);
        assert_eq!((a + b).0[3], 6.0);
        assert_eq!((a - b).0[0], -1.0);
        assert_eq!((a * b).0[7], 16.0);
        assert_eq!((a / b).0[1], 1.0);
        // lint: allow(fma-in-kernels): exercising the separately-rounded op
        assert_eq!(a.mul_add(b, F32x8::splat(1.0)).0[2], 7.0);
        let mut out = [0.0f32; 8];
        F32x8::load(&a.0).store(&mut out);
        assert_eq!(out, a.0);
        assert_eq!(F32x8::ZERO.0, [0.0; 8]);
        assert_eq!(combine8([1.0; 8]), 8.0);
    }
}
