//! Crate-local scoped thread pool for the tiled CPU kernels.
//!
//! Std-only (zero new deps): persistent worker threads block on a shared
//! condvar-guarded queue; [`ThreadPool::run`] fans a task range out over
//! at most `threads` contiguous chunks and blocks until every chunk has
//! finished, so task closures may freely borrow the caller's stack.
//!
//! Sizing: [`ThreadPool::new`] honours `BOF4_THREADS` (a positive
//! integer), else the detected core count. A pool of 1 thread never
//! spawns workers and executes everything inline on the caller — the
//! kernels are written so results are **bit-identical at every thread
//! count** (each output tile has exactly one owner, and every reduction
//! runs in the canonical 8-lane-strided order of [`super::simd`]).
//!
//! The pool also carries the kernel-execution policy for the inner
//! loops: the active [`SimdPath`] (`BOF4_SIMD`, else the best detected
//! path). Kernels read it via [`ThreadPool::simd`], so a pool pins both
//! knobs of the bit-exactness contract — results are identical at every
//! `(threads, simd)` combination.
//!
//! Nested calls: a task that calls [`ThreadPool::run`] again (e.g. a
//! tiled matmul inside a per-row decode task) runs the inner range inline
//! — workers never block on other workers, so the pool cannot deadlock.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::obs::tracer::{self, TraceLevel};

use super::simd::{self, SimdPath};

/// Upper bound on pool width (defensive cap for `BOF4_THREADS`).
pub const MAX_THREADS: usize = 64;

type Job = Box<dyn FnOnce() + Send + 'static>;

std::thread_local! {
    static IS_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
    static CURRENT_PHASE: std::cell::Cell<KernelPhase> =
        const { std::cell::Cell::new(KernelPhase::Other) };
}

/// Which kernel family a pool dispatch belongs to. Kernel entry points
/// set the calling thread's phase with [`phase_scope`]; the pool
/// attributes each **top-level** dispatch's wall time and call count to
/// the phase active on the launching thread (nested launches run inline
/// inside their parent's dispatch and are already covered by it). This
/// generalizes the `pool_busy` lane gauge into a per-kernel profile —
/// where the step's time went, not just how wide it fanned out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPhase {
    /// Launches outside any tagged kernel (embedding gather, eval loops).
    Other,
    /// Dense f32 matmuls (`tiling::matmul` / `_nt` / `_tn`).
    Dense,
    /// RMSNorm forward/backward.
    Norm,
    /// Elementwise maps/zips (`par_map`, `par_zip_apply`).
    Map,
    /// Full-context attention forward/backward.
    Attention,
    /// Fused 4-bit dequant matmuls (incl. OPQ outlier patching).
    Q4,
    /// The batched f32-KV incremental decode step.
    Decode,
    /// The batched quantized-KV decode step (fused q8/q4 cache
    /// dequantization inside the decode attention).
    Kv,
    /// Block-wise weight quantization (`quantize_blocks`).
    Quantize,
}

/// Number of [`KernelPhase`] variants (profile array width).
pub const N_KERNEL_PHASES: usize = 9;

const ALL_PHASES: [KernelPhase; N_KERNEL_PHASES] = [
    KernelPhase::Other,
    KernelPhase::Dense,
    KernelPhase::Norm,
    KernelPhase::Map,
    KernelPhase::Attention,
    KernelPhase::Q4,
    KernelPhase::Decode,
    KernelPhase::Kv,
    KernelPhase::Quantize,
];

impl KernelPhase {
    /// Stable label used in the kernel profile, the Prometheus
    /// `kernel="…"` series label and the kernel-level trace spans.
    pub fn name(self) -> &'static str {
        match self {
            KernelPhase::Other => "other",
            KernelPhase::Dense => "dense",
            KernelPhase::Norm => "norm",
            KernelPhase::Map => "map",
            KernelPhase::Attention => "attention",
            KernelPhase::Q4 => "q4",
            KernelPhase::Decode => "decode",
            KernelPhase::Kv => "kv",
            KernelPhase::Quantize => "quantize",
        }
    }

    fn index(self) -> usize {
        ALL_PHASES.iter().position(|&p| p == self).unwrap_or(0)
    }
}

/// The kernel phase active on the calling thread.
pub fn current_phase() -> KernelPhase {
    CURRENT_PHASE.with(|c| c.get())
}

/// RAII guard restoring the previous kernel phase on drop (see
/// [`phase_scope`]).
pub struct PhaseGuard {
    prev: KernelPhase,
}

impl Drop for PhaseGuard {
    fn drop(&mut self) {
        CURRENT_PHASE.with(|c| c.set(self.prev));
    }
}

/// Tag the calling thread with a kernel phase for the guard's lifetime.
/// Placed at kernel *entry points* — never inside a reduction loop — so
/// the cost is two `Cell` writes per kernel call and determinism is
/// untouched.
pub fn phase_scope(p: KernelPhase) -> PhaseGuard {
    PhaseGuard {
        prev: CURRENT_PHASE.with(|c| c.replace(p)),
    }
}

/// Aggregated execution stats of one kernel phase on a pool: top-level
/// dispatch count and summed wall time (process-lifetime totals — diff
/// two snapshots for a windowed rate).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelStat {
    /// Phase label ([`KernelPhase::name`]).
    pub kernel: &'static str,
    /// Top-level pool dispatches attributed to this phase.
    pub calls: u64,
    /// Summed wall time of those dispatches, in nanoseconds.
    pub nanos: u64,
}

impl KernelStat {
    /// Summed wall time in seconds.
    pub fn seconds(&self) -> f64 {
        self.nanos as f64 / 1e9
    }
}

/// Thread count from `BOF4_THREADS`, else the detected core count.
pub fn threads_from_env() -> usize {
    match std::env::var("BOF4_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
    {
        Some(n) if n >= 1 => n.min(MAX_THREADS),
        _ => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_THREADS),
    }
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
}

/// A fixed-width pool of persistent worker threads plus the calling
/// thread (a pool of width `t` spawns `t - 1` workers; the caller always
/// executes the first chunk itself).
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    /// Active SIMD path for the kernels running on this pool.
    simd: SimdPath,
    /// Fan-out statistics for the `pool_busy` gauge: lanes used and call
    /// count over all top-level [`ThreadPool::run`] invocations.
    lanes_used: AtomicU64,
    calls: AtomicU64,
    /// Per-phase top-level dispatch counts and wall time (the
    /// [`ThreadPool::kernel_profile`] accumulators; always on — two
    /// timestamps and two relaxed adds per dispatch).
    phase_calls: [AtomicU64; N_KERNEL_PHASES],
    phase_nanos: [AtomicU64; N_KERNEL_PHASES],
}

impl ThreadPool {
    /// Pool sized by `BOF4_THREADS` / detected core count, SIMD path from
    /// `BOF4_SIMD` / runtime detection.
    pub fn new() -> ThreadPool {
        Self::with_config(threads_from_env(), simd::path_from_env())
    }

    /// Pool of an explicit width, SIMD path still from the environment
    /// (tests and thread-count comparisons).
    pub fn with_threads(threads: usize) -> ThreadPool {
        Self::with_config(threads, simd::path_from_env())
    }

    /// Pool with both knobs explicit — what the path-equality tests and
    /// the scalar-vs-SIMD benches use. The path is sanitized, so forcing
    /// `avx2` on a host without it degrades to the array path instead of
    /// faulting.
    pub fn with_config(threads: usize, simd: SimdPath) -> ThreadPool {
        let threads = threads.clamp(1, MAX_THREADS);
        let simd = simd.sanitize();
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let mut handles = Vec::new();
        for i in 1..threads {
            let sh = shared.clone();
            let h = std::thread::Builder::new()
                .name(format!("bof4-kernel-{i}"))
                .spawn(move || {
                    IS_POOL_WORKER.with(|f| f.set(true));
                    loop {
                        let job = {
                            // recover from a poisoned queue mutex: jobs are
                            // plain FnOnce boxes, so the queue is never left
                            // half-mutated by a panicking holder, and
                            // propagating the poison here would double-panic
                            // the pool on top of the task panic the caller
                            // is already surfacing
                            let mut q = sh.queue.lock().unwrap_or_else(PoisonError::into_inner);
                            loop {
                                if let Some(j) = q.pop_front() {
                                    break Some(j);
                                }
                                if sh.shutdown.load(Ordering::Acquire) {
                                    break None;
                                }
                                q = sh.available.wait(q).unwrap_or_else(PoisonError::into_inner);
                            }
                        };
                        match job {
                            Some(j) => j(),
                            None => return,
                        }
                    }
                })
                .expect("spawn kernel pool worker");
            handles.push(h);
        }
        ThreadPool {
            shared,
            handles,
            threads,
            simd,
            lanes_used: AtomicU64::new(0),
            calls: AtomicU64::new(0),
            phase_calls: std::array::from_fn(|_| AtomicU64::new(0)),
            phase_nanos: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Pool width (the caller lane plus the spawned workers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Active SIMD path the kernels on this pool dispatch through.
    pub fn simd(&self) -> SimdPath {
        self.simd
    }

    /// Mean fraction of pool lanes used per top-level kernel launch
    /// **since the previous sample** (read-and-reset) — the `pool_busy`
    /// gauge the serving engine records after each prefill/decode step,
    /// so the series tracks current saturation rather than a
    /// process-lifetime average. Returns 0.0 when no launches happened in
    /// the window.
    pub fn occupancy(&self) -> f64 {
        let calls = self.calls.swap(0, Ordering::Relaxed);
        let lanes = self.lanes_used.swap(0, Ordering::Relaxed);
        if calls == 0 {
            return 0.0;
        }
        lanes as f64 / (calls * self.threads as u64) as f64
    }

    /// Execute `f(i)` for every `i in 0..tasks`, fanned out over at most
    /// `threads` contiguous chunks (chunk `c` owns
    /// `[c*tasks/chunks, (c+1)*tasks/chunks)` — deterministic ownership).
    /// Blocks until every chunk has completed; a panic in any chunk
    /// resurfaces on the caller after all chunks have finished. Nested
    /// calls from pool workers run inline (serially).
    pub fn run<F: Fn(usize) + Sync>(&self, tasks: usize, f: F) {
        self.run_dyn(tasks, &f)
    }

    fn run_dyn(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        let chunks = self.threads.min(tasks);
        let nested = IS_POOL_WORKER.with(|w| w.get());
        if nested {
            // nested fan-out runs inline inside its parent's dispatch:
            // no stats (the parent's top-level dispatch covers it)
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        // Top-level dispatch: attribute wall time + call count to the
        // launching thread's kernel phase, and (at BOF4_TRACE=kernel)
        // emit one span per dispatch. Both wrap the dispatch from the
        // outside — nothing here runs inside a task or reduction, so
        // results stay bit-identical with profiling always on and
        // tracing at any level.
        let phase = current_phase();
        let t0 = Instant::now();
        let _span = tracer::span(
            TraceLevel::Kernel,
            phase.name(),
            &[("tasks", tasks as i64), ("chunks", chunks as i64)],
        );
        if chunks <= 1 {
            // top-level serial launch: one lane used
            self.calls.fetch_add(1, Ordering::Relaxed);
            self.lanes_used.fetch_add(1, Ordering::Relaxed);
            for i in 0..tasks {
                f(i);
            }
            self.record_phase(phase, t0);
            return;
        }
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.lanes_used.fetch_add(chunks as u64, Ordering::Relaxed);

        // SAFETY: the jobs queued below only touch `f` before signalling
        // `done_tx`, and this frame blocks on `done_rx` for every queued
        // job (even if its own chunk panics) before returning — so the
        // lifetime-erased borrow never outlives `f`.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        let (done_tx, done_rx) = mpsc::channel::<Result<(), String>>();
        {
            // as in the worker loop: recover the guard from a poisoned
            // mutex instead of double-panicking while a task panic is
            // already in flight
            let mut q = self.shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
            for c in 1..chunks {
                let (lo, hi) = (c * tasks / chunks, (c + 1) * tasks / chunks);
                let tx = done_tx.clone();
                q.push_back(Box::new(move || {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        for i in lo..hi {
                            f_static(i);
                        }
                    }));
                    let _ = tx.send(r.map_err(|e| panic_message(e.as_ref())));
                }));
            }
        }
        self.shared.available.notify_all();

        // The caller owns chunk 0. Mark this lane as a pool task for the
        // duration, so nested kernel launches from chunk 0 run inline
        // (the same rule the workers follow) instead of queueing behind
        // the chunks just dispatched — a nested fan-out here would block
        // on jobs sitting behind busy workers and serialize the caller.
        let prev = IS_POOL_WORKER.with(|w| w.replace(true));
        let own = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for i in 0..tasks / chunks {
                f(i);
            }
        }));
        IS_POOL_WORKER.with(|w| w.set(prev));
        let mut first_err: Option<String> = None;
        for _ in 1..chunks {
            match done_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(m)) => {
                    if first_err.is_none() {
                        first_err = Some(m);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some("kernel pool worker died".into());
                    }
                }
            }
        }
        self.record_phase(phase, t0);
        if let Err(e) = own {
            std::panic::resume_unwind(e);
        }
        if let Some(m) = first_err {
            panic!("kernel pool task panicked: {m}");
        }
    }

    fn record_phase(&self, phase: KernelPhase, t0: Instant) {
        let idx = phase.index();
        self.phase_calls[idx].fetch_add(1, Ordering::Relaxed);
        self.phase_nanos[idx].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Per-kernel-phase execution profile: top-level dispatch counts and
    /// summed wall time since the pool was built (cumulative — diff two
    /// reads for a window). Phases with no dispatches are omitted.
    pub fn kernel_profile(&self) -> Vec<KernelStat> {
        ALL_PHASES
            .iter()
            .filter_map(|&p| {
                let idx = p.index();
                let calls = self.phase_calls[idx].load(Ordering::Relaxed);
                (calls > 0).then(|| KernelStat {
                    kernel: p.name(),
                    calls,
                    nanos: self.phase_nanos[idx].load(Ordering::Relaxed),
                })
            })
            .collect()
    }
}

impl Default for ThreadPool {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ThreadPool(threads={}, simd={})",
            self.threads,
            self.simd.name()
        )
    }
}

fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Process-wide default pool (lazily sized from `BOF4_THREADS` at first
/// use). [`super::super::cpu::CpuBackend::new`] shares this pool across
/// all backend instances; explicit pools exist for tests and benches.
pub fn default_pool() -> Arc<ThreadPool> {
    static POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
    POOL.get_or_init(|| Arc::new(ThreadPool::new())).clone()
}

/// Shared mutable slice for disjoint-tile writes from pool tasks.
///
/// The kernels assign every output tile to exactly one task (deterministic
/// ownership), which is what makes handing out `&mut` sub-slices from a
/// shared borrow sound. The `unsafe` is concentrated in
/// [`SyncSlice::slice_mut`]; each call site states its disjointness
/// argument.
pub struct SyncSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    /// Debug-build ledger of ranges claimed through
    /// [`SyncSlice::slice_mut`], keyed by claiming thread
    /// (`start -> end`). [`SyncSlice::assert_disjoint`] checks new
    /// claims against every *other* thread's entries.
    #[cfg(debug_assertions)]
    claims: Mutex<std::collections::HashMap<std::thread::ThreadId, ClaimMap>>,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

#[cfg(debug_assertions)]
type ClaimMap = std::collections::BTreeMap<usize, usize>;

// SAFETY: SyncSlice is a borrow of a `&mut [T]` exclusive for its whole
// lifetime; sending it to a pool worker moves only the pointer/len pair,
// and `T: Send` makes the elements themselves movable across threads.
unsafe impl<T: Send> Send for SyncSlice<'_, T> {}
// SAFETY: sharing `&SyncSlice` across tasks is sound because the only
// mutation path is `slice_mut`, whose contract (one owner per disjoint
// tile, checked in debug builds) prevents overlapping `&mut` views.
unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    pub fn new(s: &'a mut [T]) -> SyncSlice<'a, T> {
        SyncSlice {
            ptr: s.as_mut_ptr(),
            len: s.len(),
            #[cfg(debug_assertions)]
            claims: Mutex::new(std::collections::HashMap::new()),
            _marker: std::marker::PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `[start, start + len)`.
    ///
    /// # Safety
    /// The caller must guarantee that no two live views overlap — i.e.
    /// concurrent tasks request disjoint ranges (one owner per tile).
    /// Debug builds enforce the cross-thread half of this contract: a
    /// claim that intersects a range previously claimed by a different
    /// thread panics with a `SyncSlice overlap` message.
    #[allow(clippy::mut_from_ref)] // disjointness is the call-site contract
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        #[cfg(debug_assertions)]
        self.assert_disjoint(start, len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }

    /// Panic if `[start, start + len)` intersects a range claimed by a
    /// *different* thread. Same-thread re-claims are allowed — kernels
    /// legitimately re-derive the same stripe across outer-loop
    /// iterations (e.g. the attention backward pass touches each
    /// dK/dV stripe once per query row) — and refresh the ledger entry.
    /// Release builds compile the ledger away entirely.
    #[cfg(debug_assertions)]
    fn assert_disjoint(&self, start: usize, len: usize) {
        let me = std::thread::current().id();
        let end = start + len;
        let mut g = crate::util::sync::lock_recover(&self.claims);
        for (tid, owned) in g.iter() {
            if *tid == me {
                continue;
            }
            // Per-thread claims are disjoint tiles, so the one with the
            // largest start below `end` is the only intersection
            // candidate from this thread.
            if let Some((&s, &e)) = owned.range(..end).next_back() {
                assert!(
                    e <= start,
                    "SyncSlice overlap: [{start}, {end}) claimed on {me:?} \
                     intersects [{s}, {e}) claimed on {tid:?}"
                );
            }
        }
        g.entry(me).or_default().insert(start, end);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn run_covers_every_index_exactly_once() {
        let pool = ThreadPool::with_threads(4);
        for tasks in [0usize, 1, 3, 4, 17, 100] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(tasks, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "tasks={tasks} index {i}");
            }
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::with_threads(1);
        let counter = AtomicUsize::new(0);
        pool.run(9, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 9);
        assert_eq!(pool.threads(), 1);
        let mut buf = vec![0u8; 4];
        let s = SyncSlice::new(&mut buf);
        assert_eq!(s.len(), 4);
        assert!(!s.is_empty());
    }

    #[test]
    fn nested_run_from_worker_completes() {
        let pool = ThreadPool::with_threads(3);
        let counter = AtomicUsize::new(0);
        pool.run(6, |_| {
            // nested fan-out must run inline without deadlocking
            pool.run(5, |_| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(counter.load(Ordering::Relaxed), 30);
    }

    #[test]
    fn panic_in_task_propagates_after_all_chunks() {
        let pool = ThreadPool::with_threads(4);
        let done = AtomicUsize::new(0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 5 {
                    panic!("boom at {i}");
                }
                done.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(r.is_err());
        // the pool stays usable afterwards
        let counter = AtomicUsize::new(0);
        pool.run(4, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn sync_slice_disjoint_tiles() {
        let pool = ThreadPool::with_threads(4);
        let n = 64usize;
        let mut out = vec![0u32; n];
        {
            let s = SyncSlice::new(&mut out);
            pool.run(n, |i| {
                // SAFETY: tile i is written only by task i.
                let t = unsafe { s.slice_mut(i, 1) };
                t[0] = i as u32 * 3;
            });
        }
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u32 * 3);
        }
    }

    /// The debug-build claim ledger must catch cross-thread overlap:
    /// with 2 threads and 2 tasks the caller always runs chunk 0 and
    /// the worker chunk 1, so the two identical claims are guaranteed
    /// to come from different threads whichever lands second.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "SyncSlice overlap")]
    fn sync_slice_overlap_panics_in_debug() {
        let pool = ThreadPool::with_threads(2);
        let mut out = vec![0u32; 8];
        let s = SyncSlice::new(&mut out);
        pool.run(2, |_| {
            // SAFETY: deliberately violated — both tasks claim the same
            // range, and the ledger panics before the second `&mut`
            // view ever materializes.
            let t = unsafe { s.slice_mut(0, 4) };
            t[0] = 1;
        });
    }

    /// Poison the queue mutex directly (a panic while the guard is
    /// held), then verify workers and `run` recover the guard via
    /// `PoisonError::into_inner` instead of double-panicking — the only
    /// panic a caller ever sees stays the propagated task panic.
    #[test]
    fn pool_recovers_from_poisoned_queue_mutex() {
        let pool = ThreadPool::with_threads(4);
        let sh = pool.shared.clone();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = sh.queue.lock().unwrap_or_else(PoisonError::into_inner);
            panic!("poison the queue mutex");
        }));
        assert!(r.is_err());
        assert!(sh.queue.is_poisoned(), "mutex should be poisoned");
        // dispatch through the poisoned mutex still works end to end
        let counter = AtomicUsize::new(0);
        pool.run(16, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 16);
        // and a task panic still surfaces exactly once
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 3 {
                    panic!("task panic");
                }
            });
        }));
        assert!(r.is_err());
    }

    #[test]
    fn with_config_pins_simd_path() {
        for path in simd::all_paths() {
            let pool = ThreadPool::with_config(2, path);
            assert_eq!(pool.simd(), path);
            assert_eq!(pool.threads(), 2);
        }
        // forcing avx2 off-host degrades to an executable path
        let pool = ThreadPool::with_config(1, SimdPath::Avx2);
        assert!(simd::all_paths().contains(&pool.simd()));
        let dbg = format!("{pool:?}");
        assert!(dbg.contains("simd="), "{dbg}");
    }

    #[test]
    fn occupancy_is_a_fraction() {
        let pool = ThreadPool::with_threads(4);
        assert_eq!(pool.occupancy(), 0.0);
        pool.run(16, |_| {});
        let f = pool.occupancy();
        assert!(f > 0.0 && f <= 1.0, "occupancy {f}");
    }

    #[test]
    fn phase_scope_nests_and_restores() {
        assert_eq!(current_phase(), KernelPhase::Other);
        {
            let _d = phase_scope(KernelPhase::Dense);
            assert_eq!(current_phase(), KernelPhase::Dense);
            {
                let _q = phase_scope(KernelPhase::Q4);
                assert_eq!(current_phase(), KernelPhase::Q4);
            }
            assert_eq!(current_phase(), KernelPhase::Dense);
        }
        assert_eq!(current_phase(), KernelPhase::Other);
    }

    #[test]
    fn kernel_profile_attributes_dispatches() {
        let pool = ThreadPool::with_threads(2);
        assert!(pool.kernel_profile().is_empty());
        {
            let _p = phase_scope(KernelPhase::Dense);
            pool.run(8, |_| {});
            pool.run(8, |_| {});
        }
        {
            let _p = phase_scope(KernelPhase::Attention);
            pool.run(4, |_| {
                // nested launches run inside the parent dispatch and must
                // not be double-counted
                pool.run(2, |_| {});
            });
        }
        let prof = pool.kernel_profile();
        let get = |k: &str| prof.iter().find(|s| s.kernel == k).copied();
        let dense = get("dense").expect("dense profiled");
        assert_eq!(dense.calls, 2);
        assert!(dense.seconds() >= 0.0);
        assert_eq!(get("attention").expect("attention profiled").calls, 1);
        assert!(get("q4").is_none(), "untouched phases are omitted");
    }

    #[test]
    fn env_sizing_clamps() {
        // cannot mutate the env safely in-process; just sanity-check the
        // default derivation stays in range
        let t = threads_from_env();
        assert!((1..=MAX_THREADS).contains(&t));
    }
}
