//! Crate-local scoped thread pool for the tiled CPU kernels.
//!
//! Std-only (zero new deps): persistent worker threads block on a shared
//! condvar-guarded queue; [`ThreadPool::run`] fans a task range out over
//! at most `threads` contiguous chunks and blocks until every chunk has
//! finished, so task closures may freely borrow the caller's stack.
//!
//! Sizing: [`ThreadPool::new`] honours `BOF4_THREADS` (a positive
//! integer), else the detected core count. A pool of 1 thread never
//! spawns workers and executes everything inline on the caller — the
//! kernels are written so results are **bit-identical at every thread
//! count** (each output tile has exactly one owner, and every reduction
//! runs in the canonical 8-lane-strided order of [`super::simd`]).
//!
//! The pool also carries the kernel-execution policy for the inner
//! loops: the active [`SimdPath`] (`BOF4_SIMD`, else the best detected
//! path). Kernels read it via [`ThreadPool::simd`], so a pool pins both
//! knobs of the bit-exactness contract — results are identical at every
//! `(threads, simd)` combination.
//!
//! Nested calls: a task that calls [`ThreadPool::run`] again (e.g. a
//! tiled matmul inside a per-row decode task) runs the inner range inline
//! — workers never block on other workers, so the pool cannot deadlock.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock, PoisonError};
use std::thread::JoinHandle;

use super::simd::{self, SimdPath};

/// Upper bound on pool width (defensive cap for `BOF4_THREADS`).
pub const MAX_THREADS: usize = 64;

type Job = Box<dyn FnOnce() + Send + 'static>;

std::thread_local! {
    static IS_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Thread count from `BOF4_THREADS`, else the detected core count.
pub fn threads_from_env() -> usize {
    match std::env::var("BOF4_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
    {
        Some(n) if n >= 1 => n.min(MAX_THREADS),
        _ => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_THREADS),
    }
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
}

/// A fixed-width pool of persistent worker threads plus the calling
/// thread (a pool of width `t` spawns `t - 1` workers; the caller always
/// executes the first chunk itself).
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    /// Active SIMD path for the kernels running on this pool.
    simd: SimdPath,
    /// Fan-out statistics for the `pool_busy` gauge: lanes used and call
    /// count over all top-level [`ThreadPool::run`] invocations.
    lanes_used: AtomicU64,
    calls: AtomicU64,
}

impl ThreadPool {
    /// Pool sized by `BOF4_THREADS` / detected core count, SIMD path from
    /// `BOF4_SIMD` / runtime detection.
    pub fn new() -> ThreadPool {
        Self::with_config(threads_from_env(), simd::path_from_env())
    }

    /// Pool of an explicit width, SIMD path still from the environment
    /// (tests and thread-count comparisons).
    pub fn with_threads(threads: usize) -> ThreadPool {
        Self::with_config(threads, simd::path_from_env())
    }

    /// Pool with both knobs explicit — what the path-equality tests and
    /// the scalar-vs-SIMD benches use. The path is sanitized, so forcing
    /// `avx2` on a host without it degrades to the array path instead of
    /// faulting.
    pub fn with_config(threads: usize, simd: SimdPath) -> ThreadPool {
        let threads = threads.clamp(1, MAX_THREADS);
        let simd = simd.sanitize();
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let mut handles = Vec::new();
        for i in 1..threads {
            let sh = shared.clone();
            let h = std::thread::Builder::new()
                .name(format!("bof4-kernel-{i}"))
                .spawn(move || {
                    IS_POOL_WORKER.with(|f| f.set(true));
                    loop {
                        let job = {
                            // recover from a poisoned queue mutex: jobs are
                            // plain FnOnce boxes, so the queue is never left
                            // half-mutated by a panicking holder, and
                            // propagating the poison here would double-panic
                            // the pool on top of the task panic the caller
                            // is already surfacing
                            let mut q = sh.queue.lock().unwrap_or_else(PoisonError::into_inner);
                            loop {
                                if let Some(j) = q.pop_front() {
                                    break Some(j);
                                }
                                if sh.shutdown.load(Ordering::Acquire) {
                                    break None;
                                }
                                q = sh.available.wait(q).unwrap_or_else(PoisonError::into_inner);
                            }
                        };
                        match job {
                            Some(j) => j(),
                            None => return,
                        }
                    }
                })
                .expect("spawn kernel pool worker");
            handles.push(h);
        }
        ThreadPool {
            shared,
            handles,
            threads,
            simd,
            lanes_used: AtomicU64::new(0),
            calls: AtomicU64::new(0),
        }
    }

    /// Pool width (the caller lane plus the spawned workers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Active SIMD path the kernels on this pool dispatch through.
    pub fn simd(&self) -> SimdPath {
        self.simd
    }

    /// Mean fraction of pool lanes used per top-level kernel launch
    /// **since the previous sample** (read-and-reset) — the `pool_busy`
    /// gauge the serving engine records after each prefill/decode step,
    /// so the series tracks current saturation rather than a
    /// process-lifetime average. Returns 0.0 when no launches happened in
    /// the window.
    pub fn occupancy(&self) -> f64 {
        let calls = self.calls.swap(0, Ordering::Relaxed);
        let lanes = self.lanes_used.swap(0, Ordering::Relaxed);
        if calls == 0 {
            return 0.0;
        }
        lanes as f64 / (calls * self.threads as u64) as f64
    }

    /// Execute `f(i)` for every `i in 0..tasks`, fanned out over at most
    /// `threads` contiguous chunks (chunk `c` owns
    /// `[c*tasks/chunks, (c+1)*tasks/chunks)` — deterministic ownership).
    /// Blocks until every chunk has completed; a panic in any chunk
    /// resurfaces on the caller after all chunks have finished. Nested
    /// calls from pool workers run inline (serially).
    pub fn run<F: Fn(usize) + Sync>(&self, tasks: usize, f: F) {
        self.run_dyn(tasks, &f)
    }

    fn run_dyn(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        let chunks = self.threads.min(tasks);
        let nested = IS_POOL_WORKER.with(|w| w.get());
        if chunks <= 1 || nested {
            if !nested {
                // top-level serial launch: one lane used
                self.calls.fetch_add(1, Ordering::Relaxed);
                self.lanes_used.fetch_add(1, Ordering::Relaxed);
            }
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.lanes_used.fetch_add(chunks as u64, Ordering::Relaxed);

        // SAFETY: the jobs queued below only touch `f` before signalling
        // `done_tx`, and this frame blocks on `done_rx` for every queued
        // job (even if its own chunk panics) before returning — so the
        // lifetime-erased borrow never outlives `f`.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        let (done_tx, done_rx) = mpsc::channel::<Result<(), String>>();
        {
            // as in the worker loop: recover the guard from a poisoned
            // mutex instead of double-panicking while a task panic is
            // already in flight
            let mut q = self.shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
            for c in 1..chunks {
                let (lo, hi) = (c * tasks / chunks, (c + 1) * tasks / chunks);
                let tx = done_tx.clone();
                q.push_back(Box::new(move || {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        for i in lo..hi {
                            f_static(i);
                        }
                    }));
                    let _ = tx.send(r.map_err(|e| panic_message(e.as_ref())));
                }));
            }
        }
        self.shared.available.notify_all();

        // The caller owns chunk 0. Mark this lane as a pool task for the
        // duration, so nested kernel launches from chunk 0 run inline
        // (the same rule the workers follow) instead of queueing behind
        // the chunks just dispatched — a nested fan-out here would block
        // on jobs sitting behind busy workers and serialize the caller.
        let prev = IS_POOL_WORKER.with(|w| w.replace(true));
        let own = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for i in 0..tasks / chunks {
                f(i);
            }
        }));
        IS_POOL_WORKER.with(|w| w.set(prev));
        let mut first_err: Option<String> = None;
        for _ in 1..chunks {
            match done_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(m)) => {
                    if first_err.is_none() {
                        first_err = Some(m);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some("kernel pool worker died".into());
                    }
                }
            }
        }
        if let Err(e) = own {
            std::panic::resume_unwind(e);
        }
        if let Some(m) = first_err {
            panic!("kernel pool task panicked: {m}");
        }
    }
}

impl Default for ThreadPool {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ThreadPool(threads={}, simd={})",
            self.threads,
            self.simd.name()
        )
    }
}

fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Process-wide default pool (lazily sized from `BOF4_THREADS` at first
/// use). [`super::super::cpu::CpuBackend::new`] shares this pool across
/// all backend instances; explicit pools exist for tests and benches.
pub fn default_pool() -> Arc<ThreadPool> {
    static POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
    POOL.get_or_init(|| Arc::new(ThreadPool::new())).clone()
}

/// Shared mutable slice for disjoint-tile writes from pool tasks.
///
/// The kernels assign every output tile to exactly one task (deterministic
/// ownership), which is what makes handing out `&mut` sub-slices from a
/// shared borrow sound. The `unsafe` is concentrated in
/// [`SyncSlice::slice_mut`]; each call site states its disjointness
/// argument.
pub struct SyncSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SyncSlice<'_, T> {}
unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    pub fn new(s: &'a mut [T]) -> SyncSlice<'a, T> {
        SyncSlice {
            ptr: s.as_mut_ptr(),
            len: s.len(),
            _marker: std::marker::PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `[start, start + len)`.
    ///
    /// # Safety
    /// The caller must guarantee that no two live views overlap — i.e.
    /// concurrent tasks request disjoint ranges (one owner per tile).
    #[allow(clippy::mut_from_ref)] // disjointness is the call-site contract
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn run_covers_every_index_exactly_once() {
        let pool = ThreadPool::with_threads(4);
        for tasks in [0usize, 1, 3, 4, 17, 100] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(tasks, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "tasks={tasks} index {i}");
            }
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::with_threads(1);
        let counter = AtomicUsize::new(0);
        pool.run(9, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 9);
        assert_eq!(pool.threads(), 1);
        let mut buf = vec![0u8; 4];
        let s = SyncSlice::new(&mut buf);
        assert_eq!(s.len(), 4);
        assert!(!s.is_empty());
    }

    #[test]
    fn nested_run_from_worker_completes() {
        let pool = ThreadPool::with_threads(3);
        let counter = AtomicUsize::new(0);
        pool.run(6, |_| {
            // nested fan-out must run inline without deadlocking
            pool.run(5, |_| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(counter.load(Ordering::Relaxed), 30);
    }

    #[test]
    fn panic_in_task_propagates_after_all_chunks() {
        let pool = ThreadPool::with_threads(4);
        let done = AtomicUsize::new(0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 5 {
                    panic!("boom at {i}");
                }
                done.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(r.is_err());
        // the pool stays usable afterwards
        let counter = AtomicUsize::new(0);
        pool.run(4, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn sync_slice_disjoint_tiles() {
        let pool = ThreadPool::with_threads(4);
        let n = 64usize;
        let mut out = vec![0u32; n];
        {
            let s = SyncSlice::new(&mut out);
            pool.run(n, |i| {
                // SAFETY: tile i is written only by task i.
                let t = unsafe { s.slice_mut(i, 1) };
                t[0] = i as u32 * 3;
            });
        }
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u32 * 3);
        }
    }

    /// Poison the queue mutex directly (a panic while the guard is
    /// held), then verify workers and `run` recover the guard via
    /// `PoisonError::into_inner` instead of double-panicking — the only
    /// panic a caller ever sees stays the propagated task panic.
    #[test]
    fn pool_recovers_from_poisoned_queue_mutex() {
        let pool = ThreadPool::with_threads(4);
        let sh = pool.shared.clone();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = sh.queue.lock().unwrap_or_else(PoisonError::into_inner);
            panic!("poison the queue mutex");
        }));
        assert!(r.is_err());
        assert!(sh.queue.is_poisoned(), "mutex should be poisoned");
        // dispatch through the poisoned mutex still works end to end
        let counter = AtomicUsize::new(0);
        pool.run(16, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 16);
        // and a task panic still surfaces exactly once
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 3 {
                    panic!("task panic");
                }
            });
        }));
        assert!(r.is_err());
    }

    #[test]
    fn with_config_pins_simd_path() {
        for path in simd::all_paths() {
            let pool = ThreadPool::with_config(2, path);
            assert_eq!(pool.simd(), path);
            assert_eq!(pool.threads(), 2);
        }
        // forcing avx2 off-host degrades to an executable path
        let pool = ThreadPool::with_config(1, SimdPath::Avx2);
        assert!(simd::all_paths().contains(&pool.simd()));
        let dbg = format!("{pool:?}");
        assert!(dbg.contains("simd="), "{dbg}");
    }

    #[test]
    fn occupancy_is_a_fraction() {
        let pool = ThreadPool::with_threads(4);
        assert_eq!(pool.occupancy(), 0.0);
        pool.run(16, |_| {});
        let f = pool.occupancy();
        assert!(f > 0.0 && f <= 1.0, "occupancy {f}");
    }

    #[test]
    fn env_sizing_clamps() {
        // cannot mutate the env safely in-process; just sanity-check the
        // default derivation stays in range
        let t = threads_from_env();
        assert!((1..=MAX_THREADS).contains(&t));
    }
}
