//! `runtime::kernels` — the threaded tiled-kernel subsystem behind the
//! CPU backend's hot paths.
//!
//! Layout:
//!
//! - [`pool`]: a crate-local scoped thread pool (std-only; sized by
//!   `BOF4_THREADS`, else the detected core count) plus [`SyncSlice`],
//!   the disjoint-tile write primitive every kernel builds on.
//! - [`tiling`]: cache-blocked dense matmul (`y = x@w`, `dy@w^T`,
//!   `x^T@dy`), row-parallel RMS-norm forward/backward, and element-wise
//!   maps.
//! - [`q4`]: the fused 4-bit dequant-matmul family — one BOF4 block
//!   dequantized per tile, constants optionally 8-bit double-quantized —
//!   plus the weight materializer the prefill path uses.
//! - [`attention`]: causal multi-head attention forward/backward fanned
//!   out over `(batch row x head)`, and the single-row incremental
//!   decode-step attention.
//!
//! **Determinism contract**: every kernel is bit-identical to its serial
//! loop at any thread count. Tiles have exactly one owning task
//! (deterministic ownership), per-element reductions keep the serial
//! `k`-ascending order, and the only cross-row reduction
//! ([`tiling::rmsnorm_bwd`]'s gain gradient) is staged per row and summed
//! serially in row order. `rust/tests/runtime_e2e.rs` pins logits and
//! AdamW/LoRA training steps across `BOF4_THREADS in {1, 2, 8}`.

pub mod attention;
pub mod pool;
pub mod q4;
pub mod tiling;

pub use pool::{default_pool, threads_from_env, SyncSlice, ThreadPool};
pub use q4::MatW;
