//! `runtime::kernels` — the threaded tiled-kernel subsystem behind the
//! CPU backend's hot paths.
//!
//! Layout:
//!
//! - [`pool`]: a crate-local scoped thread pool (std-only; sized by
//!   `BOF4_THREADS`, else the detected core count) plus [`SyncSlice`],
//!   the disjoint-tile write primitive every kernel builds on. The pool
//!   also carries the active [`simd::SimdPath`] for its kernels.
//! - [`simd`]: the portable 8-lane vector layer — [`simd::F32x8`] array
//!   ops LLVM autovectorizes anywhere, plus runtime-detected x86_64
//!   AVX2 intrinsics (`BOF4_SIMD=0|1|array|avx2` forces a path). Every
//!   inner-loop primitive (dots, axpy, the fused q4 dequant forms, the
//!   norm maps) is implemented bit-identically in all paths.
//! - [`tiling`]: cache-blocked dense matmul (`y = x@w`, `dy@w^T`,
//!   `x^T@dy`), row-parallel RMS-norm forward/backward, and element-wise
//!   maps.
//! - [`q4`]: the fused 4-bit dequant-matmul family — one BOF4 block
//!   dequantized per tile, constants optionally 8-bit double-quantized —
//!   plus the weight materializer the prefill path uses.
//! - [`attention`]: causal multi-head attention forward/backward fanned
//!   out over `(batch row x head)`, and the single-row incremental
//!   decode-step attention.
//! - [`kv`]: the fused-dequant variant of the decode-step attention for
//!   block-quantized (`BOF4_KV=q8|q4`) KV caches — reads codes through
//!   [`simd`]'s `kv_dot_*`/`kv_axpy_*` forms without materializing f32
//!   rows.
//!
//! **Observability**: kernel entry points tag their calling thread with
//! a [`KernelPhase`] ([`pool::phase_scope`]); the pool attributes every
//! top-level dispatch's wall time and call count to that phase
//! ([`ThreadPool::kernel_profile`], exported as the Prometheus
//! `bof4_kernel_seconds_total{kernel="…"}` series) and, at
//! `BOF4_TRACE=kernel`, emits one trace span per dispatch. Both wrap
//! dispatch from the outside — never a reduction loop — so the
//! determinism contract below is untouched.
//!
//! **Determinism contract**: every kernel is bit-identical across every
//! `(BOF4_THREADS, BOF4_SIMD)` combination. Tiles have exactly one
//! owning task (deterministic ownership); element-wise accumulations
//! keep the serial `k`-ascending per-element order; every inner-`k`
//! reduction (dot products, sums of squares) runs in the canonical
//! **8-lane-strided** order of [`simd`] — 8 independent lane
//! accumulators combined in a fixed tree — implemented identically by
//! the scalar, array-SIMD and AVX2 paths; and the only cross-row
//! reduction ([`tiling::rmsnorm_bwd`]'s gain gradient) is staged per
//! row and summed serially in row order. `rust/tests/runtime_e2e.rs`
//! pins logits and AdamW/LoRA training steps across
//! `BOF4_THREADS in {1, 2, 8}` × the SIMD paths executable on the host.

pub mod attention;
pub mod kv;
pub mod pool;
pub mod q4;
pub mod simd;
pub mod tiling;

pub use pool::{
    default_pool, phase_scope, threads_from_env, KernelPhase, KernelStat, SyncSlice, ThreadPool,
};
pub use q4::MatW;
pub use simd::SimdPath;
