//! Host-side tensors crossing the backend ABI.
//!
//! [`HostTensor`] is the only value type exchanged with a
//! [`super::Backend`]: a flat little-endian buffer plus a shape, in one of
//! the four dtypes the graph ABIs use (`float32`, `int32`, `uint8`,
//! `uint32`).

use crate::error::Result;

/// A host-side tensor in one of the dtypes crossing the ABI.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    U8(Vec<u8>, Vec<usize>),
    U32(Vec<u32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_u32(v: u32) -> Self {
        HostTensor::U32(vec![v], vec![])
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32(data, shape)
    }

    /// Zero-filled f32 tensor (cache slabs, argument placeholders).
    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::F32(vec![0.0; n], shape)
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32(data, shape)
    }

    pub fn u8(data: Vec<u8>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::U8(data, shape)
    }

    pub fn u32(data: Vec<u32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::U32(data, shape)
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s)
            | HostTensor::I32(_, s)
            | HostTensor::U8(_, s)
            | HostTensor::U32(_, s) => s,
        }
    }

    pub fn dtype_str(&self) -> &'static str {
        match self {
            HostTensor::F32(..) => "float32",
            HostTensor::I32(..) => "int32",
            HostTensor::U8(..) => "uint8",
            HostTensor::U32(..) => "uint32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            other => Err(crate::err!(
                "expected f32 tensor, got {}",
                other.dtype_str()
            )),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            other => Err(crate::err!(
                "expected i32 tensor, got {}",
                other.dtype_str()
            )),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self {
            HostTensor::U8(d, _) => Ok(d),
            other => Err(crate::err!(
                "expected u8 tensor, got {}",
                other.dtype_str()
            )),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            HostTensor::U32(d, _) => Ok(d),
            other => Err(crate::err!(
                "expected u32 tensor, got {}",
                other.dtype_str()
            )),
        }
    }

    /// Mutable f32 view (the serving engine scatters prefilled K/V rows
    /// into its cache slabs in place).
    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            other => Err(crate::err!(
                "expected f32 tensor, got {}",
                other.dtype_str()
            )),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            other => Err(crate::err!(
                "expected f32 tensor, got {}",
                other.dtype_str()
            )),
        }
    }

    pub fn scalar_f32_value(&self) -> Result<f32> {
        Ok(self.as_f32()?[0])
    }

    pub fn scalar_i32_value(&self) -> Result<i32> {
        Ok(self.as_i32()?[0])
    }

    pub fn scalar_u32_value(&self) -> Result<u32> {
        Ok(self.as_u32()?[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::f32(vec![1.0; 6], vec![2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype_str(), "float32");
        assert!(t.as_f32().is_ok());
        let t = HostTensor::scalar_i32(5);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert!(t.as_f32().is_err());
        assert_eq!(t.scalar_i32_value().unwrap(), 5);
        let t = HostTensor::scalar_u32(9);
        assert_eq!(t.scalar_u32_value().unwrap(), 9);
        let z = HostTensor::zeros_f32(vec![2, 4]);
        assert_eq!(z.shape(), &[2, 4]);
        assert!(z.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn host_tensor_rejects_shape_mismatch() {
        HostTensor::f32(vec![1.0; 5], vec![2, 3]);
    }
}
