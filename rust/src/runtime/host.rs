//! Host-side tensors crossing the backend ABI.
//!
//! [`HostTensor`] is the only value type exchanged with a
//! [`super::Backend`]: a flat little-endian buffer plus a shape, in one of
//! the four dtypes the graph ABIs use (`float32`, `int32`, `uint8`,
//! `uint32`).
//!
//! The data buffer is reference-counted (`Arc`), so cloning a tensor is a
//! cheap handle copy that **shares** the underlying storage — this is what
//! lets every serving-engine replica read one immutable weight set instead
//! of owning a private parameter copy. Mutation goes through
//! [`HostTensor::as_f32_mut`], which is copy-on-write: a uniquely-owned
//! buffer (e.g. a replica's private KV-cache slab) is mutated in place, a
//! shared buffer is cloned first so aliased readers never observe writes.

use std::sync::Arc;

use crate::error::Result;

/// A host-side tensor in one of the dtypes crossing the ABI. Clones share
/// the underlying buffer (see the module docs).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Arc<Vec<f32>>, Vec<usize>),
    I32(Arc<Vec<i32>>, Vec<usize>),
    U8(Arc<Vec<u8>>, Vec<usize>),
    U32(Arc<Vec<u32>>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_u32(v: u32) -> Self {
        HostTensor::U32(Arc::new(vec![v]), vec![])
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32(Arc::new(vec![v]), vec![])
    }

    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32(Arc::new(data), shape)
    }

    /// Zero-filled f32 tensor (cache slabs, argument placeholders).
    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::F32(Arc::new(vec![0.0; n]), shape)
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32(Arc::new(data), shape)
    }

    pub fn u8(data: Vec<u8>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::U8(Arc::new(data), shape)
    }

    pub fn u32(data: Vec<u32>, shape: Vec<usize>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::U32(Arc::new(data), shape)
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s)
            | HostTensor::I32(_, s)
            | HostTensor::U8(_, s)
            | HostTensor::U32(_, s) => s,
        }
    }

    pub fn dtype_str(&self) -> &'static str {
        match self {
            HostTensor::F32(..) => "float32",
            HostTensor::I32(..) => "int32",
            HostTensor::U8(..) => "uint8",
            HostTensor::U32(..) => "uint32",
        }
    }

    /// Size in bytes of the element buffer.
    pub fn byte_len(&self) -> usize {
        match self {
            HostTensor::F32(d, _) => 4 * d.len(),
            HostTensor::I32(d, _) => 4 * d.len(),
            HostTensor::U8(d, _) => d.len(),
            HostTensor::U32(d, _) => 4 * d.len(),
        }
    }

    /// Identity of the underlying buffer (the element pointer), used to
    /// deduplicate shared storage when accounting resident memory: two
    /// handles over the same buffer report the same address. Only
    /// meaningful for non-empty tensors.
    pub fn buf_addr(&self) -> usize {
        match self {
            HostTensor::F32(d, _) => d.as_ptr() as usize,
            HostTensor::I32(d, _) => d.as_ptr() as usize,
            HostTensor::U8(d, _) => d.as_ptr() as usize,
            HostTensor::U32(d, _) => d.as_ptr() as usize,
        }
    }

    /// Whether `self` and `other` are handles over the same buffer.
    pub fn shares_buffer(&self, other: &HostTensor) -> bool {
        self.byte_len() > 0 && self.buf_addr() == other.buf_addr()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            other => Err(crate::err!(
                "expected f32 tensor, got {}",
                other.dtype_str()
            )),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            other => Err(crate::err!(
                "expected i32 tensor, got {}",
                other.dtype_str()
            )),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self {
            HostTensor::U8(d, _) => Ok(d),
            other => Err(crate::err!(
                "expected u8 tensor, got {}",
                other.dtype_str()
            )),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            HostTensor::U32(d, _) => Ok(d),
            other => Err(crate::err!(
                "expected u32 tensor, got {}",
                other.dtype_str()
            )),
        }
    }

    /// Mutable f32 view (the serving engine scatters prefilled K/V rows
    /// into its cache slabs in place). Copy-on-write: mutating a tensor
    /// whose buffer is shared with other handles clones the buffer first,
    /// so aliased readers (e.g. weight views in other replicas) are never
    /// affected.
    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            HostTensor::F32(d, _) => Ok(Arc::make_mut(d)),
            other => Err(crate::err!(
                "expected f32 tensor, got {}",
                other.dtype_str()
            )),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(d, _) => {
                Ok(Arc::try_unwrap(d).unwrap_or_else(|shared| (*shared).clone()))
            }
            other => Err(crate::err!(
                "expected f32 tensor, got {}",
                other.dtype_str()
            )),
        }
    }

    pub fn scalar_f32_value(&self) -> Result<f32> {
        Ok(self.as_f32()?[0])
    }

    pub fn scalar_i32_value(&self) -> Result<i32> {
        Ok(self.as_i32()?[0])
    }

    pub fn scalar_u32_value(&self) -> Result<u32> {
        Ok(self.as_u32()?[0])
    }
}

/// Unique resident bytes across a set of tensor handles: shared buffers
/// are counted once (deduplicated by buffer identity via
/// [`HostTensor::buf_addr`]). This is the measurement behind the serving
/// engine's memory profile — N replicas holding handles over one weight
/// set contribute that set's bytes once, not N times.
pub fn unique_resident_bytes<'a>(
    tensors: impl IntoIterator<Item = &'a HostTensor>,
    seen: &mut std::collections::HashSet<usize>,
) -> usize {
    let mut total = 0usize;
    for t in tensors {
        let bytes = t.byte_len();
        if bytes == 0 {
            continue; // empty tensors have no buffer worth counting
        }
        if seen.insert(t.buf_addr()) {
            total += bytes;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::f32(vec![1.0; 6], vec![2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype_str(), "float32");
        assert!(t.as_f32().is_ok());
        let t = HostTensor::scalar_i32(5);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert!(t.as_f32().is_err());
        assert_eq!(t.scalar_i32_value().unwrap(), 5);
        let t = HostTensor::scalar_u32(9);
        assert_eq!(t.scalar_u32_value().unwrap(), 9);
        let z = HostTensor::zeros_f32(vec![2, 4]);
        assert_eq!(z.shape(), &[2, 4]);
        assert!(z.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn host_tensor_rejects_shape_mismatch() {
        HostTensor::f32(vec![1.0; 5], vec![2, 3]);
    }

    #[test]
    fn clones_share_one_buffer() {
        let a = HostTensor::f32(vec![1.0; 128], vec![128]);
        let b = a.clone();
        assert!(a.shares_buffer(&b));
        assert_eq!(a.buf_addr(), b.buf_addr());
        // the shared buffer is counted once
        let mut seen = HashSet::new();
        let total = unique_resident_bytes([&a, &b], &mut seen);
        assert_eq!(total, 128 * 4);
        // a distinct tensor adds its own bytes
        let c = HostTensor::f32(vec![2.0; 8], vec![8]);
        assert_eq!(unique_resident_bytes([&c], &mut seen), 8 * 4);
    }

    #[test]
    fn mutation_is_copy_on_write() {
        let mut a = HostTensor::f32(vec![0.0; 4], vec![4]);
        let b = a.clone();
        // uniquely-owned after the write: b keeps the original bits
        a.as_f32_mut().unwrap()[0] = 7.0;
        assert_eq!(a.as_f32().unwrap()[0], 7.0);
        assert_eq!(b.as_f32().unwrap()[0], 0.0);
        assert!(!a.shares_buffer(&b));
        // an unshared tensor mutates in place (no reallocation)
        let mut c = HostTensor::f32(vec![0.0; 4], vec![4]);
        let addr = c.buf_addr();
        c.as_f32_mut().unwrap()[1] = 3.0;
        assert_eq!(c.buf_addr(), addr, "unique buffer must mutate in place");
    }

    #[test]
    fn into_f32_recovers_data_shared_or_not() {
        let a = HostTensor::f32(vec![1.5, -2.5], vec![2]);
        let b = a.clone();
        assert_eq!(a.into_f32().unwrap(), vec![1.5, -2.5]); // shared: copies
        assert_eq!(b.into_f32().unwrap(), vec![1.5, -2.5]); // unique: moves
    }

    #[test]
    fn empty_tensors_do_not_collide_in_accounting() {
        let a = HostTensor::u32(Vec::new(), vec![0]);
        let b = HostTensor::f32(Vec::new(), vec![0]);
        let mut seen = HashSet::new();
        assert_eq!(unique_resident_bytes([&a, &b], &mut seen), 0);
        assert!(seen.is_empty());
    }
}
