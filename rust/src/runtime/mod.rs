//! Multi-backend graph runtime.
//!
//! Every model computation in this crate — training steps, NLL/logit
//! evals, the quantized serving forward, the standalone kernels — is
//! expressed as a named *graph* with a flat positional ABI described by
//! [`Meta`]. The [`Backend`] trait abstracts who executes those graphs:
//!
//! - [`cpu::CpuBackend`] (default): a pure-Rust interpreter of the same
//!   graph semantics — embedding gather, matmul with fused 4-bit dequant,
//!   RMS-norm, GELU, causal attention softmax, NLL, AdamW and LoRA
//!   updates. Fully hermetic: zero Python, zero artifacts, zero network.
//! - `client::XlaBackend` (behind the off-by-default `xla` cargo
//!   feature): compiles the AOT'd HLO-text artifacts produced by
//!   `make artifacts` through PJRT and executes them (start pattern:
//!   /opt/xla-example/load_hlo).
//!
//! [`Runtime`] owns a [`Meta`] plus one backend, validates every call
//! against the ABI, and is what the coordinator/eval layers hold.
//!
//! Backend selection: [`Runtime::new`] honours `BOF4_BACKEND=cpu|xla`
//! (default `cpu`).

#[cfg(feature = "xla")]
pub mod client;
pub mod cpu;
pub mod host;
pub mod meta;

pub use cpu::CpuBackend;
pub use host::HostTensor;
pub use meta::{ArgMeta, GraphMeta, Meta, ModelMeta};

use crate::error::Result;

/// A graph executor: prepare (compile/warm) and execute graphs over the
/// flat `meta.json` ABI. Implementations must be shareable across the
/// coordinator's threads.
pub trait Backend: Send + Sync {
    /// Human-readable platform tag ("cpu-interpreter", "Host", ...).
    fn platform(&self) -> String;

    /// Compile or otherwise warm the graph so the first [`Backend::execute`]
    /// is not slow. A no-op for interpreters.
    fn compile(&self, gm: &GraphMeta) -> Result<()>;

    /// Execute one graph invocation. `args` are already validated against
    /// `gm.args`; the returned tensors must align with `gm.results`.
    fn execute(&self, gm: &GraphMeta, args: &[HostTensor]) -> Result<Vec<HostTensor>>;
}

/// ABI-validating facade over a [`Backend`].
pub struct Runtime {
    pub meta: Meta,
    backend: Box<dyn Backend>,
}

impl Runtime {
    /// Default runtime: `BOF4_BACKEND` env override, else the hermetic
    /// CPU backend.
    pub fn new() -> Result<Runtime> {
        match std::env::var("BOF4_BACKEND").ok().as_deref() {
            None | Some("cpu") | Some("") => Ok(Self::cpu()),
            Some("xla") => Self::xla_runtime(),
            Some(other) => Err(crate::err!(
                "unknown BOF4_BACKEND '{other}' (expected 'cpu' or 'xla')"
            )),
        }
    }

    /// The pure-Rust CPU interpreter over the builtin ABI (infallible,
    /// artifact-free).
    pub fn cpu() -> Runtime {
        let meta = Meta::builtin();
        let backend = CpuBackend::new(meta.model.clone());
        crate::info!("runtime up: backend={} (hermetic)", backend.platform());
        Runtime {
            meta,
            backend: Box::new(backend),
        }
    }

    /// The PJRT/XLA backend over `artifacts/meta.json` (requires the
    /// `xla` cargo feature and `make artifacts`).
    #[cfg(feature = "xla")]
    pub fn xla() -> Result<Runtime> {
        let meta = Meta::load_default()?;
        let backend = client::XlaBackend::new()?;
        Ok(Runtime {
            meta,
            backend: Box::new(backend),
        })
    }

    #[cfg(feature = "xla")]
    fn xla_runtime() -> Result<Runtime> {
        Self::xla()
    }

    #[cfg(not(feature = "xla"))]
    fn xla_runtime() -> Result<Runtime> {
        Err(crate::err!(
            "BOF4_BACKEND=xla but this build has no XLA support \
             (rebuild with `--features xla` and a vendored xla crate)"
        ))
    }

    /// Assemble from explicit parts (tests / custom backends).
    ///
    /// Invariant: the backend must have been constructed for this `meta`
    /// (in particular, `CpuBackend::new` must receive `meta.model`) —
    /// `run` validates arguments against `meta`, but a backend sizes its
    /// buffers from its own model configuration.
    pub fn with_backend(meta: Meta, backend: Box<dyn Backend>) -> Runtime {
        Runtime { meta, backend }
    }

    /// Compile (or warm) a graph so the first `run` is not slow.
    pub fn prepare(&self, graph: &str) -> Result<()> {
        let gm = self.meta.graph(graph)?;
        self.backend.compile(gm)
    }

    /// Execute a graph with ABI validation against the manifest.
    pub fn run(&self, graph: &str, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let gm = self.meta.graph(graph)?;
        self.validate_args(gm, args)?;
        let out = self.backend.execute(gm, args)?;
        if out.len() != gm.results.len() {
            return Err(crate::err!(
                "{graph}: backend returned {} results, ABI expects {}",
                out.len(),
                gm.results.len()
            ));
        }
        Ok(out)
    }

    /// Map result names to tensors.
    pub fn run_named(&self, graph: &str, args: &[HostTensor]) -> Result<Vec<(String, HostTensor)>> {
        let names = self.meta.graph(graph)?.results.clone();
        let vals = self.run(graph, args)?;
        Ok(names.into_iter().zip(vals).collect())
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    fn validate_args(&self, gm: &GraphMeta, args: &[HostTensor]) -> Result<()> {
        if args.len() != gm.args.len() {
            return Err(crate::err!(
                "{}: expected {} args, got {}",
                gm.name,
                gm.args.len(),
                args.len()
            ));
        }
        for (i, (a, m)) in args.iter().zip(&gm.args).enumerate() {
            if a.shape() != m.shape.as_slice() {
                return Err(crate::err!(
                    "{} arg {i} ({}): shape {:?} != expected {:?}",
                    gm.name,
                    m.name,
                    a.shape(),
                    m.shape
                ));
            }
            if a.dtype_str() != m.dtype {
                return Err(crate::err!(
                    "{} arg {i} ({}): dtype {} != expected {}",
                    gm.name,
                    m.name,
                    a.dtype_str(),
                    m.dtype
                ));
            }
        }
        Ok(())
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Runtime(platform={}, graphs={})",
            self.backend.platform(),
            self.meta.graphs.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_runtime_validates_abi() {
        let rt = Runtime::cpu();
        assert_eq!(rt.platform(), "cpu-interpreter");
        // wrong arg count
        assert!(rt.run("lm_nll", &[]).is_err());
        // wrong dtype for the seed
        assert!(rt.run("init_params", &[HostTensor::scalar_i32(0)]).is_err());
        // unknown graph
        assert!(rt.run("nope", &[]).is_err());
    }

    #[test]
    fn run_named_aligns_names() {
        let rt = Runtime::cpu();
        let out = rt
            .run_named("init_params", &[HostTensor::scalar_u32(1)])
            .unwrap();
        assert_eq!(out.len(), 16);
        assert_eq!(out[0].0, "embed");
        assert_eq!(out[15].0, "head");
    }
}
