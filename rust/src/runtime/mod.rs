//! Multi-backend graph runtime.
//!
//! Every model computation in this crate — training steps, NLL/logit
//! evals, the quantized serving forward, the standalone kernels — is
//! expressed as a named *graph* with a flat positional ABI described by
//! [`Meta`]. The [`Backend`] trait abstracts who executes those graphs:
//!
//! - [`cpu::CpuBackend`] (default): a pure-Rust interpreter of the same
//!   graph semantics — embedding gather, matmul with fused 4-bit dequant,
//!   RMS-norm, GELU, causal attention softmax, NLL, AdamW and LoRA
//!   updates. Fully hermetic: zero Python, zero artifacts, zero network.
//!   Hot paths execute through the [`kernels`] subsystem: a crate-local
//!   scoped thread pool (`BOF4_THREADS`, std-only) driving tiled,
//!   SIMD-vectorized matmul/attention/norm kernels (`BOF4_SIMD` selects
//!   scalar / portable-array / AVX2 inner loops) that are bit-identical
//!   at every thread count and path, plus the in-place KV-cache protocol
//!   ([`Backend::alloc_decode_state`] / [`DecodeState`]) that keeps the
//!   serving engine's cache slabs resident across decode steps.
//! - `client::XlaBackend` (behind the off-by-default `xla` cargo
//!   feature): compiles the AOT'd HLO-text artifacts produced by
//!   `make artifacts` through PJRT and executes them (start pattern:
//!   /opt/xla-example/load_hlo).
//!
//! [`Runtime`] owns a [`Meta`] plus one backend, validates every call
//! against the ABI, and is what the coordinator/eval layers hold.
//!
//! Backend selection: [`Runtime::new`] honours `BOF4_BACKEND=cpu|xla`
//! (default `cpu`).

#[cfg(feature = "xla")]
pub mod client;
pub mod cpu;
pub mod host;
pub mod kernels;
pub mod meta;

pub use cpu::CpuBackend;
pub use host::HostTensor;
pub use meta::{ArgMeta, GraphMeta, Meta, ModelMeta};

use crate::error::Result;
pub use crate::quant::KvFormat;

/// Opaque backend-resident decode state: the per-layer KV-cache slabs a
/// decode-step graph mutates in place instead of round-tripping them
/// through [`HostTensor`] args/results (~2 MB of memcpy per step on the
/// canonical model). Allocated by [`Backend::alloc_decode_state`];
/// backends without in-place support simply never hand one out and the
/// engine keeps using the clone-based [`Backend::execute`] path.
pub trait DecodeState: Send {
    /// Copy one session's prefilled rows (`[seq_len * d_model]` f32) into
    /// cache `c` (the graph's cache-argument index: `2*layer` for K,
    /// `2*layer + 1` for V), batch slot `slot`.
    fn load_slot(&mut self, c: usize, slot: usize, rows: &[f32]) -> Result<()>;

    /// Downcast hook for the owning backend.
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;

    /// Bytes of backend-resident cache storage this state holds — the
    /// per-replica component of the serving engine's memory profile.
    /// Default 0 for backends that do not account their state.
    fn resident_bytes(&self) -> usize {
        0
    }
}

/// A graph executor: prepare (compile/warm) and execute graphs over the
/// flat `meta.json` ABI. Implementations must be shareable across the
/// coordinator's threads.
pub trait Backend: Send + Sync {
    /// Human-readable platform tag ("cpu-interpreter", "Host", ...).
    fn platform(&self) -> String;

    /// Compile or otherwise warm the graph so the first [`Backend::execute`]
    /// is not slow. A no-op for interpreters.
    fn compile(&self, gm: &GraphMeta) -> Result<()>;

    /// Execute one graph invocation. `args` are already validated against
    /// `gm.args`; the returned tensors must align with `gm.results`.
    fn execute(&self, gm: &GraphMeta, args: &[HostTensor]) -> Result<Vec<HostTensor>>;

    /// Allocate resident KV-cache state for a decode-step graph, or
    /// `None` when this backend has no in-place decode support (the
    /// engine then falls back to passing caches through
    /// [`Backend::execute`]). `kv` selects the resident storage format
    /// (the `BOF4_KV` knob): plain f32 slabs, or block-quantized q8/q4
    /// codes dequantized fused inside the attention kernels. Backends
    /// that cannot quantize must reject non-f32 requests rather than
    /// silently serving f32. Default: unsupported.
    fn alloc_decode_state(
        &self,
        _gm: &GraphMeta,
        kv: KvFormat,
    ) -> Result<Option<Box<dyn DecodeState>>> {
        if kv != KvFormat::F32 {
            return Err(crate::err!(
                "backend {} has no {kv} KV-cache support",
                self.platform()
            ));
        }
        Ok(None)
    }

    /// Execute one decode step against resident state. `args` are the
    /// graph's arguments *minus* the cache tensors (which live in
    /// `state` and are mutated in place); the return is the graph's
    /// results minus the cache tensors. Must be bit-identical to
    /// [`Backend::execute`] over the same caches.
    fn execute_decode_inplace(
        &self,
        gm: &GraphMeta,
        _state: &mut dyn DecodeState,
        _args: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        Err(crate::err!(
            "backend {} has no in-place decode for {}",
            self.platform(),
            gm.name
        ))
    }

    /// Mean kernel-pool occupancy (0..=1) over launches since the last
    /// sample (read-and-reset), when this backend runs on a thread pool —
    /// the `pool_busy` gauge the serving engine samples after each step.
    /// `None` for backends without a pool.
    fn pool_occupancy(&self) -> Option<f64> {
        None
    }

    /// Width of this backend's kernel pool, when it has one — what the
    /// decode-throughput bench records as its `threads` field.
    fn pool_threads(&self) -> Option<usize> {
        None
    }

    /// Active SIMD inner-loop path of this backend's kernels
    /// (`"none" | "array" | "avx2"`), when it runs on the tiled CPU
    /// kernel subsystem — what the benches record as their `simd` field.
    /// `None` for backends without the concept (XLA picks its own
    /// vectorization).
    fn simd_path(&self) -> Option<&'static str> {
        None
    }

    /// Cumulative per-kernel-phase wall time and dispatch counts
    /// ([`kernels::ThreadPool::kernel_profile`]), when this backend runs
    /// on the tiled kernel pool — the `bof4_kernel_seconds_total` /
    /// `bof4_kernel_calls_total` Prometheus series. `None` for backends
    /// without a pool.
    fn kernel_profile(&self) -> Option<Vec<kernels::KernelStat>> {
        None
    }
}

/// ABI-validating facade over a [`Backend`].
pub struct Runtime {
    pub meta: Meta,
    backend: Box<dyn Backend>,
}

impl Runtime {
    /// Default runtime: `BOF4_BACKEND` env override, else the hermetic
    /// CPU backend.
    pub fn new() -> Result<Runtime> {
        match std::env::var("BOF4_BACKEND").ok().as_deref() {
            None | Some("cpu") | Some("") => Ok(Self::cpu()),
            Some("xla") => Self::xla_runtime(),
            Some(other) => Err(crate::err!(
                "unknown BOF4_BACKEND '{other}' (expected 'cpu' or 'xla')"
            )),
        }
    }

    /// The pure-Rust CPU interpreter over the builtin ABI (infallible,
    /// artifact-free).
    pub fn cpu() -> Runtime {
        let meta = Meta::builtin();
        let backend = CpuBackend::new(meta.model.clone());
        crate::info!("runtime up: backend={} (hermetic)", backend.platform());
        Runtime {
            meta,
            backend: Box::new(backend),
        }
    }

    /// The PJRT/XLA backend over `artifacts/meta.json` (requires the
    /// `xla` cargo feature and `make artifacts`).
    #[cfg(feature = "xla")]
    pub fn xla() -> Result<Runtime> {
        let meta = Meta::load_default()?;
        let backend = client::XlaBackend::new()?;
        Ok(Runtime {
            meta,
            backend: Box::new(backend),
        })
    }

    #[cfg(feature = "xla")]
    fn xla_runtime() -> Result<Runtime> {
        Self::xla()
    }

    #[cfg(not(feature = "xla"))]
    fn xla_runtime() -> Result<Runtime> {
        Err(crate::err!(
            "BOF4_BACKEND=xla but this build has no XLA support \
             (rebuild with `--features xla` and a vendored xla crate)"
        ))
    }

    /// Assemble from explicit parts (tests / custom backends).
    ///
    /// Invariant: the backend must have been constructed for this `meta`
    /// (in particular, `CpuBackend::new` must receive `meta.model`) —
    /// `run` validates arguments against `meta`, but a backend sizes its
    /// buffers from its own model configuration.
    pub fn with_backend(meta: Meta, backend: Box<dyn Backend>) -> Runtime {
        Runtime { meta, backend }
    }

    /// Compile (or warm) a graph so the first `run` is not slow.
    pub fn prepare(&self, graph: &str) -> Result<()> {
        let gm = self.meta.graph(graph)?;
        self.backend.compile(gm)
    }

    /// Execute a graph with ABI validation against the manifest.
    pub fn run(&self, graph: &str, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let gm = self.meta.graph(graph)?;
        self.validate_args(gm, args)?;
        let out = self.backend.execute(gm, args)?;
        if out.len() != gm.results.len() {
            return Err(crate::err!(
                "{graph}: backend returned {} results, ABI expects {}",
                out.len(),
                gm.results.len()
            ));
        }
        Ok(out)
    }

    /// Map result names to tensors.
    pub fn run_named(&self, graph: &str, args: &[HostTensor]) -> Result<Vec<(String, HostTensor)>> {
        let names = self.meta.graph(graph)?.results.clone();
        let vals = self.run(graph, args)?;
        Ok(names.into_iter().zip(vals).collect())
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Allocate backend-resident KV state for a decode-step graph (`None`
    /// when the backend only supports the clone-based cache path), with
    /// plain f32 cache slabs — the pre-`BOF4_KV` behaviour.
    pub fn alloc_decode_state(&self, graph: &str) -> Result<Option<Box<dyn DecodeState>>> {
        self.alloc_decode_state_fmt(graph, KvFormat::F32)
    }

    /// [`Runtime::alloc_decode_state`] with an explicit KV-cache storage
    /// format (the `BOF4_KV` knob): `F32` keeps the plain slabs, `Q8`/`Q4`
    /// store block-quantized codes dequantized fused inside the decode
    /// attention. Errors when the backend cannot honour a quantized
    /// request (never silently degrades to f32).
    pub fn alloc_decode_state_fmt(
        &self,
        graph: &str,
        kv: KvFormat,
    ) -> Result<Option<Box<dyn DecodeState>>> {
        let gm = self.meta.graph(graph)?;
        self.backend.alloc_decode_state(gm, kv)
    }

    /// Execute one decode step against resident state: `args` must match
    /// the graph ABI with the cache tensors removed (they live in
    /// `state`); returns the non-cache results (the logits).
    pub fn run_decode_step_inplace(
        &self,
        graph: &str,
        state: &mut dyn DecodeState,
        args: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let gm = self.meta.graph(graph)?;
        let expect = gm.non_cache_args();
        if args.len() != expect.len() {
            return Err(crate::err!(
                "{graph} (in-place): expected {} non-cache args, got {}",
                expect.len(),
                args.len()
            ));
        }
        for (i, (a, m)) in args.iter().zip(&expect).enumerate() {
            let shape_ok = if m.is_dynamic() {
                // outlier side-tables: any element count, same rank
                a.shape().len() == m.shape.len()
            } else {
                a.shape() == m.shape.as_slice()
            };
            if !shape_ok || a.dtype_str() != m.dtype {
                return Err(crate::err!(
                    "{graph} (in-place) arg {i} ({}): got {}{:?}, expected {}{:?}",
                    m.name,
                    a.dtype_str(),
                    a.shape(),
                    m.dtype,
                    m.shape
                ));
            }
        }
        let out = self.backend.execute_decode_inplace(gm, state, args)?;
        let n_res = gm
            .results
            .iter()
            .filter(|r| !meta::is_cache_name(r.as_str()))
            .count();
        if out.len() != n_res {
            return Err(crate::err!(
                "{graph} (in-place): backend returned {} results, ABI expects {}",
                out.len(),
                n_res
            ));
        }
        Ok(out)
    }

    /// Mean kernel-pool occupancy since the last sample, when the backend
    /// runs on a thread pool (the serving engine's `pool_busy` gauge).
    pub fn pool_occupancy(&self) -> Option<f64> {
        self.backend.pool_occupancy()
    }

    /// Width of the backend's kernel pool, when it has one.
    pub fn pool_threads(&self) -> Option<usize> {
        self.backend.pool_threads()
    }

    /// Active SIMD inner-loop path (`"none" | "array" | "avx2"`), when
    /// the backend runs on the tiled CPU kernels.
    pub fn simd_path(&self) -> Option<&'static str> {
        self.backend.simd_path()
    }

    /// Cumulative per-kernel-phase wall time and dispatch counts, when
    /// the backend runs on the tiled kernel pool (the observability
    /// snapshot's kernel profile).
    pub fn kernel_profile(&self) -> Option<Vec<kernels::KernelStat>> {
        self.backend.kernel_profile()
    }

    fn validate_args(&self, gm: &GraphMeta, args: &[HostTensor]) -> Result<()> {
        if args.len() != gm.args.len() {
            return Err(crate::err!(
                "{}: expected {} args, got {}",
                gm.name,
                gm.args.len(),
                args.len()
            ));
        }
        for (i, (a, m)) in args.iter().zip(&gm.args).enumerate() {
            let shape_ok = if m.is_dynamic() {
                // dynamic-length args (OPQ outlier side-tables): the
                // element count is data-dependent; hold rank and dtype
                a.shape().len() == m.shape.len()
            } else {
                a.shape() == m.shape.as_slice()
            };
            if !shape_ok {
                return Err(crate::err!(
                    "{} arg {i} ({}): shape {:?} != expected {:?}",
                    gm.name,
                    m.name,
                    a.shape(),
                    m.shape
                ));
            }
            if a.dtype_str() != m.dtype {
                return Err(crate::err!(
                    "{} arg {i} ({}): dtype {} != expected {}",
                    gm.name,
                    m.name,
                    a.dtype_str(),
                    m.dtype
                ));
            }
        }
        Ok(())
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Runtime(platform={}, graphs={})",
            self.backend.platform(),
            self.meta.graphs.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_runtime_validates_abi() {
        let rt = Runtime::cpu();
        assert_eq!(rt.platform(), "cpu-interpreter");
        // the CPU backend always reports its active SIMD path
        assert!(["none", "array", "avx2"].contains(&rt.simd_path().unwrap()));
        // wrong arg count
        assert!(rt.run("lm_nll", &[]).is_err());
        // wrong dtype for the seed
        assert!(rt.run("init_params", &[HostTensor::scalar_i32(0)]).is_err());
        // unknown graph
        assert!(rt.run("nope", &[]).is_err());
    }

    #[test]
    fn run_named_aligns_names() {
        let rt = Runtime::cpu();
        let out = rt
            .run_named("init_params", &[HostTensor::scalar_u32(1)])
            .unwrap();
        assert_eq!(out.len(), 16);
        assert_eq!(out[0].0, "embed");
        assert_eq!(out[15].0, "head");
    }
}
