//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the rust hot path (start pattern: /opt/xla-example/load_hlo).
//!
//! `make artifacts` (python, build-time only) produces `artifacts/*.hlo.txt`
//! plus `meta.json` describing each graph's flat argument/result ABI. This
//! module is the only place the `xla` crate is touched:
//!
//! - [`meta`]: parse `meta.json` into [`meta::GraphMeta`] ABIs
//! - [`client`]: the process-wide `PjRtClient`, graph compilation cache,
//!   and typed literal marshalling helpers ([`client::HostTensor`])

pub mod client;
pub mod meta;

pub use client::{HostTensor, Runtime};
pub use meta::{ArgMeta, GraphMeta, Meta};
