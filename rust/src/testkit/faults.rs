//! Fault-injection harness for the serving engine (`BOF4_FAULT`).
//!
//! Chaos tests need deterministic ways to kill a replica mid-decode,
//! fail a prefill, or wedge a decode step. This module owns a tiny
//! process-global fault plan consulted by hooks compiled into the CPU
//! backend's prefill/decode paths:
//!
//! * `panic_decode:<n>` — panic on the *n*-th decode-step call
//!   (process-wide count), simulating a replica crash.
//! * `err_prefill:<n>` — return an error from the *n*-th prefill call,
//!   simulating a backend fault during admission.
//! * `slow_step:<ms>`  — sleep `<ms>` before every decode step,
//!   simulating a stalled replica.
//!
//! Multiple faults combine with commas: `panic_decode:5,slow_step:2`.
//!
//! The off path is a single relaxed atomic load (the same discipline as
//! the tracer level gate), so production binaries pay nothing unless
//! `BOF4_FAULT` is set — the decode bench asserts this. The plan itself
//! lives entirely in atomics, so a hook that panics (the whole point)
//! can never poison a lock.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use crate::error::Result;

/// Master switch: hooks return immediately while this is false.
static ARMED: AtomicBool = AtomicBool::new(false);
/// Panic on the n-th decode call (0 = disabled).
static PANIC_AFTER: AtomicU64 = AtomicU64::new(0);
/// Error on the n-th prefill call (0 = disabled).
static ERR_AFTER: AtomicU64 = AtomicU64::new(0);
/// Sleep this many ms before every decode call (0 = disabled).
static SLOW_MS: AtomicU64 = AtomicU64::new(0);

/// Call + trigger accounting, readable by tests to pin that the engine
/// observed exactly the injected schedule.
static DECODE_CALLS: AtomicU64 = AtomicU64::new(0);
static PREFILL_CALLS: AtomicU64 = AtomicU64::new(0);
static PANICS_FIRED: AtomicU64 = AtomicU64::new(0);
static PREFILL_ERRS_FIRED: AtomicU64 = AtomicU64::new(0);

/// A parsed `BOF4_FAULT` schedule.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultSpec {
    /// Panic on the n-th decode-step call (1-indexed).
    pub panic_decode: Option<u64>,
    /// Error on the n-th prefill call (1-indexed).
    pub err_prefill: Option<u64>,
    /// Sleep before every decode step, in milliseconds.
    pub slow_step_ms: Option<u64>,
}

impl FaultSpec {
    /// Parse a comma-separated schedule, e.g. `panic_decode:3,slow_step:5`.
    pub fn parse(spec: &str) -> Result<FaultSpec> {
        let mut out = FaultSpec::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind, arg) = part
                .split_once(':')
                .ok_or_else(|| crate::err!("BOF4_FAULT entry '{part}' missing ':<n>'"))?;
            let n: u64 = arg
                .trim()
                .parse()
                .map_err(|_| crate::err!("BOF4_FAULT entry '{part}': '{arg}' is not a number"))?;
            match kind.trim() {
                "panic_decode" => out.panic_decode = Some(n),
                "err_prefill" => out.err_prefill = Some(n),
                "slow_step" => out.slow_step_ms = Some(n),
                other => {
                    return Err(crate::err!(
                        "unknown BOF4_FAULT kind '{other}' \
                         (expected panic_decode|err_prefill|slow_step)"
                    ))
                }
            }
        }
        Ok(out)
    }

    fn is_empty(&self) -> bool {
        *self == FaultSpec::default()
    }
}

/// Counts of hook calls and fired faults since the last install/clear.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub decode_calls: u64,
    pub prefill_calls: u64,
    pub panics_fired: u64,
    pub prefill_errs_fired: u64,
}

/// Snapshot the trigger accounting.
pub fn stats() -> FaultStats {
    FaultStats {
        decode_calls: DECODE_CALLS.load(Ordering::Relaxed),
        prefill_calls: PREFILL_CALLS.load(Ordering::Relaxed),
        panics_fired: PANICS_FIRED.load(Ordering::Relaxed),
        prefill_errs_fired: PREFILL_ERRS_FIRED.load(Ordering::Relaxed),
    }
}

/// True when a fault plan is installed. The decode bench asserts this
/// stays false when `BOF4_FAULT` is unset (zero-cost contract).
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

fn install(spec: &FaultSpec) {
    DECODE_CALLS.store(0, Ordering::Relaxed);
    PREFILL_CALLS.store(0, Ordering::Relaxed);
    PANICS_FIRED.store(0, Ordering::Relaxed);
    PREFILL_ERRS_FIRED.store(0, Ordering::Relaxed);
    PANIC_AFTER.store(spec.panic_decode.unwrap_or(0), Ordering::Relaxed);
    ERR_AFTER.store(spec.err_prefill.unwrap_or(0), Ordering::Relaxed);
    SLOW_MS.store(spec.slow_step_ms.unwrap_or(0), Ordering::Relaxed);
    ARMED.store(!spec.is_empty(), Ordering::Relaxed);
}

fn clear() {
    ARMED.store(false, Ordering::Relaxed);
    PANIC_AFTER.store(0, Ordering::Relaxed);
    ERR_AFTER.store(0, Ordering::Relaxed);
    SLOW_MS.store(0, Ordering::Relaxed);
}

/// One-shot env installation for binaries (`bof4`, benches), cached the
/// same way as `BOF4_THREADS`/`BOF4_KV`. Tests must use
/// [`install_for_test`] instead so faults cannot leak across tests.
pub fn init_from_env() {
    static INIT: OnceLock<()> = OnceLock::new();
    INIT.get_or_init(|| {
        if let Ok(spec) = std::env::var("BOF4_FAULT") {
            match FaultSpec::parse(&spec) {
                Ok(plan) => install(&plan),
                Err(e) => crate::warn!("ignoring invalid BOF4_FAULT: {e:#}"),
            }
        }
    });
}

/// The fault plan is process-global, so tests that install one (or that
/// run engines which must NOT see someone else's plan) serialize here.
static TEST_LOCK: Mutex<()> = Mutex::new(());

/// RAII handle from [`install_for_test`]/[`exclusive`]: holds the
/// process-wide fault lock and clears the plan on drop.
pub struct FaultGuard {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        clear();
    }
}

/// Install a fault schedule for the duration of one test. Panics on an
/// invalid spec (tests author their own schedules).
pub fn install_for_test(spec: &str) -> FaultGuard {
    let guard = exclusive();
    install(&FaultSpec::parse(spec).expect("valid fault spec"));
    guard
}

/// Take the fault lock *without* installing anything — for tests that
/// run engines in the fault-tolerance suite and must not race an armed
/// sibling. Recovers from poisoning: a panicking test is normal here.
pub fn exclusive() -> FaultGuard {
    let lock = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    clear();
    FaultGuard { _lock: lock }
}

/// Hook compiled into `CpuBackend::prefill`. Fails the n-th call when
/// an `err_prefill` fault is armed.
#[inline]
pub fn prefill_hook() -> Result<()> {
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    prefill_hook_armed()
}

#[cold]
fn prefill_hook_armed() -> Result<()> {
    let n = PREFILL_CALLS.fetch_add(1, Ordering::Relaxed) + 1;
    let after = ERR_AFTER.load(Ordering::Relaxed);
    if after > 0 && n == after {
        PREFILL_ERRS_FIRED.fetch_add(1, Ordering::Relaxed);
        return Err(crate::err!(
            "fault injection: err_prefill fired at prefill call {n}"
        ));
    }
    Ok(())
}

/// Hook compiled into the CPU backend's decode-step cores. Sleeps when
/// `slow_step` is armed and panics on the n-th call when `panic_decode`
/// is armed (the panic crosses the replica's `catch_unwind`).
#[inline]
pub fn decode_hook() {
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    decode_hook_armed();
}

#[cold]
fn decode_hook_armed() {
    let n = DECODE_CALLS.fetch_add(1, Ordering::Relaxed) + 1;
    let slow = SLOW_MS.load(Ordering::Relaxed);
    if slow > 0 {
        std::thread::sleep(Duration::from_millis(slow));
    }
    let after = PANIC_AFTER.load(Ordering::Relaxed);
    if after > 0 && n == after {
        PANICS_FIRED.fetch_add(1, Ordering::Relaxed);
        panic!("fault injection: panic_decode fired at decode call {n}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_schedule() {
        let spec = FaultSpec::parse("panic_decode:3, err_prefill:1 ,slow_step:25").unwrap();
        assert_eq!(
            spec,
            FaultSpec {
                panic_decode: Some(3),
                err_prefill: Some(1),
                slow_step_ms: Some(25),
            }
        );
        assert!(FaultSpec::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultSpec::parse("panic_decode").is_err());
        assert!(FaultSpec::parse("panic_decode:x").is_err());
        assert!(FaultSpec::parse("eat_flaming_death:1").is_err());
    }

    // Trigger thresholds in lib-level tests are set beyond any call
    // count reachable while the guard is held, so a concurrently
    // running engine test can never trip them; firing semantics are
    // pinned in tests/fault_tolerance.rs, where every test serializes
    // on the same lock.
    #[test]
    fn guard_arms_and_clears() {
        {
            let _g = install_for_test("slow_step:0,panic_decode:18446744073709551615");
            assert!(armed());
            assert!(prefill_hook().is_ok());
            decode_hook(); // counts, must not fire at threshold u64::MAX
            assert!(stats().decode_calls >= 1);
            assert_eq!(stats().panics_fired, 0);
        }
        assert!(!armed(), "guard drop must clear the plan");
        let before = stats().decode_calls;
        decode_hook();
        assert_eq!(stats().decode_calls, before, "disarmed hook must not count");
    }

    #[test]
    fn exclusive_guard_installs_nothing() {
        let _g = exclusive();
        assert!(!armed());
        assert!(prefill_hook().is_ok());
        decode_hook();
    }
}
