//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! Provides seeded generators, a `forall` runner with failure-case
//! shrinking for the common shapes we need (vectors of floats, block
//! geometries), and assertion helpers. Deliberately tiny but real:
//! failures report the *shrunk* input and the reproducing seed.

pub mod faults;

use crate::util::rng::Pcg64;

/// A generator of random values of `T`.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Pcg64) -> T;
    /// Candidate simpler versions of a failing input (for shrinking).
    fn shrink(&self, value: &T) -> Vec<T> {
        let _ = value;
        Vec::new()
    }
}

/// Uniform f32 in [lo, hi).
pub struct F32Range(pub f32, pub f32);

impl Gen<f32> for F32Range {
    fn generate(&self, rng: &mut Pcg64) -> f32 {
        self.0 + rng.next_f32() * (self.1 - self.0)
    }
    fn shrink(&self, v: &f32) -> Vec<f32> {
        let mut out = Vec::new();
        if *v != 0.0 && self.0 <= 0.0 && self.1 > 0.0 {
            out.push(0.0);
            out.push(v / 2.0);
        }
        out
    }
}

/// usize in [lo, hi].
pub struct USizeRange(pub usize, pub usize);

impl Gen<usize> for USizeRange {
    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.0 + rng.next_below((self.1 - self.0 + 1) as u64) as usize
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
        }
        out.dedup();
        out
    }
}

/// Vector of Gaussian f32s with random scale, length in [1, max_len].
pub struct GaussianVec {
    pub max_len: usize,
    pub max_scale: f32,
}

impl Gen<Vec<f32>> for GaussianVec {
    fn generate(&self, rng: &mut Pcg64) -> Vec<f32> {
        let len = 1 + rng.next_below(self.max_len as u64) as usize;
        let scale = (rng.next_f32() * self.max_scale).max(1e-4);
        (0..len)
            .map(|_| rng.next_gaussian() as f32 * scale)
            .collect()
    }
    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[v.len() / 2..].to_vec());
        }
        if v.iter().any(|&x| x != 0.0) {
            out.push(vec![0.0; v.len()]);
        }
        out
    }
}

/// Outcome of a property check.
pub enum Prop {
    Pass,
    Fail(String),
}

impl Prop {
    pub fn check(cond: bool, msg: impl FnOnce() -> String) -> Prop {
        if cond {
            Prop::Pass
        } else {
            Prop::Fail(msg())
        }
    }
}

/// Run `prop` on `cases` random inputs; on failure, shrink and panic with
/// the smallest failing input found.
pub fn forall<T: Clone + std::fmt::Debug, G: Gen<T>>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: &G,
    prop: impl Fn(&T) -> Prop,
) {
    let mut rng = Pcg64::seed_from_u64(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if let Prop::Fail(msg) = prop(&input) {
            // shrink loop: greedily take any failing shrink candidate
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut progress = true;
            let mut rounds = 0;
            while progress && rounds < 64 {
                progress = false;
                rounds += 1;
                for cand in gen.shrink(&best) {
                    if let Prop::Fail(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        progress = true;
                        break;
                    }
                }
            }
            panic!(
                "property '{name}' failed (seed {seed}, case {case}):\n  \
                 input: {best:?}\n  reason: {best_msg}"
            );
        }
    }
}

/// Relative-or-absolute closeness assertion for float comparisons.
pub fn assert_close(a: f64, b: f64, rtol: f64, atol: f64, what: &str) {
    let diff = (a - b).abs();
    let tol = atol + rtol * b.abs().max(a.abs());
    assert!(
        diff <= tol,
        "{what}: {a} vs {b} (diff {diff:.3e} > tol {tol:.3e})"
    );
}

/// Max-abs-diff over slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("abs-nonneg", 1, 200, &F32Range(-5.0, 5.0), |x| {
            Prop::check(x.abs() >= 0.0, || "abs < 0".into())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn forall_reports_failure() {
        forall("always-fails", 2, 10, &USizeRange(1, 100), |_| {
            Prop::Fail("nope".into())
        });
    }

    #[test]
    fn shrinking_reduces_vec() {
        // Property: no vector longer than 3. Shrinker should find a short
        // one (len 4..=some small bound after halving).
        let gen = GaussianVec {
            max_len: 64,
            max_scale: 1.0,
        };
        let result = std::panic::catch_unwind(|| {
            forall("short-vecs", 3, 50, &gen, |v| {
                Prop::check(v.len() <= 3, || format!("len {}", v.len()))
            });
        });
        let err = *result.unwrap_err().downcast::<String>().unwrap();
        // extract the reported length; shrinking halves until <= 7
        let reported: usize = err
            .split("len ")
            .nth(1)
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert!(reported <= 8, "shrunk to {reported}: {err}");
    }

    #[test]
    fn usize_range_bounds() {
        let gen = USizeRange(3, 9);
        let mut rng = Pcg64::seed_from_u64(4);
        for _ in 0..100 {
            let v = gen.generate(&mut rng);
            assert!((3..=9).contains(&v));
        }
    }

    #[test]
    fn assert_close_accepts_and_rejects() {
        assert_close(1.0, 1.0 + 1e-9, 1e-6, 0.0, "close");
        let r = std::panic::catch_unwind(|| assert_close(1.0, 2.0, 1e-6, 0.0, "far"));
        assert!(r.is_err());
    }
}
