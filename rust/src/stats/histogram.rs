//! Fixed-bin histograms (distribution figures 4, 7, 8 and the OPQ
//! illustration benches).

/// Equal-width histogram over `[lo, hi)`.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub below: u64,
    pub above: u64,
    pub count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            below: 0,
            above: 0,
            count: 0,
        }
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.below += 1;
        } else if x >= self.hi {
            self.above += 1;
        } else {
            let n = self.bins.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    pub fn add_all<'a>(&mut self, xs: impl IntoIterator<Item = &'a f64>) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Probability density estimate per bin (normalized by count·binwidth).
    pub fn density(&self) -> Vec<f64> {
        let bw = (self.hi - self.lo) / self.bins.len() as f64;
        let n = self.count.max(1) as f64;
        self.bins.iter().map(|&c| c as f64 / (n * bw)).collect()
    }

    pub fn bin_centers(&self) -> Vec<f64> {
        let bw = (self.hi - self.lo) / self.bins.len() as f64;
        (0..self.bins.len())
            .map(|i| self.lo + (i as f64 + 0.5) * bw)
            .collect()
    }

    /// Render a crude console sparkline for reports.
    pub fn sparkline(&self, width: usize) -> String {
        const GLYPHS: &[char] = &[' ', '▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let step = (self.bins.len() as f64 / width as f64).max(1.0);
        let mut agg = Vec::with_capacity(width);
        let mut i = 0.0;
        while (i as usize) < self.bins.len() && agg.len() < width {
            let a = i as usize;
            let b = ((i + step) as usize).min(self.bins.len());
            agg.push(self.bins[a..b].iter().sum::<u64>());
            i += step;
        }
        let max = *agg.iter().max().unwrap_or(&1) as f64;
        agg.iter()
            .map(|&c| GLYPHS[((c as f64 / max.max(1.0)) * 8.0).round() as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn counts_and_overflow() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.add(-0.1);
        h.add(0.0);
        h.add(0.55);
        h.add(0.999);
        h.add(1.0);
        assert_eq!(h.below, 1);
        assert_eq!(h.above, 1);
        assert_eq!(h.count, 5);
        assert_eq!(h.bins[0], 1);
        assert_eq!(h.bins[5], 1);
        assert_eq!(h.bins[9], 1);
    }

    #[test]
    fn density_integrates_to_coverage() {
        let mut h = Histogram::new(-4.0, 4.0, 64);
        let mut rng = Pcg64::seed_from_u64(5);
        for _ in 0..50_000 {
            h.add(rng.next_gaussian());
        }
        let bw = 8.0 / 64.0;
        let total: f64 = h.density().iter().map(|d| d * bw).sum();
        assert!((total - 1.0).abs() < 0.01, "{total}"); // ~all mass in ±4
    }

    #[test]
    fn gaussian_shape_peak_at_center() {
        let mut h = Histogram::new(-4.0, 4.0, 16);
        let mut rng = Pcg64::seed_from_u64(6);
        for _ in 0..20_000 {
            h.add(rng.next_gaussian());
        }
        let d = h.density();
        let peak = d
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert!((7..=8).contains(&peak), "peak bin {peak}");
    }

    #[test]
    fn sparkline_len() {
        let mut h = Histogram::new(0.0, 1.0, 100);
        for i in 0..100 {
            for _ in 0..i {
                h.add(i as f64 / 100.0);
            }
        }
        let s = h.sparkline(20);
        assert_eq!(s.chars().count(), 20);
    }
}
