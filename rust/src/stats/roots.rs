//! Root finding: bisection (guaranteed) and Brent (fast), used by the MAE
//! centroid condition (paper eq. 7/59) and the OPQ threshold inversion.

/// Bisection on a sign-changing interval; returns the midpoint after the
/// interval shrinks below `xtol`.
pub fn bisect<F: Fn(f64) -> f64>(f: F, mut a: f64, mut b: f64, xtol: f64) -> Option<f64> {
    let mut fa = f(a);
    let fb = f(b);
    if fa == 0.0 {
        return Some(a);
    }
    if fb == 0.0 {
        return Some(b);
    }
    if fa.signum() == fb.signum() {
        return None;
    }
    for _ in 0..200 {
        let m = 0.5 * (a + b);
        let fm = f(m);
        if fm == 0.0 || (b - a).abs() < xtol {
            return Some(m);
        }
        if fm.signum() == fa.signum() {
            a = m;
            fa = fm;
        } else {
            b = m;
        }
    }
    Some(0.5 * (a + b))
}

/// Brent's method (inverse-quadratic + secant + bisection safeguards).
pub fn brent<F: Fn(f64) -> f64>(f: F, mut a: f64, mut b: f64, xtol: f64) -> Option<f64> {
    let mut fa = f(a);
    let mut fb = f(b);
    if fa == 0.0 {
        return Some(a);
    }
    if fb == 0.0 {
        return Some(b);
    }
    if fa.signum() == fb.signum() {
        return None;
    }
    if fa.abs() < fb.abs() {
        std::mem::swap(&mut a, &mut b);
        std::mem::swap(&mut fa, &mut fb);
    }
    let mut c = a;
    let mut fc = fa;
    let mut mflag = true;
    let mut d = 0.0;
    for _ in 0..200 {
        if (b - a).abs() < xtol || fb == 0.0 {
            return Some(b);
        }
        let mut s = if fa != fc && fb != fc {
            // inverse quadratic interpolation
            a * fb * fc / ((fa - fb) * (fa - fc))
                + b * fa * fc / ((fb - fa) * (fb - fc))
                + c * fa * fb / ((fc - fa) * (fc - fb))
        } else {
            b - fb * (b - a) / (fb - fa)
        };
        let lo = (3.0 * a + b) / 4.0;
        let cond = !((lo.min(b) < s && s < lo.max(b))
            && !(mflag && (s - b).abs() >= (b - c).abs() / 2.0)
            && !(!mflag && (s - b).abs() >= (c - d).abs() / 2.0));
        if cond {
            s = 0.5 * (a + b);
            mflag = true;
        } else {
            mflag = false;
        }
        let fs = f(s);
        d = c;
        c = b;
        fc = fb;
        if fa.signum() != fs.signum() {
            b = s;
            fb = fs;
        } else {
            a = s;
            fa = fs;
        }
        if fa.abs() < fb.abs() {
            std::mem::swap(&mut a, &mut b);
            std::mem::swap(&mut fa, &mut fb);
        }
    }
    Some(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bisect_finds_sqrt2() {
        let r = bisect(|x| x * x - 2.0, 0.0, 2.0, 1e-12).unwrap();
        assert!((r - std::f64::consts::SQRT_2).abs() < 1e-10);
    }

    #[test]
    fn bisect_rejects_no_sign_change() {
        assert!(bisect(|x| x * x + 1.0, -1.0, 1.0, 1e-12).is_none());
    }

    #[test]
    fn bisect_exact_endpoint() {
        assert_eq!(bisect(|x| x, 0.0, 1.0, 1e-12), Some(0.0));
    }

    #[test]
    fn brent_finds_cos_root() {
        let r = brent(|x| x.cos(), 0.0, 3.0, 1e-14).unwrap();
        assert!((r - std::f64::consts::FRAC_PI_2).abs() < 1e-10, "{r}");
    }

    #[test]
    fn brent_matches_bisect() {
        let f = |x: f64| x.exp() - 3.0;
        let rb = brent(f, 0.0, 2.0, 1e-13).unwrap();
        let ri = bisect(f, 0.0, 2.0, 1e-13).unwrap();
        assert!((rb - ri).abs() < 1e-9);
        assert!((rb - 3.0f64.ln()).abs() < 1e-10);
    }
}
