//! Distribution of block maxima and of normalized weights (paper App. B.1).
//!
//! For i.i.d. weights `W ~ N(0,1)` grouped into blocks of size `I`:
//!
//! - `M = max_i |W_i|` has CDF `F_M(m) = (2Φ(m) − 1)^I` (eq. 11) and pdf
//!   `p_M(m) = 2I (2Φ(m)−1)^{I−1} φ(m)` (eq. 12);
//! - the normalized weights `X = W / M` (or `W / M_signed`) have, for fixed
//!   `M = m`, the continuous conditional CDF
//!   `F_X^cont(x|m) = (Φ(mx) − Φ(−m)) / (2Φ(m) − 1)` (eq. 10);
//! - the full conditional CDF carries discrete mass `1/(2I)` at each of
//!   ±1 for absolute normalization (eq. 41), or `1/I` at +1 only for
//!   signed normalization (eq. 42).

use crate::stats::special::{folded_gauss_cdf, gauss_cdf, gauss_pdf, gauss_quantile};

/// Normalization mode for block-wise absmax quantization.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Norm {
    /// Divide by `max |w|` (NF4/AF4/BOF4; paper eq. 1).
    Absmax,
    /// Divide by the *signed* value of the absolutely-largest weight
    /// (BOF4-S; paper eq. 4).
    SignedAbsmax,
}

/// The distribution family of block maxima for unit-variance Gaussian
/// weights with block size `I`.
#[derive(Clone, Copy, Debug)]
pub struct BlockMax {
    pub block: usize,
}

impl BlockMax {
    pub fn new(block: usize) -> Self {
        assert!(block >= 2, "block size must be >= 2");
        BlockMax { block }
    }

    /// `F_M(m)` (eq. 11).
    pub fn cdf(&self, m: f64) -> f64 {
        folded_gauss_cdf(m).powi(self.block as i32)
    }

    /// `p_M(m)` (eq. 12).
    pub fn pdf(&self, m: f64) -> f64 {
        if m <= 0.0 {
            return 0.0;
        }
        2.0 * self.block as f64
            * folded_gauss_cdf(m).powi(self.block as i32 - 1)
            * gauss_pdf(m)
    }

    /// Quantile `F_M^{-1}(q)` — the OPQ outlier threshold (eq. 9):
    /// `F_M(m) = q  ⇔  2Φ(m) − 1 = q^{1/I}  ⇔  m = Φ⁻¹((q^{1/I} + 1)/2)`.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..1.0).contains(&q) && q > 0.0, "q in (0,1)");
        let r = q.powf(1.0 / self.block as f64);
        gauss_quantile((r + 1.0) / 2.0)
    }

    /// Expected value E[M] by quadrature (used in reports/illustrations).
    pub fn mean(&self) -> f64 {
        let gl = crate::stats::quadrature::GaussLegendre::new(64);
        gl.integrate_panels(|m| m * self.pdf(m), 0.0, 12.0, 12)
    }

    /// Practical upper integration limit: p_M mass above is < ~1e-16.
    pub fn upper_limit(&self) -> f64 {
        // F_|W|(m) = 1 - eps -> F_M ≈ exp(-I eps); want I*eps ~ 1e-16
        // erfc(m/√2) = eps/... just return a conservative bound:
        let mut m = 4.0;
        while 1.0 - self.cdf(m) > 1e-15 && m < 16.0 {
            m += 0.5;
        }
        m + 1.0
    }
}

/// Conditional CDF of normalized weights for fixed block max `m`:
/// continuous part only, `F_X^cont(x | M = m)` (eq. 10). `x ∈ [-1, 1]`.
pub fn fx_cont_given_m(x: f64, m: f64) -> f64 {
    debug_assert!(m > 0.0);
    let x = x.clamp(-1.0, 1.0);
    let denom = folded_gauss_cdf(m);
    if denom <= 0.0 {
        return 0.5; // degenerate m -> symmetric limit
    }
    ((gauss_cdf(m * x) - gauss_cdf(-m)) / denom).clamp(0.0, 1.0)
}

/// Full conditional CDF with the discrete endpoint mass (eqs. 41/42).
pub fn fx_given_m(x: f64, m: f64, block: usize, norm: Norm) -> f64 {
    let i = block as f64;
    if x < -1.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let cont = fx_cont_given_m(x, m);
    match norm {
        Norm::Absmax => 1.0 / (2.0 * i) + (i - 1.0) / i * cont,
        Norm::SignedAbsmax => (i - 1.0) / i * cont,
    }
}

/// Marginal CDF of normalized weights `F_X(x)` (eqs. 15–17), by quadrature
/// over `p_M`. Used for the Fig. 5 reproduction and for level-utilization
/// reports.
pub fn fx_marginal(x: f64, block: usize, norm: Norm) -> f64 {
    if x < -1.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let bm = BlockMax::new(block);
    let gl = crate::stats::quadrature::GaussLegendre::new(64);
    let hi = bm.upper_limit();
    let cont = gl.integrate_panels(|m| bm.pdf(m) * fx_cont_given_m(x, m), 1e-9, hi, 16);
    let i = block as f64;
    match norm {
        Norm::Absmax => 1.0 / (2.0 * i) + (i - 1.0) / i * cont,
        Norm::SignedAbsmax => (i - 1.0) / i * cont,
    }
}

/// Probability that a normalized weight falls in `[a, b)` (marginal).
pub fn px_region(a: f64, b: f64, block: usize, norm: Norm) -> f64 {
    let fa = if a <= -1.0 { 0.0 } else { fx_marginal(a, block, norm) };
    let fb = if b >= 1.0 { 1.0 } else { fx_marginal(b, block, norm) };
    (fb - fa).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn cdf_pdf_consistent() {
        let bm = BlockMax::new(64);
        // numeric derivative of F_M matches p_M
        for m in [1.5, 2.0, 2.5, 3.0] {
            let h = 1e-6;
            let d = (bm.cdf(m + h) - bm.cdf(m - h)) / (2.0 * h);
            assert!((d - bm.pdf(m)).abs() < 1e-6, "m={m}");
        }
    }

    #[test]
    fn quantile_inverts_cdf() {
        for block in [16, 64, 256] {
            let bm = BlockMax::new(block);
            for q in [0.5, 0.9, 0.95, 0.99] {
                let m = bm.quantile(q);
                assert!((bm.cdf(m) - q).abs() < 1e-10, "I={block} q={q}");
            }
        }
    }

    #[test]
    fn quantile_matches_monte_carlo() {
        // F_M^{-1}(0.95) for I = 64 — the OPQ threshold constant shared
        // with the python fixture generator (aot.py).
        let bm = BlockMax::new(64);
        let thr = bm.quantile(0.95);
        assert!((thr - 3.352_401_773_130_375).abs() < 1e-12, "thr={thr}");

        let mut rng = Pcg64::seed_from_u64(4);
        let trials = 20_000;
        let mut below = 0;
        for _ in 0..trials {
            let mx = (0..64)
                .map(|_| rng.next_gaussian().abs())
                .fold(0.0f64, f64::max);
            if mx <= thr {
                below += 1;
            }
        }
        let frac = below as f64 / trials as f64;
        assert!((frac - 0.95).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn mean_increases_with_block() {
        let m16 = BlockMax::new(16).mean();
        let m64 = BlockMax::new(64).mean();
        let m256 = BlockMax::new(256).mean();
        assert!(m16 < m64 && m64 < m256);
        // E[max of 64 |N(0,1)|] ≈ 2.596 (Monte-Carlo cross-checked)
        assert!((m64 - 2.596).abs() < 0.01, "{m64}");
        assert!((m16 - 2.077).abs() < 0.01, "{m16}");
    }

    #[test]
    fn fx_cont_bounds_and_symmetry() {
        for m in [1.0, 2.5, 4.0] {
            assert!(fx_cont_given_m(-1.0, m).abs() < 1e-12);
            assert!((fx_cont_given_m(1.0, m) - 1.0).abs() < 1e-12);
            // symmetric distribution: F(0) = 1/2
            assert!((fx_cont_given_m(0.0, m) - 0.5).abs() < 1e-12);
            // symmetry F(-x) = 1 - F(x)
            for x in [0.2, 0.6, 0.9] {
                let s = fx_cont_given_m(-x, m) + fx_cont_given_m(x, m);
                assert!((s - 1.0).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn fx_full_endpoint_mass() {
        let i = 64usize;
        // Just below +1, absolute normalization: 1 - 1/(2I) of mass seen.
        let v = fx_given_m(1.0 - 1e-12, 3.0, i, Norm::Absmax);
        assert!((v - (1.0 - 1.0 / (2.0 * i as f64))).abs() < 1e-6, "{v}");
        // signed: 1 - 1/I below +1, no mass at -1.
        let v = fx_given_m(1.0 - 1e-12, 3.0, i, Norm::SignedAbsmax);
        assert!((v - (1.0 - 1.0 / i as f64)).abs() < 1e-6, "{v}");
        let v = fx_given_m(-1.0, 3.0, i, Norm::SignedAbsmax);
        assert!(v < 1e-9, "{v}");
        // absolute: mass 1/(2I) sits at exactly -1.
        let v = fx_given_m(-1.0, 3.0, i, Norm::Absmax);
        assert!((v - 1.0 / (2.0 * i as f64)).abs() < 1e-9, "{v}");
    }

    #[test]
    fn fx_marginal_matches_monte_carlo() {
        let block = 16;
        let mut rng = Pcg64::seed_from_u64(99);
        let trials = 40_000;
        let mut cnt = 0usize;
        let x0 = 0.3;
        for _ in 0..trials {
            let w: Vec<f64> = (0..block).map(|_| rng.next_gaussian()).collect();
            let mx = w.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
            for &wi in &w {
                if wi / mx <= x0 {
                    cnt += 1;
                }
            }
        }
        let emp = cnt as f64 / (trials * block) as f64;
        let theo = fx_marginal(x0, block, Norm::Absmax);
        assert!((emp - theo).abs() < 0.01, "emp={emp} theo={theo}");
    }

    #[test]
    fn px_region_sums_to_one() {
        let edges = [-1.0, -0.5, -0.1, 0.0, 0.2, 0.7, 1.0];
        for norm in [Norm::Absmax, Norm::SignedAbsmax] {
            // The region ending at b = 1.0 maps to F = 1, so the discrete
            // endpoint masses are included; the partition must sum to 1.
            let total: f64 = edges
                .windows(2)
                .map(|w| px_region(w[0], w[1], 64, norm))
                .sum::<f64>();
            assert!((total - 1.0).abs() < 1e-6, "{norm:?} {total}");
        }
    }
}
