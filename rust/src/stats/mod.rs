//! Statistical substrate for quantizer design and OPQ.
//!
//! Everything the paper's Appendix B needs, built from scratch (no scipy,
//! no statrs in the offline image):
//!
//! - [`special`]: erf / erfc / Gaussian pdf-cdf-quantile in double precision
//! - [`blockmax`]: the distribution of (absolute) block maxima `M` —
//!   `F_M = F_|W|^I` (paper eq. 11), `p_M` (eq. 12), its quantile function
//!   (used by OPQ eq. 9), and the conditional normalized-weight CDF `F_X`
//!   (eqs. 10, 41, 42)
//! - [`quadrature`]: adaptive Simpson + Gauss-Legendre integration
//! - [`roots`]: bisection / Brent root finding (for the MAE centroid eq. 7)
//! - [`histogram`]: fixed-bin histograms for the distribution figures

pub mod blockmax;
pub mod histogram;
pub mod quadrature;
pub mod roots;
pub mod special;
