//! Special functions in double precision: erf/erfc and the standard
//! Gaussian pdf φ, cdf Φ, and quantile Φ⁻¹.
//!
//! Implementation notes: erf uses its Maclaurin series for small arguments
//! (alternating, fast convergence for |x| ≲ 2.5) and a modified-Lentz
//! continued fraction for erfc at large arguments; the two agree to
//! ~1e-14 on the switchover. Φ⁻¹ uses a Hastings-style initial guess
//! refined by Newton steps on Φ (quadratic convergence; ≤ 6 iterations).

use std::f64::consts::{FRAC_2_SQRT_PI, PI};

const SQRT_2: f64 = std::f64::consts::SQRT_2;
const INV_SQRT_2PI: f64 = 0.398_942_280_401_432_7; // 1/sqrt(2π)

/// Error function, |error| ≲ 1e-14.
pub fn erf(x: f64) -> f64 {
    if x < 0.0 {
        return -erf(-x);
    }
    if x < 2.5 {
        erf_series(x)
    } else {
        1.0 - erfc_cf(x)
    }
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    if x < 2.5 {
        1.0 - erf_series(x)
    } else {
        erfc_cf(x)
    }
}

/// Maclaurin series: erf(x) = 2/√π Σ (-1)^n x^(2n+1) / (n! (2n+1)).
fn erf_series(x: f64) -> f64 {
    let x2 = x * x;
    let mut term = x; // x^(2n+1)/n!
    let mut sum = x;
    for n in 1..200 {
        term *= -x2 / n as f64;
        let add = term / (2.0 * n as f64 + 1.0);
        sum += add;
        if add.abs() < 1e-17 * sum.abs().max(1e-300) {
            break;
        }
    }
    FRAC_2_SQRT_PI * sum
}

/// Continued fraction for erfc (x ≥ ~2), evaluated by backward recurrence:
/// erfc(x) = exp(-x²)/√π · 1/(x + (1/2)/(x + 1/(x + (3/2)/(x + ...)))).
/// Depth 80 is far past convergence for x ≥ 2 (terms shrink like (n/2)/x²).
fn erfc_cf(x: f64) -> f64 {
    let mut f = x;
    for n in (1..=80).rev() {
        f = x + (n as f64 / 2.0) / f;
    }
    (-x * x).exp() / PI.sqrt() / f
}

/// Standard Gaussian density φ(x).
#[inline]
pub fn gauss_pdf(x: f64) -> f64 {
    INV_SQRT_2PI * (-0.5 * x * x).exp()
}

/// Standard Gaussian CDF Φ(x).
#[inline]
pub fn gauss_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / SQRT_2)
}

/// Standard Gaussian quantile Φ⁻¹(p), p ∈ (0, 1).
pub fn gauss_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "quantile needs p in (0,1), got {p}");
    // Hastings initial guess for the lower tail, reflected for the upper.
    let (pp, sign) = if p < 0.5 { (p, -1.0) } else { (1.0 - p, 1.0) };
    let t = (-2.0 * pp.ln()).sqrt();
    let mut x = sign
        * (t - (2.30753 + 0.27061 * t) / (1.0 + 0.99229 * t + 0.04481 * t * t));
    // Newton refinement on Φ(x) - p = 0.
    for _ in 0..8 {
        let err = gauss_cdf(x) - p;
        let d = gauss_pdf(x);
        if d <= 0.0 {
            break;
        }
        let step = err / d;
        x -= step;
        if step.abs() < 1e-14 * (1.0 + x.abs()) {
            break;
        }
    }
    x
}

/// CDF of |W| for W ~ N(0,1): F_|W|(m) = 2Φ(m) − 1 (paper eq. 13).
#[inline]
pub fn folded_gauss_cdf(m: f64) -> f64 {
    if m <= 0.0 {
        0.0
    } else {
        2.0 * gauss_cdf(m) - 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values from standard tables (15 significant digits).
    const ERF_TABLE: &[(f64, f64)] = &[
        (0.0, 0.0),
        (0.1, 0.112462916018285),
        (0.5, 0.520499877813047),
        (1.0, 0.842700792949715),
        (1.5, 0.966105146475311),
        (2.0, 0.995322265018953),
        (2.5, 0.999593047982555),
        (3.0, 0.999977909503001),
        (4.0, 0.999999984582742),
    ];

    #[test]
    fn erf_matches_tables() {
        for &(x, want) in ERF_TABLE {
            let got = erf(x);
            assert!(
                (got - want).abs() < 1e-13,
                "erf({x}) = {got}, want {want}"
            );
            assert!((erf(-x) + want).abs() < 1e-13, "odd symmetry at {x}");
        }
    }

    #[test]
    fn erfc_complements_erf() {
        for x in [-3.0, -1.0, 0.0, 0.5, 2.0, 2.4999, 2.5001, 5.0] {
            assert!((erf(x) + erfc(x) - 1.0).abs() < 1e-13, "x={x}");
        }
    }

    #[test]
    fn erfc_tail_accuracy() {
        // erfc(3) = 2.209049699858544e-5 ; erfc(5) = 1.537459794428035e-12
        assert!((erfc(3.0) / 2.209_049_699_858_544e-5 - 1.0).abs() < 1e-10);
        assert!((erfc(5.0) / 1.537_459_794_428_035e-12 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cdf_known_points() {
        assert!((gauss_cdf(0.0) - 0.5).abs() < 1e-15);
        assert!((gauss_cdf(1.0) - 0.841344746068543).abs() < 1e-13);
        assert!((gauss_cdf(-1.959963984540054) - 0.025).abs() < 1e-12);
    }

    #[test]
    fn quantile_inverts_cdf() {
        for p in [1e-6, 0.001, 0.025, 0.2, 0.5, 0.8, 0.95, 0.999, 1.0 - 1e-6] {
            let x = gauss_quantile(p);
            assert!((gauss_cdf(x) - p).abs() < 1e-12, "p={p} x={x}");
        }
        assert!((gauss_quantile(0.975) - 1.959963984540054).abs() < 1e-10);
    }

    #[test]
    fn pdf_integrates_to_cdf() {
        // Simple trapezoid check: ∫φ over [-1,1] = Φ(1)-Φ(-1)
        let n = 100_000;
        let h = 2.0 / n as f64;
        let mut s = 0.5 * (gauss_pdf(-1.0) + gauss_pdf(1.0));
        for i in 1..n {
            s += gauss_pdf(-1.0 + i as f64 * h);
        }
        s *= h;
        assert!((s - (gauss_cdf(1.0) - gauss_cdf(-1.0))).abs() < 1e-9);
    }

    #[test]
    fn folded_cdf_properties() {
        assert_eq!(folded_gauss_cdf(-1.0), 0.0);
        assert_eq!(folded_gauss_cdf(0.0), 0.0);
        assert!((folded_gauss_cdf(1.0) - 0.682689492137086).abs() < 1e-12);
        assert!(folded_gauss_cdf(10.0) <= 1.0);
    }
}
