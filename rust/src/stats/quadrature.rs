//! Numerical integration: adaptive Simpson and Gauss-Legendre.
//!
//! The theoretical centroid updates (paper eqs. 5/7/35/59) integrate smooth,
//! rapidly-decaying functions of the block maximum m over (0, ∞). The mass
//! of `p_M` for block sizes 2..2¹² lives well inside [0, 8]; integrands are
//! C^∞ there, so fixed-order Gauss-Legendre on a truncated interval
//! converges spectrally. Adaptive Simpson is the general-purpose fallback
//! (and the cross-check in tests).

/// Adaptive Simpson with absolute tolerance `tol` on `[a, b]`.
pub fn adaptive_simpson<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64, tol: f64) -> f64 {
    let c = 0.5 * (a + b);
    let fa = f(a);
    let fb = f(b);
    let fc = f(c);
    let whole = simpson(a, b, fa, fc, fb);
    simpson_rec(f, a, b, fa, fb, fc, whole, tol, 40)
}

#[inline]
fn simpson(a: f64, b: f64, fa: f64, fc: f64, fb: f64) -> f64 {
    (b - a) / 6.0 * (fa + 4.0 * fc + fb)
}

#[allow(clippy::too_many_arguments)]
fn simpson_rec<F: Fn(f64) -> f64>(
    f: &F,
    a: f64,
    b: f64,
    fa: f64,
    fb: f64,
    fc: f64,
    whole: f64,
    tol: f64,
    depth: u32,
) -> f64 {
    let c = 0.5 * (a + b);
    let d = 0.5 * (a + c);
    let e = 0.5 * (c + b);
    let fd = f(d);
    let fe = f(e);
    let left = simpson(a, c, fa, fd, fc);
    let right = simpson(c, b, fc, fe, fb);
    let err = left + right - whole;
    if depth == 0 || err.abs() <= 15.0 * tol {
        left + right + err / 15.0
    } else {
        simpson_rec(f, a, c, fa, fc, fd, left, tol / 2.0, depth - 1)
            + simpson_rec(f, c, b, fc, fb, fe, right, tol / 2.0, depth - 1)
    }
}

/// Gauss-Legendre nodes/weights on [-1, 1], computed by Newton iteration on
/// P_n (no coefficient tables needed; accurate to machine precision).
pub struct GaussLegendre {
    pub nodes: Vec<f64>,
    pub weights: Vec<f64>,
}

impl GaussLegendre {
    pub fn new(n: usize) -> Self {
        assert!(n >= 2);
        let mut nodes = vec![0.0; n];
        let mut weights = vec![0.0; n];
        let m = n.div_ceil(2);
        for i in 0..m {
            // Initial guess: Chebyshev-like
            let mut x = (std::f64::consts::PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
            let mut dp = 0.0;
            for _ in 0..100 {
                // Evaluate P_n(x) and P'_n(x) by recurrence.
                let mut p0 = 1.0;
                let mut p1 = x;
                for k in 2..=n {
                    let kf = k as f64;
                    let p2 = ((2.0 * kf - 1.0) * x * p1 - (kf - 1.0) * p0) / kf;
                    p0 = p1;
                    p1 = p2;
                }
                dp = n as f64 * (x * p1 - p0) / (x * x - 1.0);
                let dx = p1 / dp;
                x -= dx;
                if dx.abs() < 1e-15 {
                    break;
                }
            }
            nodes[i] = -x;
            nodes[n - 1 - i] = x;
            let w = 2.0 / ((1.0 - x * x) * dp * dp);
            weights[i] = w;
            weights[n - 1 - i] = w;
        }
        GaussLegendre { nodes, weights }
    }

    /// Integrate `f` over `[a, b]` with this rule.
    pub fn integrate<F: Fn(f64) -> f64>(&self, f: F, a: f64, b: f64) -> f64 {
        let half = 0.5 * (b - a);
        let mid = 0.5 * (a + b);
        self.nodes
            .iter()
            .zip(&self.weights)
            .map(|(&x, &w)| w * f(mid + half * x))
            .sum::<f64>()
            * half
    }

    /// Integrate over `[a, b]` split into `panels` equal panels (composite
    /// rule; robust when the integrand is sharply peaked).
    pub fn integrate_panels<F: Fn(f64) -> f64>(
        &self,
        f: F,
        a: f64,
        b: f64,
        panels: usize,
    ) -> f64 {
        let h = (b - a) / panels as f64;
        (0..panels)
            .map(|i| {
                let lo = a + i as f64 * h;
                self.integrate(&f, lo, lo + h)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::special::{gauss_cdf, gauss_pdf};

    #[test]
    fn simpson_polynomial_exact() {
        // Simpson is exact for cubics
        let f = |x: f64| 3.0 * x * x * x - x + 2.0;
        let got = adaptive_simpson(&f, -1.0, 2.0, 1e-12);
        // ∫ = 3/4 x^4 - x²/2 + 2x over [-1,2] = (12-2+4)-(0.75-0.5-2)=15.75
        assert!((got - 15.75).abs() < 1e-10, "{got}");
    }

    #[test]
    fn simpson_gaussian_mass() {
        let got = adaptive_simpson(&gauss_pdf, -8.0, 8.0, 1e-12);
        assert!((got - 1.0).abs() < 1e-10, "{got}");
    }

    #[test]
    fn gl_nodes_symmetric_weights_sum() {
        for n in [4, 16, 32, 64] {
            let gl = GaussLegendre::new(n);
            let wsum: f64 = gl.weights.iter().sum();
            assert!((wsum - 2.0).abs() < 1e-12, "n={n} wsum={wsum}");
            for i in 0..n {
                assert!((gl.nodes[i] + gl.nodes[n - 1 - i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gl_high_degree_exactness() {
        // n-point GL integrates degree 2n-1 polynomials exactly.
        let gl = GaussLegendre::new(8);
        let f = |x: f64| x.powi(15) + 2.0 * x.powi(14);
        // over [-1,1]: odd term 0; 2·(2/15)
        let got = gl.integrate(f, -1.0, 1.0);
        assert!((got - 4.0 / 15.0).abs() < 1e-13, "{got}");
    }

    #[test]
    fn gl_matches_simpson_on_cdf_integral() {
        let f = |m: f64| gauss_cdf(m) * gauss_pdf(m) * m;
        let gl = GaussLegendre::new(64);
        let a = gl.integrate_panels(f, 0.0, 8.0, 8);
        let b = adaptive_simpson(&f, 0.0, 8.0, 1e-13);
        assert!((a - b).abs() < 1e-11, "{a} vs {b}");
    }
}
