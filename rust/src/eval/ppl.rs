//! Held-out perplexity via the AOT'd `lm_nll` graph (the WikiText-2 /
//! LAMBADA stand-in; same mechanism, different corpus), plus the
//! decode-path variant that scores the same tokens through the KV-cached
//! serving protocol — the probe for `BOF4_KV` cache-quantization
//! degradation.

use crate::error::Result;
use crate::models::{Corpus, ParamSet};
use crate::quant::KvFormat;
use crate::runtime::{HostTensor, Runtime};

/// Perplexity evaluation configuration.
#[derive(Clone, Copy, Debug)]
pub struct PplConfig {
    /// Number of eval batches (each `batch × seq_len` tokens).
    pub batches: usize,
    pub corpus_tokens: usize,
    pub corpus_seed: u64,
}

impl Default for PplConfig {
    fn default() -> Self {
        PplConfig {
            batches: 24,
            corpus_tokens: 400_000,
            corpus_seed: 2024,
        }
    }
}

/// Compute held-out perplexity of `params` (natural-log PPL = exp(mean NLL
/// per token), the paper's convention).
pub fn perplexity(rt: &Runtime, params: &ParamSet, cfg: &PplConfig) -> Result<f64> {
    let m = rt.meta.model.clone();
    let corpus = Corpus::generate(cfg.corpus_tokens, cfg.corpus_seed);
    let (_, eval_split) = corpus.split(0.9);

    let tensors = params.to_tensors();
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0usize;
    for step in 0..cfg.batches {
        let tokens = corpus.batch(eval_split, m.batch, m.seq_len, step);
        let mut args = tensors.clone();
        args.push(HostTensor::i32(tokens, vec![m.batch, m.seq_len]));
        let out = rt.run("lm_nll", &args)?;
        let nll = out[0].as_f32()?;
        total_nll += nll.iter().map(|&x| x as f64).sum::<f64>();
        total_tokens += m.batch * (m.seq_len - 1);
    }
    Ok((total_nll / total_tokens as f64).exp())
}

/// Teacher-forced perplexity through the KV-cached decode path at an
/// explicit cache format — the probe for `BOF4_KV` quantization
/// degradation. Each eval row is prefixed on its first token, then
/// advanced one `lm_decode_step` at a time with the **ground-truth**
/// token teacher-forced in (greedy sampling never diverges the context),
/// scoring every next-token prediction; K/V rows therefore pass through
/// the format's quantize-at-append / fused-dequant-attention cycle at
/// every position, exactly as in serving. At
/// [`KvFormat::F32`] this equals [`perplexity`] up to the
/// full-forward-vs-decode execution order (bit-identical on the CPU
/// backend, same token count either way); at `Q8`/`Q4` the difference
/// **is** the cache-quantization degradation. Needs a backend with the
/// in-place decode protocol.
pub fn kv_decode_perplexity(
    rt: &Runtime,
    params: &ParamSet,
    kv: KvFormat,
    cfg: &PplConfig,
) -> Result<f64> {
    use crate::models::corpus::TOK_SPACE;
    let m = rt.meta.model.clone();
    let (b, s, v, d) = (m.batch, m.seq_len, m.vocab, m.d_model);
    let corpus = Corpus::generate(cfg.corpus_tokens, cfg.corpus_seed);
    let (_, eval_split) = corpus.split(0.9);
    let tensors = params.to_tensors();

    let mut total_nll = 0.0f64;
    let mut total_tokens = 0usize;
    for step in 0..cfg.batches {
        let tokens = corpus.batch(eval_split, b, s, step);
        let mut state = rt
            .alloc_decode_state_fmt("lm_decode_step", kv)?
            .ok_or_else(|| {
                crate::err!(
                    "backend {} has no in-place decode state; the KV \
                     perplexity eval needs it",
                    rt.platform()
                )
            })?;
        // prefill every row on its first token only (len = 1), scatter
        // the returned K/V rows into the resident state — the same
        // admission move the serving engine makes
        let mut ptoks = vec![TOK_SPACE as i32; b * s];
        for i in 0..b {
            ptoks[i * s] = tokens[i * s];
        }
        let mut args = tensors.clone();
        args.push(HostTensor::i32(ptoks, vec![b, s]));
        args.push(HostTensor::i32(vec![1i32; b], vec![b]));
        let out = rt.run("lm_prefill", &args)?;
        let row = s * d;
        for c in 0..2 * m.n_layers {
            let src = out[1 + c].as_f32()?;
            for i in 0..b {
                state.load_slot(c, i, &src[i * row..(i + 1) * row])?;
            }
        }
        // logits predict position p; teacher-force token p in, repeat
        let mut logits = out[0].as_f32()?.to_vec();
        for p in 1..s {
            for i in 0..b {
                let target = tokens[i * s + p] as usize;
                total_nll += nll_one(&logits[i * v..(i + 1) * v], target);
                total_tokens += 1;
            }
            if p == s - 1 {
                break;
            }
            let tok: Vec<i32> = (0..b).map(|i| tokens[i * s + p]).collect();
            let mut dargs = tensors.clone();
            dargs.push(HostTensor::i32(tok, vec![b]));
            dargs.push(HostTensor::i32(vec![p as i32; b], vec![b]));
            let dout = rt.run_decode_step_inplace("lm_decode_step", state.as_mut(), &dargs)?;
            logits = dout[0].as_f32()?.to_vec();
        }
    }
    Ok((total_nll / total_tokens as f64).exp())
}

/// `-log softmax(logits)[target]`, accumulated in f64 with the usual
/// max-subtraction for stability.
fn nll_one(logits: &[f32], target: usize) -> f64 {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let sum: f64 = logits.iter().map(|&x| (x as f64 - max).exp()).sum();
    max + sum.ln() - logits[target] as f64
}

/// Perplexity + the (MAE, MSE) of the quantized weights vs the originals —
/// the per-row contents of paper Tables 1 and 9.
pub fn ppl_and_error(
    rt: &Runtime,
    original: &ParamSet,
    quantized: &ParamSet,
    cfg: &PplConfig,
) -> Result<(f64, f64, f64)> {
    let ppl = perplexity(rt, quantized, cfg)?;
    let mut all_orig = Vec::new();
    let mut all_quant = Vec::new();
    for ((_, _, o), (_, _, q)) in original.entries.iter().zip(&quantized.entries) {
        all_orig.extend_from_slice(o);
        all_quant.extend_from_slice(q);
    }
    let mae = crate::quant::error::mae(&all_orig, &all_quant);
    let mse = crate::quant::error::mse(&all_orig, &all_quant);
    Ok((mae, mse, ppl))
}
