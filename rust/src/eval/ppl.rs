//! Held-out perplexity via the AOT'd `lm_nll` graph (the WikiText-2 /
//! LAMBADA stand-in; same mechanism, different corpus).

use crate::error::Result;
use crate::models::{Corpus, ParamSet};
use crate::runtime::{HostTensor, Runtime};

/// Perplexity evaluation configuration.
#[derive(Clone, Copy, Debug)]
pub struct PplConfig {
    /// Number of eval batches (each `batch × seq_len` tokens).
    pub batches: usize,
    pub corpus_tokens: usize,
    pub corpus_seed: u64,
}

impl Default for PplConfig {
    fn default() -> Self {
        PplConfig {
            batches: 24,
            corpus_tokens: 400_000,
            corpus_seed: 2024,
        }
    }
}

/// Compute held-out perplexity of `params` (natural-log PPL = exp(mean NLL
/// per token), the paper's convention).
pub fn perplexity(rt: &Runtime, params: &ParamSet, cfg: &PplConfig) -> Result<f64> {
    let m = rt.meta.model.clone();
    let corpus = Corpus::generate(cfg.corpus_tokens, cfg.corpus_seed);
    let (_, eval_split) = corpus.split(0.9);

    let tensors = params.to_tensors();
    let mut total_nll = 0.0f64;
    let mut total_tokens = 0usize;
    for step in 0..cfg.batches {
        let tokens = corpus.batch(eval_split, m.batch, m.seq_len, step);
        let mut args = tensors.clone();
        args.push(HostTensor::i32(tokens, vec![m.batch, m.seq_len]));
        let out = rt.run("lm_nll", &args)?;
        let nll = out[0].as_f32()?;
        total_nll += nll.iter().map(|&x| x as f64).sum::<f64>();
        total_tokens += m.batch * (m.seq_len - 1);
    }
    Ok((total_nll / total_tokens as f64).exp())
}

/// Perplexity + the (MAE, MSE) of the quantized weights vs the originals —
/// the per-row contents of paper Tables 1 and 9.
pub fn ppl_and_error(
    rt: &Runtime,
    original: &ParamSet,
    quantized: &ParamSet,
    cfg: &PplConfig,
) -> Result<(f64, f64, f64)> {
    let ppl = perplexity(rt, quantized, cfg)?;
    let mut all_orig = Vec::new();
    let mut all_quant = Vec::new();
    for ((_, _, o), (_, _, q)) in original.entries.iter().zip(&quantized.entries) {
        all_orig.extend_from_slice(o);
        all_quant.extend_from_slice(q);
    }
    let mae = crate::quant::error::mae(&all_orig, &all_quant);
    let mse = crate::quant::error::mse(&all_orig, &all_quant);
    Ok((mae, mse, ppl))
}
