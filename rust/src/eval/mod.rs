//! Evaluation harness: perplexity, task accuracies, training drivers, and
//! report generation — everything the paper's experiment section needs.
//!
//! - [`trainer`]: pre-train the in-repo LM via the AOT'd `train_step`
//!   graph (cached in `artifacts/trained_model.wbin`)
//! - [`ppl`]: held-out perplexity via `lm_nll`
//! - [`quantized`]: quantize a trained [`ParamSet`](crate::models::ParamSet) with any
//!   [`crate::quant::QuantConfig`] and rebuild eval tensors, or pack the
//!   serving engine's end-to-end q4 + double-quantized representation
//!   ([`quantize_for_serving`])
//! - [`artifact`]: versioned on-disk serialization of serving parameter
//!   sets (dense or q4+OPQ), with an optional RLE compressed-at-rest
//!   variant — pack once, reload near-zero-copy into the engine's
//!   shared weight set
//! - [`lora`]: QLoRA-style fine-tuning via `lora_step` (Tables 3/4 proxy)
//! - [`tasks`]: synthetic multiple-choice suite + NAV ACC (eq. 74) and the
//!   two fine-tuning tasks (instruction echo / bracket code)
//! - [`report`]: markdown/CSV table writers into `results/`

pub mod artifact;
pub mod lora;
pub mod ppl;
pub mod quantized;
pub mod report;
pub mod tasks;
pub mod trainer;

pub use artifact::{load_artifact, save_artifact, ArtifactInfo, ArtifactKind, SaveOptions};
pub use ppl::{kv_decode_perplexity, perplexity};
pub use quantized::{
    dense_from_q4_prefix, quantize_for_serving, quantize_params, QuantizedServingParams,
};
pub use trainer::ensure_trained;
