//! Report writers: markdown tables + CSV series into `results/`.
//!
//! Every bench regenerates its paper table/figure through these, so the
//! repository's outputs are diffable run-to-run.

use std::path::PathBuf;

use crate::error::{Context, Result};

/// A simple column-aligned markdown table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as github-flavoured markdown.
    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .header
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Render as a JSON object (`{"title", "header", "rows"}`) so table
    /// baselines are machine-diffable under `results/`. Serialization
    /// goes through [`crate::util::json::Json`], which escapes control
    /// characters correctly.
    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        let str_arr = |cells: &[String]| -> Json {
            Json::Arr(cells.iter().map(|c| Json::Str(c.clone())).collect())
        };
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("title".to_string(), Json::Str(self.title.clone()));
        obj.insert("header".to_string(), str_arr(&self.header));
        obj.insert(
            "rows".to_string(),
            Json::Arr(self.rows.iter().map(|r| str_arr(r)).collect()),
        );
        let mut out = Json::Obj(obj).to_string();
        out.push('\n');
        out
    }

    /// Print to stdout and persist the markdown/CSV/JSON renderings under
    /// `results/`.
    pub fn emit(&self, stem: &str) -> Result<()> {
        // lint: allow(stdout-in-lib): printing the table is this API's job
        println!("{}", self.to_markdown());
        let dir = results_dir();
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join(format!("{stem}.md")), self.to_markdown())
            .with_context(|| format!("writing {stem}.md"))?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())
            .with_context(|| format!("writing {stem}.csv"))?;
        std::fs::write(dir.join(format!("{stem}.json")), self.to_json())
            .with_context(|| format!("writing {stem}.json"))?;
        Ok(())
    }
}

/// Locate `results/` next to the artifacts dir (works from any cwd).
pub fn results_dir() -> PathBuf {
    let art = crate::runtime::Meta::default_dir();
    art.parent()
        .map(|p| p.join("results"))
        .unwrap_or_else(|| PathBuf::from("results"))
}

/// Write an x/y series as CSV (figure reproductions).
pub fn write_series(stem: &str, xlabel: &str, series: &[(&str, Vec<(f64, f64)>)]) -> Result<()> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let mut out = format!("{xlabel},series,value\n");
    for (name, points) in series {
        for (x, y) in points {
            out.push_str(&format!("{x},{name},{y}\n"));
        }
    }
    std::fs::write(dir.join(format!("{stem}.csv")), out)?;
    Ok(())
}

/// Console ASCII plot of one or more series (log-x), for bench output.
pub fn ascii_plot(title: &str, series: &[(&str, Vec<(f64, f64)>)], height: usize) -> String {
    let mut all_y: Vec<f64> = series
        .iter()
        .flat_map(|(_, pts)| pts.iter().map(|p| p.1))
        .collect();
    all_y.retain(|y| y.is_finite());
    if all_y.is_empty() {
        return format!("{title}: (no data)\n");
    }
    let (ymin, ymax) = all_y
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(a, b), &y| {
            (a.min(y), b.max(y))
        });
    let span = (ymax - ymin).max(1e-12);
    let width = series.iter().map(|(_, p)| p.len()).max().unwrap_or(0);
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width * 3]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        for (xi, (_, y)) in pts.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let row = ((ymax - y) / span * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][xi * 3 + 1] = marks[si % marks.len()];
        }
    }
    let mut out = format!("{title}  [{ymin:.4} .. {ymax:.4}]\n");
    for row in grid {
        out.push_str(&row.into_iter().collect::<String>());
        out.push('\n');
    }
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (n, _))| format!("{} {}", marks[i % marks.len()], n))
        .collect();
    out.push_str(&legend.join("   "));
    out.push('\n');
    out
}

/// Save a markdown section (appending) into results/summary.md.
pub fn append_summary(section: &str) -> Result<()> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("summary.md");
    let mut existing = std::fs::read_to_string(&path).unwrap_or_default();
    existing.push_str(section);
    existing.push('\n');
    std::fs::write(path, existing)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_rendering() {
        let mut t = Table::new("Demo", &["method", "mse"]);
        t.row(vec!["NF4".into(), "1.637".into()]);
        t.row(vec!["BOF4-S (MSE)".into(), "1.441".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| NF4"));
        assert!(md.lines().count() >= 5);
    }

    #[test]
    fn json_rendering_escapes() {
        let mut t = Table::new("Ti\"tle", &["a", "b"]);
        t.row(vec!["x\"y".into(), "multi\nline\tcell".into()]);
        let j = t.to_json();
        assert!(j.contains("\"title\":\"Ti\\\"tle\""), "{j}");
        assert!(j.contains("\"x\\\"y\""), "{j}");
        assert!(j.contains("\"multi\\nline\\tcell\""), "{j}");
        assert!(j.contains("\"rows\":[["), "{j}");
        // and it parses back
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.get("title").unwrap().as_str(), Some("Ti\"tle"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn rejects_wrong_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn ascii_plot_renders() {
        let pts: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, (i * i) as f64)).collect();
        let s = ascii_plot("sq", &[("x²", pts)], 8);
        assert!(s.contains("sq"));
        assert!(s.lines().count() >= 9);
    }
}
