//! Pre-training driver: run the AOT'd `train_step` graph from rust until
//! the LM has learned the corpus, then cache the weights.
//!
//! This is the end-to-end proof that the three layers compose: the L2 JAX
//! train step (with the L1-adjacent compute inside) executes under the L3
//! rust event loop, with data produced by the rust corpus generator.

use std::path::PathBuf;
use std::sync::Arc;

use crate::error::Result;
use crate::models::{Corpus, ParamSet};
use crate::runtime::{HostTensor, Runtime};

/// Training run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub corpus_tokens: usize,
    pub corpus_seed: u64,
    pub init_seed: u32,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            // 3000 steps take ~3 min on the single-core CPU PJRT backend
            // and are enough for the LM to learn in-context recall
            // (NAV ACC ~0.74); cached afterwards in artifacts/.
            steps: 3000,
            corpus_tokens: 400_000,
            corpus_seed: 2024,
            init_seed: 0,
            log_every: 250,
        }
    }
}

/// Result of a training run.
#[derive(Debug)]
pub struct TrainOutcome {
    pub params: ParamSet,
    pub losses: Vec<f32>,
    pub steps: usize,
}

/// Train the LM from scratch; returns params + the loss curve.
pub fn train(rt: &Runtime, cfg: &TrainConfig) -> Result<TrainOutcome> {
    let m = rt.meta.model.clone();
    let corpus = Corpus::generate(cfg.corpus_tokens, cfg.corpus_seed);
    let (train_split, _) = corpus.split(0.9);

    let params = rt.run("init_params", &[HostTensor::scalar_u32(cfg.init_seed)])?;
    let n = params.len();
    let zeros: Vec<HostTensor> = params
        .iter()
        .map(|p| HostTensor::f32(vec![0.0; p.shape().iter().product()], p.shape().to_vec()))
        .collect();

    let mut state: Vec<HostTensor> = params
        .iter()
        .chain(zeros.iter())
        .chain(zeros.iter())
        .cloned()
        .collect();
    let mut step_t = HostTensor::scalar_i32(0);

    let mut losses = Vec::with_capacity(cfg.steps);
    let sw = crate::util::timer::Stopwatch::start();
    for step in 0..cfg.steps {
        let tokens = corpus.batch(train_split, m.batch, m.seq_len, step);
        let mut args = state.clone();
        args.push(step_t.clone());
        args.push(HostTensor::i32(tokens, vec![m.batch, m.seq_len]));
        let out = rt.run("train_step", &args)?;
        let loss = out[3 * n + 1].scalar_f32_value()?;
        losses.push(loss);
        state = out[..3 * n].to_vec();
        step_t = out[3 * n].clone();
        if cfg.log_every > 0 && (step + 1) % cfg.log_every == 0 {
            crate::info!(
                "train step {:>4}/{}: loss {:.4} ({:.0} ms/step)",
                step + 1,
                cfg.steps,
                loss,
                sw.elapsed_ms() / (step + 1) as f64
            );
        }
    }

    let gm = rt.meta.graph("lm_nll")?;
    let params = ParamSet::from_tensors(gm, &state[..n])?;
    Ok(TrainOutcome {
        params,
        losses,
        steps: cfg.steps,
    })
}

/// Cache path for the default trained model.
pub fn trained_model_path(rt: &Runtime) -> PathBuf {
    rt.meta.dir.join("trained_model.wbin")
}

/// Return the default trained model, training (once) if not yet cached.
pub fn ensure_trained(rt: &Arc<Runtime>) -> Result<ParamSet> {
    let path = trained_model_path(rt);
    if path.exists() {
        if let Ok(p) = ParamSet::load(&path) {
            crate::info!("loaded cached trained model from {path:?}");
            return Ok(p);
        }
    }
    crate::info!("no cached model; pre-training (one-time, cached afterwards)");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let outcome = train(rt, &TrainConfig::default())?;
    let first = outcome.losses.first().copied().unwrap_or(f32::NAN);
    let last = outcome.losses.last().copied().unwrap_or(f32::NAN);
    crate::info!(
        "training done: loss {first:.3} -> {last:.3} over {} steps",
        outcome.steps
    );
    outcome.params.save(&path)?;
    Ok(outcome.params)
}
