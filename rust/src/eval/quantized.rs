//! Quantize a trained [`ParamSet`] with any quantizer config.
//!
//! Follows QLoRA practice (and the paper's evaluation protocol): the
//! 2-D matmul weights are quantized; norms/embeddings stay in 16/32-bit.
//! The quantization itself runs through the multithreaded
//! [`crate::coordinator::QuantScheduler`].

use crate::coordinator::{QuantJob, QuantScheduler};
use crate::error::Result;
use crate::models::ParamSet;
use crate::quant::{pack, QuantConfig, Quantizer};
use crate::runtime::meta::{matmul_param_names, param_specs};
use crate::runtime::{HostTensor, Meta};

/// Which parameters get quantized: 2-D weights except the embedding table
/// (QLoRA quantizes linear layers; embeddings stay high-precision).
pub fn is_quantized_param(name: &str, shape: &[usize]) -> bool {
    shape.len() == 2 && name != "embed" && name != "pos"
}

/// Outcome of whole-model quantization.
#[derive(Debug)]
pub struct QuantizedModel {
    /// Dequantized parameters (ready for the eval graphs).
    pub params: ParamSet,
    /// Whole-model error over the quantized tensors only.
    pub mae: f64,
    pub mse: f64,
    /// Storage bytes of the quantized representation.
    pub quant_bytes: usize,
    /// f32 bytes of the same tensors, for the memory ratio.
    pub orig_bytes: usize,
    /// OPQ outlier count across tensors.
    pub outliers: usize,
}

/// Quantize + dequantize every eligible tensor of `params`.
pub fn quantize_params(params: &ParamSet, config: &QuantConfig) -> Result<QuantizedModel> {
    let sched = QuantScheduler::new(config.clone());
    let mut jobs = Vec::new();
    let mut job_names = Vec::new();
    for (name, shape, data) in &params.entries {
        if is_quantized_param(name, shape) {
            jobs.push(QuantJob {
                name: name.clone(),
                data: data.clone(),
            });
            job_names.push(name.clone());
        }
    }
    let results = sched.run(jobs)?;

    let mut out = params.clone();
    let mut se = 0.0f64;
    let mut ae = 0.0f64;
    let mut n = 0usize;
    let mut quant_bytes = 0usize;
    let mut orig_bytes = 0usize;
    let mut outliers = 0usize;
    let q = crate::quant::Quantizer::new(config.clone());
    for r in results {
        let deq = q.dequantize(&r.tensor);
        let dst = out.get_mut(&r.name).expect("param exists");
        // accumulate error vs original
        let orig = params.get(&r.name).unwrap().1;
        for (a, b) in orig.iter().zip(&deq) {
            let d = (*a as f64) - (*b as f64);
            se += d * d;
            ae += d.abs();
        }
        n += deq.len();
        quant_bytes += r.tensor.bytes();
        orig_bytes += 4 * deq.len();
        outliers += r.tensor.outliers.len();
        *dst = deq;
    }
    Ok(QuantizedModel {
        params: out,
        mae: ae / n.max(1) as f64,
        mse: se / n.max(1) as f64,
        quant_bytes,
        orig_bytes,
        outliers,
    })
}

/// A model quantized **for the serving engine**: 4-bit codes plus 8-bit
/// double-quantized block constants plus (when OPQ is configured) a
/// per-matrix outlier side-table, laid out as the argument prefix of
/// the `lm_prefill_q4` / `lm_decode_step_q4` graphs. Unlike
/// [`quantize_params`] (which dequantizes back to f32 for the eval
/// graphs), the weights here stay quantized at rest end-to-end: the CPU
/// backend dequantizes block constants inside the fused q4 matmul and
/// patches the outlier side-table sparsely inside the same kernels.
#[derive(Clone, Debug)]
pub struct QuantizedServingParams {
    /// ABI-ordered prefix: non-matmul f32 params, per-matrix unpacked
    /// codes, per-matrix 8-bit constant codes, per-matrix chunk
    /// `(min, scale)` pairs, per-matrix sorted u32 outlier indices,
    /// per-matrix bf16-rounded f32 outlier values (both empty when OPQ
    /// is off), codebook levels. Feed to
    /// [`crate::coordinator::EngineParams::QuantizedQ4`].
    pub prefix: Vec<HostTensor>,
    /// Exact dequantization of the same weights, outliers restored
    /// (bit-identical to what the fused kernel computes) in canonical
    /// dense ABI order — the equivalence oracle and the fallback for
    /// backends without the q4 serving graphs.
    pub dense: Vec<HostTensor>,
    /// Storage bytes of the quantized matmul weights (codes + DQ'd
    /// constants + OPQ side-table via [`crate::quant::opq::opq_bytes`]).
    pub quant_bytes: usize,
    /// f32 bytes of the same tensors.
    pub orig_bytes: usize,
    /// OPQ outlier count across all matmul weights (0 when OPQ is off).
    pub outliers: usize,
}

/// Quantize a [`ParamSet`] for the serving engine's q4 graphs. The
/// config's `double_quant` flag is implied (constants are always stored
/// 8-bit on this path); `cfg.opq` stores outlier weights in a
/// bf16-precision side-table per matrix (sorted flat u32 indices + f32
/// values), patched sparsely inside the fused serving kernels so the
/// model stays 4-bit at rest. `cfg.block` must match the model's block
/// size.
pub fn quantize_for_serving(
    meta: &Meta,
    params: &ParamSet,
    cfg: &QuantConfig,
) -> Result<QuantizedServingParams> {
    let m = &meta.model;
    if cfg.block != m.block {
        return Err(crate::err!(
            "serving block size {} != model block {}",
            cfg.block,
            m.block
        ));
    }
    let q = Quantizer::new(QuantConfig {
        double_quant: true,
        ..cfg.clone()
    });
    let mm = matmul_param_names(m);
    let mut f32s = Vec::new();
    let mut codes_t = Vec::new();
    let mut am_codes_t = Vec::new();
    let mut am_params_t = Vec::new();
    let mut out_idx_t = Vec::new();
    let mut out_val_t = Vec::new();
    let mut quant_bytes = 0usize;
    let mut orig_bytes = 0usize;
    let mut outliers = 0usize;
    for (name, shape) in param_specs(m) {
        let (pshape, data) = params
            .get(&name)
            .ok_or_else(|| crate::err!("param '{name}' missing from ParamSet"))?;
        if pshape != shape.as_slice() {
            return Err(crate::err!(
                "param '{name}': shape {pshape:?} != canonical {shape:?}"
            ));
        }
        if !mm.contains(&name) {
            f32s.push(HostTensor::f32(data.to_vec(), shape.clone()));
            continue;
        }
        let (k, n) = (shape[0], shape[1]);
        if n % m.block != 0 {
            return Err(crate::err!(
                "param '{name}': row length {n} not a multiple of block {}",
                m.block
            ));
        }
        // OPQ runs inside the quantizer: outliers are extracted (and
        // zeroed) before the block-max search, so the codes encode the
        // outlier-free tensor and `qt.outliers` carries the side-table
        // in ascending flat-index order.
        let qt = q.quantize(data);
        let dq = qt.dq.as_ref().expect("double_quant is on");
        let codes = pack::unpack_u4(&qt.codes, k * n);
        let nb = n / m.block;
        let mut oi = Vec::with_capacity(qt.outliers.len());
        let mut ov = Vec::with_capacity(qt.outliers.len());
        for o in &qt.outliers {
            oi.push(o.index as u32);
            ov.push(o.value.to_f32());
        }
        debug_assert!(oi.windows(2).all(|p| p[0] < p[1]), "side-table sorted");
        outliers += qt.outliers.len();
        let mut chunk_flat = Vec::with_capacity(dq.chunk_params.len() * 2);
        for &(mn, scale) in &dq.chunk_params {
            chunk_flat.push(mn);
            chunk_flat.push(scale);
        }
        quant_bytes +=
            qt.codes.len() + dq.bytes() + crate::quant::opq::opq_bytes(qt.outliers.len());
        orig_bytes += 4 * k * n;
        codes_t.push(HostTensor::u8(codes, vec![k, n]));
        am_codes_t.push(HostTensor::u8(dq.codes.clone(), vec![k, nb]));
        am_params_t.push(HostTensor::f32(
            chunk_flat,
            vec![dq.chunk_params.len(), 2],
        ));
        let n_out = oi.len();
        out_idx_t.push(HostTensor::u32(oi, vec![n_out]));
        out_val_t.push(HostTensor::f32(ov, vec![n_out]));
    }
    let mut prefix = f32s;
    prefix.extend(codes_t);
    prefix.extend(am_codes_t);
    prefix.extend(am_params_t);
    prefix.extend(out_idx_t);
    prefix.extend(out_val_t);
    prefix.push(HostTensor::f32(q.codebook.levels.to_vec(), vec![16]));
    // The dense oracle is *derived from the prefix* through the one
    // shared reconstruction (`dense_from_q4_prefix`) — the same function
    // the artifact loader uses — so every consumer of a q4 prefix
    // (in-memory or reloaded from disk) sees bit-identical dense weights.
    let dense = dense_from_q4_prefix(meta, &prefix)?;
    Ok(QuantizedServingParams {
        prefix,
        dense,
        quant_bytes,
        orig_bytes,
        outliers,
    })
}

/// Exactly dequantize a q4 serving prefix back to the canonical dense
/// parameter tensors (outliers restored), bit-identical to what the
/// fused q4 kernels compute: block constants through
/// [`crate::quant::double_quant::reconstruct`], weights as
/// `levels[code] * absmax`, then the bf16-rounded outlier side-table
/// patched verbatim. Non-matmul tensors come back as buffer-sharing
/// views of the prefix.
///
/// This is the single reconstruction shared by [`quantize_for_serving`]
/// (to build its `dense` oracle) and the artifact loader
/// ([`crate::eval::artifact`]) — both paths produce the same bits by
/// construction.
pub fn dense_from_q4_prefix(meta: &Meta, prefix: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let m = &meta.model;
    let specs = param_specs(m);
    let mm = matmul_param_names(m);
    let n_mm = mm.len();
    let n_dense = specs.len() - n_mm;
    let want = n_dense + 5 * n_mm + 1;
    if prefix.len() != want {
        return Err(crate::err!(
            "q4 prefix has {} tensors, expected {want}",
            prefix.len()
        ));
    }
    let levels = prefix[n_dense + 5 * n_mm].as_f32()?;
    if levels.len() != 16 {
        return Err(crate::err!("codebook has {} levels, expected 16", levels.len()));
    }
    let mut dense = Vec::with_capacity(specs.len());
    let (mut fi, mut mi) = (0usize, 0usize);
    for (name, shape) in specs {
        if !mm.contains(&name) {
            let t = &prefix[fi];
            if t.shape() != shape.as_slice() {
                return Err(crate::err!(
                    "prefix tensor {fi} ('{name}'): shape {:?} != {shape:?}",
                    t.shape()
                ));
            }
            dense.push(t.clone()); // buffer-sharing view
            fi += 1;
            continue;
        }
        let (k, n) = (shape[0], shape[1]);
        let nb = n / m.block;
        let codes = prefix[n_dense + mi].as_u8()?;
        let am_codes = prefix[n_dense + n_mm + mi].as_u8()?;
        let am_params = prefix[n_dense + 2 * n_mm + mi].as_f32()?;
        let out_idx = prefix[n_dense + 3 * n_mm + mi].as_u32()?;
        let out_val = prefix[n_dense + 4 * n_mm + mi].as_f32()?;
        if codes.len() != k * n || am_codes.len() != k * nb {
            return Err(crate::err!(
                "'{name}': code tensors sized {}/{}, expected {}/{}",
                codes.len(),
                am_codes.len(),
                k * n,
                k * nb
            ));
        }
        if out_idx.len() != out_val.len() {
            return Err(crate::err!(
                "'{name}': outlier side-table lengths differ ({} idx, {} val)",
                out_idx.len(),
                out_val.len()
            ));
        }
        let mut w = vec![0.0f32; k * n];
        for kk in 0..k {
            for jb in 0..nb {
                let bi = kk * nb + jb;
                let chunk = bi / crate::quant::double_quant::CHUNK;
                let ps = am_params.get(2 * chunk..2 * chunk + 2).ok_or_else(|| {
                    crate::err!("'{name}': chunk params truncated at chunk {chunk}")
                })?;
                let (mn, scale) = (ps[0], ps[1]);
                let am = crate::quant::double_quant::reconstruct(mn, scale, am_codes[bi]);
                for i in 0..m.block {
                    let j = jb * m.block + i;
                    w[kk * n + j] = levels[(codes[kk * n + j] & 0x0f) as usize] * am;
                }
            }
        }
        // patch exactly as the fused kernels patch their side-table:
        // bf16-rounded outlier values, verbatim
        for (&idx, &val) in out_idx.iter().zip(out_val) {
            let idx = idx as usize;
            if idx >= w.len() {
                return Err(crate::err!(
                    "'{name}': outlier index {idx} out of range ({} weights)",
                    w.len()
                ));
            }
            w[idx] = val;
        }
        dense.push(HostTensor::f32(w, shape));
        mi += 1;
    }
    Ok(dense)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Method, Norm};
    use crate::util::rng::Pcg64;

    fn fake_params() -> ParamSet {
        let mut rng = Pcg64::seed_from_u64(1);
        let mk = |n: usize, rng: &mut Pcg64| -> Vec<f32> {
            let mut v = vec![0.0f32; n];
            rng.fill_gaussian_f32(&mut v, 0.05);
            v
        };
        ParamSet {
            entries: vec![
                ("embed".into(), vec![64, 32], mk(64 * 32, &mut rng)),
                ("l0.wqkv".into(), vec![32, 96], mk(32 * 96, &mut rng)),
                ("l0.ln1".into(), vec![32], vec![1.0; 32]),
                ("head".into(), vec![32, 64], mk(32 * 64, &mut rng)),
            ],
        }
    }

    #[test]
    fn eligibility() {
        assert!(is_quantized_param("l0.wqkv", &[32, 96]));
        assert!(is_quantized_param("head", &[32, 64]));
        assert!(!is_quantized_param("embed", &[64, 32]));
        assert!(!is_quantized_param("l0.ln1", &[32]));
    }

    #[test]
    fn quantizes_only_eligible() {
        let p = fake_params();
        let qm = quantize_params(
            &p,
            &QuantConfig {
                method: Method::Nf4,
                norm: Norm::Absmax,
                ..Default::default()
            },
        )
        .unwrap();
        // embed and ln unchanged
        assert_eq!(qm.params.get("embed").unwrap().1, p.get("embed").unwrap().1);
        assert_eq!(qm.params.get("l0.ln1").unwrap().1, p.get("l0.ln1").unwrap().1);
        // wqkv changed (quantization noise)
        assert_ne!(
            qm.params.get("l0.wqkv").unwrap().1,
            p.get("l0.wqkv").unwrap().1
        );
        assert!(qm.mse > 0.0);
        assert!(qm.quant_bytes < qm.orig_bytes / 5); // ~4.5 bits vs 32
    }

    #[test]
    fn better_codebook_lower_error() {
        let p = fake_params();
        let nf4 = quantize_params(
            &p,
            &QuantConfig {
                method: Method::Nf4,
                norm: Norm::Absmax,
                ..Default::default()
            },
        )
        .unwrap();
        let bof4s = quantize_params(
            &p,
            &QuantConfig {
                method: Method::Bof4 { mse: true },
                norm: Norm::SignedAbsmax,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            bof4s.mse < nf4.mse,
            "BOF4-S {} should beat NF4 {}",
            bof4s.mse,
            nf4.mse
        );
    }
}
