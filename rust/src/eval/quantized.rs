//! Quantize a trained [`ParamSet`] with any quantizer config.
//!
//! Follows QLoRA practice (and the paper's evaluation protocol): the
//! 2-D matmul weights are quantized; norms/embeddings stay in 16/32-bit.
//! The quantization itself runs through the multithreaded
//! [`crate::coordinator::QuantScheduler`].

use crate::coordinator::{QuantJob, QuantScheduler};
use crate::error::Result;
use crate::models::ParamSet;
use crate::quant::QuantConfig;

/// Which parameters get quantized: 2-D weights except the embedding table
/// (QLoRA quantizes linear layers; embeddings stay high-precision).
pub fn is_quantized_param(name: &str, shape: &[usize]) -> bool {
    shape.len() == 2 && name != "embed" && name != "pos"
}

/// Outcome of whole-model quantization.
#[derive(Debug)]
pub struct QuantizedModel {
    /// Dequantized parameters (ready for the eval graphs).
    pub params: ParamSet,
    /// Whole-model error over the quantized tensors only.
    pub mae: f64,
    pub mse: f64,
    /// Storage bytes of the quantized representation.
    pub quant_bytes: usize,
    /// f32 bytes of the same tensors, for the memory ratio.
    pub orig_bytes: usize,
    /// OPQ outlier count across tensors.
    pub outliers: usize,
}

/// Quantize + dequantize every eligible tensor of `params`.
pub fn quantize_params(params: &ParamSet, config: &QuantConfig) -> Result<QuantizedModel> {
    let sched = QuantScheduler::new(config.clone());
    let mut jobs = Vec::new();
    let mut job_names = Vec::new();
    for (name, shape, data) in &params.entries {
        if is_quantized_param(name, shape) {
            jobs.push(QuantJob {
                name: name.clone(),
                data: data.clone(),
            });
            job_names.push(name.clone());
        }
    }
    let results = sched.run(jobs)?;

    let mut out = params.clone();
    let mut se = 0.0f64;
    let mut ae = 0.0f64;
    let mut n = 0usize;
    let mut quant_bytes = 0usize;
    let mut orig_bytes = 0usize;
    let mut outliers = 0usize;
    let q = crate::quant::Quantizer::new(config.clone());
    for r in results {
        let deq = q.dequantize(&r.tensor);
        let dst = out.get_mut(&r.name).expect("param exists");
        // accumulate error vs original
        let orig = params.get(&r.name).unwrap().1;
        for (a, b) in orig.iter().zip(&deq) {
            let d = (*a as f64) - (*b as f64);
            se += d * d;
            ae += d.abs();
        }
        n += deq.len();
        quant_bytes += r.tensor.bytes();
        orig_bytes += 4 * deq.len();
        outliers += r.tensor.outliers.len();
        *dst = deq;
    }
    Ok(QuantizedModel {
        params: out,
        mae: ae / n.max(1) as f64,
        mse: se / n.max(1) as f64,
        quant_bytes,
        orig_bytes,
        outliers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Method, Norm};
    use crate::util::rng::Pcg64;

    fn fake_params() -> ParamSet {
        let mut rng = Pcg64::seed_from_u64(1);
        let mk = |n: usize, rng: &mut Pcg64| -> Vec<f32> {
            let mut v = vec![0.0f32; n];
            rng.fill_gaussian_f32(&mut v, 0.05);
            v
        };
        ParamSet {
            entries: vec![
                ("embed".into(), vec![64, 32], mk(64 * 32, &mut rng)),
                ("l0.wqkv".into(), vec![32, 96], mk(32 * 96, &mut rng)),
                ("l0.ln1".into(), vec![32], vec![1.0; 32]),
                ("head".into(), vec![32, 64], mk(32 * 64, &mut rng)),
            ],
        }
    }

    #[test]
    fn eligibility() {
        assert!(is_quantized_param("l0.wqkv", &[32, 96]));
        assert!(is_quantized_param("head", &[32, 64]));
        assert!(!is_quantized_param("embed", &[64, 32]));
        assert!(!is_quantized_param("l0.ln1", &[32]));
    }

    #[test]
    fn quantizes_only_eligible() {
        let p = fake_params();
        let qm = quantize_params(
            &p,
            &QuantConfig {
                method: Method::Nf4,
                norm: Norm::Absmax,
                ..Default::default()
            },
        )
        .unwrap();
        // embed and ln unchanged
        assert_eq!(qm.params.get("embed").unwrap().1, p.get("embed").unwrap().1);
        assert_eq!(qm.params.get("l0.ln1").unwrap().1, p.get("l0.ln1").unwrap().1);
        // wqkv changed (quantization noise)
        assert_ne!(
            qm.params.get("l0.wqkv").unwrap().1,
            p.get("l0.wqkv").unwrap().1
        );
        assert!(qm.mse > 0.0);
        assert!(qm.quant_bytes < qm.orig_bytes / 5); // ~4.5 bits vs 32
    }

    #[test]
    fn better_codebook_lower_error() {
        let p = fake_params();
        let nf4 = quantize_params(
            &p,
            &QuantConfig {
                method: Method::Nf4,
                norm: Norm::Absmax,
                ..Default::default()
            },
        )
        .unwrap();
        let bof4s = quantize_params(
            &p,
            &QuantConfig {
                method: Method::Bof4 { mse: true },
                norm: Norm::SignedAbsmax,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            bof4s.mse < nf4.mse,
            "BOF4-S {} should beat NF4 {}",
            bof4s.mse,
            nf4.mse
        );
    }
}
