//! Synthetic evaluation tasks.
//!
//! **Multiple-choice suite** (the MMLU/ARC/HellaSwag/PIQA/SIQA/WinoGrande
//! stand-in of Tables 2/10): six tasks built from the corpus's formal
//! language, with chance levels matching the real benchmarks (4/4/4/2/3/2
//! choices). Scoring is NLL-based choice ranking via the `lm_nll` graph —
//! the same mechanism the real benchmarks use. The normalized average
//! accuracy (NAV ACC) implements the paper's eq. 74.
//!
//! **Fine-tuning tasks** (Tables 3/4 proxy): an instruction-echo task and
//! a bracket-code task; data generators + greedy-decode accuracy live
//! here, the LoRA optimizer loop in [`crate::eval::lora`].

use crate::error::Result;
use crate::models::corpus::{
    Corpus, TOK_ARROW, TOK_COLON, TOK_FN, TOK_KEY, TOK_LBRK, TOK_RBRK, TOK_SPACE,
};
use crate::models::ParamSet;
use crate::runtime::{HostTensor, Runtime};
use crate::util::rng::Pcg64;

const DIGIT0: u8 = 26;
/// Echo-instruction token (reserved corpus slot 48).
pub const TOK_ECHO: u8 = 48;

/// One multiple-choice question: shared context, candidate continuations,
/// index of the correct one.
#[derive(Clone, Debug)]
pub struct McQuestion {
    pub context: Vec<u8>,
    pub choices: Vec<Vec<u8>>,
    pub correct: usize,
}

/// A named task with its chance-level accuracy.
#[derive(Clone, Debug)]
pub struct McTask {
    pub name: &'static str,
    pub chance: f64,
    pub questions: Vec<McQuestion>,
}

/// Build the six-task suite from the corpus eval split.
pub fn build_suite(n_questions: usize, seed: u64) -> Vec<McTask> {
    let corpus = Corpus::generate(600_000, seed);
    let (_, eval_split) = corpus.split(0.9);
    vec![
        recall_task("mmlu-like", eval_split, n_questions, 4, seed ^ 1),
        arith_task("arc-like", eval_split, n_questions, 4, seed ^ 2),
        bracket_task("hellaswag-like", n_questions, 4, seed ^ 3),
        close_task("piqa-like", n_questions, 2, seed ^ 4),
        next_stmt_task("siqa-like", eval_split, n_questions, 3, seed ^ 5),
        recall_task("winogrande-like", eval_split, n_questions, 2, seed ^ 6),
    ]
}

/// Recall questions: context ends at `K a b ->`; choices are digit pairs.
fn recall_task(
    name: &'static str,
    toks: &[u8],
    n: usize,
    n_choices: usize,
    seed: u64,
) -> McTask {
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut questions = Vec::new();
    let mut i = 0;
    while questions.len() < n && i + 8 < toks.len() {
        if toks[i] == TOK_KEY && toks[i + 3] == TOK_ARROW {
            let ctx_start = i.saturating_sub(52);
            let context = toks[ctx_start..i + 4].to_vec();
            // the question is only answerable if the assignment
            // `K a b =` appears inside the context window
            let (ka, kb) = (toks[i + 1], toks[i + 2]);
            let assigned_in_ctx = context.windows(4).any(|w| {
                w[0] == TOK_KEY
                    && w[1] == ka
                    && w[2] == kb
                    && w[3] == crate::models::corpus::TOK_EQ
            });
            if !assigned_in_ctx {
                i += 7;
                continue;
            }
            let correct_pair = [toks[i + 4], toks[i + 5]];
            let mut choices = vec![correct_pair.to_vec()];
            while choices.len() < n_choices {
                let cand = vec![
                    DIGIT0 + rng.next_below(10) as u8,
                    DIGIT0 + rng.next_below(10) as u8,
                ];
                if !choices.contains(&cand) {
                    choices.push(cand);
                }
            }
            // shuffle: put correct at a random slot
            let correct = rng.next_below(n_choices as u64) as usize;
            choices.swap(0, correct);
            questions.push(McQuestion {
                context,
                choices,
                correct,
            });
            i += 7;
        } else {
            i += 1;
        }
    }
    McTask {
        name,
        chance: 1.0 / n_choices as f64,
        questions,
    }
}

/// Harder recall discrimination (ARC-style "reasoning"): the context ends
/// at `K a b ->` (assignment visible); the distractors are *permutations
/// and near-misses* of the correct digits — (d2 d1), (d1 d1), (d2 d2) —
/// so order sensitivity is required, not just content recall.
fn arith_task(
    name: &'static str,
    toks: &[u8],
    n: usize,
    n_choices: usize,
    seed: u64,
) -> McTask {
    use crate::models::corpus::TOK_EQ;
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut questions = Vec::new();
    let mut i = 0;
    while questions.len() < n && i + 8 < toks.len() {
        if toks[i] == TOK_KEY && toks[i + 3] == TOK_ARROW {
            let ctx_start = i.saturating_sub(52);
            let context = toks[ctx_start..i + 4].to_vec();
            let (ka, kb) = (toks[i + 1], toks[i + 2]);
            let assigned_in_ctx = context.windows(4).any(|w| {
                w[0] == TOK_KEY && w[1] == ka && w[2] == kb && w[3] == TOK_EQ
            });
            let (d1, d2) = (toks[i + 4], toks[i + 5]);
            if !assigned_in_ctx || d1 == d2 {
                i += 7;
                continue;
            }
            let mut choices = vec![
                vec![d1, d2], // correct
                vec![d2, d1],
                vec![d1, d1],
                vec![d2, d2],
            ];
            choices.truncate(n_choices);
            let correct = rng.next_below(choices.len() as u64) as usize;
            choices.swap(0, correct);
            questions.push(McQuestion {
                context,
                choices,
                correct,
            });
            i += 7;
        } else {
            i += 1;
        }
    }
    McTask {
        name,
        chance: 1.0 / n_choices as f64,
        questions,
    }
}

/// Bracket-continuation: context is an unfinished nest; the correct choice
/// closes it with the right number of `]`s.
fn bracket_task(name: &'static str, n: usize, n_choices: usize, seed: u64) -> McTask {
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut questions = Vec::new();
    for _ in 0..n {
        let depth = 2 + rng.next_below(3) as usize; // 2..4
        let mut context = vec![TOK_SPACE];
        for _ in 0..depth {
            context.push(TOK_LBRK);
            context.push(rng.next_below(26) as u8);
        }
        // correct: close `depth` brackets
        let mut choices = Vec::new();
        for d in 0..n_choices {
            // candidate closes depth-d brackets (d=0 correct), then space
            let closes = depth.saturating_sub(d).max(1);
            let mut c = vec![TOK_RBRK; closes];
            c.push(TOK_SPACE);
            choices.push(c);
        }
        choices.dedup();
        while choices.len() < n_choices {
            let mut c = vec![TOK_RBRK; choices.len() + depth];
            c.push(TOK_SPACE);
            choices.push(c);
        }
        // pad all choices to equal length with separators so the NLL
        // ranking is not length-biased
        let maxlen = choices.iter().map(Vec::len).max().unwrap();
        for c in &mut choices {
            c.resize(maxlen, TOK_SPACE);
        }
        let correct = rng.next_below(n_choices as u64) as usize;
        choices.swap(0, correct);
        questions.push(McQuestion {
            context,
            choices,
            correct,
        });
    }
    McTask {
        name,
        chance: 1.0 / n_choices as f64,
        questions,
    }
}

/// Two-way "physical plausibility" analogue: after `[ x`, a close bracket
/// is a *possible* continuation while an operator (`+`) is grammatically
/// impossible in the corpus — the model must prefer the possible one.
fn close_task(name: &'static str, n: usize, n_choices: usize, seed: u64) -> McTask {
    use crate::models::corpus::TOK_PLUS;
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut questions = Vec::new();
    for _ in 0..n {
        let letter = rng.next_below(26) as u8;
        let context = vec![TOK_SPACE, TOK_LBRK, letter];
        let choices = vec![vec![TOK_RBRK], vec![TOK_PLUS]];
        let correct = rng.next_below(n_choices as u64) as usize;
        let mut ch = choices;
        ch.swap(0, correct);
        questions.push(McQuestion {
            context,
            choices: ch,
            correct,
        });
    }
    McTask {
        name,
        chance: 1.0 / n_choices as f64,
        questions,
    }
}

/// Next-statement-type: after `;` + space, which statement opener follows
/// in the corpus? (K / [ / F — 3 choices.)
fn next_stmt_task(
    name: &'static str,
    toks: &[u8],
    n: usize,
    n_choices: usize,
    seed: u64,
) -> McTask {
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut questions = Vec::new();
    let mut i = 1;
    let openers = [TOK_KEY, TOK_LBRK, TOK_FN];
    while questions.len() < n && i + 2 < toks.len() {
        if toks[i] == TOK_SPACE && openers.contains(&toks[i + 1]) {
            let ctx_start = i.saturating_sub(40);
            let context = toks[ctx_start..=i].to_vec();
            let correct_tok = toks[i + 1];
            let mut choices: Vec<Vec<u8>> = vec![vec![correct_tok]];
            for &o in &openers {
                if o != correct_tok && choices.len() < n_choices {
                    choices.push(vec![o]);
                }
            }
            let correct = rng.next_below(choices.len() as u64) as usize;
            choices.swap(0, correct);
            questions.push(McQuestion {
                context,
                choices,
                correct,
            });
            i += 2;
        } else {
            i += 1;
        }
    }
    McTask {
        name,
        chance: 1.0 / n_choices as f64,
        questions,
    }
}

/// Score a suite: NLL-rank choices with `lm_nll`, batching sequences.
pub fn score_task(rt: &Runtime, params: &ParamSet, task: &McTask) -> Result<f64> {
    let m = rt.meta.model.clone();
    let tensors = params.to_tensors();
    // flatten all (question, choice) sequences
    let mut seqs: Vec<Vec<u8>> = Vec::new();
    for q in &task.questions {
        for c in &q.choices {
            let mut s = q.context.clone();
            s.extend_from_slice(c);
            seqs.push(s);
        }
    }
    // right-align into fixed windows; pad left with separator
    let mut nlls = Vec::with_capacity(seqs.len());
    for chunk in seqs.chunks(m.batch) {
        let mut toks = vec![TOK_SPACE as i32; m.batch * m.seq_len];
        for (i, s) in chunk.iter().enumerate() {
            let take = s.len().min(m.seq_len);
            let tail = &s[s.len() - take..];
            let row = &mut toks[i * m.seq_len..(i + 1) * m.seq_len];
            for (dst, &t) in row[m.seq_len - take..].iter_mut().zip(tail) {
                *dst = t as i32;
            }
        }
        let mut args = tensors.clone();
        args.push(HostTensor::i32(toks, vec![m.batch, m.seq_len]));
        let out = rt.run("lm_nll", &args)?;
        let batch_nll = out[0].as_f32()?;
        nlls.extend_from_slice(&batch_nll[..chunk.len()]);
    }
    // rank per question
    let mut correct = 0usize;
    let mut idx = 0;
    for q in &task.questions {
        let k = q.choices.len();
        let slice = &nlls[idx..idx + k];
        let best = slice
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        if best == q.correct {
            correct += 1;
        }
        idx += k;
    }
    Ok(correct as f64 / task.questions.len().max(1) as f64)
}

/// Normalized accuracy (paper eq. 74): (ACC − chance) / (1 − chance).
pub fn normalized_acc(acc: f64, chance: f64) -> f64 {
    (acc - chance) / (1.0 - chance)
}

/// NAV ACC over a suite of (accuracy, chance) results.
pub fn nav_acc(results: &[(f64, f64)]) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    results
        .iter()
        .map(|&(a, c)| normalized_acc(a, c))
        .sum::<f64>()
        / results.len() as f64
}

// ------------------------------------------------------------------
// Fine-tuning task data (Tables 3/4 proxies)
// ------------------------------------------------------------------

/// Which fine-tune task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FtTask {
    /// In-context key recall (IFEval-style instruction proxy): the prompt
    /// shows `K a b = ( d1 + d2 ) ; K a b ->` and the model must answer
    /// `d1 d2` — fully determined by the prompt, in-distribution for the
    /// pre-trained LM, sharpened by fine-tuning.
    KeyRecall,
    /// `F n : [^n letter ]^n` — emit a correct depth-n nest (MBPP+/
    /// HumanEval+ code proxy; the letter position is a wildcard).
    BracketCode,
}

/// One supervised example: prompt and expected completion. ``wildcards``
/// lists answer positions whose content is inherently unpredictable (e.g.
/// the random letter inside a bracket nest); scoring ignores them and
/// teacher-forces the expected token so the continuation stays aligned.
#[derive(Clone, Debug)]
pub struct FtExample {
    pub prompt: Vec<u8>,
    pub answer: Vec<u8>,
    pub wildcards: Vec<usize>,
}

/// Generate fine-tune examples.
pub fn ft_examples(task: FtTask, n: usize, seed: u64) -> Vec<FtExample> {
    let mut rng = Pcg64::seed_from_u64(seed);
    (0..n)
        .map(|_| match task {
            FtTask::KeyRecall => {
                use crate::models::corpus::{TOK_EQ, TOK_LPAR, TOK_PLUS, TOK_RPAR, TOK_SEMI};
                let (a, b) = (rng.next_below(26) as u8, rng.next_below(26) as u8);
                let d1 = DIGIT0 + rng.next_below(10) as u8;
                let d2 = DIGIT0 + rng.next_below(10) as u8;
                let prompt = vec![
                    TOK_KEY, a, b, TOK_EQ, TOK_LPAR, d1, TOK_PLUS, d2, TOK_RPAR,
                    TOK_SEMI, TOK_SPACE, TOK_KEY, a, b, TOK_ARROW,
                ];
                FtExample {
                    prompt,
                    answer: vec![d1, d2],
                    wildcards: Vec::new(),
                }
            }
            FtTask::BracketCode => {
                let depth = 1 + rng.next_below(4) as usize; // 1-4
                let letter = rng.next_below(26) as u8;
                let prompt = vec![TOK_FN, DIGIT0 + depth as u8, TOK_COLON];
                let mut answer = vec![TOK_LBRK; depth];
                answer.push(letter);
                answer.extend(vec![TOK_RBRK; depth]);
                FtExample {
                    prompt,
                    answer,
                    wildcards: vec![depth], // the letter is content-free
                }
            }
        })
        .collect()
}

/// Build fine-tuning token batches.
///
/// Each `[batch, seq]` row packs *whole* examples (prompt + answer +
/// separator) from the right, with the front left-padded by the separator
/// token — exactly the layout the greedy-decode evaluation uses, so
/// training and inference see the same conditioning distribution.
pub fn ft_batches(
    examples: &[FtExample],
    batch: usize,
    seq: usize,
    step: usize,
) -> Vec<i32> {
    assert!(!examples.is_empty());
    let mut out = vec![TOK_SPACE as i32; batch * seq];
    let mut next = step * batch * 3; // advance through examples per step
    for b in 0..batch {
        // pack whole examples right-to-left, with a varying right offset so
        // the model cannot overfit to absolute positions (the evaluator
        // reads predictions at seq-2; training must cover that alignment)
        let row = &mut out[b * seq..(b + 1) * seq];
        let mut end = seq - (b * 5 + step * 3) % 7;
        loop {
            let e = &examples[next % examples.len()];
            next += 1;
            let total = e.prompt.len() + e.answer.len() + 1;
            if total > end {
                break;
            }
            let start = end - total;
            for (dst, &t) in row[start..].iter_mut().zip(
                e.prompt
                    .iter()
                    .chain(e.answer.iter())
                    .chain(std::iter::once(&TOK_SPACE)),
            ) {
                *dst = t as i32;
            }
            end = start;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_builds_with_correct_shapes() {
        let suite = build_suite(20, 9);
        assert_eq!(suite.len(), 6);
        let chances: Vec<f64> = suite.iter().map(|t| t.chance).collect();
        assert_eq!(chances, vec![0.25, 0.25, 0.25, 0.5, 1.0 / 3.0, 0.5]);
        for t in &suite {
            assert!(
                t.questions.len() >= 10,
                "{}: only {} questions",
                t.name,
                t.questions.len()
            );
            for q in &t.questions {
                assert!(q.correct < q.choices.len());
                // choices distinct
                for i in 0..q.choices.len() {
                    for j in i + 1..q.choices.len() {
                        assert_ne!(q.choices[i], q.choices[j], "{}", t.name);
                    }
                }
            }
        }
    }

    #[test]
    fn recall_correct_choice_matches_corpus() {
        let corpus = Corpus::generate(200_000, 3);
        let (_, eval) = corpus.split(0.9);
        let t = recall_task("r", eval, 30, 4, 11);
        for q in &t.questions {
            // context ends with arrow; correct choice = next two tokens in
            // the corpus, i.e. digits
            let c = &q.choices[q.correct];
            assert!(c.iter().all(|&d| (DIGIT0..DIGIT0 + 10).contains(&d)));
            assert_eq!(*q.context.last().unwrap(), TOK_ARROW);
        }
    }

    #[test]
    fn nav_acc_eq74() {
        // chance-level accuracy normalizes to 0; perfect to 1
        assert!((normalized_acc(0.25, 0.25)).abs() < 1e-12);
        assert!((normalized_acc(1.0, 0.25) - 1.0).abs() < 1e-12);
        assert!((normalized_acc(0.625, 0.25) - 0.5).abs() < 1e-12);
        let nav = nav_acc(&[(0.25, 0.25), (1.0, 0.5)]);
        assert!((nav - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ft_examples_shapes() {
        let recall = ft_examples(FtTask::KeyRecall, 50, 1);
        for e in &recall {
            assert_eq!(e.prompt[0], TOK_KEY);
            assert_eq!(*e.prompt.last().unwrap(), TOK_ARROW);
            // the answer digits appear inside the prompt (in-context)
            assert_eq!(e.answer.len(), 2);
            assert_eq!(e.answer[0], e.prompt[5]);
            assert_eq!(e.answer[1], e.prompt[7]);
            assert!(e.wildcards.is_empty());
        }
        let code = ft_examples(FtTask::BracketCode, 50, 2);
        for e in &code {
            let depth = (e.prompt[1] - DIGIT0) as usize;
            assert_eq!(e.answer.len(), 2 * depth + 1);
            assert!(e.answer[..depth].iter().all(|&t| t == TOK_LBRK));
            assert!(e.answer[depth + 1..].iter().all(|&t| t == TOK_RBRK));
            assert_eq!(e.wildcards, vec![depth]);
        }
    }

    #[test]
    fn ft_batches_shape() {
        let ex = ft_examples(FtTask::KeyRecall, 200, 3);
        let b = ft_batches(&ex, 16, 64, 0);
        assert_eq!(b.len(), 16 * 64);
        assert!(b.iter().all(|&t| t >= 0 && t < 64));
        assert_ne!(b, ft_batches(&ex, 16, 64, 1));
    }
}
