//! QLoRA-style fine-tuning driver (Tables 3/4 proxy).
//!
//! The frozen base weights — quantized and dequantized by the chosen
//! quantizer, exactly the QLoRA setup — are fed to the AOT'd `lora_step`
//! graph; only the LoRA A/B adapters (and their Adam state) update.
//! Task accuracy is greedy-decode exact-match via `lm_logits_last_lora`.

use crate::error::Result;

use super::tasks::{ft_batches, ft_examples, FtTask};
use crate::models::corpus::TOK_SPACE;
use crate::models::ParamSet;
use crate::runtime::{HostTensor, Runtime};

/// LoRA fine-tune configuration.
#[derive(Clone, Debug)]
pub struct LoraConfig {
    pub steps: usize,
    pub train_examples: usize,
    pub eval_examples: usize,
    pub seed: u64,
}

impl Default for LoraConfig {
    fn default() -> Self {
        LoraConfig {
            steps: 120,
            train_examples: 1500,
            eval_examples: 48,
            seed: 7,
        }
    }
}

/// Outcome: adapters plus the loss curve.
#[derive(Debug)]
pub struct LoraOutcome {
    pub lora: Vec<HostTensor>,
    pub losses: Vec<f32>,
}

/// Fine-tune LoRA adapters over a frozen base on a task.
pub fn finetune(
    rt: &Runtime,
    base: &ParamSet,
    task: FtTask,
    cfg: &LoraConfig,
) -> Result<LoraOutcome> {
    let m = rt.meta.model.clone();
    let examples = ft_examples(task, cfg.train_examples, cfg.seed);
    let base_tensors = base.to_tensors();

    let lora = rt.run("init_lora", &[HostTensor::scalar_u32(cfg.seed as u32)])?;
    let nl = lora.len();
    let zeros: Vec<HostTensor> = lora
        .iter()
        .map(|p| HostTensor::f32(vec![0.0; p.shape().iter().product()], p.shape().to_vec()))
        .collect();

    let mut lstate: Vec<HostTensor> = lora
        .iter()
        .chain(zeros.iter())
        .chain(zeros.iter())
        .cloned()
        .collect();
    let mut step_t = HostTensor::scalar_i32(0);
    let mut losses = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let tokens = ft_batches(&examples, m.batch, m.seq_len, step);
        let mut args = base_tensors.clone();
        args.extend(lstate.iter().cloned());
        args.push(step_t.clone());
        args.push(HostTensor::i32(tokens, vec![m.batch, m.seq_len]));
        let out = rt.run("lora_step", &args)?;
        let loss = out[3 * nl + 1].scalar_f32_value()?;
        losses.push(loss);
        lstate = out[..3 * nl].to_vec();
        step_t = out[3 * nl].clone();
        if (step + 1) % 40 == 0 {
            crate::info!("lora step {:>4}/{}: loss {:.4}", step + 1, cfg.steps, loss);
        }
    }
    Ok(LoraOutcome {
        lora: lstate[..nl].to_vec(),
        losses,
    })
}

/// Greedy-decode accuracy of (base + adapters) on a task.
/// `lora = None` evaluates the plain base model (the "Base Model" rows).
///
/// Decoding reads the prediction at position S-2 via the full-logits
/// graphs — position S-1 is never supervised by the CE loss (its target
/// would lie outside the window), so conditioning a decode on it is
/// undefined behaviour for a narrowly fine-tuned model.
pub fn task_accuracy(
    rt: &Runtime,
    base: &ParamSet,
    lora: Option<&[HostTensor]>,
    task: FtTask,
    cfg: &LoraConfig,
) -> Result<f64> {
    let m = rt.meta.model.clone();
    // held-out examples: different seed stream than training
    let examples = ft_examples(task, cfg.eval_examples, cfg.seed ^ 0xEEEE);
    let base_tensors = base.to_tensors();
    let graph = if lora.is_some() {
        "lm_logits_all_lora"
    } else {
        "lm_logits_all"
    };
    let read_pos = m.seq_len - 2; // last supervised position

    // Few-shot-style conditioning: the window is left-filled with *other*
    // examples of the task (as in the training rows and in real LLM task
    // evals) rather than a long pad run the model never trained on.
    let filler: Vec<u8> = {
        let fill_ex = ft_examples(task, 16, cfg.seed ^ 0x1111);
        let mut f = Vec::new();
        for e in &fill_ex {
            f.extend_from_slice(&e.prompt);
            f.extend_from_slice(&e.answer);
            f.push(TOK_SPACE);
        }
        f
    };

    // batched greedy decode: all examples advance one token per XLA call
    let mut contexts: Vec<Vec<u8>> = examples
        .iter()
        .map(|e| {
            let mut c = filler.clone();
            c.extend_from_slice(&e.prompt);
            c
        })
        .collect();
    let mut done: Vec<Vec<u8>> = vec![Vec::new(); examples.len()];
    let max_len = examples.iter().map(|e| e.answer.len()).max().unwrap_or(0);
    for _ in 0..max_len {
        for chunk_start in (0..contexts.len()).step_by(m.batch) {
            let chunk_end = (chunk_start + m.batch).min(contexts.len());
            let mut toks = vec![TOK_SPACE as i32; m.batch * m.seq_len];
            for (i, ctx) in contexts[chunk_start..chunk_end].iter().enumerate() {
                // right-align so the context *ends at* read_pos
                let take = ctx.len().min(read_pos + 1);
                let tail = &ctx[ctx.len() - take..];
                let row = &mut toks[i * m.seq_len..(i + 1) * m.seq_len];
                for (dst, &t) in row[read_pos + 1 - take..read_pos + 1]
                    .iter_mut()
                    .zip(tail)
                {
                    *dst = t as i32;
                }
            }
            let mut args = base_tensors.clone();
            if let Some(l) = lora {
                args.extend(l.iter().cloned());
            }
            args.push(HostTensor::i32(toks, vec![m.batch, m.seq_len]));
            let out = rt.run(graph, &args)?;
            let logits = out[0].as_f32()?;
            let stride_b = m.seq_len * m.vocab;
            for i in 0..(chunk_end - chunk_start) {
                let ex = chunk_start + i;
                let pos = done[ex].len();
                if pos >= examples[ex].answer.len() {
                    continue;
                }
                let row =
                    &logits[i * stride_b + read_pos * m.vocab..i * stride_b + (read_pos + 1) * m.vocab];
                let tok = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0 as u8;
                // wildcard positions are content-free: teacher-force the
                // expected token so the continuation stays aligned.
                let forced = if examples[ex].wildcards.contains(&pos) {
                    examples[ex].answer[pos]
                } else {
                    tok
                };
                done[ex].push(forced);
                contexts[ex].push(forced);
            }
        }
    }
    // Per-token accuracy over content (non-wildcard) positions — the
    // smoother analogue of the paper's task accuracies at this scale.
    let mut correct = 0usize;
    let mut total = 0usize;
    for (e, d) in examples.iter().zip(&done) {
        for (i, &a) in e.answer.iter().enumerate() {
            if e.wildcards.contains(&i) {
                continue;
            }
            total += 1;
            if d[i] == a {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}
