//! On-disk model artifacts: a versioned, length-prefixed binary format
//! for serving parameter sets, so a quantized model is packed once by
//! [`crate::eval::quantize_for_serving`] and loaded straight into the
//! engine's shared weight set on every later start.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic      b"BOF4ARTF"                     8 bytes
//! version    u32 = 1
//! flags      u32 (bit 0: payload is RLE-compressed at rest)
//! meta_len   u32, then `meta_len` bytes of JSON metadata
//! payload_len u64 (uncompressed payload bytes)
//! stored_len  u64 (bytes on disk, == payload_len when uncompressed)
//! payload    `stored_len` bytes
//! checksum   u64 FNV-1a over the stored payload
//! ```
//!
//! The JSON block (via the hermetic [`crate::util::json`]) carries the
//! artifact kind (`"dense"` or `"q4"`), the model configuration it was
//! packed for (checked against the loading runtime's model), and
//! size/outlier statistics. The payload is a flat sequence of tensor
//! records:
//!
//! ```text
//! dtype  u8 (0 = f32, 1 = i32, 2 = u8, 3 = u32)
//! role   u8 (0 = raw bytes; 1 = 4-bit codes, stored nibble-packed via
//!            `quant::pack` at ceil(n/2) bytes)
//! rank   u8, then `rank` u64 dims
//! len    u64 stored data bytes, then the data
//! ```
//!
//! Loading is a single pass over one read of the file — header checks,
//! checksum, then each record is decoded directly into the `HostTensor`
//! the engine serves (f32 bit patterns round-trip exactly, NaN included).
//! Every malformed input — truncation, flipped bytes, wrong version,
//! wrong model — returns `Err`; the loader never panics on file content.
//!
//! The optional RLE variant (flag bit 0) is a PackBits-style byte codec:
//! a control byte `c < 128` is followed by `c + 1` literal bytes, and
//! `c >= 128` repeats the next byte `c - 125` times (runs of 3..=130).
//! Zero-heavy payloads (fresh side-tables, sparse tensors) shrink
//! substantially; incompressible payloads cost at most 1/129 overhead.

use std::path::Path;

use crate::coordinator::EngineParams;
use crate::error::Result;
use crate::eval::quantized::QuantizedServingParams;
use crate::quant::pack;
use crate::runtime::meta::{matmul_param_names, param_specs, ModelMeta};
use crate::runtime::HostTensor;
use crate::util::json::{obj, Json};

pub const MAGIC: &[u8; 8] = b"BOF4ARTF";
pub const VERSION: u32 = 1;
const FLAG_RLE: u32 = 1;

const DTYPE_F32: u8 = 0;
const DTYPE_I32: u8 = 1;
const DTYPE_U8: u8 = 2;
const DTYPE_U32: u8 = 3;
const ROLE_RAW: u8 = 0;
const ROLE_PACKED_Q4: u8 = 1;

/// What an artifact holds (mirrors [`EngineParams`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// The canonical dense f32 parameter tensors.
    Dense,
    /// The q4 serving prefix: non-matmul f32 params, 4-bit codes
    /// (nibble-packed at rest), 8-bit DQ constants, chunk params, OPQ
    /// outlier side-tables, codebook levels.
    QuantizedQ4,
}

impl ArtifactKind {
    fn tag(self) -> &'static str {
        match self {
            ArtifactKind::Dense => "dense",
            ArtifactKind::QuantizedQ4 => "q4",
        }
    }

    fn from_tag(s: &str) -> Result<ArtifactKind> {
        match s {
            "dense" => Ok(ArtifactKind::Dense),
            "q4" => Ok(ArtifactKind::QuantizedQ4),
            other => Err(crate::err!("unknown artifact kind '{other}'")),
        }
    }
}

/// Metadata of a saved/loaded artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub kind: ArtifactKind,
    /// Free-form provenance (e.g. the quantizer configuration).
    pub label: String,
    pub n_tensors: usize,
    /// OPQ outlier count across matmul tensors (0 for dense artifacts).
    pub outliers: usize,
    /// Storage bytes of the quantized representation (0 for dense).
    pub quant_bytes: usize,
    /// f32 bytes of the quantized tensors (0 for dense).
    pub orig_bytes: usize,
    /// Whether the payload is RLE-compressed at rest.
    pub compressed: bool,
    /// Total artifact size on disk.
    pub file_bytes: usize,
}

/// Options for [`save_artifact`].
#[derive(Clone, Debug, Default)]
pub struct SaveOptions {
    pub label: String,
    /// RLE-compress the payload at rest.
    pub compress: bool,
    pub outliers: usize,
    pub quant_bytes: usize,
    pub orig_bytes: usize,
}

impl QuantizedServingParams {
    /// Pack this serving set into an on-disk artifact; reload with
    /// [`load_artifact`] for a bit-identical [`EngineParams::QuantizedQ4`].
    pub fn save_artifact(
        &self,
        path: &Path,
        model: &ModelMeta,
        label: &str,
        compress: bool,
    ) -> Result<ArtifactInfo> {
        save_artifact(
            path,
            model,
            &EngineParams::QuantizedQ4(self.prefix.clone()),
            &SaveOptions {
                label: label.to_string(),
                compress,
                outliers: self.outliers,
                quant_bytes: self.quant_bytes,
                orig_bytes: self.orig_bytes,
            },
        )
    }
}

/// Expected tensor-section layout of a q4 prefix for `model`:
/// `(n_dense, n_mm)` — the prefix is `n_dense + 5 * n_mm + 1` tensors.
fn q4_layout(model: &ModelMeta) -> (usize, usize) {
    let n_mm = matmul_param_names(model).len();
    (param_specs(model).len() - n_mm, n_mm)
}

/// Serialize a parameter set to `path`. For q4 prefixes the 4-bit code
/// tensors are nibble-packed at rest (half the bytes); everything else
/// is stored as raw little-endian.
pub fn save_artifact(
    path: &Path,
    model: &ModelMeta,
    params: &EngineParams,
    opts: &SaveOptions,
) -> Result<ArtifactInfo> {
    let (kind, tensors) = match params {
        EngineParams::Dense(t) => (ArtifactKind::Dense, t),
        EngineParams::QuantizedQ4(t) => (ArtifactKind::QuantizedQ4, t),
    };
    // Validate the tensor count against the model so a malformed set
    // fails at save time, not at load/serve time.
    let expected = match kind {
        ArtifactKind::Dense => param_specs(model).len(),
        ArtifactKind::QuantizedQ4 => {
            let (nd, nm) = q4_layout(model);
            nd + 5 * nm + 1
        }
    };
    if tensors.len() != expected {
        return Err(crate::err!(
            "{} artifact wants {expected} tensors, got {}",
            kind.tag(),
            tensors.len()
        ));
    }
    // Which tensor indices hold 4-bit codes (packable)?
    let packed_range = match kind {
        ArtifactKind::Dense => 0..0,
        ArtifactKind::QuantizedQ4 => {
            let (nd, nm) = q4_layout(model);
            nd..nd + nm
        }
    };

    let mut payload = Vec::new();
    for (i, t) in tensors.iter().enumerate() {
        let role = if packed_range.contains(&i) {
            ROLE_PACKED_Q4
        } else {
            ROLE_RAW
        };
        write_tensor(&mut payload, t, role)?;
    }
    let payload_len = payload.len() as u64;
    let (stored, flags) = if opts.compress {
        (rle_encode(&payload), FLAG_RLE)
    } else {
        (payload, 0)
    };

    let meta = obj(vec![
        ("kind", Json::Str(kind.tag().to_string())),
        ("label", Json::Str(opts.label.clone())),
        ("model", model_json(model)),
        ("n_tensors", Json::Num(tensors.len() as f64)),
        ("outliers", Json::Num(opts.outliers as f64)),
        ("quant_bytes", Json::Num(opts.quant_bytes as f64)),
        ("orig_bytes", Json::Num(opts.orig_bytes as f64)),
    ]);
    let meta_bytes = meta.to_string().into_bytes();

    let mut out = Vec::with_capacity(stored.len() + meta_bytes.len() + 64);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(&(meta_bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&meta_bytes);
    out.extend_from_slice(&payload_len.to_le_bytes());
    out.extend_from_slice(&(stored.len() as u64).to_le_bytes());
    out.extend_from_slice(&stored);
    out.extend_from_slice(&fnv1a64(&stored).to_le_bytes());
    let file_bytes = out.len();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| crate::err!("create {}: {e}", dir.display()))?;
        }
    }
    std::fs::write(path, &out).map_err(|e| crate::err!("write {}: {e}", path.display()))?;
    Ok(ArtifactInfo {
        kind,
        label: opts.label.clone(),
        n_tensors: tensors.len(),
        outliers: opts.outliers,
        quant_bytes: opts.quant_bytes,
        orig_bytes: opts.orig_bytes,
        compressed: opts.compress,
        file_bytes,
    })
}

/// Load an artifact saved by [`save_artifact`], validating magic,
/// version, checksum, model compatibility and per-tensor layout. The
/// returned [`EngineParams`] feeds [`crate::coordinator::Engine::start`]
/// directly; every failure mode is an `Err`, never a panic.
pub fn load_artifact(path: &Path, model: &ModelMeta) -> Result<(EngineParams, ArtifactInfo)> {
    let bytes =
        std::fs::read(path).map_err(|e| crate::err!("read {}: {e}", path.display()))?;
    let file_bytes = bytes.len();
    let mut cur = Cursor::new(&bytes);
    if cur.take(8)? != MAGIC {
        return Err(crate::err!("{}: not a BOF4 artifact (bad magic)", path.display()));
    }
    let version = cur.u32()?;
    if version != VERSION {
        return Err(crate::err!(
            "{}: artifact version {version}, this build reads {VERSION}",
            path.display()
        ));
    }
    let flags = cur.u32()?;
    if flags & !FLAG_RLE != 0 {
        return Err(crate::err!("{}: unknown flags {flags:#x}", path.display()));
    }
    let compressed = flags & FLAG_RLE != 0;
    let meta_len = cur.u32()? as usize;
    let meta_raw = cur.take(meta_len)?;
    let meta_str = std::str::from_utf8(meta_raw)
        .map_err(|_| crate::err!("artifact metadata is not UTF-8"))?;
    let meta =
        Json::parse(meta_str).map_err(|e| crate::err!("artifact metadata: {e}"))?;
    let kind = ArtifactKind::from_tag(
        meta.get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| crate::err!("artifact metadata missing 'kind'"))?,
    )?;
    check_model(&meta, model)?;
    let n_tensors = meta
        .get("n_tensors")
        .and_then(Json::as_usize)
        .ok_or_else(|| crate::err!("artifact metadata missing 'n_tensors'"))?;

    let payload_len = cur.u64()? as usize;
    let stored_len = cur.u64()? as usize;
    let stored = cur.take(stored_len)?;
    let checksum = cur.u64()?;
    if fnv1a64(stored) != checksum {
        return Err(crate::err!(
            "{}: checksum mismatch — artifact is corrupted",
            path.display()
        ));
    }
    let payload_owned;
    let payload: &[u8] = if compressed {
        payload_owned = rle_decode(stored, payload_len)?;
        &payload_owned
    } else {
        if stored.len() != payload_len {
            return Err(crate::err!(
                "uncompressed payload is {} bytes, header says {payload_len}",
                stored.len()
            ));
        }
        stored
    };

    let expected = match kind {
        ArtifactKind::Dense => param_specs(model).len(),
        ArtifactKind::QuantizedQ4 => {
            let (nd, nm) = q4_layout(model);
            nd + 5 * nm + 1
        }
    };
    if n_tensors != expected {
        return Err(crate::err!(
            "{} artifact holds {n_tensors} tensors, this model wants {expected}",
            kind.tag()
        ));
    }
    let mut pcur = Cursor::new(payload);
    let mut tensors = Vec::with_capacity(n_tensors);
    for i in 0..n_tensors {
        tensors.push(
            read_tensor(&mut pcur).map_err(|e| crate::err!("tensor {i}: {e}"))?,
        );
    }
    if pcur.remaining() != 0 {
        return Err(crate::err!(
            "{} trailing payload bytes after the last tensor",
            pcur.remaining()
        ));
    }
    validate_layout(kind, model, &tensors)?;

    let info = ArtifactInfo {
        kind,
        label: meta
            .get("label")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        n_tensors,
        outliers: meta.get("outliers").and_then(Json::as_usize).unwrap_or(0),
        quant_bytes: meta.get("quant_bytes").and_then(Json::as_usize).unwrap_or(0),
        orig_bytes: meta.get("orig_bytes").and_then(Json::as_usize).unwrap_or(0),
        compressed,
        file_bytes,
    };
    let params = match kind {
        ArtifactKind::Dense => EngineParams::Dense(tensors),
        ArtifactKind::QuantizedQ4 => EngineParams::QuantizedQ4(tensors),
    };
    Ok((params, info))
}

fn model_json(m: &ModelMeta) -> Json {
    obj(vec![
        ("vocab", Json::Num(m.vocab as f64)),
        ("d_model", Json::Num(m.d_model as f64)),
        ("n_layers", Json::Num(m.n_layers as f64)),
        ("n_heads", Json::Num(m.n_heads as f64)),
        ("d_ff", Json::Num(m.d_ff as f64)),
        ("seq_len", Json::Num(m.seq_len as f64)),
        ("batch", Json::Num(m.batch as f64)),
        ("block", Json::Num(m.block as f64)),
    ])
}

fn check_model(meta: &Json, model: &ModelMeta) -> Result<()> {
    let want = [
        ("vocab", model.vocab),
        ("d_model", model.d_model),
        ("n_layers", model.n_layers),
        ("n_heads", model.n_heads),
        ("d_ff", model.d_ff),
        ("seq_len", model.seq_len),
        ("batch", model.batch),
        ("block", model.block),
    ];
    for (key, v) in want {
        let got = meta
            .path(&format!("model.{key}"))
            .and_then(Json::as_usize)
            .ok_or_else(|| crate::err!("artifact metadata missing model.{key}"))?;
        if got != v {
            return Err(crate::err!(
                "artifact was packed for {key}={got}, this runtime has {key}={v}"
            ));
        }
    }
    Ok(())
}

/// Cheap structural checks on a decoded tensor set: section dtypes, code
/// shapes, side-table pairing. (Value-level integrity is the checksum's
/// job; exact dequantization errors surface in `dense_from_q4_prefix`.)
fn validate_layout(kind: ArtifactKind, model: &ModelMeta, tensors: &[HostTensor]) -> Result<()> {
    match kind {
        ArtifactKind::Dense => {
            for ((name, shape), t) in param_specs(model).iter().zip(tensors) {
                if t.dtype_str() != "float32" || t.shape() != shape.as_slice() {
                    return Err(crate::err!(
                        "dense tensor '{name}': got {}{:?}, expected float32 {shape:?}",
                        t.dtype_str(),
                        t.shape()
                    ));
                }
            }
        }
        ArtifactKind::QuantizedQ4 => {
            let (nd, nm) = q4_layout(model);
            for mi in 0..nm {
                let codes = &tensors[nd + mi];
                let am_codes = &tensors[nd + nm + mi];
                if codes.dtype_str() != "uint8" || am_codes.dtype_str() != "uint8" {
                    return Err(crate::err!("q4 code tensors {mi} are not uint8"));
                }
                let oi = &tensors[nd + 3 * nm + mi];
                let ov = &tensors[nd + 4 * nm + mi];
                if oi.dtype_str() != "uint32" || ov.dtype_str() != "float32" {
                    return Err(crate::err!("outlier side-table {mi} has wrong dtypes"));
                }
                if oi.shape() != ov.shape() {
                    return Err(crate::err!(
                        "outlier side-table {mi}: {:?} indices vs {:?} values",
                        oi.shape(),
                        ov.shape()
                    ));
                }
            }
            let levels = &tensors[nd + 5 * nm];
            if levels.dtype_str() != "float32" || levels.shape() != [16] {
                return Err(crate::err!("codebook tensor must be float32 [16]"));
            }
        }
    }
    Ok(())
}

fn write_tensor(out: &mut Vec<u8>, t: &HostTensor, role: u8) -> Result<()> {
    let dtype = match t.dtype_str() {
        "float32" => DTYPE_F32,
        "int32" => DTYPE_I32,
        "uint8" => DTYPE_U8,
        "uint32" => DTYPE_U32,
        other => return Err(crate::err!("unsupported artifact dtype {other}")),
    };
    out.push(dtype);
    out.push(role);
    let shape = t.shape();
    out.push(shape.len() as u8);
    for &d in shape {
        out.extend_from_slice(&(d as u64).to_le_bytes());
    }
    let data: Vec<u8> = match (t, role) {
        (HostTensor::U8(d, _), ROLE_PACKED_Q4) => {
            if let Some(&bad) = d.iter().find(|&&c| c >= 16) {
                return Err(crate::err!(
                    "packed-q4 tensor has code {bad} >= 16 — not 4-bit data"
                ));
            }
            pack::pack_u4(d.as_slice())
        }
        (HostTensor::U8(d, _), _) => d.as_slice().to_vec(),
        (HostTensor::F32(d, _), _) => d.iter().flat_map(|v| v.to_le_bytes()).collect(),
        (HostTensor::I32(d, _), _) => d.iter().flat_map(|v| v.to_le_bytes()).collect(),
        (HostTensor::U32(d, _), _) => d.iter().flat_map(|v| v.to_le_bytes()).collect(),
    };
    out.extend_from_slice(&(data.len() as u64).to_le_bytes());
    out.extend_from_slice(&data);
    Ok(())
}

fn read_tensor(cur: &mut Cursor<'_>) -> Result<HostTensor> {
    let dtype = cur.u8()?;
    let role = cur.u8()?;
    if role > ROLE_PACKED_Q4 {
        return Err(crate::err!("unknown tensor role {role}"));
    }
    let rank = cur.u8()? as usize;
    if rank > 4 {
        return Err(crate::err!("implausible tensor rank {rank}"));
    }
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(cur.u64()? as usize);
    }
    // product of an empty shape is 1: scalars carry one element
    let elems: usize = shape.iter().product();
    let len = cur.u64()? as usize;
    let data = cur.take(len)?;
    let elem_check = |unit: usize| -> Result<()> {
        if len != elems * unit {
            return Err(crate::err!(
                "data is {len} bytes, shape {shape:?} wants {}",
                elems * unit
            ));
        }
        Ok(())
    };
    Ok(match (dtype, role) {
        (DTYPE_U8, ROLE_PACKED_Q4) => {
            if len != elems.div_ceil(2) {
                return Err(crate::err!(
                    "packed q4 data is {len} bytes, shape {shape:?} wants {}",
                    elems.div_ceil(2)
                ));
            }
            HostTensor::u8(pack::unpack_u4(data, elems), shape)
        }
        (DTYPE_U8, _) => {
            elem_check(1)?;
            HostTensor::u8(data.to_vec(), shape)
        }
        (DTYPE_F32, ROLE_RAW) => {
            elem_check(4)?;
            let v = data
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            HostTensor::f32(v, shape)
        }
        (DTYPE_I32, ROLE_RAW) => {
            elem_check(4)?;
            let v = data
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            HostTensor::i32(v, shape)
        }
        (DTYPE_U32, ROLE_RAW) => {
            elem_check(4)?;
            let v = data
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            HostTensor::u32(v, shape)
        }
        (d, r) => return Err(crate::err!("invalid dtype/role combination {d}/{r}")),
    })
}

/// FNV-1a 64-bit over a byte stream (hermetic, no dependency).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// PackBits-style RLE: control `c < 128` → `c + 1` literal bytes follow;
/// `c >= 128` → the next byte repeats `c - 125` times (runs of 3..=130).
fn rle_encode(src: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(src.len() / 4 + 16);
    let mut i = 0;
    let mut lit_start = 0;
    let mut flush_literals = |out: &mut Vec<u8>, lo: usize, hi: usize| {
        let mut s = lo;
        while s < hi {
            let n = (hi - s).min(128);
            out.push((n - 1) as u8);
            out.extend_from_slice(&src[s..s + n]);
            s += n;
        }
    };
    while i < src.len() {
        let b = src[i];
        let mut run = 1;
        while run < 130 && i + run < src.len() && src[i + run] == b {
            run += 1;
        }
        if run >= 3 {
            flush_literals(&mut out, lit_start, i);
            out.push((125 + run) as u8);
            out.push(b);
            i += run;
            lit_start = i;
        } else {
            i += run;
        }
    }
    flush_literals(&mut out, lit_start, src.len());
    out
}

fn rle_decode(src: &[u8], expect: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(expect);
    let mut i = 0;
    while i < src.len() {
        let c = src[i] as usize;
        i += 1;
        if c < 128 {
            let n = c + 1;
            let lit = src
                .get(i..i + n)
                .ok_or_else(|| crate::err!("RLE literal run truncated"))?;
            out.extend_from_slice(lit);
            i += n;
        } else {
            let b = *src
                .get(i)
                .ok_or_else(|| crate::err!("RLE repeat run truncated"))?;
            i += 1;
            let n = c - 125;
            out.resize(out.len() + n, b);
        }
        if out.len() > expect {
            return Err(crate::err!(
                "RLE stream expands past the declared payload length {expect}"
            ));
        }
    }
    if out.len() != expect {
        return Err(crate::err!(
            "RLE stream decoded to {} bytes, header says {expect}",
            out.len()
        ));
    }
    Ok(out)
}

/// Bounds-checked byte reader — every overrun is an `Err`, not a panic.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let s = self
            .b
            .get(self.pos..self.pos.checked_add(n).ok_or_else(|| {
                crate::err!("artifact length overflow")
            })?)
            .ok_or_else(|| crate::err!("artifact truncated (wanted {n} more bytes)"))?;
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn rle_roundtrip_shapes() {
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            vec![7],
            vec![0; 1000],                                // one long run
            (0..=255u8).collect(),                        // pure literals
            [vec![1; 5], (0..200).collect(), vec![9; 3]].concat(), // mixed
            vec![4; 130],                                 // exactly max run
            vec![4; 131],                                 // run + 1
            vec![1, 1],                                   // run below threshold
        ];
        for c in cases {
            let enc = rle_encode(&c);
            assert_eq!(rle_decode(&enc, c.len()).unwrap(), c, "len {}", c.len());
        }
        // zero-heavy data actually compresses
        let zeros = vec![0u8; 4096];
        assert!(rle_encode(&zeros).len() < 100);
    }

    #[test]
    fn rle_decode_rejects_bad_streams() {
        assert!(rle_decode(&[5], 6).is_err()); // literal run truncated
        assert!(rle_decode(&[200], 75).is_err()); // repeat byte missing
        assert!(rle_decode(&[130, 9], 2).is_err()); // expands past expect
        assert!(rle_decode(&[0, 1], 5).is_err()); // too short overall
    }

    #[test]
    fn cursor_overruns_are_errors() {
        let mut c = Cursor::new(&[1, 2, 3]);
        assert_eq!(c.take(2).unwrap(), &[1, 2]);
        assert!(c.u32().is_err());
        assert_eq!(c.u8().unwrap(), 3);
        assert!(c.u8().is_err());
    }

    #[test]
    fn tensor_record_roundtrip_bit_exact() {
        let tensors = vec![
            HostTensor::f32(vec![1.5, -0.0, f32::NAN, f32::INFINITY], vec![4]),
            HostTensor::i32(vec![-5, 0, 7], vec![3]),
            HostTensor::u32(vec![u32::MAX, 0], vec![2]),
            HostTensor::u8(vec![0, 15, 200], vec![3]),
            HostTensor::f32(vec![2.25], vec![]), // scalar rank
        ];
        let mut buf = Vec::new();
        for t in &tensors {
            write_tensor(&mut buf, t, ROLE_RAW).unwrap();
        }
        let mut cur = Cursor::new(&buf);
        for t in &tensors {
            let rt = read_tensor(&mut cur).unwrap();
            assert_eq!(rt.shape(), t.shape());
            assert_eq!(rt.dtype_str(), t.dtype_str());
            // bit-exact comparison (NaN != NaN under PartialEq)
            if let (Ok(a), Ok(b)) = (rt.as_f32(), t.as_f32()) {
                let ab: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
                assert_eq!(ab, bb);
            } else {
                assert_eq!(rt, *t);
            }
        }
        assert_eq!(cur.remaining(), 0);
    }

    #[test]
    fn packed_role_halves_codes_and_rejects_wide_values() {
        let codes = HostTensor::u8((0..16u8).chain(0..16).collect(), vec![4, 8]);
        let mut buf = Vec::new();
        write_tensor(&mut buf, &codes, ROLE_PACKED_Q4).unwrap();
        // record data = 16 bytes (32 nibbles), vs 32 raw
        let rt = read_tensor(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(rt, codes);
        // a u8 tensor with values >= 16 must not silently corrupt
        let wide = HostTensor::u8(vec![99], vec![1]);
        assert!(write_tensor(&mut Vec::new(), &wide, ROLE_PACKED_Q4).is_err());
    }
}
