//! Crate-local error type: a tiny, dependency-free `anyhow` stand-in.
//!
//! The hermetic default build must compile with zero crates.io
//! dependencies, so this module provides the three things the crate
//! actually used from `anyhow`: a string-y error with a cause chain, a
//! `Result` alias, and `.context()` / `.with_context()` adapters. The
//! `crate::err!` macro replaces `anyhow!`.

use std::fmt;

/// Typed serving-engine failure classes, attached to [`Error`] so
/// callers can branch on *why* a session failed instead of parsing
/// message strings. Every variant corresponds to a documented engine
/// behaviour (see README "Fault tolerance & admission control").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// Admission refused (or an older session shed) because the engine
    /// queue depth reached `limit`. Retryable: the caller may back off
    /// and resubmit.
    Overloaded { depth: u64, limit: u64 },
    /// The session exceeded its configured deadline and was cancelled
    /// at a decode-step boundary.
    DeadlineExceeded { elapsed_ms: u64, deadline_ms: u64 },
    /// The replica serving this session died (panic or backend fault)
    /// and its in-flight work could not be preserved.
    ReplicaDead { replica: usize },
    /// The stream produced no token within the admission timeout — the
    /// engine is wedged or the replica stalled. Retryable.
    Timeout { waited_ms: u64 },
    /// The engine has shut down (all replicas gone or dropped).
    Stopped,
}

impl EngineError {
    /// Transient faults a client may retry after backoff.
    pub fn is_retryable(self) -> bool {
        matches!(
            self,
            EngineError::Overloaded { .. } | EngineError::Timeout { .. }
        )
    }
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::Overloaded { depth, limit } => {
                write!(f, "engine overloaded: queue depth {depth} >= limit {limit}")
            }
            EngineError::DeadlineExceeded {
                elapsed_ms,
                deadline_ms,
            } => write!(
                f,
                "session deadline exceeded: {elapsed_ms}ms elapsed > {deadline_ms}ms deadline"
            ),
            EngineError::ReplicaDead { replica } => {
                write!(f, "replica {replica} died while serving this session")
            }
            EngineError::Timeout { waited_ms } => {
                write!(f, "no token within {waited_ms}ms admission timeout")
            }
            EngineError::Stopped => write!(f, "engine stopped"),
        }
    }
}

/// A message plus an optional chain of causes.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
    engine: Option<EngineError>,
}

impl Error {
    /// Build from a message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error {
            msg: msg.into(),
            cause: None,
            engine: None,
        }
    }

    /// Wrap an existing error with outer context.
    pub fn wrap(msg: impl Into<String>, cause: Error) -> Error {
        Error {
            msg: msg.into(),
            cause: Some(Box::new(cause)),
            engine: None,
        }
    }

    /// Build a typed serving-engine error. The Display message comes
    /// from the [`EngineError`] itself, so logs and matches agree.
    pub fn engine(kind: EngineError) -> Error {
        Error {
            msg: kind.to_string(),
            cause: None,
            engine: Some(kind),
        }
    }

    /// The typed engine failure class, if any error in the cause chain
    /// carries one (outermost wins).
    pub fn engine_error(&self) -> Option<EngineError> {
        std::iter::successors(Some(self), |e| e.cause.as_deref()).find_map(|e| e.engine)
    }

    /// True when the chain carries a retryable [`EngineError`].
    pub fn is_retryable(&self) -> bool {
        self.engine_error().is_some_and(EngineError::is_retryable)
    }

    /// Iterate the cause chain (outermost first).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::successors(Some(self), |e| e.cause.as_deref()).map(|e| e.msg.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cause = self.cause.as_deref();
            while let Some(e) = cause {
                write!(f, ": {}", e.msg)?;
                cause = e.cause.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.cause.as_deref();
        while let Some(e) = cause {
            write!(f, "\n  caused by: {}", e.msg)?;
            cause = e.cause.as_deref();
        }
        Ok(())
    }
}

// Any std error converts losslessly (message + source chain). `Error`
// itself deliberately does NOT implement `std::error::Error`, which is
// what makes this blanket impl coherent (the same trick anyhow uses).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut messages = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            messages.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(messages.pop().expect("at least one message"));
        while let Some(m) = messages.pop() {
            err = Error::wrap(m, err);
        }
        err
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context()` / `.with_context()` on results, mirroring anyhow's API.
pub trait Context<T> {
    fn context<S: Into<String>>(self, msg: S) -> Result<T>;
    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<S: Into<String>>(self, msg: S) -> Result<T> {
        self.map_err(|e| Error::wrap(msg, e.into()))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::wrap(f(), e.into()))
    }
}

/// Format an [`Error`] in place (the crate's `anyhow!` replacement).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_alternate() {
        let e = Error::wrap("outer", Error::msg("inner"));
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert!(format!("{e:?}").contains("caused by: inner"));
    }

    #[test]
    fn from_std_error_keeps_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(format!("{e}").contains("gone"));
    }

    #[test]
    fn context_wraps() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::other("deep"));
        let e = r.with_context(|| "shallow".to_string()).unwrap_err();
        assert_eq!(format!("{e}"), "shallow");
        assert!(format!("{e:#}").contains("deep"));
    }

    #[test]
    fn macro_formats() {
        let e = crate::err!("x = {}", 42);
        assert_eq!(format!("{e}"), "x = 42");
    }

    #[test]
    fn engine_error_survives_wrapping() {
        let kind = EngineError::Overloaded {
            depth: 9,
            limit: 8,
        };
        let e = Error::wrap("submit failed", Error::engine(kind));
        assert_eq!(e.engine_error(), Some(kind));
        assert!(e.is_retryable());
        assert!(format!("{e:#}").contains("queue depth 9 >= limit 8"));
    }

    #[test]
    fn engine_error_retryability_split() {
        assert!(Error::engine(EngineError::Timeout { waited_ms: 5 }).is_retryable());
        assert!(!Error::engine(EngineError::Stopped).is_retryable());
        assert!(!Error::engine(EngineError::ReplicaDead { replica: 1 }).is_retryable());
        assert!(!Error::engine(EngineError::DeadlineExceeded {
            elapsed_ms: 10,
            deadline_ms: 1,
        })
        .is_retryable());
        assert!(crate::err!("plain").engine_error().is_none());
    }
}
