//! # BOF4 — 4-bit Block-Wise Optimal Float quantization for LLMs
//!
//! Production-grade reproduction of *"Improving Block-Wise LLM Quantization
//! by 4-bit Block-Wise Optimal Float (BOF4): Analysis and Variations"*
//! (Blumenberg, Graave, Fingscheidt, 2025).
//!
//! The crate is the **Layer-3 rust coordinator** of a three-layer stack:
//!
//! - **L1** Pallas kernels (build-time python, `python/compile/kernels/`):
//!   block-wise quantization and the fused 4-bit dequant-matmul hot path.
//! - **L2** JAX model graphs (`python/compile/model.py`): a GPT-style LM,
//!   its AdamW train step, LoRA fine-tune step and NLL/logit eval heads,
//!   AOT-lowered once to HLO text in `artifacts/`.
//! - **L3** this crate: the complete quantization system (codebooks, EM
//!   design, OPQ, packing), the PJRT runtime that executes the lowered
//!   graphs, the multithreaded quantization scheduler, the batched
//!   inference service, and the experiment harness regenerating every
//!   table and figure of the paper.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `bof4` binary and all benches are self-contained.
//!
//! ## Quick tour
//!
//! ```no_run
//! use bof4::quant::{Quantizer, QuantConfig, Method, Norm};
//! use bof4::util::rng::Pcg64;
//!
//! // 1M Gaussian "network weights"
//! let mut rng = Pcg64::seed_from_u64(7);
//! let w: Vec<f32> = (0..1 << 20).map(|_| rng.next_gaussian() as f32).collect();
//!
//! // BOF4-S (MSE-optimal, signed absmax normalization), block size 64
//! let q = Quantizer::new(QuantConfig {
//!     method: Method::Bof4 { mse: true },
//!     norm: Norm::SignedAbsmax,
//!     block: 64,
//!     ..Default::default()
//! });
//! let packed = q.quantize(&w);
//! let w_hat = q.dequantize(&packed);
//! let mse = bof4::quant::error::mse(&w, &w_hat);
//! println!("MSE = {mse:.3e}");
//! ```

pub mod bench;
pub mod coordinator;
pub mod eval;
pub mod lloyd;
pub mod models;
pub mod quant;
pub mod runtime;
pub mod stats;
pub mod tensor;
pub mod testkit;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Paper reference string used in reports.
pub const PAPER: &str =
    "Blumenberg, Graave, Fingscheidt (2025): Improving Block-Wise LLM \
     Quantization by 4-bit Block-Wise Optimal Float (BOF4)";
