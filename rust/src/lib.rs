//! # BOF4 — 4-bit Block-Wise Optimal Float quantization for LLMs
//!
//! Production-grade reproduction of *"Improving Block-Wise LLM Quantization
//! by 4-bit Block-Wise Optimal Float (BOF4): Analysis and Variations"*
//! (Blumenberg, Graave, Fingscheidt, 2025).
//!
//! The crate is the **Layer-3 rust coordinator** of a three-layer stack:
//!
//! - **L1** Pallas kernels (build-time python, `python/compile/kernels/`):
//!   block-wise quantization and the fused 4-bit dequant-matmul hot path.
//! - **L2** JAX model graphs (`python/compile/model.py`): a GPT-style LM,
//!   its AdamW train step, LoRA fine-tune step and NLL/logit eval heads,
//!   AOT-lowered once to HLO text in `artifacts/`.
//! - **L3** this crate: the complete quantization system (codebooks, EM
//!   design, OPQ, packing), a **multi-backend runtime** behind
//!   [`runtime::Backend`] — a pure-Rust CPU interpreter (default, fully
//!   hermetic) and the PJRT/XLA executor (behind the `xla` feature) — the
//!   multithreaded quantization scheduler, the session-based serving
//!   engine ([`coordinator::Engine`]: KV-cached incremental decoding with
//!   multi-replica continuous batching), and the experiment harness
//!   regenerating every table and figure of the paper.
//!
//! Python never runs on the request path. The default build needs no
//! Python at all: the CPU backend interprets every graph (embedding
//! gather, fused 4-bit dequant-matmul, attention, layer norms, AdamW and
//! LoRA training steps) directly in Rust, so `cargo test` is
//! self-contained offline.
//!
//! ## Quick tour
//!
//! Quantize Gaussian "network weights" with BOF4-S (MSE-optimal, signed
//! absmax normalization) at block size 64:
//!
//! ```
//! use bof4::quant::{Quantizer, QuantConfig, Method, Norm};
//! use bof4::util::rng::Pcg64;
//!
//! let mut rng = Pcg64::seed_from_u64(7);
//! let w: Vec<f32> = (0..1 << 16).map(|_| rng.next_gaussian() as f32).collect();
//!
//! let q = Quantizer::new(QuantConfig {
//!     method: Method::Bof4 { mse: true },
//!     norm: Norm::SignedAbsmax,
//!     block: 64,
//!     ..Default::default()
//! });
//! let packed = q.quantize(&w);
//! let w_hat = q.dequantize(&packed);
//! let mse = bof4::quant::error::mse(&w, &w_hat);
//! assert!(mse > 0.0 && mse < 1e-2);
//! ```
//!
//! Run a model graph end-to-end on the hermetic CPU backend (no Python,
//! no artifacts, no network):
//!
//! ```
//! use bof4::runtime::{HostTensor, Runtime};
//!
//! let rt = Runtime::new().unwrap(); // defaults to the CPU interpreter
//! let params = rt.run("init_params", &[HostTensor::scalar_u32(0)]).unwrap();
//! assert_eq!(params.len(), 16);
//! assert_eq!(params[0].shape(), &[rt.meta.model.vocab, rt.meta.model.d_model]);
//! ```
//!
//! Stream tokens from the serving engine — prompts are prefilled once
//! into per-session KV caches, then each token costs one incremental
//! `lm_decode_step` (attention over `cache_len + 1` positions) instead of
//! a full-context recompute. Sessions admit into free batch slots while
//! others are mid-decode (continuous batching), and
//! [`coordinator::EngineConfig`] scales replicas:
//!
//! ```
//! use std::sync::Arc;
//! use bof4::coordinator::{Engine, EngineConfig};
//! use bof4::runtime::{HostTensor, Runtime};
//!
//! let rt = Arc::new(Runtime::new().unwrap());
//! let params = rt.run("init_params", &[HostTensor::scalar_u32(0)]).unwrap();
//! let engine = Engine::start(rt, params, EngineConfig::default()).unwrap();
//! let session = engine.session_with(&[1, 2, 3], 4).unwrap();
//! let tokens: Vec<u8> = session.map(|ev| ev.unwrap().next_token).collect();
//! assert_eq!(tokens.len(), 4);
//! ```
//!
//! Greedy streams are bit-identical to full-context re-execution through
//! `lm_logits_last`/`lm_logits_all` (integration-tested for every prompt
//! length, dense and 4-bit + double-quantized weights). The former
//! single-shot service, [`coordinator::BatchedLm`], survives as a thin
//! deprecated shim over the engine.
//!
//! With the off-by-default `xla` cargo feature (plus vendored `xla` crate
//! and `make artifacts`), the same calls execute the AOT'd HLO graphs
//! through PJRT instead — see [`runtime::Backend`]. The XLA artifact set
//! stops at the eval forwards: the engine's `lm_prefill`/`lm_decode_step`
//! graphs are CPU-builtin, and [`coordinator::Engine::start`]
//! automatically falls back to full-context serving through
//! `lm_logits_all` (same session semantics, quadratic decode cost) on
//! backends without them.

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod error;
pub mod eval;
pub mod lloyd;
pub mod models;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod stats;
pub mod tensor;
pub mod testkit;
pub mod util;

pub use error::Error;

/// Crate-wide result type.
pub type Result<T> = error::Result<T>;

/// Paper reference string used in reports.
pub const PAPER: &str =
    "Blumenberg, Graave, Fingscheidt (2025): Improving Block-Wise LLM \
     Quantization by 4-bit Block-Wise Optimal Float (BOF4)";
