//! Measurement harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/p50/p95 and throughput reporting.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>10}  p50 {:>10}  p95 {:>10}",
            self.name,
            self.iters,
            crate::util::timer::fmt_duration(self.mean),
            crate::util::timer::fmt_duration(self.p50),
            crate::util::timer::fmt_duration(self.p95),
        )
    }
}

/// Run `f` with warmup, then time `iters` iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let total: Duration = times.iter().sum();
    let m = Measurement {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: times[iters / 2],
        p95: times[(iters as f64 * 0.95) as usize % iters],
        min: times[0],
    };
    println!("{}", m.report()); // lint: allow(stdout-in-lib): bench harness
    m
}

/// Auto-calibrated variant: choose the iteration count so the measured
/// phase takes roughly `target`.
pub fn bench_auto<F: FnMut()>(name: &str, target: Duration, mut f: F) -> Measurement {
    // one probe run
    let t0 = Instant::now();
    f();
    let probe = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (target.as_secs_f64() / probe.as_secs_f64()).clamp(3.0, 10_000.0) as usize;
    bench(name, (iters / 10).max(1), iters, f)
}

/// Decode-throughput comparison between the pre-engine full-recompute
/// path, the engine at one kernel thread, the engine with SIMD forced
/// off, and the engine at the default configuration (threaded +
/// vectorized kernels + in-place KV caches).
#[derive(Clone, Copy, Debug)]
pub struct DecodeThroughput {
    pub tokens: usize,
    pub full_recompute: Duration,
    /// Engine wall time at the default thread count.
    pub engine: Duration,
    /// Engine wall time with a 1-thread kernel pool (the PR-2-shaped
    /// single-thread baseline; equals `engine` on non-CPU backends or
    /// when the pool already has one thread).
    pub engine_single: Duration,
    /// Engine wall time at the default thread count with the SIMD layer
    /// forced to the scalar path (equals `engine` on non-CPU backends or
    /// when the active path is already `none`).
    pub engine_scalar: Duration,
    /// Engine wall time over the dense weights with the KV cache pinned
    /// `f32` — the baseline for the quantized-KV overhead contract.
    /// `None` off-CPU (quantized KV needs the in-place decode protocol).
    pub engine_kv_f32: Option<Duration>,
    /// Engine wall time over the same dense weights with the KV cache
    /// pinned `q8` (block-wise absmax int8, dequantized fused inside the
    /// decode attention). `None` alongside `engine_kv_f32`.
    pub engine_kv_q8: Option<Duration>,
    /// KV-cache format of the measured (default-config) engine.
    pub kv_format: &'static str,
    /// Resident KV bytes one session costs per context token at the
    /// measured engine's format (0 in full-context mode).
    pub kv_bytes_per_token: usize,
    /// Sessions per GiB of KV-cache memory at the measured engine's
    /// format (0.0 in full-context mode).
    pub sessions_per_gb: f64,
    /// Engine wall time serving the q4 serving path (4-bit codes + DQ
    /// constants, empty outlier side-table). `None` when the backend has
    /// no q4 serving graphs.
    pub engine_q4: Option<Duration>,
    /// Engine wall time serving the same (spiked) weights q4 **with an
    /// OPQ outlier side-table** — isolates the side-table lookup cost in
    /// the fused kernels. `None` alongside `engine_q4`.
    pub engine_q4_opq: Option<Duration>,
    /// OPQ outliers in the side-table the `engine_q4_opq` leg served.
    pub opq_outliers: usize,
    /// Engine wall time (best-of-5) with the span tracer forced
    /// [`crate::obs::TraceLevel::Off`] — the trace-overhead baseline.
    /// `None` when the trace legs were skipped (off-CPU).
    pub engine_trace_off: Option<Duration>,
    /// Engine wall time (best-of-5) at engine-level tracing over the
    /// same engine and prompt. The release smoke asserts
    /// [`DecodeThroughput::trace_overhead`] stays under 1.05, and the
    /// leg itself pins the streams bit-identical across levels.
    pub engine_trace_on: Option<Duration>,
    /// Engine wall time (best-of-5) with admission control off (no
    /// `max_queue_depth`) — the baseline for the admission-overhead
    /// contract. `None` when the admission legs were skipped (off-CPU).
    pub engine_admit_off: Option<Duration>,
    /// Engine wall time (best-of-5) with `max_queue_depth` set far above
    /// the bench load, so the full admission bookkeeping (depth check +
    /// shed-registry insert/remove) runs on every submit without ever
    /// shedding. The release smoke asserts
    /// [`DecodeThroughput::admission_overhead`] stays under 1.02, and
    /// the leg itself pins the streams bit-identical.
    pub engine_admit_on: Option<Duration>,
    /// Sessions the admission-on leg shed (the leg engine's
    /// `sessions_shed` counter). Must be 0: the leg's depth bound is
    /// unreachable, so any shed there is an admission-control bug.
    pub admit_shed_total: u64,
    /// Kernel-pool width the `engine` measurement ran at.
    pub threads: usize,
    /// Active SIMD path of the measured engine (`none|array|avx2`).
    pub simd: &'static str,
    /// Wall time for `Engine::start` over the in-memory dense weights
    /// (replica spawn + prefill-arg setup — the warm cold-start).
    pub cold_start: Duration,
    /// Wall time to reload the serialized artifact from disk and
    /// `Engine::start` from it (the serve-from-artifact cold start).
    pub artifact_cold_start: Duration,
    /// On-disk size of the round-tripped artifact.
    pub artifact_bytes: usize,
    /// Replica count of the measured engine.
    pub replicas: usize,
    /// Parameter bytes resident once, shared by every replica of the
    /// measured engine.
    pub shared_param_bytes: usize,
    /// Private resident bytes of one replica (KV-cache slab, token and
    /// position placeholders).
    pub per_replica_bytes: usize,
    /// Total resident bytes of a 1-replica engine over the same
    /// weights.
    pub total_resident_1: usize,
    /// Total resident bytes of a 2-replica engine over the same
    /// weights. Sharing invariant: parameters are resident once, so
    /// `total_resident_2 < 2 * total_resident_1` (strictly, because
    /// only the per-replica KV slabs doubled).
    pub total_resident_2: usize,
}

impl DecodeThroughput {
    pub fn full_tps(&self) -> f64 {
        self.tokens as f64 / self.full_recompute.as_secs_f64().max(1e-12)
    }

    pub fn engine_tps(&self) -> f64 {
        self.tokens as f64 / self.engine.as_secs_f64().max(1e-12)
    }

    pub fn engine_single_tps(&self) -> f64 {
        self.tokens as f64 / self.engine_single.as_secs_f64().max(1e-12)
    }

    pub fn speedup(&self) -> f64 {
        self.full_recompute.as_secs_f64() / self.engine.as_secs_f64().max(1e-12)
    }

    /// Threaded engine vs the 1-thread engine (1.0 when no comparison
    /// ran).
    pub fn thread_speedup(&self) -> f64 {
        self.engine_single.as_secs_f64() / self.engine.as_secs_f64().max(1e-12)
    }

    pub fn engine_scalar_tps(&self) -> f64 {
        self.tokens as f64 / self.engine_scalar.as_secs_f64().max(1e-12)
    }

    /// SIMD engine vs the forced-scalar engine at the same thread count
    /// (1.0 when no comparison ran).
    pub fn simd_speedup(&self) -> f64 {
        self.engine_scalar.as_secs_f64() / self.engine.as_secs_f64().max(1e-12)
    }

    /// Relative cost of the OPQ side-table lookup in the fused q4
    /// kernels: `engine_q4_opq / engine_q4` (1.0 when the q4 legs did
    /// not run). The release smoke asserts this stays under 1.10.
    pub fn opq_overhead(&self) -> f64 {
        match (self.engine_q4, self.engine_q4_opq) {
            (Some(q4), Some(opq)) => opq.as_secs_f64() / q4.as_secs_f64().max(1e-12),
            _ => 1.0,
        }
    }

    /// Relative decode cost of the q8 KV cache over the f32 baseline:
    /// `engine_kv_q8 / engine_kv_f32` (1.0 when the KV legs did not
    /// run). The release smoke asserts this stays under 1.15.
    pub fn kv_overhead(&self) -> f64 {
        match (self.engine_kv_f32, self.engine_kv_q8) {
            (Some(f), Some(q)) => q.as_secs_f64() / f.as_secs_f64().max(1e-12),
            _ => 1.0,
        }
    }

    /// Relative cost of engine-level span tracing:
    /// `engine_trace_on / engine_trace_off` (1.0 when the trace legs
    /// did not run). The release smoke asserts this stays under 1.05.
    pub fn trace_overhead(&self) -> f64 {
        match (self.engine_trace_off, self.engine_trace_on) {
            (Some(off), Some(on)) => on.as_secs_f64() / off.as_secs_f64().max(1e-12),
            _ => 1.0,
        }
    }

    /// Relative cost of admission control on the serve path:
    /// `engine_admit_on / engine_admit_off` (1.0 when the admission
    /// legs did not run). The release smoke asserts this stays under
    /// 1.02 — admission is a queue-depth gauge read plus one
    /// short-critical-section registry update per session, never
    /// per-token work.
    pub fn admission_overhead(&self) -> f64 {
        match (self.engine_admit_off, self.engine_admit_on) {
            (Some(off), Some(on)) => on.as_secs_f64() / off.as_secs_f64().max(1e-12),
            _ => 1.0,
        }
    }

    /// Resident-byte growth when doubling the replica count:
    /// `total_resident_2 / total_resident_1`. Must stay strictly below
    /// 2.0 — the shared weight set is counted once no matter how many
    /// replicas hold views over it.
    pub fn replica_growth(&self) -> f64 {
        self.total_resident_2 as f64 / (self.total_resident_1 as f64).max(1.0)
    }
}

/// Greedy-decode `n_tokens` over the same parameters four ways: (a) the
/// old full-recompute loop — one whole-context `lm_logits_last`
/// execution per emitted token, cost quadratic in sequence length; (b)
/// one [`crate::coordinator::Engine`] session over a 1-thread CPU
/// backend (the PR-2-shaped single-thread baseline; skipped off-CPU);
/// (c) one engine session at the default thread count with the SIMD
/// layer forced scalar (skipped off-CPU or when the active path is
/// already `none`); (d) one engine session at the default configuration
/// (threaded + vectorized kernels + in-place KV caches); plus, on
/// backends with the q4 serving graphs, (e) a q4-at-rest engine leg and
/// (f) the same weights with an OPQ outlier side-table, pricing the
/// fused side-table lookup ([`DecodeThroughput::opq_overhead`]). The
/// dense streams must agree — the bench doubles as a determinism smoke
/// test for both the thread and the SIMD contract.
///
/// Two further legs pin the PR-6 serving contracts: the engine's
/// [`memory profile`](crate::coordinator::Engine::memory_profile) is
/// compared between a 1- and a 2-replica engine (shared parameter bytes
/// must be identical; total resident bytes must grow sub-linearly), and
/// the dense weights are round-tripped through the on-disk artifact
/// ([`crate::eval::save_artifact`] / [`crate::eval::load_artifact`])
/// with the artifact-loaded engine required to serve the identical
/// token stream. Cold-start wall times for both paths are reported.
///
/// The PR-7 KV legs serve the dense weights twice more with the
/// per-session cache pinned [`crate::quant::KvFormat::F32`] vs
/// [`crate::quant::KvFormat::Q8`], pricing the fused q8 dequant inside
/// the decode attention ([`DecodeThroughput::kv_overhead`]); the
/// measured engine's KV format, per-token cache bytes and sessions/GiB
/// are reported alongside.
///
/// The trace legs re-time the default engine with the span tracer
/// forced [`crate::obs::TraceLevel::Off`] and then at engine level
/// (best-of-5 each), pinning the streams bit-identical across levels
/// and pricing the instrumentation
/// ([`DecodeThroughput::trace_overhead`], asserted < 1.05 by the
/// release smoke).
///
/// The PR-9 admission legs re-serve the dense weights with admission
/// control off vs `max_queue_depth` bounded-but-unreachable (best-of-5
/// each), pinning the streams bit-identical and pricing the per-submit
/// admission bookkeeping ([`DecodeThroughput::admission_overhead`],
/// asserted < 1.02 by the release smoke).
pub fn decode_throughput(
    rt: &std::sync::Arc<crate::runtime::Runtime>,
    params: Vec<crate::runtime::HostTensor>,
    prompt: &[u8],
    n_tokens: usize,
) -> crate::error::Result<DecodeThroughput> {
    use crate::coordinator::{greedy_argmax, Engine, EngineConfig};
    use crate::models::corpus::TOK_SPACE;
    use crate::runtime::kernels::SimdPath;
    use crate::runtime::{CpuBackend, HostTensor, Meta, Runtime};
    use std::sync::Arc;
    let m = rt.meta.model.clone();
    let (b, s, v) = (m.batch, m.seq_len, m.vocab);

    // (a) full recompute, exactly the pre-engine BatchedLm::generate
    // shape: left-aligned pad, full forward per token
    let mut ctx = prompt.to_vec();
    let t0 = Instant::now();
    for _ in 0..n_tokens {
        let mut toks = vec![TOK_SPACE as i32; b * s];
        let take = ctx.len().min(s);
        let tail = &ctx[ctx.len() - take..];
        for (dst, &t) in toks[s - take..s].iter_mut().zip(tail) {
            *dst = t as i32;
        }
        let mut args = params.clone();
        args.push(HostTensor::i32(toks, vec![b, s]));
        let out = rt.run("lm_logits_last", &args)?;
        let logits = out[0].as_f32()?;
        let (tok, _) = greedy_argmax(&logits[..v]);
        ctx.push(tok);
    }
    let full_recompute = t0.elapsed();

    // the measured engine's actual pool width and SIMD path (not the env
    // derivation — a runtime built via CpuBackend::with_threads /
    // with_config must be reported as built)
    let threads = rt.pool_threads().unwrap_or(1);
    let simd = rt.simd_path().unwrap_or("none");

    // (b) the engine over a 1-thread kernel pool (CPU backend only)
    let mut engine_single = None;
    let mut single_toks = None;
    if rt.platform() == "cpu-interpreter" && threads > 1 {
        let meta = Meta::builtin();
        let be = CpuBackend::with_threads(meta.model.clone(), 1);
        let rt1 = Arc::new(Runtime::with_backend(meta, Box::new(be)));
        let engine1 = Engine::start(rt1, params.clone(), EngineConfig::default())?;
        let t0 = Instant::now();
        let toks1 = engine1.generate(prompt, n_tokens)?;
        engine_single = Some(t0.elapsed());
        single_toks = Some(toks1);
    }

    // (c) the engine at the same thread count with SIMD forced scalar
    // (CPU backend only, and only when the measured path is vectorized)
    let mut engine_scalar = None;
    let mut scalar_toks = None;
    if rt.platform() == "cpu-interpreter" && simd != "none" {
        let meta = Meta::builtin();
        let be = CpuBackend::with_config(meta.model.clone(), threads, SimdPath::None);
        let rts = Arc::new(Runtime::with_backend(meta, Box::new(be)));
        let engine_s = Engine::start(rts, params.clone(), EngineConfig::default())?;
        let t0 = Instant::now();
        let toks_s = engine_s.generate(prompt, n_tokens)?;
        engine_scalar = Some(t0.elapsed());
        scalar_toks = Some(toks_s);
    }

    // (e/f) the q4 serving legs: the same weights (spiked so OPQ has a
    // non-empty side-table) served 4-bit at rest — once with an empty
    // outlier table and once with OPQ — to price the side-table lookup
    // inside the fused kernels. CPU backend only (needs the q4 graphs).
    let mut engine_q4 = None;
    let mut engine_q4_opq = None;
    let mut opq_outliers = 0usize;
    if rt.meta.graphs.contains_key("lm_prefill_q4") {
        use crate::models::ParamSet;
        use crate::quant::{Method, Norm, OpqConfig, QuantConfig};
        let gm = rt.meta.graph("lm_nll")?.clone();
        let mut pset = ParamSet::from_tensors(&gm, &params)?;
        for (name, shape, data) in pset.entries.iter_mut() {
            if shape.len() == 2 && name.contains(".w") {
                for i in (13..data.len()).step_by(401) {
                    data[i] *= 30.0;
                }
            }
        }
        let qcfg = QuantConfig {
            method: Method::Bof4 { mse: true },
            norm: Norm::SignedAbsmax,
            block: rt.meta.model.block,
            opq: None,
            double_quant: true,
        };
        let qsp_plain = crate::eval::quantize_for_serving(&rt.meta, &pset, &qcfg)?;
        let qsp_opq = crate::eval::quantize_for_serving(
            &rt.meta,
            &pset,
            &QuantConfig {
                opq: Some(OpqConfig::default()),
                ..qcfg
            },
        )?;
        if qsp_opq.outliers == 0 {
            return Err(crate::err!("OPQ bench leg flagged no outliers"));
        }
        opq_outliers = qsp_opq.outliers;
        for (prefix, slot) in [
            (qsp_plain.prefix, &mut engine_q4),
            (qsp_opq.prefix, &mut engine_q4_opq),
        ] {
            let eng = Engine::start(
                rt.clone(),
                crate::coordinator::EngineParams::QuantizedQ4(prefix),
                EngineConfig::default(),
            )?;
            // warm-up pass, then best-of-3 timed passes — the smoke
            // asserts a hard 10% margin between the two legs, so a
            // single sample would be at the mercy of scheduler noise
            let _ = eng.generate(prompt, n_tokens.min(8))?;
            let mut best: Option<Duration> = None;
            for _ in 0..3 {
                let t0 = Instant::now();
                let got = eng.generate(prompt, n_tokens)?;
                let dt = t0.elapsed();
                if got.len() != n_tokens {
                    return Err(crate::err!("q4 leg decoded {} of {n_tokens}", got.len()));
                }
                best = Some(best.map_or(dt, |b| b.min(dt)));
            }
            *slot = best;
        }
    }

    // KV-format legs: the same dense weights served with the per-session
    // cache pinned f32 vs pinned q8 (block-wise absmax int8, fused
    // dequant attention). Prices the quantized-KV decode overhead
    // independently of the `BOF4_KV` env default. CPU backend only
    // (quantized KV needs the in-place decode protocol).
    let mut engine_kv_f32 = None;
    let mut engine_kv_q8 = None;
    if rt.platform() == "cpu-interpreter" && rt.meta.graphs.contains_key("lm_prefill") {
        use crate::quant::KvFormat;
        for (fmt, slot) in [
            (KvFormat::F32, &mut engine_kv_f32),
            (KvFormat::Q8, &mut engine_kv_q8),
        ] {
            let eng = Engine::start(
                rt.clone(),
                params.clone(),
                EngineConfig {
                    kv_format: fmt,
                    ..EngineConfig::default()
                },
            )?;
            // warm-up, then best-of-3 — the smoke asserts a hard 15%
            // margin between the legs, so a single sample would be at
            // the mercy of scheduler noise
            let _ = eng.generate(prompt, n_tokens.min(8))?;
            let mut best: Option<Duration> = None;
            for _ in 0..3 {
                let t0 = Instant::now();
                let got = eng.generate(prompt, n_tokens)?;
                let dt = t0.elapsed();
                if got.len() != n_tokens {
                    return Err(crate::err!(
                        "{fmt}-KV leg decoded {} of {n_tokens}",
                        got.len()
                    ));
                }
                best = Some(best.map_or(dt, |b| b.min(dt)));
            }
            *slot = best;
        }
    }

    // (d) the session engine: prefill + incremental in-place decode.
    // `Engine::start` is timed separately as the warm (in-memory)
    // cold-start baseline for the artifact leg below.
    let t0 = Instant::now();
    let engine = Engine::start(rt.clone(), params.clone(), EngineConfig::default())?;
    let cold_start = t0.elapsed();
    let t0 = Instant::now();
    let toks = engine.generate(prompt, n_tokens)?;
    let engine_elapsed = t0.elapsed();
    if toks.len() != n_tokens {
        return Err(crate::err!(
            "engine decoded {} of {n_tokens} tokens",
            toks.len()
        ));
    }
    if let Some(t1) = &single_toks {
        if t1 != &toks {
            return Err(crate::err!(
                "threaded engine stream diverged from the 1-thread stream"
            ));
        }
    }
    if let Some(ts) = &scalar_toks {
        if ts != &toks {
            return Err(crate::err!(
                "SIMD engine stream diverged from the forced-scalar stream \
                 (bit-exactness contract broken)"
            ));
        }
    }

    // trace-overhead legs: the same default-config engine re-timed with
    // the span tracer forced off, then at engine level. Streams must
    // stay bit-identical at every level (tracing wraps dispatch from
    // outside, never a reduction), and the release smoke asserts the
    // traced leg costs < 5%. The level flip is process-global — safe
    // here because only the standalone bench binary calls this function.
    let mut engine_trace_off = None;
    let mut engine_trace_on = None;
    if rt.platform() == "cpu-interpreter" {
        use crate::obs::tracer::{self, TraceLevel};
        let prev = tracer::level();
        for (lv, slot) in [
            (TraceLevel::Off, &mut engine_trace_off),
            (TraceLevel::Engine, &mut engine_trace_on),
        ] {
            tracer::set_level(lv);
            // warm-up, then best-of-5 — the smoke asserts a hard 5%
            // margin, so single samples would be scheduler-noise bound
            let _ = engine.generate(prompt, n_tokens.min(8))?;
            let mut best: Option<Duration> = None;
            for _ in 0..5 {
                let t0 = Instant::now();
                let got = engine.generate(prompt, n_tokens)?;
                let dt = t0.elapsed();
                if got != toks {
                    tracer::set_level(prev);
                    return Err(crate::err!(
                        "stream diverged at trace level {lv:?} \
                         (tracing determinism contract broken)"
                    ));
                }
                best = Some(best.map_or(dt, |b| b.min(dt)));
            }
            *slot = best;
        }
        tracer::set_level(prev);
        tracer::tracer().clear();
    }

    // admission-control legs: the same dense weights served twice more —
    // once with admission control off (the unbounded default) and once
    // with `max_queue_depth` set far above the bench load, so the full
    // admission path (queue-depth gauge read + shed-registry
    // insert/remove) runs on every submit without ever shedding. The
    // streams must stay bit-identical — admission decides *whether* a
    // session runs, never *what* it decodes — and the release smoke
    // asserts the bounded leg costs < 2%.
    let mut engine_admit_off = None;
    let mut engine_admit_on = None;
    let mut admit_shed_total = 0u64;
    if rt.platform() == "cpu-interpreter" {
        for (depth, slot) in [
            (None, &mut engine_admit_off),
            (Some(1usize << 20), &mut engine_admit_on),
        ] {
            let eng = Engine::start(
                rt.clone(),
                params.clone(),
                EngineConfig {
                    max_queue_depth: depth,
                    ..EngineConfig::default()
                },
            )?;
            // warm-up, then best-of-5 — the smoke asserts a hard 2%
            // margin, the tightest in the suite, so single samples
            // would be scheduler-noise bound
            let _ = eng.generate(prompt, n_tokens.min(8))?;
            let mut best: Option<Duration> = None;
            for _ in 0..5 {
                let t0 = Instant::now();
                let got = eng.generate(prompt, n_tokens)?;
                let dt = t0.elapsed();
                if got != toks {
                    return Err(crate::err!(
                        "stream diverged with admission control \
                         (max_queue_depth {depth:?})"
                    ));
                }
                best = Some(best.map_or(dt, |b| b.min(dt)));
            }
            *slot = best;
            if depth.is_some() {
                admit_shed_total = eng.metrics.shed_total();
            }
        }
    }

    // shared-weight accounting: the parameter set is resident once no
    // matter the replica count; only the private KV slabs scale. Profile
    // the measured engine, then a 2-replica engine over the same
    // (Arc-shared) weights, and pin the sub-linear growth here so every
    // bench run re-checks the invariant.
    let prof = engine.memory_profile();
    let replicas = prof.replicas;
    let kv_format = prof.kv_format;
    let kv_bytes_per_token = prof.session_kv_bytes / s.max(1);
    let sessions_per_gb = prof.sessions_per_gb().unwrap_or(0.0);
    let shared_param_bytes = prof.shared_param_bytes;
    let per_replica_bytes = prof.per_replica_bytes.first().copied().unwrap_or(0);
    let total_resident_1 = prof.total_resident_bytes;
    let engine2 = Engine::start(
        rt.clone(),
        params.clone(),
        EngineConfig {
            replicas: 2,
            ..EngineConfig::default()
        },
    )?;
    let prof2 = engine2.memory_profile();
    if prof2.shared_param_bytes != shared_param_bytes {
        return Err(crate::err!(
            "shared parameter bytes changed with replica count: {} @1r vs {} @2r",
            shared_param_bytes,
            prof2.shared_param_bytes
        ));
    }
    let total_resident_2 = prof2.total_resident_bytes;
    drop(engine2);
    if shared_param_bytes > 0 && total_resident_2 >= 2 * total_resident_1 {
        return Err(crate::err!(
            "resident bytes scaled linearly with replicas: {} @1r vs {} @2r \
             (weights are not shared)",
            total_resident_1,
            total_resident_2
        ));
    }

    // artifact round-trip: serialize the dense set, reload from disk,
    // cold-start a fresh engine from the loaded artifact, and require
    // the served stream to match the in-memory engine's bit-for-bit.
    let art_path = std::env::temp_dir().join("bof4_bench_artifact.bof4");
    let info = crate::eval::save_artifact(
        &art_path,
        &m,
        &crate::coordinator::EngineParams::Dense(params),
        &crate::eval::SaveOptions {
            label: "bench round-trip".into(),
            ..Default::default()
        },
    )?;
    let artifact_bytes = info.file_bytes;
    let t0 = Instant::now();
    let (loaded, _) = crate::eval::load_artifact(&art_path, &m)?;
    let engine_a = Engine::start(rt.clone(), loaded, EngineConfig::default())?;
    let artifact_cold_start = t0.elapsed();
    let toks_a = engine_a.generate(prompt, n_tokens)?;
    drop(engine_a);
    let _ = std::fs::remove_file(&art_path);
    if toks_a != toks {
        return Err(crate::err!(
            "artifact-loaded engine stream diverged from the in-memory stream"
        ));
    }

    Ok(DecodeThroughput {
        tokens: n_tokens,
        full_recompute,
        engine: engine_elapsed,
        engine_single: engine_single.unwrap_or(engine_elapsed),
        engine_scalar: engine_scalar.unwrap_or(engine_elapsed),
        engine_kv_f32,
        engine_kv_q8,
        kv_format,
        kv_bytes_per_token,
        sessions_per_gb,
        engine_q4,
        engine_q4_opq,
        opq_outliers,
        engine_trace_off,
        engine_trace_on,
        engine_admit_off,
        engine_admit_on,
        admit_shed_total,
        threads,
        simd,
        cold_start,
        artifact_cold_start,
        artifact_bytes,
        replicas,
        shared_param_bytes,
        per_replica_bytes,
        total_resident_1,
        total_resident_2,
    })
}

/// The paper's standard quantizer line-up (Tables 1/2/9/10 rows), in
/// presentation order. `block` parameterizes every entry.
pub fn paper_lineup(block: usize) -> Vec<crate::quant::QuantConfig> {
    use crate::quant::{Method, Norm, OpqConfig, QuantConfig};
    let base = |method: Method, norm: Norm| QuantConfig {
        method,
        norm,
        block,
        opq: None,
        double_quant: false,
    };
    let with_opq = |mut c: QuantConfig| {
        c.opq = Some(OpqConfig::default());
        c
    };
    vec![
        base(Method::Nf4, Norm::Absmax),
        base(Method::Af4, Norm::Absmax),
        base(Method::Bof4 { mse: false }, Norm::Absmax),
        base(Method::Bof4 { mse: true }, Norm::Absmax),
        base(Method::Bof4 { mse: false }, Norm::SignedAbsmax),
        with_opq(base(Method::Bof4 { mse: false }, Norm::SignedAbsmax)),
        base(Method::Bof4 { mse: true }, Norm::SignedAbsmax),
        with_opq(base(Method::Bof4 { mse: true }, Norm::SignedAbsmax)),
    ]
}

/// Env-tunable scale factor for bench workloads (`BOF4_BENCH_SCALE`,
/// default 1.0; smaller = faster smoke runs).
pub fn scale() -> f64 {
    std::env::var("BOF4_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Scaled count helper.
pub fn scaled(n: usize) -> usize {
    ((n as f64) * scale()).max(1.0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lineup_has_paper_rows() {
        let l = paper_lineup(64);
        assert_eq!(l.len(), 8);
        assert_eq!(l[0].label(), "NF4");
        assert_eq!(l[7].label(), "BOF4-S (MSE) +OPQ");
    }

    #[test]
    fn bench_produces_sane_stats() {
        let m = bench("noop-ish", 2, 50, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(m.iters, 50);
        assert!(m.min <= m.p50 && m.p50 <= m.p95);
        assert!(m.throughput(1000.0) > 0.0);
    }

    #[test]
    fn bench_auto_calibrates() {
        let m = bench_auto("sleepless", Duration::from_millis(20), || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert!(m.iters >= 3);
    }
}
