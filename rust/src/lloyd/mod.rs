//! Lloyd-style EM quantizer design for block-wise absmax quantization
//! (paper §3.2, Appendix B) — the paper's first contribution.
//!
//! Standard Lloyd's algorithm minimizes the error of the quantizer's
//! *input* distribution. Block-wise absmax quantization applies the
//! codebook to normalized weights `X = W / M`, while the objective is the
//! end-to-end error on `W`. The corrected centroid updates are:
//!
//! - **MSE** (eq. 6 empirical / eq. 5, 35 theoretical): block-max²-weighted
//!   mean of the normalized weights in the region;
//! - **MAE** (eq. 8 empirical / eq. 7, 59 theoretical): block-max-weighted
//!   median.
//!
//! Two interchangeable backends implement these updates:
//! [`empirical`] (Monte-Carlo over sampled Gaussian blocks, sorted once +
//! prefix sums so each EM iteration is O(L log N)) and [`theoretical`]
//! (numerical integration over the block-max distribution). Their
//! agreement is the paper's Table 8 / eq. 70 experiment, reproduced in
//! `benches/tab6_7_8_codebooks.rs` and pinned by tests here.
//!
//! The App.-D variant (optimizing the error of *normalized* weights, i.e.
//! plain unweighted centroids) is also provided — it defines AF4 and the
//! Fig.-6 comparison.

pub mod empirical;
pub mod theoretical;

use crate::quant::codebook::{Codebook, LEVELS};
use crate::quant::Norm;

/// Optimization target.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Mae,
    Mse,
}

/// Which weighting the centroid update uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// End-to-end weight error (BOF4/BOF4-S; paper eqs. 5–8).
    EndToEnd,
    /// Error of normalized weights (App. D; defines AF4).
    Normalized,
}

/// EM design configuration.
#[derive(Clone, Debug)]
pub struct EmConfig {
    pub metric: Metric,
    pub objective: Objective,
    pub norm: Norm,
    pub block: usize,
    /// Levels pinned to fixed values (initialized and never updated);
    /// e.g. `[-1.0, 0.0, 1.0]` for BOF4, `[0.0, 1.0]` for BOF4-S.
    pub constrained: Vec<f32>,
    pub max_iters: usize,
    pub tol: f64,
}

impl EmConfig {
    /// The paper's default constraint set for a normalization mode
    /// (App. A shows {0, ±1} is PPL-optimal for absolute normalization;
    /// §3.1 motivates {0, +1} for signed).
    pub fn default_constraints(norm: Norm) -> Vec<f32> {
        match norm {
            Norm::Absmax => vec![-1.0, 0.0, 1.0],
            Norm::SignedAbsmax => vec![0.0, 1.0],
        }
    }

    pub fn new(metric: Metric, norm: Norm, block: usize) -> Self {
        EmConfig {
            metric,
            objective: Objective::EndToEnd,
            norm,
            block,
            constrained: Self::default_constraints(norm),
            max_iters: 200,
            tol: 1e-7,
        }
    }
}

/// Initial levels: constrained values pinned, free levels spread over the
/// Gaussian-quantile positions of the normalized-weight distribution
/// (a good starting partition for every block size).
pub fn init_levels(cfg: &EmConfig) -> ([f64; LEVELS], [bool; LEVELS]) {
    use crate::stats::special::gauss_quantile;
    // Spread 16 probabilities uniformly, map through N(0,1) quantiles and
    // squash into (-1, 1) by the ~3σ block-normalized scale.
    let mut levels = [0.0f64; LEVELS];
    for (i, l) in levels.iter_mut().enumerate() {
        let p = (i as f64 + 0.5) / LEVELS as f64;
        *l = (gauss_quantile(p) / 3.2).clamp(-0.97, 0.97);
    }
    // Pin constraints by replacing the nearest free level with each value.
    let mut fixed = [false; LEVELS];
    for &c in &cfg.constrained {
        let c = c as f64;
        let mut best = 0usize;
        let mut bestd = f64::INFINITY;
        for (i, &l) in levels.iter().enumerate() {
            if fixed[i] {
                continue;
            }
            let d = (l - c).abs();
            if d < bestd {
                bestd = d;
                best = i;
            }
        }
        levels[best] = c;
        fixed[best] = true;
    }
    sort_with_flags(&mut levels, &mut fixed);
    (levels, fixed)
}

/// Keep (level, fixed-flag) pairs sorted by level.
fn sort_with_flags(levels: &mut [f64; LEVELS], fixed: &mut [bool; LEVELS]) {
    let mut pairs: Vec<(f64, bool)> =
        levels.iter().cloned().zip(fixed.iter().cloned()).collect();
    pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
    for (i, (l, f)) in pairs.into_iter().enumerate() {
        levels[i] = l;
        fixed[i] = f;
    }
}

/// Decision boundaries (midpoints) for a sorted level vector.
pub fn boundaries(levels: &[f64; LEVELS]) -> [f64; LEVELS - 1] {
    let mut b = [0.0f64; LEVELS - 1];
    for i in 0..LEVELS - 1 {
        b[i] = 0.5 * (levels[i] + levels[i + 1]);
    }
    b
}

/// A centroid backend: given current boundaries, produce the updated level
/// for region ℓ (regions are `[ξ(ℓ-1), ξ(ℓ))` with ξ(0) = -∞, ξ(L) = ∞).
pub trait CentroidBackend {
    /// Returns `None` if the region holds no probability mass (level kept).
    fn centroid(&self, region: usize, bounds: &[f64; LEVELS - 1]) -> Option<f64>;
}

/// Generic EM driver shared by both backends.
pub fn run_em(cfg: &EmConfig, backend: &dyn CentroidBackend) -> [f64; LEVELS] {
    let (mut levels, fixed) = init_levels(cfg);
    for _iter in 0..cfg.max_iters {
        let bounds = boundaries(&levels);
        let mut delta: f64 = 0.0;
        let mut next = levels;
        for l in 0..LEVELS {
            if fixed[l] {
                continue;
            }
            if let Some(c) = backend.centroid(l, &bounds) {
                // keep levels ordered: clamp into the open region interval
                let lo = if l == 0 { -1.0 } else { bounds[l - 1] + 1e-9 };
                let hi = if l == LEVELS - 1 {
                    1.0
                } else {
                    bounds[l] - 1e-9
                };
                let c = c.clamp(lo.min(hi), hi.max(lo));
                delta = delta.max((c - levels[l]).abs());
                next[l] = c;
            }
        }
        levels = next;
        if delta < cfg.tol {
            break;
        }
    }
    levels
}

fn codebook_name(cfg: &EmConfig, backend: &str) -> String {
    format!(
        "{}{} ({}) I={} [{}]",
        match cfg.objective {
            Objective::EndToEnd => "BOF4",
            Objective::Normalized => "NORM",
        },
        if cfg.norm == Norm::SignedAbsmax { "-S" } else { "" },
        match cfg.metric {
            Metric::Mae => "MAE",
            Metric::Mse => "MSE",
        },
        cfg.block,
        backend
    )
}

/// Design a codebook with the empirical (Monte-Carlo) backend.
pub fn design_empirical(cfg: &EmConfig, n_samples: usize, seed: u64) -> Codebook {
    let backend = empirical::EmpiricalBackend::new(cfg, n_samples, seed);
    let levels = run_em(cfg, &backend);
    let mut lv = [0.0f32; LEVELS];
    for (o, &l) in lv.iter_mut().zip(&levels) {
        *o = l as f32;
    }
    Codebook::new(codebook_name(cfg, "emp"), lv)
}

/// Design a codebook with the theoretical (integration) backend.
pub fn design_theoretical(cfg: &EmConfig) -> Codebook {
    let backend = theoretical::TheoreticalBackend::new(cfg);
    let levels = run_em(cfg, &backend);
    let mut lv = [0.0f32; LEVELS];
    for (o, &l) in lv.iter_mut().zip(&levels) {
        *o = l as f32;
    }
    Codebook::new(codebook_name(cfg, "theo"), lv)
}

/// Default BOF4(-S) empirical design used by the codebook registry for
/// block sizes the paper does not publish (2^22 samples, fixed seed).
pub fn design_bof4_empirical_default(mse: bool, norm: Norm, block: usize) -> Codebook {
    let cfg = EmConfig::new(if mse { Metric::Mse } else { Metric::Mae }, norm, block);
    design_empirical(&cfg, (1usize << 22).max(block * 2048), 0xB0F4)
}

/// AF4 (Yoshida): MAE-optimal for *normalized* weights, absolute absmax
/// normalization, levels {-1, 0, 1} constrained. Regenerated per block
/// size from its defining optimization.
pub fn design_af4(block: usize) -> Codebook {
    let mut cfg = EmConfig::new(Metric::Mae, Norm::Absmax, block);
    cfg.objective = Objective::Normalized;
    let mut cb = design_empirical(&cfg, (1usize << 22).max(block * 2048), 0xAF4);
    cb.name = format!("AF4 I={block}");
    cb
}

/// App.-D codebook: MSE-optimal for normalized weights (Fig. 6 comparison).
pub fn design_normalized_mse(block: usize) -> Codebook {
    let mut cfg = EmConfig::new(Metric::Mse, Norm::Absmax, block);
    cfg.objective = Objective::Normalized;
    design_empirical(&cfg, (1usize << 22).max(block * 2048), 0x40B)
}

/// Relative MSE (in dB) between two codebooks weighted by region
/// probability — the paper's eq. 70 (Table 8 agreement metric).
pub fn codebook_mse_db(theo: &Codebook, emp: &Codebook, block: usize, norm: Norm) -> f64 {
    use crate::stats::blockmax::px_region;
    let mut num = 0.0;
    let mut den = 0.0;
    let bounds: Vec<f64> = theo
        .bounds
        .iter()
        .take(LEVELS - 1)
        .map(|&b| b as f64)
        .collect();
    for l in 0..LEVELS {
        let a = if l == 0 { -1.0 } else { bounds[l - 1] };
        let b = if l == LEVELS - 1 { 1.0 } else { bounds[l] };
        let p = px_region(a, b, block, norm);
        let d = theo.levels[l] as f64 - emp.levels[l] as f64;
        num += p * d * d;
        den += p * (theo.levels[l] as f64).powi(2);
    }
    10.0 * (num / den.max(1e-300)).log10()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook;

    #[test]
    fn init_contains_constraints_sorted() {
        let cfg = EmConfig::new(Metric::Mse, Norm::Absmax, 64);
        let (levels, fixed) = init_levels(&cfg);
        assert!(levels.windows(2).all(|w| w[0] < w[1]));
        for &c in &[-1.0, 0.0, 1.0] {
            let i = levels.iter().position(|&l| l == c).expect("constraint");
            assert!(fixed[i]);
        }
        assert_eq!(fixed.iter().filter(|&&f| f).count(), 3);
    }

    #[test]
    fn signed_constraints_only_two() {
        let cfg = EmConfig::new(Metric::Mse, Norm::SignedAbsmax, 64);
        let (levels, fixed) = init_levels(&cfg);
        assert_eq!(fixed.iter().filter(|&&f| f).count(), 2);
        assert!(levels.contains(&0.0) && levels.contains(&1.0));
    }

    // The headline verification: our EM reproduces the paper's published
    // Table-6 codebooks. Empirical backend, so tolerance reflects
    // Monte-Carlo noise (paper Table 8 shows ~1e-4 deviations).
    #[test]
    fn em_reproduces_paper_bof4_mse_64() {
        let cfg = EmConfig::new(Metric::Mse, Norm::Absmax, 64);
        let cb = design_empirical(&cfg, 1 << 22, 42);
        for (got, want) in cb.levels.iter().zip(&codebook::BOF4_MSE_64) {
            assert!(
                (got - want).abs() < 2.5e-3,
                "level {got} vs paper {want}"
            );
        }
    }

    #[test]
    fn em_reproduces_paper_bof4s_mse_64() {
        let cfg = EmConfig::new(Metric::Mse, Norm::SignedAbsmax, 64);
        let cb = design_empirical(&cfg, 1 << 22, 43);
        for (got, want) in cb.levels.iter().zip(&codebook::BOF4_S_MSE_64) {
            assert!(
                (got - want).abs() < 2.5e-3,
                "level {got} vs paper {want}"
            );
        }
    }

    #[test]
    fn em_reproduces_paper_bof4_mae_64() {
        let cfg = EmConfig::new(Metric::Mae, Norm::Absmax, 64);
        let cb = design_empirical(&cfg, 1 << 22, 44);
        for (got, want) in cb.levels.iter().zip(&codebook::BOF4_MAE_64) {
            assert!(
                (got - want).abs() < 3e-3,
                "level {got} vs paper {want}"
            );
        }
    }

    #[test]
    fn theoretical_reproduces_paper_bof4_mse_64() {
        // Table 8's "theoretical solution" column: exact to ~1e-4.
        let cfg = EmConfig::new(Metric::Mse, Norm::Absmax, 64);
        let cb = design_theoretical(&cfg);
        for (got, want) in cb.levels.iter().zip(&codebook::BOF4_MSE_64) {
            assert!(
                (got - want).abs() < 1e-3,
                "level {got} vs paper {want}"
            );
        }
    }

    #[test]
    fn empirical_theoretical_equivalence_table8() {
        // Paper Table 8 / eq. 70: MSE between backends ≈ -56 dB. We assert
        // better than -40 dB (practical equivalence).
        let cfg = EmConfig::new(Metric::Mse, Norm::Absmax, 64);
        let emp = design_empirical(&cfg, 1 << 22, 45);
        let theo = design_theoretical(&cfg);
        let db = codebook_mse_db(&theo, &emp, 64, Norm::Absmax);
        assert!(db < -40.0, "equivalence only {db:.1} dB");
    }

    #[test]
    fn af4_design_properties() {
        let cb = design_af4(64);
        // contains the three constrained levels
        assert_eq!(cb.levels[0], -1.0);
        assert_eq!(cb.levels[15], 1.0);
        assert!(cb.levels.contains(&0.0));
        // AF4 (normalized-MAE) differs from BOF4 (MAE): the end-to-end
        // weighting pulls levels outward.
        let bof4 = codebook::Codebook::new("p", codebook::BOF4_MAE_64);
        let diff: f32 = cb
            .levels
            .iter()
            .zip(&bof4.levels)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 0.01, "AF4 should differ from BOF4 (diff {diff})");
    }

    #[test]
    fn design_monotone_in_block_size() {
        // Larger blocks concentrate normalized weights near 0, so interior
        // levels shrink toward 0 (visible in paper Table 7).
        let c32 = design_bof4_empirical_default(true, Norm::SignedAbsmax, 32);
        let c256 = design_bof4_empirical_default(true, Norm::SignedAbsmax, 256);
        // compare a mid-positive level (index 11)
        assert!(c256.levels[11] < c32.levels[11]);
    }
}
