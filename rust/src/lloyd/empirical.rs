//! Empirical (Monte-Carlo) centroid backend — paper Appendix B.3.
//!
//! Samples `B × I` Gaussian weights, normalizes per block, then sorts the
//! normalized samples once and builds prefix sums of the centroid weights
//! so that each EM iteration costs O(L log N):
//!
//! - MSE centroid (eq. 64): weighted mean `Σ w_k² x_k / Σ w_k²` over the
//!   region — two prefix-sum lookups;
//! - MAE centroid (eq. 69): weighted median — binary search for the point
//!   where the cumulative `|w_k|` crosses half the region's total.
//!
//! For the normalized objective (App. D, AF4) the weights are 1.

use super::{CentroidBackend, EmConfig, Metric, Objective};
use crate::quant::absmax::{block_constant, safe_constant};
use crate::quant::codebook::LEVELS;
use crate::util::rng::Pcg64;

pub struct EmpiricalBackend {
    /// Normalized samples, ascending.
    xs: Vec<f64>,
    /// Prefix sums (len N+1): Σ weight, Σ weight·x. For MSE the weight is
    /// m², for MAE |m| (or 1 under the normalized objective).
    cum_w: Vec<f64>,
    cum_wx: Vec<f64>,
    metric: Metric,
}

impl EmpiricalBackend {
    pub fn new(cfg: &EmConfig, n_samples: usize, seed: u64) -> Self {
        let block = cfg.block;
        let n_blocks = n_samples.div_ceil(block);
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut pairs: Vec<(f64, f64)> = Vec::with_capacity(n_blocks * block);
        let mut buf = vec![0.0f32; block];
        for _ in 0..n_blocks {
            for v in buf.iter_mut() {
                *v = rng.next_gaussian() as f32;
            }
            let m = block_constant(&buf, cfg.norm);
            let ms = safe_constant(m) as f64;
            let weight = match (cfg.objective, cfg.metric) {
                (Objective::Normalized, _) => 1.0,
                (Objective::EndToEnd, Metric::Mse) => (m as f64) * (m as f64),
                (Objective::EndToEnd, Metric::Mae) => (m as f64).abs(),
            };
            for &v in buf.iter() {
                pairs.push((v as f64 / ms, weight));
            }
        }
        pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
        let n = pairs.len();
        let mut xs = Vec::with_capacity(n);
        let mut cum_w = Vec::with_capacity(n + 1);
        let mut cum_wx = Vec::with_capacity(n + 1);
        cum_w.push(0.0);
        cum_wx.push(0.0);
        let (mut sw, mut swx) = (0.0, 0.0);
        for (x, w) in pairs {
            xs.push(x);
            sw += w;
            swx += w * x;
            cum_w.push(sw);
            cum_wx.push(swx);
        }
        EmpiricalBackend {
            xs,
            cum_w,
            cum_wx,
            metric: cfg.metric,
        }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Index range [lo, hi) of samples falling in [a, b).
    fn range(&self, a: f64, b: f64) -> (usize, usize) {
        let lo = self.xs.partition_point(|&x| x < a);
        let hi = self.xs.partition_point(|&x| x < b);
        (lo, hi)
    }
}

impl CentroidBackend for EmpiricalBackend {
    fn centroid(&self, region: usize, bounds: &[f64; LEVELS - 1]) -> Option<f64> {
        let a = if region == 0 {
            f64::NEG_INFINITY
        } else {
            bounds[region - 1]
        };
        let b = if region == LEVELS - 1 {
            f64::INFINITY
        } else {
            bounds[region]
        };
        let (lo, hi) = self.range(a, b);
        if hi <= lo {
            return None;
        }
        let total_w = self.cum_w[hi] - self.cum_w[lo];
        if total_w <= 0.0 {
            return None;
        }
        match self.metric {
            Metric::Mse => {
                let total_wx = self.cum_wx[hi] - self.cum_wx[lo];
                Some(total_wx / total_w)
            }
            Metric::Mae => {
                // weighted median: smallest index k in [lo, hi) with
                // cum_w[k+1] - cum_w[lo] >= total_w / 2
                let target = self.cum_w[lo] + total_w / 2.0;
                let mut l = lo;
                let mut h = hi; // searching k in [lo, hi)
                while l < h {
                    let mid = (l + h) / 2;
                    if self.cum_w[mid + 1] < target {
                        l = mid + 1;
                    } else {
                        h = mid;
                    }
                }
                Some(self.xs[l.min(hi - 1)])
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lloyd::boundaries;
    use crate::quant::Norm;

    fn bounds_for(levels: [f64; LEVELS]) -> [f64; LEVELS - 1] {
        boundaries(&levels)
    }

    fn simple_cfg(metric: Metric, norm: Norm) -> EmConfig {
        EmConfig::new(metric, norm, 64)
    }

    #[test]
    fn samples_normalized_to_unit_interval() {
        let be = EmpiricalBackend::new(&simple_cfg(Metric::Mse, Norm::Absmax), 1 << 14, 1);
        assert!(be.xs.iter().all(|&x| (-1.0..=1.0).contains(&x)));
        // absolute normalization: both endpoints present
        assert!((be.xs[0] + 1.0).abs() < 1e-12);
        assert!((be.xs[be.len() - 1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn signed_normalization_only_plus_one() {
        let be =
            EmpiricalBackend::new(&simple_cfg(Metric::Mse, Norm::SignedAbsmax), 1 << 14, 2);
        // signed: max normalized value +1, min strictly inside (-1, 1)
        assert!((be.xs[be.len() - 1] - 1.0).abs() < 1e-12);
        assert!(be.xs[0] > -1.0);
    }

    #[test]
    fn mse_centroid_is_weighted_mean() {
        let be = EmpiricalBackend::new(&simple_cfg(Metric::Mse, Norm::Absmax), 1 << 14, 3);
        // single full region: centroid = global weighted mean ≈ 0
        let mut levels = [0.0f64; LEVELS];
        for (i, l) in levels.iter_mut().enumerate() {
            *l = -1.0 + 2.0 * i as f64 / 15.0;
        }
        let b = bounds_for(levels);
        // regions 7 and 8 are mirror images: centroids symmetric about 0
        let c7 = be.centroid(7, &b).unwrap();
        let c8 = be.centroid(8, &b).unwrap();
        assert!((c7 + c8).abs() < 0.01, "{c7} vs {c8}");
        assert!(c7 < 0.0 && c8 > 0.0);
    }

    #[test]
    fn mae_centroid_within_region() {
        let be = EmpiricalBackend::new(&simple_cfg(Metric::Mae, Norm::Absmax), 1 << 14, 4);
        let mut levels = [0.0f64; LEVELS];
        for (i, l) in levels.iter_mut().enumerate() {
            *l = -1.0 + 2.0 * i as f64 / 15.0;
        }
        let b = bounds_for(levels);
        for region in 0..LEVELS {
            if let Some(c) = be.centroid(region, &b) {
                let lo = if region == 0 { -1.0 } else { b[region - 1] };
                let hi = if region == 15 { 1.0 } else { b[region] };
                assert!(c >= lo - 1e-12 && c <= hi + 1e-12, "region {region}: {c}");
            }
        }
    }

    #[test]
    fn empty_region_returns_none() {
        let be = EmpiricalBackend::new(&simple_cfg(Metric::Mse, Norm::Absmax), 1 << 12, 5);
        // construct bounds with an empty region beyond +1
        let mut levels = [0.0f64; LEVELS];
        for (i, l) in levels.iter_mut().enumerate() {
            *l = i as f64 / 4.0; // levels 0..3.75, regions past 1 are empty
        }
        let b = bounds_for(levels);
        assert!(be.centroid(15, &b).is_none());
    }

    #[test]
    fn weighted_median_simple_case() {
        // Hand-built backend: three points with weights via cum arrays.
        let be = EmpiricalBackend {
            xs: vec![0.1, 0.2, 0.9],
            cum_w: vec![0.0, 1.0, 2.0, 10.0],
            cum_wx: vec![0.0, 0.1, 0.3, 7.5],
            metric: Metric::Mae,
        };
        let mut b = [f64::INFINITY; LEVELS - 1];
        b[0] = 1.5; // region 0 = (-inf, 1.5) covers all points
        // total weight 10, half = 5 -> first index where cum >= 5 is x=0.9
        let c = be.centroid(0, &b).unwrap();
        assert_eq!(c, 0.9);
    }
}
