//! Theoretical centroid backend — numerical integration of the paper's
//! closed-form centroid conditions (Appendix B.2).
//!
//! For Gaussian weights, writing g = φ, G = Φ, F(m) = 2G(m) − 1:
//!
//! **MSE** (eq. 35, extended to the discrete endpoint masses of eqs. 36–42):
//! for region ℛ = [a, b) with a' = clamp(a), b' = clamp(b) to [−1, 1],
//!
//! ```text
//!            ∫ m² · num(m) · p_M(m) dm
//!   x̂(ℓ) = ─────────────────────────────
//!            ∫ m² · den(m) · p_M(m) dm
//!
//!   num(m) = (I−1)/I · (g(m a') − g(m b')) / (m (2G(m)−1))
//!            [+ mass₊·1 if b ≥ 1]  [− mass₋·1 if a ≤ −1]
//!   den(m) = (I−1)/I · (G(m b') − G(m a')) / (2G(m)−1)
//!            [+ mass₊]            [+ mass₋]
//! ```
//!
//! with mass₊ = 1/(2I) (absolute) or 1/I (signed), mass₋ = 1/(2I)
//! (absolute) or 0 (signed). The continuous parts follow from eq. 31 via
//! the Gaussian antiderivative ∫ x g(mx) dx = −g(mx)/m² (eq. 32).
//!
//! **MAE** (eq. 59): x̂ is the root in (a', b') of
//!
//! ```text
//!   h(x̂) = ∫ m · p_M(m) · ( F_X(x̂|m) − F_X(a|m) − ½ [F_X(b|m) − F_X(a|m)] ) dm
//! ```
//!
//! which is monotone in x̂; we bracket it with bisection (paper's choice).
//!
//! Integration uses composite Gauss-Legendre over [ε, m_hi] where m_hi is
//! chosen so the neglected p_M tail is < 1e-15.

use super::{CentroidBackend, EmConfig, Metric, Objective};
use crate::quant::codebook::LEVELS;
use crate::quant::Norm;
use crate::stats::blockmax::{fx_given_m, BlockMax};
use crate::stats::quadrature::GaussLegendre;
use crate::stats::roots::bisect;
use crate::stats::special::{gauss_cdf, gauss_pdf};

pub struct TheoreticalBackend {
    block: usize,
    norm: Norm,
    metric: Metric,
    objective: Objective,
    bm: BlockMax,
    gl: GaussLegendre,
    m_hi: f64,
    panels: usize,
}

impl TheoreticalBackend {
    pub fn new(cfg: &EmConfig) -> Self {
        let bm = BlockMax::new(cfg.block);
        let m_hi = bm.upper_limit();
        TheoreticalBackend {
            block: cfg.block,
            norm: cfg.norm,
            metric: cfg.metric,
            objective: cfg.objective,
            bm,
            gl: GaussLegendre::new(48),
            m_hi,
            panels: 24,
        }
    }

    fn region_interval(&self, region: usize, bounds: &[f64; LEVELS - 1]) -> (f64, f64) {
        let a = if region == 0 {
            f64::NEG_INFINITY
        } else {
            bounds[region - 1]
        };
        let b = if region == LEVELS - 1 {
            f64::INFINITY
        } else {
            bounds[region]
        };
        (a, b)
    }

    /// Endpoint masses captured by region [a, b).
    fn endpoint_masses(&self, a: f64, b: f64) -> (f64, f64) {
        let i = self.block as f64;
        // +1 is included iff b > 1 (region extends past the endpoint).
        let mass_p = if b >= 1.0 {
            match self.norm {
                Norm::Absmax => 1.0 / (2.0 * i),
                Norm::SignedAbsmax => 1.0 / i,
            }
        } else {
            0.0
        };
        // −1 included iff a < −1 ⇔ a = −inf (leftmost region).
        let mass_m = if a <= -1.0 {
            match self.norm {
                Norm::Absmax => 1.0 / (2.0 * i),
                Norm::SignedAbsmax => 0.0,
            }
        } else {
            0.0
        };
        (mass_p, mass_m)
    }

    fn mse_centroid(&self, a: f64, b: f64) -> Option<f64> {
        let i = self.block as f64;
        let ap = a.clamp(-1.0, 1.0);
        let bp = b.clamp(-1.0, 1.0);
        let (mass_p, mass_m) = self.endpoint_masses(a, b);
        // Under the *normalized* objective the weighting m² (resp. m)
        // disappears (App. D): weights w(m) = 1.
        let end_to_end = self.objective == Objective::EndToEnd;
        let f = |m: f64| -> (f64, f64) {
            let pm = self.bm.pdf(m);
            if pm <= 0.0 {
                return (0.0, 0.0);
            }
            let fw = 2.0 * gauss_cdf(m) - 1.0; // F_{|W|}(m)
            if fw <= 0.0 {
                return (0.0, 0.0);
            }
            let cont_num =
                (i - 1.0) / i * (gauss_pdf(m * ap) - gauss_pdf(m * bp)) / (m * fw);
            let cont_den =
                (i - 1.0) / i * (gauss_cdf(m * bp) - gauss_cdf(m * ap)) / fw;
            let num = cont_num + mass_p - mass_m;
            let den = cont_den + mass_p + mass_m;
            let w = if end_to_end { m * m } else { 1.0 };
            (w * num * pm, w * den * pm)
        };
        let num = self
            .gl
            .integrate_panels(|m| f(m).0, 1e-8, self.m_hi, self.panels);
        let den = self
            .gl
            .integrate_panels(|m| f(m).1, 1e-8, self.m_hi, self.panels);
        if den.abs() < 1e-300 {
            None
        } else {
            Some(num / den)
        }
    }

    fn mae_centroid(&self, a: f64, b: f64) -> Option<f64> {
        let ap = a.max(-1.0 - 1e-12);
        let bp = b.min(1.0 + 1e-12);
        if bp <= ap {
            return None;
        }
        let end_to_end = self.objective == Objective::EndToEnd;
        let h = |xhat: f64| -> f64 {
            self.gl.integrate_panels(
                |m| {
                    let pm = self.bm.pdf(m);
                    if pm <= 0.0 {
                        return 0.0;
                    }
                    let fa = if a <= -1.0 {
                        0.0
                    } else {
                        fx_given_m(a, m, self.block, self.norm)
                    };
                    let fb = if b >= 1.0 {
                        1.0
                    } else {
                        fx_given_m(b, m, self.block, self.norm)
                    };
                    let fx = fx_given_m(xhat, m, self.block, self.norm);
                    let w = if end_to_end { m } else { 1.0 };
                    w * pm * (fx - fa - 0.5 * (fb - fa))
                },
                1e-8,
                self.m_hi,
                self.panels,
            )
        };
        // h is monotone increasing in x̂; bracket inside the clamped region.
        let lo = ap.max(-1.0) + 1e-9;
        let hi = bp.min(1.0) - 1e-9;
        if hi <= lo {
            return None;
        }
        let (hl, hh) = (h(lo), h(hi));
        if hl >= 0.0 {
            return Some(lo);
        }
        if hh <= 0.0 {
            return Some(hi);
        }
        bisect(h, lo, hi, 1e-12)
    }
}

impl CentroidBackend for TheoreticalBackend {
    fn centroid(&self, region: usize, bounds: &[f64; LEVELS - 1]) -> Option<f64> {
        let (a, b) = self.region_interval(region, bounds);
        // Degenerate: region entirely outside [-1, 1].
        if b <= -1.0 || a >= 1.0 {
            return None;
        }
        match self.metric {
            Metric::Mse => self.mse_centroid(a, b),
            Metric::Mae => self.mae_centroid(a, b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lloyd::{boundaries, EmConfig};

    fn backend(metric: Metric, norm: Norm, block: usize) -> TheoreticalBackend {
        let mut cfg = EmConfig::new(metric, norm, block);
        cfg.metric = metric;
        TheoreticalBackend::new(&cfg)
    }

    fn uniform_levels() -> [f64; LEVELS] {
        let mut l = [0.0f64; LEVELS];
        for (i, v) in l.iter_mut().enumerate() {
            *v = -1.0 + 2.0 * i as f64 / 15.0;
        }
        l
    }

    #[test]
    fn mse_centroid_symmetric_center() {
        let be = backend(Metric::Mse, Norm::Absmax, 64);
        let b = boundaries(&uniform_levels());
        // regions 7 and 8 mirror each other about 0
        let c7 = be.centroid(7, &b).unwrap();
        let c8 = be.centroid(8, &b).unwrap();
        assert!((c7 + c8).abs() < 1e-9, "{c7} vs {c8}");
        assert!(c7 < 0.0 && c8 > 0.0);
    }

    #[test]
    fn mse_centroid_inside_region() {
        let be = backend(Metric::Mse, Norm::Absmax, 64);
        let b = boundaries(&uniform_levels());
        for region in 0..LEVELS {
            if let Some(c) = be.centroid(region, &b) {
                let lo = if region == 0 { -1.0 } else { b[region - 1] };
                let hi = if region == 15 { 1.0 } else { b[region] };
                assert!(
                    c >= lo - 1e-9 && c <= hi + 1e-9,
                    "region {region}: {c} not in [{lo}, {hi}]"
                );
            }
        }
    }

    #[test]
    fn mae_centroid_monotone_function_root() {
        let be = backend(Metric::Mae, Norm::Absmax, 64);
        let b = boundaries(&uniform_levels());
        let c7 = be.centroid(7, &b).unwrap();
        let c8 = be.centroid(8, &b).unwrap();
        assert!((c7 + c8).abs() < 1e-8, "{c7} vs {c8}");
        let c5 = be.centroid(5, &b).unwrap();
        assert!(c5 < c7);
    }

    #[test]
    fn rightmost_region_pulled_to_one_by_endpoint_mass() {
        // With the region [0.9, inf), the discrete mass at +1 pulls the
        // MSE centroid above the continuous-only mean.
        let be_abs = backend(Metric::Mse, Norm::Absmax, 64);
        let be_signed = backend(Metric::Mse, Norm::SignedAbsmax, 64);
        let mut b = [0.0f64; LEVELS - 1];
        // put the last boundary at 0.9; others below
        for (i, v) in b.iter_mut().enumerate() {
            *v = -1.2 + 2.1 * (i as f64) / 14.0;
        }
        b[14] = 0.9;
        let c_abs = be_abs.centroid(15, &b).unwrap();
        let c_signed = be_signed.centroid(15, &b).unwrap();
        assert!(c_abs > 0.93, "{c_abs}");
        // signed has twice the mass at +1 -> pulled harder
        assert!(c_signed > c_abs, "{c_signed} vs {c_abs}");
    }

    #[test]
    fn signed_and_absolute_agree_on_interior_regions() {
        // The continuous part of p_X is identical for both normalizations;
        // interior centroids must match (paper App. B.2.1 closing remark).
        let be_a = backend(Metric::Mse, Norm::Absmax, 64);
        let be_s = backend(Metric::Mse, Norm::SignedAbsmax, 64);
        let b = boundaries(&uniform_levels());
        for region in 2..14 {
            let ca = be_a.centroid(region, &b).unwrap();
            let cs = be_s.centroid(region, &b).unwrap();
            crate::testkit::assert_close(ca, cs, 1e-9, 1e-10, "interior centroid");
        }
    }

    #[test]
    fn block_size_dependence() {
        // Larger I concentrates X near 0 -> centroid of a fixed interior
        // region shifts toward the region's inner edge... more simply:
        // the same region's |centroid| shrinks with I for regions near 0.
        let be64 = backend(Metric::Mse, Norm::Absmax, 64);
        let be1k = backend(Metric::Mse, Norm::Absmax, 1024);
        let b = boundaries(&uniform_levels());
        let c64 = be64.centroid(9, &b).unwrap();
        let c1k = be1k.centroid(9, &b).unwrap();
        assert!(c1k < c64, "{c1k} vs {c64}");
    }
}
