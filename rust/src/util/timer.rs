//! Timing helpers shared by the bench harness and the coordinator metrics.

use std::time::{Duration, Instant};

/// Scope timer; report with [`Stopwatch::elapsed_ms`].
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Human formatting for durations in reports.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_ms() >= 1.0);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(Duration::from_nanos(50)).ends_with("ns"));
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).ends_with('s'));
    }
}
