//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, typed
//! accessors with defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

/// Declared option.
struct Opt {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative argument parser.
pub struct Args {
    program: String,
    about: &'static str,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(about: &'static str) -> Self {
        Args {
            program: std::env::args().next().unwrap_or_else(|| "bof4".into()),
            about,
            opts: Vec::new(),
            values: BTreeMap::new(),
            flags: Vec::new(),
            positional: Vec::new(),
        }
    }

    /// Declare a `--key value` option (with optional default).
    pub fn opt(mut self, name: &'static str, default: Option<&str>, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            takes_value: true,
            default: default.map(str::to_string),
        });
        self
    }

    /// Declare a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    /// Parse `std::env::args`; exits on `--help` or unknown option.
    pub fn parse(self) -> Parsed {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        self.parse_from(argv)
    }

    /// Parse an explicit argv (testable).
    pub fn parse_from(mut self, argv: Vec<String>) -> Parsed {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                self.print_help();
                std::process::exit(0);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let decl = self.opts.iter().find(|o| o.name == key);
                match decl {
                    Some(o) if o.takes_value => {
                        let v = match inline_val {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .unwrap_or_else(|| {
                                        eprintln!("missing value for --{key}");
                                        std::process::exit(2);
                                    })
                                    .clone()
                            }
                        };
                        self.values.insert(key, v);
                    }
                    Some(_) => self.flags.push(key),
                    None => {
                        eprintln!("unknown option --{key} (see --help)");
                        std::process::exit(2);
                    }
                }
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        // fill defaults
        for o in &self.opts {
            if o.takes_value && !self.values.contains_key(o.name) {
                if let Some(d) = &o.default {
                    self.values.insert(o.name.to_string(), d.clone());
                }
            }
        }
        Parsed {
            values: self.values,
            flags: self.flags,
            positional: self.positional,
        }
    }

    fn print_help(&self) {
        println!("{} — {}\n", self.program, self.about);
        println!("OPTIONS:");
        for o in &self.opts {
            let arg = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            println!("  {arg:<26} {}{def}", o.help);
        }
    }
}

/// Parse result with typed accessors.
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name)?.parse().ok()
    }

    pub fn get_u64(&self, name: &str) -> Option<u64> {
        self.get(name)?.parse().ok()
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name)?.parse().ok()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated list of usizes, e.g. `--blocks 16,32,64`.
    pub fn get_usize_list(&self, name: &str) -> Option<Vec<usize>> {
        self.get(name)?
            .split(',')
            .map(|s| s.trim().parse().ok())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args() -> Args {
        Args::new("test")
            .opt("block", Some("64"), "block size")
            .opt("out", None, "output path")
            .flag("verbose", "log more")
    }

    fn v(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = args().parse_from(v(&[]));
        assert_eq!(p.get_usize("block"), Some(64));
        assert_eq!(p.get("out"), None);
        assert!(!p.has_flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let p = args().parse_from(v(&["--block", "128", "--out=x.json", "--verbose"]));
        assert_eq!(p.get_usize("block"), Some(128));
        assert_eq!(p.get("out"), Some("x.json"));
        assert!(p.has_flag("verbose"));
    }

    #[test]
    fn positional_collected() {
        let p = args().parse_from(v(&["quantize", "--block", "32", "file.bin"]));
        assert_eq!(p.positional(), &["quantize".to_string(), "file.bin".to_string()]);
    }

    #[test]
    fn usize_list() {
        let p = args().parse_from(v(&["--block", "64"]));
        assert_eq!(p.get_usize_list("block"), Some(vec![64]));
        let p = Args::new("t")
            .opt("blocks", Some("16,32,64"), "")
            .parse_from(v(&[]));
        assert_eq!(p.get_usize_list("blocks"), Some(vec![16, 32, 64]));
    }
}
