//! Infrastructure substrates built in-repo (the offline image ships no
//! `clap`, `serde`, `rand`, `criterion` or `tokio`; per the reproduction
//! mandate we build the pieces we need instead of stubbing them).

pub mod cli;
pub mod json;
pub mod log;
pub mod rng;
pub mod sync;
pub mod timer;
