//! PCG64 pseudo-random generator + Gaussian sampling.
//!
//! The quantizer-design experiments draw up to 2^25 Gaussian samples
//! (paper Fig. 2); this module provides a fast, reproducible source:
//! PCG-XSL-RR-128/64 (O'Neill 2014) with Box-Muller and a cached spare for
//! Gaussians. Reproducibility matters — every bench seeds explicitly so
//! tables regenerate identically.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second Box-Muller variate.
    spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed from a 64-bit value (stream constant fixed).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (0xda3e_39cb_94b9_5bdb_u128 << 1) | 1,
            spare: None,
        };
        rng.state = rng.inc.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Seed with an explicit stream (used to give worker threads
    /// independent, non-overlapping sequences).
    pub fn seed_with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            spare: None,
        };
        rng.state = rng.inc.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) (Lemire's method, bias-free for our use).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // widening multiply rejection sampling
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let low = m as u64;
            if low >= n {
                return (m >> 64) as u64;
            }
            // threshold for the biased region
            let t = n.wrapping_neg() % n;
            if low >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard Gaussian via Box-Muller (spare cached).
    #[inline]
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fill a slice with N(0, sigma²) f32 samples.
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.next_gaussian() as f32 * sigma;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::seed_with_stream(1, 10);
        let mut b = Pcg64::seed_with_stream(1, 11);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Pcg64::seed_from_u64(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::seed_from_u64(3);
        let n = 200_000;
        let (mut s1, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_gaussian();
            s1 += x;
            s2 += x * x;
            s4 += x * x * x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        let kurt = s4 / n as f64 / (var * var);
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        assert!((kurt - 3.0).abs() < 0.1, "kurtosis {kurt}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut rng = Pcg64::seed_from_u64(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed_from_u64(11);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
