//! Shared synchronization helpers.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock a mutex, recovering the data if a previous holder panicked.
///
/// House policy (enforced by the `lock-unwrap` lint rule): library code
/// never calls `.lock().unwrap()`. A panicking metrics or telemetry
/// thread must not poison its peers into a panic cascade — every
/// protected structure in this crate stays internally consistent under
/// item-level writes, so recovering the guard is always sound here.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recovers_after_a_panicked_holder() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap(); // lint: allow(lock-unwrap)
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 8);
    }
}
