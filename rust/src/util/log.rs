//! Leveled stderr logging with wall-clock offsets (the `log` crate facade
//! exists in the vendor tree, but a facade without an implementation crate
//! is useless — this is the ~80-line implementation we actually need).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static START: OnceLock<Instant> = OnceLock::new();
static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Process start time (first call wins).
fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        }
    }
}

/// Set the global log level (also honours `BOF4_LOG=debug|info|warn|error`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from the environment; call once from main()/bench.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("BOF4_LOG") {
        let lv = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        };
        set_level(lv);
    }
    let _ = start();
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = start().elapsed();
    eprintln!(
        "[{:>8.3}s {} {}] {}",
        t.as_secs_f64(),
        level.tag(),
        module,
        msg
    );
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
