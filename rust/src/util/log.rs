//! Leveled stderr logging with wall-clock offsets (the `log` crate facade
//! exists in the vendor tree, but a facade without an implementation crate
//! is useless — this is the ~80-line implementation we actually need).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static START: OnceLock<Instant> = OnceLock::new();
static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Process start time (first call wins).
fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        }
    }
}

/// Set the global log level (also honours
/// `BOF4_LOG=debug|info|warn|error|trace`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Parse a `BOF4_LOG` value (case-insensitive): the four level names,
/// plus the `trace` alias — debug logging *and* engine-level span
/// tracing ([`crate::obs::tracer`]), returned as the `bool`. `None` for
/// anything unrecognized.
pub fn parse_level(v: &str) -> Option<(Level, bool)> {
    match v.to_ascii_lowercase().as_str() {
        "error" => Some((Level::Error, false)),
        "warn" => Some((Level::Warn, false)),
        "info" => Some((Level::Info, false)),
        "debug" => Some((Level::Debug, false)),
        "trace" => Some((Level::Debug, true)),
        _ => None,
    }
}

/// Initialize from the environment; call once from main()/bench. An
/// unrecognized `BOF4_LOG` value warns to stderr and keeps the current
/// level (a typo must not silently drop to the default and hide the
/// diagnostics the caller asked for). `BOF4_LOG=trace` additionally
/// switches the span tracer to engine level unless `BOF4_TRACE` already
/// configured it.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("BOF4_LOG") {
        match parse_level(&v) {
            Some((lv, trace)) => {
                set_level(lv);
                if trace && crate::obs::tracer::level() == crate::obs::TraceLevel::Off {
                    crate::obs::tracer::set_level(crate::obs::TraceLevel::Engine);
                }
            }
            None => eprintln!(
                "bof4: unknown BOF4_LOG value '{v}' \
                 (expected error|warn|info|debug|trace); ignored"
            ),
        }
    }
    let _ = start();
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = start().elapsed();
    eprintln!(
        "[{:>8.3}s {} {}] {}",
        t.as_secs_f64(),
        level.tag(),
        module,
        msg
    );
    // Warn/Error records double as trace instants, so operator-visible
    // problems land on the trace timeline next to the spans they
    // interrupt.
    if level <= Level::Warn && crate::obs::tracer::enabled(crate::obs::TraceLevel::Engine) {
        let name = match level {
            Level::Error => "log_error",
            _ => "log_warn",
        };
        crate::obs::tracer::tracer().instant_msg(name, &format!("{module}: {msg}"));
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn parse_log_levels() {
        assert_eq!(parse_level("error"), Some((Level::Error, false)));
        assert_eq!(parse_level("WARN"), Some((Level::Warn, false)));
        assert_eq!(parse_level("info"), Some((Level::Info, false)));
        assert_eq!(parse_level("debug"), Some((Level::Debug, false)));
        // the trace alias turns on debug logging plus span tracing
        assert_eq!(parse_level("trace"), Some((Level::Debug, true)));
        assert_eq!(parse_level("nope"), None);
        assert_eq!(parse_level(""), None);
    }
}
