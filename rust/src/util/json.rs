//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Covers the full JSON grammar we exchange with the python build layer:
//! `artifacts/meta.json`, `artifacts/fixtures/*.json`, and the result files
//! the bench harness writes under `results/`. Numbers parse as f64; large
//! integer arrays (token streams) go through [`Json::as_f64`] and cast.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: keys separated by '.'.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = match cur {
                Json::Obj(m) => m.get(part)?,
                Json::Arr(a) => a.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array of numbers -> `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|j| j.as_f64()).collect()
    }

    /// Array of numbers -> `Vec<f32>`.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|j| j.as_f64().map(|f| f as f32))
            .collect()
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for objects (insertion order not preserved — JSON
/// object order is not semantically meaningful).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

pub fn arr_str(xs: &[&str]) -> Json {
    Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.bytes.get(self.pos).copied() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.bytes.get(self.pos).copied() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let st = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = st.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path("a.2.b").unwrap().as_str().unwrap(), "c");
        assert_eq!(j.path("a.0").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("d").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn f32_vec_helper() {
        let j = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(j.as_f32_vec().unwrap(), vec![1.0f32, 2.5, -3.0]);
    }

    #[test]
    fn num_formatting_integers() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
