//! Software bfloat16 (truncated IEEE-754 binary32 with round-to-nearest-even).
//!
//! OPQ stores outlier weights in bf16 (paper §3.3), and the quantization
//! constants are conventionally kept in bf16/fp32; this is the faithful
//! conversion used by `quant::opq` and the storage layer.

/// A bfloat16 value stored as its raw 16 bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct Bf16(pub u16);

impl Bf16 {
    /// Round-to-nearest-even conversion from f32.
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        if x.is_nan() {
            // quiet NaN, preserving sign
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // Standard RNE trick: add half-ulp (0x7fff) plus the lsb of the
        // kept part; the carry performs the round-up exactly for
        // above-tie values and for ties with an odd kept lsb.
        let rounded = bits.wrapping_add(0x7fff + ((bits >> 16) & 1));
        Bf16((rounded >> 16) as u16)
    }

    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    pub fn from_bits(bits: u16) -> Self {
        Bf16(bits)
    }

    pub fn to_bits(self) -> u16 {
        self.0
    }
}

/// Convert a slice, reporting max absolute conversion error (diagnostics).
pub fn roundtrip_max_err(xs: &[f32]) -> f32 {
    xs.iter()
        .map(|&x| (Bf16::from_f32(x).to_f32() - x).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers() {
        for v in [0.0f32, 1.0, -1.0, 2.0, 0.5, -0.25, 128.0] {
            assert_eq!(Bf16::from_f32(v).to_f32(), v, "v={v}");
        }
    }

    #[test]
    fn relative_error_bounded() {
        // bf16 has 8 significand bits: RNE rel err <= 2^-8 = 1/256.
        let mut x = 1e-3f32;
        while x < 1e3 {
            let r = Bf16::from_f32(x).to_f32();
            assert!(((r - x) / x).abs() <= 1.0 / 256.0 + 1e-7, "x={x} r={r}");
            x *= 1.37;
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // bf16 ulp at 1.0 is 2^-7; the tie 1 + 2^-8 = 1.00390625 has f32
        // bits 0x3f80_8000. Ties-to-even keeps 1.0 (0x3f80 is even).
        let tie = f32::from_bits(0x3f80_8000);
        assert_eq!(Bf16::from_f32(tie).to_f32(), 1.0, "tie rounds to even");
        // Just above the tie rounds up to the next bf16, 1.0078125.
        let above = f32::from_bits(0x3f80_8001);
        assert_eq!(Bf16::from_f32(above).to_f32(), 1.0078125);
        // Tie with odd kept lsb rounds up: 1.0078125 + 2^-8 -> 1.015625.
        let tie_odd = f32::from_bits(0x3f81_8000);
        assert_eq!(Bf16::from_f32(tie_odd).to_f32(), 1.015625);
    }

    #[test]
    fn nan_and_inf() {
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(
            Bf16::from_f32(f32::NEG_INFINITY).to_f32(),
            f32::NEG_INFINITY
        );
    }

    #[test]
    fn sign_preserved() {
        assert_eq!(Bf16::from_f32(-0.0).to_bits(), 0x8000);
        assert!(Bf16::from_f32(-3.7).to_f32() < 0.0);
    }

    #[test]
    fn roundtrip_err_helper() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.037).collect();
        let e = roundtrip_max_err(&xs);
        assert!(e <= 2.0 * 0.0039 * 2.0, "{e}"); // loose bound ~ulp scale
    }
}
