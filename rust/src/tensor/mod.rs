//! Minimal tensor substrate: dtypes (incl. software bf16 and packed 4-bit
//! nibbles), a shaped dense tensor over f32, and flat views.
//!
//! This is deliberately small — the heavy compute runs inside the AOT'd
//! XLA executables; rust needs tensors only for weight storage, the
//! quantization hot path and marshalling.

pub mod bf16;

pub use bf16::Bf16;

/// Element type of stored tensors.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    Bf16,
    U8,
    I32,
    /// Two 4-bit codes per byte (low nibble first).
    PackedU4,
}

impl DType {
    /// Bytes needed for `n` elements of this dtype.
    pub fn bytes_for(self, n: usize) -> usize {
        match self {
            DType::F32 | DType::I32 => 4 * n,
            DType::Bf16 => 2 * n,
            DType::U8 => n,
            DType::PackedU4 => n.div_ceil(2),
        }
    }

    /// Bits per element.
    pub fn bits(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 32,
            DType::Bf16 => 16,
            DType::U8 => 8,
            DType::PackedU4 => 4,
        }
    }
}

/// Dense row-major f32 tensor with a shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// 2-D accessor (row-major).
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[r * self.shape[1] + c]
    }

    /// Reshape in place (size-preserving).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.bytes_for(10), 40);
        assert_eq!(DType::Bf16.bytes_for(10), 20);
        assert_eq!(DType::PackedU4.bytes_for(10), 5);
        assert_eq!(DType::PackedU4.bytes_for(11), 6); // odd count rounds up
        assert_eq!(DType::PackedU4.bits(), 4);
    }

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.rank(), 2);
        let t = t.reshape(vec![3, 2]);
        assert_eq!(t.at2(2, 1), 6.0);
    }

    #[test]
    #[should_panic]
    fn tensor_rejects_bad_shape() {
        Tensor::new(vec![2, 2], vec![1.0; 5]);
    }

    #[test]
    fn norm() {
        let t = Tensor::new(vec![2], vec![3.0, 4.0]);
        assert!((t.norm() - 5.0).abs() < 1e-12);
    }
}
