//! `bof4` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   design    EM-design a codebook and print its reconstruction levels
//!   quantize  quantize a synthetic LLM (or a .wbin) and report error/memory
//!   train     pre-train the in-repo LM via the AOT'd train_step graph
//!   eval      perplexity + task accuracy for a quantizer configuration
//!   serve     run the batched inference service on a quantized model
//!   lint      house static analysis (determinism, SAFETY, metrics schema)
//!   info      artifact + platform inventory
//!
//! Run `bof4 <cmd> --help` for flags.

use std::sync::Arc;

use bof4::eval::{self, lora, ppl, tasks};
use bof4::lloyd;
use bof4::models::{ParamSet, SyntheticModel};
use bof4::quant::{Method, Norm, OpqConfig, QuantConfig, Quantizer};
use bof4::runtime::Runtime;
use bof4::util::cli::Args;
use bof4::{info, Result};

fn main() {
    bof4::util::log::init_from_env();
    bof4::obs::tracer::init_from_env();
    bof4::testkit::faults::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let rest: Vec<String> = argv.iter().skip(1).cloned().collect();
    let code = match cmd {
        "design" => run(design(rest)),
        "quantize" => run(quantize(rest)),
        "train" => run(train(rest)),
        "eval" => run(eval_cmd(rest)),
        "serve" => run(serve(rest)),
        "lint" => run(lint(rest)),
        "info" => run(info_cmd(rest)),
        _ => {
            eprintln!(
                "bof4 — 4-bit Block-Wise Optimal Float quantization\n\n\
                 USAGE: bof4 <design|quantize|train|eval|serve|lint|info> [flags]\n\
                 Each subcommand accepts --help."
            );
            2
        }
    };
    std::process::exit(code);
}

fn run(r: Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Parse the common quantizer flags into a QuantConfig.
fn quant_config(p: &bof4::util::cli::Parsed) -> QuantConfig {
    let method = match p.get("method").unwrap_or("bof4") {
        "nf4" => Method::Nf4,
        "af4" => Method::Af4,
        "bof4" => Method::Bof4 {
            mse: p.get("metric").unwrap_or("mse") == "mse",
        },
        other => {
            eprintln!("unknown method '{other}', using bof4");
            Method::Bof4 { mse: true }
        }
    };
    let norm = if p.has_flag("signed") || p.get("norm") == Some("signed") {
        Norm::SignedAbsmax
    } else if p.get("norm") == Some("abs") {
        Norm::Absmax
    } else if matches!(method, Method::Bof4 { .. }) {
        Norm::SignedAbsmax
    } else {
        Norm::Absmax
    };
    QuantConfig {
        method,
        norm,
        block: p.get_usize("block").unwrap_or(64),
        opq: if p.has_flag("opq") {
            Some(OpqConfig {
                q: p.get_f64("opq-q").unwrap_or(0.95),
            })
        } else {
            None
        },
        double_quant: p.has_flag("double-quant"),
    }
}

fn quant_flags(a: Args) -> Args {
    a.opt("method", Some("bof4"), "nf4 | af4 | bof4")
        .opt("metric", Some("mse"), "mse | mae (BOF4 optimization target)")
        .opt("norm", None, "abs | signed (default: signed for bof4)")
        .flag("signed", "shorthand for --norm signed")
        .opt("block", Some("64"), "block size I")
        .flag("opq", "enable outlier-preserving quantization")
        .opt("opq-q", Some("0.95"), "OPQ quantile q")
        .flag("double-quant", "8-bit quantize the block constants")
}

fn design(rest: Vec<String>) -> Result<()> {
    let p = quant_flags(Args::new("EM-design a BOF4 codebook"))
        .opt("backend", Some("empirical"), "empirical | theoretical")
        .opt("samples", Some("4194304"), "Monte-Carlo samples (empirical)")
        .parse_from(rest);
    let metric = if p.get("metric") == Some("mae") {
        lloyd::Metric::Mae
    } else {
        lloyd::Metric::Mse
    };
    let norm = if p.get("norm") == Some("abs") {
        Norm::Absmax
    } else {
        Norm::SignedAbsmax
    };
    let block = p.get_usize("block").unwrap_or(64);
    let cfg = lloyd::EmConfig::new(metric, norm, block);
    let cb = match p.get("backend").unwrap_or("empirical") {
        "theoretical" => lloyd::design_theoretical(&cfg),
        _ => lloyd::design_empirical(&cfg, p.get_usize("samples").unwrap_or(1 << 22), 0xB0F4),
    };
    println!("codebook: {}", cb.name);
    for (i, l) in cb.levels.iter().enumerate() {
        println!("  x({:>2}) = {:>+.16}", i + 1, l);
    }
    Ok(())
}

fn quantize(rest: Vec<String>) -> Result<()> {
    let p = quant_flags(Args::new("quantize a model and report error/memory"))
        .opt("wbin", None, "quantize this .wbin instead of synthetic models")
        .parse_from(rest);
    let cfg = quant_config(&p);
    println!("quantizer: {}", cfg.label());
    if let Some(path) = p.get("wbin") {
        let params = ParamSet::load(std::path::Path::new(path))?;
        let qm = eval::quantize_params(&params, &cfg)?;
        println!(
            "{path}: MAE {:.4e}  MSE {:.4e}  {} -> {} bytes, {} outliers",
            qm.mae, qm.mse, qm.orig_bytes, qm.quant_bytes, qm.outliers
        );
        return Ok(());
    }
    for model in SyntheticModel::paper_suite() {
        let q = Quantizer::new(cfg.clone());
        let flat = model.flat();
        let qt = q.quantize(&flat);
        let deq = q.dequantize(&qt);
        let mae = bof4::quant::error::mae(&flat, &deq);
        let mse = bof4::quant::error::mse(&flat, &deq);
        println!(
            "{:<14} {:>9} params  MAE {:.4e}  MSE {:.4e}  {:.3} bits/weight  {} outliers",
            model.name,
            model.n_params(),
            mae,
            mse,
            qt.bits_per_weight(),
            qt.outliers.len()
        );
    }
    Ok(())
}

fn train(rest: Vec<String>) -> Result<()> {
    let p = Args::new("pre-train the in-repo LM (cached in artifacts/)")
        .opt("steps", Some("400"), "training steps")
        .flag("force", "retrain even if a cached model exists")
        .parse_from(rest);
    let rt = Arc::new(Runtime::new()?);
    let path = eval::trainer::trained_model_path(&rt);
    if p.has_flag("force") && path.exists() {
        std::fs::remove_file(&path)?;
    }
    let mut cfg = eval::trainer::TrainConfig::default();
    if let Some(s) = p.get_usize("steps") {
        cfg.steps = s;
    }
    let outcome = eval::trainer::train(&rt, &cfg)?;
    outcome.params.save(&path)?;
    println!(
        "trained {} steps: loss {:.3} -> {:.3}; saved {path:?}",
        outcome.steps,
        outcome.losses.first().unwrap(),
        outcome.losses.last().unwrap()
    );
    Ok(())
}

fn eval_cmd(rest: Vec<String>) -> Result<()> {
    let p = quant_flags(Args::new("PPL + task accuracy for a quantizer"))
        .flag("bf16", "evaluate the unquantized model instead")
        .flag("tasks", "also run the multiple-choice suite")
        .parse_from(rest);
    let rt = Arc::new(Runtime::new()?);
    let base = eval::ensure_trained(&rt)?;
    let cfg = quant_config(&p);
    let (label, params) = if p.has_flag("bf16") {
        ("BF16".to_string(), base.clone())
    } else {
        let qm = eval::quantize_params(&base, &cfg)?;
        info!("quant error: MAE {:.4e} MSE {:.4e}", qm.mae, qm.mse);
        (cfg.label(), qm.params)
    };
    let ppl = ppl::perplexity(&rt, &params, &ppl::PplConfig::default())?;
    println!("{label}: held-out PPL = {ppl:.4}");
    if p.has_flag("tasks") {
        let suite = tasks::build_suite(40, 99);
        let mut results = Vec::new();
        for t in &suite {
            let acc = tasks::score_task(&rt, &params, t)?;
            println!("  {:<18} ACC {:.3} (chance {:.3})", t.name, acc, t.chance);
            results.push((acc, t.chance));
        }
        println!("  NAV ACC = {:.4}", tasks::nav_acc(&results));
    }
    Ok(())
}

fn serve(rest: Vec<String>) -> Result<()> {
    let p = quant_flags(Args::new("run the streaming session engine (demo)"))
        .opt("requests", Some("64"), "demo session count")
        .opt("tokens", Some("8"), "tokens streamed per session")
        .opt("replicas", Some("1"), "model replicas behind the router")
        .opt(
            "kv",
            None,
            "per-session KV-cache format: f32|q8|q4 (default: BOF4_KV env, else f32)",
        )
        .flag(
            "dequant",
            "serve exactly-dequantized f32 weights through the dense graphs \
             instead of the 4-bit-at-rest q4 serving path",
        )
        .opt("save", None, "write the packed serving parameters to this artifact path")
        .opt(
            "load",
            None,
            "serve a previously saved artifact instead of quantizing from scratch",
        )
        .flag("compress", "RLE-compress the artifact at rest (with --save)")
        .opt(
            "trace",
            None,
            "write a Chrome-trace JSON of the run here (Perfetto-loadable; \
             implies BOF4_TRACE=1 unless BOF4_TRACE already set a level)",
        )
        .opt(
            "metrics-file",
            None,
            "write Prometheus text metrics here (plus <path>.json), updated \
             periodically during the run and once at the end",
        )
        .opt(
            "deadline-ms",
            None,
            "per-session wall-time SLO in ms; overdue sessions are \
             cancelled at the next decode-step boundary (counted in \
             bof4_deadline_overruns_total / bof4_deadline_cancelled_total)",
        )
        .opt(
            "max-queue-depth",
            None,
            "admission limit: submissions past this queue depth are shed \
             per --shed instead of queueing unboundedly",
        )
        .opt(
            "shed",
            Some("reject"),
            "load-shed policy at --max-queue-depth: reject (the new \
             request) | oldest (evict the oldest queued session)",
        )
        .parse_from(rest);
    let trace_path = p.get("trace").map(std::path::PathBuf::from);
    let metrics_path = p.get("metrics-file").map(std::path::PathBuf::from);
    if trace_path.is_some() && bof4::obs::tracer::level() == bof4::obs::TraceLevel::Off {
        bof4::obs::tracer::set_level(bof4::obs::TraceLevel::Engine);
    }
    let rt = Arc::new(Runtime::new()?);
    let cfg = quant_config(&p);
    // Default: serve quantized-at-rest through the fused q4 graphs (with
    // `--opq`, outlier weights ride in the bf16 side-table the kernels
    // patch in). `--dequant` keeps the old dense-f32 demo path; `--load`
    // skips quantization entirely and serves an on-disk artifact.
    let mut save_opts = eval::SaveOptions {
        label: cfg.label(),
        compress: p.has_flag("compress"),
        ..Default::default()
    };
    let engine_params = if let Some(path) = p.get("load") {
        let (params, info) =
            eval::load_artifact(std::path::Path::new(path), &rt.meta.model)?;
        println!(
            "loaded {:?} artifact {path}: {} tensors, {} outliers, {} bytes on disk{}",
            info.kind,
            info.n_tensors,
            info.outliers,
            info.file_bytes,
            if info.compressed { " (RLE)" } else { "" }
        );
        save_opts.label = info.label.clone();
        save_opts.outliers = info.outliers;
        save_opts.quant_bytes = info.quant_bytes;
        save_opts.orig_bytes = info.orig_bytes;
        params
    } else if p.has_flag("dequant") {
        let base = eval::ensure_trained(&rt)?;
        let qm = eval::quantize_params(&base, &cfg)?;
        println!(
            "serving dense dequantized weights ({}): MAE {:.4e} MSE {:.4e}",
            cfg.label(),
            qm.mae,
            qm.mse
        );
        bof4::coordinator::EngineParams::Dense(qm.params.to_tensors())
    } else {
        let base = eval::ensure_trained(&rt)?;
        let qsp = eval::quantize_for_serving(&rt.meta, &base, &cfg)?;
        println!(
            "serving q4 at rest ({}): {} -> {} bytes ({:.2}x), {} outliers \
             ({} side-table bytes)",
            cfg.label(),
            qsp.orig_bytes,
            qsp.quant_bytes,
            qsp.orig_bytes as f64 / qsp.quant_bytes.max(1) as f64,
            qsp.outliers,
            bof4::quant::opq::opq_bytes(qsp.outliers)
        );
        save_opts.outliers = qsp.outliers;
        save_opts.quant_bytes = qsp.quant_bytes;
        save_opts.orig_bytes = qsp.orig_bytes;
        bof4::coordinator::EngineParams::QuantizedQ4(qsp.prefix)
    };
    if let Some(path) = p.get("save") {
        let info = eval::save_artifact(
            std::path::Path::new(path),
            &rt.meta.model,
            &engine_params,
            &save_opts,
        )?;
        println!(
            "saved {:?} artifact to {path}: {} bytes on disk{}",
            info.kind,
            info.file_bytes,
            if info.compressed { " (RLE)" } else { "" }
        );
    }
    let kv_format = match p.get("kv") {
        Some(s) => bof4::quant::KvFormat::parse(s)?,
        None => bof4::quant::KvFormat::from_env(),
    };
    let engine = bof4::coordinator::Engine::start(
        rt.clone(),
        engine_params,
        bof4::coordinator::EngineConfig {
            replicas: p.get_usize("replicas").unwrap_or(1),
            kv_format,
            session_deadline: p
                .get_usize("deadline-ms")
                .map(|ms| std::time::Duration::from_millis(ms as u64)),
            max_queue_depth: p.get_usize("max-queue-depth"),
            shed_policy: match p.get("shed").unwrap_or("reject") {
                "oldest" => bof4::coordinator::ShedPolicy::Oldest,
                "reject" => bof4::coordinator::ShedPolicy::Reject,
                other => {
                    eprintln!("unknown shed policy '{other}', using reject");
                    bof4::coordinator::ShedPolicy::Reject
                }
            },
            ..Default::default()
        },
    )?;
    let mem = engine.memory_profile();
    println!(
        "resident memory: {} param bytes shared once across {} replicas, \
         {} bytes/replica private (total {})",
        mem.shared_param_bytes,
        mem.replicas,
        mem.per_replica_bytes.first().copied().unwrap_or(0),
        mem.total_resident_bytes
    );
    match mem.sessions_per_gb() {
        Some(spg) => println!(
            "kv cache: {} format, {} bytes/session ({:.0} sessions/GB)",
            mem.kv_format, mem.session_kv_bytes, spg
        ),
        None => println!("kv cache: none (full-context mode)"),
    }
    let n = p.get_usize("requests").unwrap_or(64);
    let tokens = p.get_usize("tokens").unwrap_or(8);
    let corpus = bof4::models::Corpus::generate(50_000, 5);
    let sw = bof4::util::timer::Stopwatch::start();
    let mut sessions = Vec::new();
    let mut shed = 0usize;
    for i in 0..n {
        let start = (i * 97) % (corpus.len() - 48);
        match engine.session_with(&corpus.tokens[start..start + 48], tokens) {
            Ok(s) => sessions.push(s),
            // admission control under --max-queue-depth sheds the new
            // request with a retryable Overloaded error — expected load
            // behaviour, not a demo failure
            Err(e) if e.is_retryable() => shed += 1,
            Err(e) => return Err(e),
        }
    }
    let mut answered = 0;
    let mut streamed = 0usize;
    let mut deadlined = 0usize;
    let mut faulted = 0usize;
    let mut first_stream: Option<Vec<u8>> = None;
    let mut last_dump = std::time::Instant::now();
    for sess in sessions {
        match sess.collect_tokens() {
            Ok(toks) => {
                if first_stream.is_none() {
                    first_stream = Some(toks.clone());
                }
                streamed += toks.len();
                answered += 1;
            }
            // typed engine faults (oldest-shed eviction, deadline
            // cancellation, replica failure) are expected under
            // --max-queue-depth / --deadline-ms / BOF4_FAULT — count
            // them and keep draining the remaining streams
            Err(e) => match e.engine_error() {
                Some(bof4::coordinator::EngineError::Overloaded { .. }) => shed += 1,
                Some(bof4::coordinator::EngineError::DeadlineExceeded { .. }) => deadlined += 1,
                Some(_) => faulted += 1,
                None => return Err(e),
            },
        }
        // periodic metrics dump, so a scraper tailing the file sees the
        // run progress (the engine handle is !Sync — dumps ride the
        // collect loop rather than a thread)
        if let Some(mp) = &metrics_path {
            if last_dump.elapsed() >= std::time::Duration::from_millis(250) {
                write_metrics_files(mp, &engine)?;
                last_dump = std::time::Instant::now();
            }
        }
    }
    let secs = sw.elapsed().as_secs_f64();
    // deterministic fingerprint of the first session's greedy stream —
    // the CI artifact smoke diffs this line between a --save run and the
    // --load run of the same artifact (bit-identical serving contract)
    if let Some(toks) = first_stream {
        let s: Vec<String> = toks.iter().map(|t| t.to_string()).collect();
        println!("stream[0]: {}", s.join(" "));
    }
    println!(
        "served {answered}/{n} sessions ({streamed} tokens) in {secs:.2}s \
         ({:.1} tok/s); {shed} shed, {deadlined} deadline-cancelled, \
         {faulted} faulted\n{}",
        streamed as f64 / secs,
        engine.metrics.summary()
    );
    if let Some(mp) = &metrics_path {
        write_metrics_files(mp, &engine)?;
        println!(
            "metrics: wrote Prometheus text to {} (and JSON to {}.json)",
            mp.display(),
            mp.display()
        );
    }
    if let Some(tp) = &trace_path {
        let snap = bof4::obs::tracer().snapshot();
        std::fs::write(tp, bof4::obs::chrome_trace(&snap).to_string())
            .map_err(|e| bof4::err!("write {}: {e}", tp.display()))?;
        println!(
            "trace: wrote {} events ({} evicted) to {} — open in \
             https://ui.perfetto.dev or chrome://tracing",
            snap.events.len(),
            snap.dropped,
            tp.display()
        );
    }
    Ok(())
}

/// Dump one engine observability snapshot: Prometheus text at `path`,
/// the same snapshot as JSON at `<path>.json`.
fn write_metrics_files(path: &std::path::Path, engine: &bof4::coordinator::Engine) -> Result<()> {
    let snap = engine.snapshot();
    std::fs::write(path, snap.to_prometheus())
        .map_err(|e| bof4::err!("write {}: {e}", path.display()))?;
    let mut jp = path.as_os_str().to_owned();
    jp.push(".json");
    let jp = std::path::PathBuf::from(jp);
    std::fs::write(&jp, snap.to_json().to_string())
        .map_err(|e| bof4::err!("write {}: {e}", jp.display()))?;
    Ok(())
}

/// `bof4 lint` — run the house static analysis over the crate's own
/// sources. Exits nonzero on any violation, so CI can gate on it.
fn lint(rest: Vec<String>) -> Result<()> {
    let p = Args::new("house-invariant static analysis over src/, benches/ and tests/")
        .opt(
            "root",
            None,
            "crate root containing src/ (default: ./rust, else .)",
        )
        .flag("json", "emit the machine-readable JSON report on stdout")
        .flag("rules", "list the rules and what they enforce, then exit")
        .parse_from(rest);
    if p.has_flag("rules") {
        for (name, summary) in bof4::analysis::rule_table() {
            println!("{name:<18} {summary}");
        }
        return Ok(());
    }
    let root = match p.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => bof4::analysis::find_root()?,
    };
    let analysis = bof4::analysis::Analysis::load_tree(&root)?;
    let report = analysis.run();
    if p.has_flag("json") {
        let json = report.to_json().to_string();
        println!("{json}");
    } else {
        print!("{}", report.render_human());
    }
    if report.is_clean() {
        Ok(())
    } else {
        Err(bof4::err!("lint: {} violation(s)", report.findings.len()))
    }
}

fn info_cmd(_rest: Vec<String>) -> Result<()> {
    let rt = Runtime::new()?;
    println!("{}", bof4::PAPER);
    println!("platform: {}", rt.platform());
    println!(
        "kernel threads: {} (set BOF4_THREADS to override; results are \
         bit-identical at any width)",
        bof4::runtime::kernels::threads_from_env()
    );
    println!(
        "kernel simd: {} (set BOF4_SIMD=0|1|array|avx2 to override; \
         results are bit-identical on every path)",
        rt.simd_path().unwrap_or("n/a")
    );
    println!(
        "kv cache format: {} (set BOF4_KV=f32|q8|q4 to override; q8/q4 \
         quantize per-session caches block-wise, dequantized fused inside \
         decode attention)",
        bof4::quant::KvFormat::from_env()
    );
    println!(
        "tracing: {:?} (set BOF4_TRACE=0|1|kernel — or BOF4_LOG=trace — \
         to record engine/kernel spans; export with bof4 serve --trace \
         <path>; token streams are bit-identical at every level)",
        bof4::obs::tracer::level()
    );
    println!(
        "fault injection: {} (set BOF4_FAULT=panic_decode:<n>,err_prefill:<n>,\
         slow_step:<ms> to arm the testkit chaos hooks in the CPU backend; \
         unset, each hook is a single relaxed atomic load)",
        if bof4::testkit::faults::armed() {
            "armed"
        } else {
            "off"
        }
    );
    println!("model: {:?}", rt.meta.model);
    println!("graphs:");
    for (name, g) in &rt.meta.graphs {
        println!(
            "  {:<22} {:>3} args -> {:>3} results ({})",
            name,
            g.args.len(),
            g.results.len(),
            g.file.file_name().unwrap().to_string_lossy()
        );
    }
    let _ = lora::LoraConfig::default(); // (module linked into the CLI)
    Ok(())
}
