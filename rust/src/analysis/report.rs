//! Diagnostics and report rendering for `bof4 lint`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::Json;

/// One rule violation, anchored to a `file:line` site.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Rule name (kebab-case, matches the `lint: allow(..)` pragma).
    pub rule: &'static str,
    /// Crate-relative forward-slash path, e.g. `src/lib.rs`.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable explanation of the violation.
    pub message: String,
}

impl Finding {
    /// `file:line: [rule] message` — the `file:line` prefix is what
    /// editors and CI annotations latch onto.
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// The result of one lint run over a file set.
#[derive(Debug)]
pub struct LintReport {
    /// All surviving findings, sorted by path, line, rule.
    pub findings: Vec<Finding>,
    /// Number of files lexed and checked.
    pub files_scanned: usize,
    /// Number of rules run (single-file rules + the cross-file one).
    pub rules_checked: usize,
}

impl LintReport {
    /// True when no rule fired.
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human report: one `file:line: [rule] message` per finding plus a
    /// one-line summary.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&f.render());
            out.push('\n');
        }
        let _ = writeln!(
            out,
            "lint: {} file(s), {} rule(s), {} violation(s)",
            self.files_scanned,
            self.rules_checked,
            self.findings.len()
        );
        out
    }

    /// Machine report: `{files_scanned, rules_checked, violations,
    /// findings: [{file, line, rule, message}]}`.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert(
            "files_scanned".to_string(),
            Json::Num(self.files_scanned as f64),
        );
        obj.insert(
            "rules_checked".to_string(),
            Json::Num(self.rules_checked as f64),
        );
        obj.insert(
            "violations".to_string(),
            Json::Num(self.findings.len() as f64),
        );
        let findings = self
            .findings
            .iter()
            .map(|f| {
                let mut m = BTreeMap::new();
                m.insert("file".to_string(), Json::Str(f.path.clone()));
                m.insert("line".to_string(), Json::Num(f.line as f64));
                m.insert("rule".to_string(), Json::Str(f.rule.to_string()));
                m.insert("message".to_string(), Json::Str(f.message.clone()));
                Json::Obj(m)
            })
            .collect();
        obj.insert("findings".to_string(), Json::Arr(findings));
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> LintReport {
        LintReport {
            findings: vec![Finding {
                rule: "float-cmp",
                path: "src/x.rs".to_string(),
                line: 7,
                message: "use total_cmp".to_string(),
            }],
            files_scanned: 3,
            rules_checked: 8,
        }
    }

    #[test]
    fn human_rendering_has_file_line_prefix() {
        let r = report();
        let text = r.render_human();
        assert!(text.starts_with("src/x.rs:7: [float-cmp] use total_cmp\n"));
        assert!(text.contains("3 file(s), 8 rule(s), 1 violation(s)"));
        assert!(!r.is_clean());
    }

    #[test]
    fn json_roundtrips_through_the_parser() {
        let text = report().to_json().to_string();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.path("violations").and_then(Json::as_usize), Some(1));
        assert_eq!(
            j.path("findings.0.file").and_then(Json::as_str),
            Some("src/x.rs")
        );
        assert_eq!(j.path("findings.0.line").and_then(Json::as_usize), Some(7));
        assert_eq!(
            j.path("findings.0.rule").and_then(Json::as_str),
            Some("float-cmp")
        );
    }
}
