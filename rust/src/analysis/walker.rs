//! Source-tree discovery for `bof4 lint`.

use std::path::{Path, PathBuf};

use crate::error::Context as _;
use crate::Result;

/// The crate-relative directories the linter covers.
pub const ROOTS: [&str; 3] = ["src", "benches", "tests"];

/// Collect every `.rs` file under `root`'s `src/`, `benches/` and
/// `tests/` directories (recursively), sorted for deterministic
/// diagnostics. Missing directories are skipped, so the walker also
/// works on partial checkouts.
pub fn source_files(root: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for dir in ROOTS {
        let d = root.join(dir);
        if d.is_dir() {
            walk(&d, &mut out)?;
        }
    }
    out.sort();
    Ok(out)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("lint: reading {}", dir.display()))?;
    for entry in entries {
        let path = entry
            .with_context(|| format!("lint: reading {}", dir.display()))?
            .path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walks_this_crate_deterministically() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let files = source_files(root).unwrap();
        assert!(files.iter().any(|p| p.ends_with("src/lib.rs")));
        assert!(files.iter().any(|p| p.ends_with("src/analysis/walker.rs")));
        let mut sorted = files.clone();
        sorted.sort();
        assert_eq!(files, sorted);
    }
}
