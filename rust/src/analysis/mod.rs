//! `bof4 lint` — house-invariant static analysis.
//!
//! The paper's reproducibility story rests on invariants no type system
//! checks for us: kernels bit-exact across `BOF4_THREADS x BOF4_SIMD x
//! BOF4_KV` (no FMA, no ambient clocks), a serving engine that recovers
//! poisoned locks instead of cascading panics, total float orderings,
//! documented `unsafe`, and exporters that never silently drop a metric
//! series. PRs 4-9 re-fixed violations of these by hand; this module
//! enforces them by machine.
//!
//! The pipeline: [`walker`] discovers `.rs` files under `src/`,
//! `benches/` and `tests/`; [`lexer`] splits each file into code and
//! comment channels (string/char literal contents blanked, literals
//! collected separately); [`rules`] runs the single-file rules and
//! [`schema`] the cross-file metrics-schema rule; [`report`] renders
//! `file:line` diagnostics or the `--json` machine report.
//!
//! Suppress a single site by putting `// lint: allow(<rule-name>)` on
//! the offending line or the line directly above it. Suppressions are
//! deliberate and visible in review — prefer fixing the code.
//!
//! Run it as `bof4 lint` (nonzero exit on any violation), `bof4 lint
//! --json` for the machine report, `bof4 lint --rules` for the rule
//! table. No dependencies, std only, like everything else in the crate.

pub mod lexer;
pub mod report;
pub mod rules;
pub mod schema;
pub mod walker;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::Context as _;
use crate::Result;
use lexer::FileModel;

pub use report::{Finding, LintReport};

/// A loaded set of source files ready to lint.
#[derive(Default)]
pub struct Analysis {
    files: Vec<FileModel>,
}

impl Analysis {
    /// Empty analysis; add files with [`Analysis::add_source`].
    pub fn new() -> Analysis {
        Analysis { files: Vec::new() }
    }

    /// Add one in-memory source file under a crate-relative path label
    /// (e.g. `src/runtime/kernels/fake.rs`). Rule scoping keys off the
    /// label, which is what makes fixture corpora testable without
    /// touching the filesystem.
    pub fn add_source(&mut self, path: &str, src: &str) {
        self.files.push(lexer::lex(path, src));
    }

    /// Lex every `.rs` file under `root`'s `src/`, `benches/` and
    /// `tests/` directories.
    pub fn load_tree(root: &Path) -> Result<Analysis> {
        let mut a = Analysis::new();
        for p in walker::source_files(root)? {
            let rel = rel_label(root, &p);
            let src = std::fs::read_to_string(&p)
                .with_context(|| format!("lint: reading {}", p.display()))?;
            a.add_source(&rel, &src);
        }
        Ok(a)
    }

    /// Run every rule. Findings come back sorted by path/line/rule and
    /// with `lint: allow(..)` pragmas already applied.
    pub fn run(&self) -> LintReport {
        let rules = rules::registry();
        let mut findings = Vec::new();
        for fm in &self.files {
            for r in &rules {
                findings.extend((r.check)(fm));
            }
        }
        findings.extend(schema::check(&self.files));
        let by_path: BTreeMap<&str, &FileModel> =
            self.files.iter().map(|f| (f.path.as_str(), f)).collect();
        findings.retain(|f| !suppressed(&by_path, f));
        findings.sort_by(|a, b| {
            (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
        });
        LintReport {
            findings,
            files_scanned: self.files.len(),
            // single-file rules + the cross-file metrics-schema rule
            rules_checked: rules.len() + 1,
        }
    }
}

/// (name, summary) for every rule — docs and `bof4 lint --rules`.
pub fn rule_table() -> Vec<(&'static str, &'static str)> {
    let mut table: Vec<(&'static str, &'static str)> = rules::registry()
        .iter()
        .map(|r| (r.name, r.summary))
        .collect();
    table.push((schema::NAME, schema::SUMMARY));
    table
}

/// Locate the crate root: `./rust` from the repo root, `.` when already
/// inside the crate.
pub fn find_root() -> Result<PathBuf> {
    for cand in ["rust", "."] {
        let p = Path::new(cand);
        if p.join("src").join("lib.rs").is_file() {
            return Ok(p.to_path_buf());
        }
    }
    Err(crate::err!(
        "lint: could not find the crate root (expected ./src/lib.rs or ./rust/src/lib.rs; \
         run from the repo root or pass --root)"
    ))
}

/// A finding is suppressed when the offending line, or the line just
/// above it, carries a `lint: allow(<rule>)` comment.
fn suppressed(by_path: &BTreeMap<&str, &FileModel>, f: &Finding) -> bool {
    let Some(fm) = by_path.get(f.path.as_str()) else {
        return false;
    };
    let needle = format!("lint: allow({})", f.rule);
    let lo = f.line.saturating_sub(2);
    fm.lines
        .get(lo..f.line)
        .unwrap_or(&[])
        .iter()
        .any(|li| li.comment.contains(&needle))
}

fn rel_label(root: &Path, p: &Path) -> String {
    let rel = p.strip_prefix(root).unwrap_or(p);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pragma_suppresses_same_line_and_line_above() {
        let mut a = Analysis::new();
        a.add_source(
            "src/a.rs",
            "let g = m.lock().unwrap(); // lint: allow(lock-unwrap)\n",
        );
        assert!(a.run().is_clean());

        let mut b = Analysis::new();
        b.add_source(
            "src/b.rs",
            "// lint: allow(lock-unwrap): poisoning exercised on purpose\n\
             let g = m.lock().unwrap();\n",
        );
        assert!(b.run().is_clean());

        let mut c = Analysis::new();
        c.add_source(
            "src/c.rs",
            "// lint: allow(float-cmp) — wrong rule name\nlet g = m.lock().unwrap();\n",
        );
        assert_eq!(c.run().findings.len(), 1);
    }

    #[test]
    fn findings_sorted_and_counted() {
        let mut a = Analysis::new();
        a.add_source("src/z.rs", "let g = m.lock().unwrap();\n");
        a.add_source("src/a.rs", "v.sort_by(|x, y| x.partial_cmp(y).unwrap());\n");
        let r = a.run();
        assert_eq!(r.findings.len(), 2);
        assert_eq!(r.findings[0].path, "src/a.rs");
        assert_eq!(r.findings[1].path, "src/z.rs");
        assert_eq!(r.files_scanned, 2);
        assert_eq!(r.rules_checked, 8);
    }

    #[test]
    fn rule_table_lists_all_eight() {
        let t = rule_table();
        assert_eq!(t.len(), 8);
        assert!(t.iter().any(|(n, _)| *n == "metrics-schema"));
        assert!(t.iter().any(|(n, _)| *n == "safety-comment"));
    }
}
