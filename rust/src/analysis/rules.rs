//! The single-file house rules (the cross-file metrics-schema rule
//! lives in [`super::schema`]).
//!
//! Every rule here is a pure function over one lexed [`FileModel`] and
//! exists because some PR in this repo's history shipped (or nearly
//! shipped) the violation it bans. The common theme is determinism:
//! bit-exact kernels across `BOF4_THREADS x BOF4_SIMD x BOF4_KV`, and
//! a serving engine that degrades instead of panicking.

use super::lexer::{self, FileModel};
use super::report::Finding;

/// One registered rule: a stable kebab-case name (used by the
/// `lint: allow(<name>)` pragma), a summary for docs, and the check.
pub struct Rule {
    pub name: &'static str,
    pub summary: &'static str,
    pub check: fn(&FileModel) -> Vec<Finding>,
}

/// All single-file rules, in diagnostic order.
pub fn registry() -> Vec<Rule> {
    vec![
        Rule {
            name: "lock-unwrap",
            summary: "no `.lock().unwrap()` — a poisoned mutex must recover via \
                      util::sync::lock_recover, not cascade panics",
            check: lock_unwrap,
        },
        Rule {
            name: "float-cmp",
            summary: "no `partial_cmp` on floats in src/ — orderings must be total \
                      (`total_cmp`) so NaN can never panic a sort or pick",
            check: float_cmp,
        },
        Rule {
            name: "safety-comment",
            summary: "every `unsafe` block/impl/fn carries a `// SAFETY:` comment or a \
                      `# Safety` doc section justifying it",
            check: safety_comment,
        },
        Rule {
            name: "fma-in-kernels",
            summary: "no `mul_add`/FMA tokens in runtime/kernels/ — fused rounding breaks \
                      the bit-exactness contract with the scalar path",
            check: fma_in_kernels,
        },
        Rule {
            name: "stdout-in-lib",
            summary: "no println!/eprintln!/dbg!/process::exit in library code — route \
                      diagnostics through util::log",
            check: stdout_in_lib,
        },
        Rule {
            name: "timing-in-kernels",
            summary: "no Instant/SystemTime inside runtime/kernels/ inner files — only \
                      pool.rs owns the profile clock",
            check: timing_in_kernels,
        },
        Rule {
            name: "gate-ordering",
            summary: "atomic fast-path gates (SCREAMING_CASE statics) load with \
                      Ordering::Relaxed, never SeqCst",
            check: gate_ordering,
        },
    ]
}

fn finding(rule: &'static str, fm: &FileModel, line: usize, message: String) -> Finding {
    Finding {
        rule,
        path: fm.path.clone(),
        line,
        message,
    }
}

/// Rule 1: `.lock().unwrap()` turns one panicked holder into a
/// process-wide panic cascade. Matched on the whitespace-free code
/// stream so a rustfmt-split chain cannot hide it.
fn lock_unwrap(fm: &FileModel) -> Vec<Finding> {
    let (flat, line_of) = lexer::flat_code(fm);
    let pat = ".lock().unwrap()";
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(off) = flat[from..].find(pat) {
        let p = from + off;
        from = p + pat.len();
        out.push(finding(
            "lock-unwrap",
            fm,
            line_of[p],
            "`.lock().unwrap()` panics forever once any holder panicked; use \
             `util::sync::lock_recover` (PoisonError::into_inner) instead"
                .to_string(),
        ));
    }
    out
}

/// Rule 2: `partial_cmp(..).unwrap()` (and friends) panic on NaN and
/// order `-0.0`/`+0.0` arbitrarily; `total_cmp` is the house ordering.
fn float_cmp(fm: &FileModel) -> Vec<Finding> {
    if !fm.path.starts_with("src/") {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (idx, li) in fm.lines.iter().enumerate() {
        if lexer::has_token(&li.code, "partial_cmp") {
            out.push(finding(
                "float-cmp",
                fm,
                idx + 1,
                "float comparison via `partial_cmp` can panic or misorder on NaN; \
                 use `total_cmp` (IEEE total order) instead"
                    .to_string(),
            ));
        }
    }
    out
}

/// Rule 3: every `unsafe` site needs a written justification — either a
/// `// SAFETY:` comment within the 5 preceding lines (one comment may
/// cover a short run of unsafe lines below it), or a `# Safety` doc
/// section in the contiguous doc/attribute block above the item.
fn safety_comment(fm: &FileModel) -> Vec<Finding> {
    let mut out = Vec::new();
    for (idx, li) in fm.lines.iter().enumerate() {
        if !lexer::has_token(&li.code, "unsafe") {
            continue;
        }
        if has_safety_note(fm, idx) {
            continue;
        }
        out.push(finding(
            "safety-comment",
            fm,
            idx + 1,
            "`unsafe` without a `// SAFETY:` comment (or `# Safety` doc section) \
             justifying why the contract holds"
                .to_string(),
        ));
    }
    out
}

fn has_safety_note(fm: &FileModel, idx: usize) -> bool {
    let lo = idx.saturating_sub(5);
    if fm.lines[lo..=idx].iter().any(|li| is_safety(&li.comment)) {
        return true;
    }
    // Long doc blocks: walk up through contiguous comment/attribute/blank
    // lines looking for a `# Safety` section further away.
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let li = &fm.lines[j];
        let code = li.code.trim();
        let annotation = code.is_empty() || code.starts_with("#[") || code.starts_with("#![");
        if !annotation {
            return false;
        }
        if is_safety(&li.comment) {
            return true;
        }
    }
    false
}

fn is_safety(comment: &str) -> bool {
    comment.contains("SAFETY") || comment.contains("# Safety")
}

/// Rule 4: fused multiply-add rounds once where the scalar reference
/// path rounds twice — any FMA token inside the kernels breaks the
/// cross-backend bit-exactness pin.
fn fma_in_kernels(fm: &FileModel) -> Vec<Finding> {
    if !fm.path.starts_with("src/runtime/kernels/") {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (idx, li) in fm.lines.iter().enumerate() {
        if lexer::has_token(&li.code, "mul_add") || li.code.contains("fmadd") {
            out.push(finding(
                "fma-in-kernels",
                fm,
                idx + 1,
                "FMA token in a kernel file: fused rounding diverges from the \
                 scalar reference path and breaks bit-exactness"
                    .to_string(),
            ));
        }
    }
    out
}

/// Files allowed to write to stdout/stderr directly: the CLI binary,
/// the argument parser (usage/errors before logging exists), and the
/// logger itself (stderr is its sink).
const STDOUT_EXEMPT: [&str; 3] = ["src/main.rs", "src/util/cli.rs", "src/util/log.rs"];

/// Rule 5: library code must not print or exit; `#[cfg(test)]` regions
/// are exempt (test diagnostics are fine).
fn stdout_in_lib(fm: &FileModel) -> Vec<Finding> {
    if !fm.path.starts_with("src/") || STDOUT_EXEMPT.contains(&fm.path.as_str()) {
        return Vec::new();
    }
    let pats = [
        "println!",
        "eprintln!",
        "print!",
        "eprint!",
        "dbg!",
        "process::exit",
    ];
    let mut out = Vec::new();
    for (idx, li) in fm.lines.iter().enumerate() {
        if li.in_test {
            continue;
        }
        if pats.iter().any(|p| lexer::has_token(&li.code, p)) {
            out.push(finding(
                "stdout-in-lib",
                fm,
                idx + 1,
                "direct stdout/stderr/exit in library code; route diagnostics \
                 through util::log so BOF4_LOG stays in control"
                    .to_string(),
            ));
        }
    }
    out
}

/// Rule 6: kernel inner files must stay clock-free — timing belongs to
/// the pool's profile points (pool.rs), where it is recorded once per
/// dispatch instead of inside tile loops.
fn timing_in_kernels(fm: &FileModel) -> Vec<Finding> {
    if !fm.path.starts_with("src/runtime/kernels/") || fm.path.ends_with("/pool.rs") {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (idx, li) in fm.lines.iter().enumerate() {
        if lexer::has_token(&li.code, "Instant") || lexer::has_token(&li.code, "SystemTime") {
            out.push(finding(
                "timing-in-kernels",
                fm,
                idx + 1,
                "clock access in a kernel inner file; only pool.rs profile points \
                 may read time (kernels stay deterministic and cheap)"
                    .to_string(),
            ));
        }
    }
    out
}

/// Rule 7: the repo's off-path gates (`LEVEL`, `ARMED`, ...) are
/// SCREAMING_CASE atomics read on hot paths; they must load Relaxed.
fn gate_ordering(fm: &FileModel) -> Vec<Finding> {
    if !fm.path.starts_with("src/") {
        return Vec::new();
    }
    let (flat, line_of) = lexer::flat_code(fm);
    let bytes = flat.as_bytes();
    let call = ".load(";
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(off) = flat[from..].find(call) {
        let p = from + off;
        from = p + call.len();
        let arg_start = p + call.len();
        let Some(arg_len) = flat[arg_start..].find(')') else {
            break;
        };
        if !flat[arg_start..arg_start + arg_len].ends_with("SeqCst") {
            continue;
        }
        let mut j = p;
        while j > 0 && lexer::is_ident_byte(bytes[j - 1]) {
            j -= 1;
        }
        let recv = &flat[j..p];
        if is_screaming(recv) {
            out.push(finding(
                "gate-ordering",
                fm,
                line_of[p],
                format!(
                    "fast-path gate `{recv}` loads with Ordering::SeqCst; house gates \
                     load Relaxed (the disarmed path must stay fence-free)"
                ),
            ));
        }
    }
    out
}

fn is_screaming(s: &str) -> bool {
    s.len() >= 2
        && s.bytes().any(|b| b.is_ascii_uppercase())
        && s.bytes()
            .all(|b| b.is_ascii_uppercase() || b.is_ascii_digit() || b == b'_')
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn run_rule(rule: fn(&FileModel) -> Vec<Finding>, path: &str, src: &str) -> Vec<Finding> {
        rule(&lex(path, src))
    }

    #[test]
    fn lock_unwrap_catches_split_chains() {
        let hits = run_rule(lock_unwrap, "src/a.rs", "let g = m.lock()\n    .unwrap();\n");
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].line, 1);
        let ok = run_rule(
            lock_unwrap,
            "src/a.rs",
            "let g = m.lock().unwrap_or_else(PoisonError::into_inner);\n",
        );
        assert!(ok.is_empty());
    }

    #[test]
    fn float_cmp_only_fires_in_src() {
        let src = "v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        assert_eq!(run_rule(float_cmp, "src/a.rs", src).len(), 1);
        assert!(run_rule(float_cmp, "benches/a.rs", src).is_empty());
    }

    #[test]
    fn safety_comment_accepts_nearby_and_doc_forms() {
        let bad = "fn f(p: *mut u8) {\n    unsafe { p.write(0) };\n}\n";
        assert_eq!(run_rule(safety_comment, "src/a.rs", bad).len(), 1);
        let near = "// SAFETY: p is valid for writes.\nlet x = unsafe { p.write(0) };\n";
        assert!(run_rule(safety_comment, "src/a.rs", near).is_empty());
        let doc = "/// Does things.\n///\n/// # Safety\n/// Caller checks p.\n\
                   #[inline]\npub unsafe fn f(p: *mut u8) {}\n";
        assert!(run_rule(safety_comment, "src/a.rs", doc).is_empty());
    }

    #[test]
    fn one_safety_comment_covers_a_short_run() {
        let src = "// SAFETY: disjoint tiles per task.\n\
                   let a = unsafe { s.slice_mut(0, 4) };\n\
                   let b = unsafe { t.slice_mut(0, 4) };\n";
        assert!(run_rule(safety_comment, "src/a.rs", src).is_empty());
    }

    #[test]
    fn fma_scoped_to_kernel_files() {
        let src = "let y = x.mul_add(a, b);\n";
        assert_eq!(run_rule(fma_in_kernels, "src/runtime/kernels/k.rs", src).len(), 1);
        assert!(run_rule(fma_in_kernels, "src/stats/m.rs", src).is_empty());
        let intrinsic = "let y = _mm256_fmadd_ps(a, b, c);\n";
        let hits = run_rule(fma_in_kernels, "src/runtime/kernels/k.rs", intrinsic);
        assert_eq!(hits.len(), 1);
    }

    #[test]
    fn stdout_rule_exempts_tests_and_cli() {
        let src = "fn f() {\n    println!(\"x\");\n}\n";
        assert_eq!(run_rule(stdout_in_lib, "src/quant/mod.rs", src).len(), 1);
        assert!(run_rule(stdout_in_lib, "src/main.rs", src).is_empty());
        let t = "#[cfg(test)]\nmod t {\n    fn f() {\n        println!(\"x\");\n    }\n}\n";
        assert!(run_rule(stdout_in_lib, "src/quant/mod.rs", t).is_empty());
    }

    #[test]
    fn timing_rule_exempts_pool() {
        let src = "let t0 = Instant::now();\n";
        assert_eq!(run_rule(timing_in_kernels, "src/runtime/kernels/kv.rs", src).len(), 1);
        assert!(run_rule(timing_in_kernels, "src/runtime/kernels/pool.rs", src).is_empty());
        assert!(run_rule(timing_in_kernels, "src/obs/tracer.rs", src).is_empty());
    }

    #[test]
    fn gate_ordering_flags_screaming_receivers_only() {
        let bad = "if ARMED.load(Ordering::SeqCst) == 0 {}\n";
        let hits = run_rule(gate_ordering, "src/a.rs", bad);
        assert_eq!(hits.len(), 1);
        assert!(hits[0].message.contains("ARMED"));
        let relaxed = "if ARMED.load(Ordering::Relaxed) == 0 {}\n";
        assert!(run_rule(gate_ordering, "src/a.rs", relaxed).is_empty());
        let lower = "let d = self.depth.load(Ordering::SeqCst);\n";
        assert!(run_rule(gate_ordering, "src/a.rs", lower).is_empty());
    }
}
