//! A minimal Rust lexer for the house linter.
//!
//! `bof4 lint` needs just enough lexing to be trustworthy: token rules
//! must never fire inside comments or string literals, SAFETY/pragma
//! detection must see comment text, and the metrics-schema rule must
//! see string-literal contents. [`lex`] therefore splits a source file
//! into per-line channels:
//!
//! - `code`: the line with comments removed and every string/char
//!   literal content blanked to spaces (quotes kept, so the shape of
//!   the line survives);
//! - `comment`: the concatenated comment text of the line (line, doc
//!   and block comments);
//! - plus an ordered list of string-literal contents, each tagged with
//!   the line its literal starts on.
//!
//! The lexer understands nested block comments, raw strings
//! (`r"..."` / `r#"..."#` / `br"..."`), byte strings, char literals,
//! and tells `'a'` char literals from `'a` lifetimes. It is not a full
//! Rust lexer — just a faithful enough one for line-level rules.

/// One analyzed line of a source file.
#[derive(Debug, Clone)]
pub struct LineInfo {
    /// Code text: comments stripped, literal contents blanked.
    pub code: String,
    /// Comment text on this line (the `//`, `/*`, `*/` markers are
    /// stripped; doc-comment `/` / `!` prefixes are kept).
    pub comment: String,
    /// True when the line sits inside a `#[cfg(test)] mod` region.
    pub in_test: bool,
}

/// One string literal: content plus the 1-based line it starts on.
#[derive(Debug, Clone)]
pub struct StrLit {
    /// 1-based line of the opening quote.
    pub line: usize,
    /// Literal content (escape sequences kept verbatim).
    pub text: String,
}

/// Lexed view of a single source file.
#[derive(Debug, Clone)]
pub struct FileModel {
    /// Forward-slash path label relative to the crate root, e.g.
    /// `src/runtime/kernels/pool.rs`. Rule scoping keys off this label.
    pub path: String,
    /// Per-line code/comment channels.
    pub lines: Vec<LineInfo>,
    /// Every string literal in source order.
    pub strings: Vec<StrLit>,
}

/// Lex `src` into a [`FileModel`] under the given path label.
pub fn lex(path: &str, src: &str) -> FileModel {
    let chars: Vec<char> = src.chars().collect();
    let mut lx = Lexer {
        c: &chars,
        i: 0,
        lines: Vec::new(),
        strings: Vec::new(),
        code: String::new(),
        comment: String::new(),
    };
    lx.run();
    let mut lines = lx.lines;
    mark_test_regions(&mut lines);
    FileModel {
        path: path.to_string(),
        lines,
        strings: lx.strings,
    }
}

struct Lexer<'a> {
    c: &'a [char],
    i: usize,
    lines: Vec<LineInfo>,
    strings: Vec<StrLit>,
    code: String,
    comment: String,
}

impl Lexer<'_> {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.c.get(self.i + ahead).copied()
    }

    fn flush_line(&mut self) {
        self.lines.push(LineInfo {
            code: std::mem::take(&mut self.code),
            comment: std::mem::take(&mut self.comment),
            in_test: false,
        });
    }

    fn run(&mut self) {
        while self.i < self.c.len() {
            let ch = self.c[self.i];
            match ch {
                '\n' => {
                    self.flush_line();
                    self.i += 1;
                }
                '/' if self.peek(1) == Some('/') => self.line_comment(),
                '/' if self.peek(1) == Some('*') => self.block_comment(),
                '"' => self.cooked_string(),
                '\'' => self.char_or_lifetime(),
                'r' | 'b' => {
                    if let Some((hashes, quote)) = self.raw_string_opener() {
                        self.raw_string(hashes, quote);
                    } else {
                        self.code.push(ch);
                        self.i += 1;
                    }
                }
                _ => {
                    self.code.push(ch);
                    self.i += 1;
                }
            }
        }
        if !self.code.is_empty() || !self.comment.is_empty() || self.lines.is_empty() {
            self.flush_line();
        }
    }

    /// `//`, `///`, `//!`: consume to end of line (the newline itself is
    /// handled by the main loop so the line flush stays in one place).
    fn line_comment(&mut self) {
        self.i += 2;
        while self.i < self.c.len() && self.c[self.i] != '\n' {
            self.comment.push(self.c[self.i]);
            self.i += 1;
        }
    }

    /// `/* ... */` with nesting; may span lines.
    fn block_comment(&mut self) {
        let mut depth = 1usize;
        self.i += 2;
        while self.i < self.c.len() && depth > 0 {
            if self.c[self.i] == '\n' {
                self.flush_line();
                self.i += 1;
            } else if self.c[self.i] == '/' && self.peek(1) == Some('*') {
                depth += 1;
                self.i += 2;
            } else if self.c[self.i] == '*' && self.peek(1) == Some('/') {
                depth -= 1;
                self.i += 2;
            } else {
                self.comment.push(self.c[self.i]);
                self.i += 1;
            }
        }
    }

    /// `"..."` with escapes; may span lines.
    fn cooked_string(&mut self) {
        let start_line = self.lines.len() + 1;
        let mut text = String::new();
        self.code.push('"');
        self.i += 1;
        while self.i < self.c.len() {
            match self.c[self.i] {
                '\\' => {
                    text.push('\\');
                    self.code.push(' ');
                    self.i += 1;
                    if self.i < self.c.len() {
                        let esc = self.c[self.i];
                        text.push(esc);
                        if esc == '\n' {
                            self.flush_line();
                        } else {
                            self.code.push(' ');
                        }
                        self.i += 1;
                    }
                }
                '"' => {
                    self.code.push('"');
                    self.i += 1;
                    break;
                }
                '\n' => {
                    text.push('\n');
                    self.flush_line();
                    self.i += 1;
                }
                other => {
                    text.push(other);
                    self.code.push(' ');
                    self.i += 1;
                }
            }
        }
        self.strings.push(StrLit {
            line: start_line,
            text,
        });
    }

    /// At an `r`/`b`, detect a raw-string opener (`r"`, `r#..#"`, `br"`)
    /// that is not the tail of a longer identifier. Returns the hash
    /// count and the index of the opening quote.
    fn raw_string_opener(&self) -> Option<(usize, usize)> {
        let mut j = self.i;
        if self.c[j] == 'b' {
            if self.peek(1) != Some('r') {
                return None;
            }
            j += 1;
        }
        if self.i > 0 && is_ident_char(self.c[self.i - 1]) {
            return None;
        }
        j += 1;
        let mut hashes = 0usize;
        while self.c.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        if self.c.get(j) == Some(&'"') {
            Some((hashes, j))
        } else {
            None
        }
    }

    /// Raw string body: no escapes, closed by `"` + the opener's hashes.
    fn raw_string(&mut self, hashes: usize, quote: usize) {
        let start_line = self.lines.len() + 1;
        while self.i <= quote {
            self.code.push(self.c[self.i]);
            self.i += 1;
        }
        let mut text = String::new();
        while self.i < self.c.len() {
            if self.c[self.i] == '"' && (1..=hashes).all(|k| self.peek(k) == Some('#')) {
                self.code.push('"');
                self.i += 1;
                for _ in 0..hashes {
                    self.code.push('#');
                    self.i += 1;
                }
                break;
            }
            if self.c[self.i] == '\n' {
                text.push('\n');
                self.flush_line();
            } else {
                text.push(self.c[self.i]);
                self.code.push(' ');
            }
            self.i += 1;
        }
        self.strings.push(StrLit {
            line: start_line,
            text,
        });
    }

    /// `'` starts either a char literal or a lifetime. `'\..'` and `'x'`
    /// are chars (contents blanked); everything else (`'a`, `'static`,
    /// `'_`) is a lifetime and only the quote reaches the code channel.
    fn char_or_lifetime(&mut self) {
        if self.peek(1) == Some('\\') {
            self.code.push('\'');
            self.i += 1;
            while self.i < self.c.len() && self.c[self.i] != '\'' {
                if self.c[self.i] == '\\' {
                    self.code.push(' ');
                    self.i += 1;
                    if self.i < self.c.len() {
                        self.code.push(' ');
                        self.i += 1;
                    }
                } else {
                    self.code.push(' ');
                    self.i += 1;
                }
            }
            if self.i < self.c.len() {
                self.code.push('\'');
                self.i += 1;
            }
        } else if self.peek(2) == Some('\'') && self.peek(1) != Some('\'') {
            self.code.push('\'');
            self.code.push(' ');
            self.code.push('\'');
            self.i += 3;
        } else {
            self.code.push('\'');
            self.i += 1;
        }
    }
}

/// Mark every line inside a `#[cfg(test)] mod` region (brace-counted on
/// the comment-stripped, literal-blanked code channel).
fn mark_test_regions(lines: &mut [LineInfo]) {
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // Find the `mod` item the attribute attaches to (tolerating
        // further attributes or blank lines in between).
        let stop = lines.len().min(i + 8);
        let Some(mstart) = (i..stop).find(|&j| has_token(&lines[j].code, "mod")) else {
            i += 1;
            continue;
        };
        let mut depth = 0i64;
        let mut opened = false;
        let mut end = lines.len();
        for (j, li) in lines.iter().enumerate().skip(mstart) {
            for ch in li.code.chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                end = j + 1;
                break;
            }
        }
        for li in &mut lines[i..end] {
            li.in_test = true;
        }
        i = end;
    }
}

/// Whitespace-free concatenation of every code channel, with the
/// 1-based source line recorded per byte — for patterns rustfmt may
/// split across lines (like a `.lock()` chain).
pub fn flat_code(fm: &FileModel) -> (String, Vec<usize>) {
    let mut text = String::new();
    let mut line_of = Vec::new();
    for (idx, li) in fm.lines.iter().enumerate() {
        for ch in li.code.chars() {
            if !ch.is_whitespace() {
                text.push(ch);
                for _ in 0..ch.len_utf8() {
                    line_of.push(idx + 1);
                }
            }
        }
    }
    (text, line_of)
}

/// True when `tok` occurs in `s` with non-identifier characters (or the
/// string boundary) on both sides.
pub fn has_token(s: &str, tok: &str) -> bool {
    find_token(s, tok).is_some()
}

/// Byte offset of the first standalone occurrence of `tok` in `s`.
pub fn find_token(s: &str, tok: &str) -> Option<usize> {
    let sb = s.as_bytes();
    let tb = tok.as_bytes();
    if tb.is_empty() || sb.len() < tb.len() {
        return None;
    }
    let mut i = 0usize;
    while i + tb.len() <= sb.len() {
        if &sb[i..i + tb.len()] == tb
            && (i == 0 || !is_ident_byte(sb[i - 1]))
            && (i + tb.len() == sb.len() || !is_ident_byte(sb[i + tb.len()]))
        {
            return Some(i);
        }
        i += 1;
    }
    None
}

/// Identifier-continuation byte (`A-Za-z0-9_`).
pub fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn is_ident_char(ch: char) -> bool {
    ch.is_ascii_alphanumeric() || ch == '_'
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_lines(src: &str) -> Vec<String> {
        lex("src/x.rs", src).lines.iter().map(|l| l.code.clone()).collect()
    }

    #[test]
    fn line_comments_stripped_but_kept() {
        let fm = lex("src/x.rs", "let a = 1; // SAFETY: fine\nlet b = 2;\n");
        assert_eq!(fm.lines[0].code, "let a = 1; ");
        assert!(fm.lines[0].comment.contains("SAFETY: fine"));
        assert_eq!(fm.lines[1].code, "let b = 2;");
    }

    #[test]
    fn nested_block_comments_span_lines() {
        let fm = lex("src/x.rs", "a /* one /* two */ still */ b\nc /* open\nclose */ d\n");
        assert_eq!(fm.lines[0].code, "a  b");
        assert!(fm.lines[0].comment.contains("two"));
        assert_eq!(fm.lines[1].code, "c ");
        assert_eq!(fm.lines[2].code, " d");
        assert!(fm.lines[1].comment.contains("open"));
    }

    #[test]
    fn string_contents_blanked_and_collected() {
        let fm = lex("src/x.rs", "m.inc(\"decode_steps\"); let x = \"unsafe // not\";\n");
        assert_eq!(fm.strings.len(), 2);
        assert_eq!(fm.strings[0].text, "decode_steps");
        assert_eq!(fm.strings[1].text, "unsafe // not");
        assert!(!fm.lines[0].code.contains("unsafe"));
        assert!(!fm.lines[0].code.contains("decode_steps"));
        assert!(fm.lines[0].code.contains("m.inc(\""));
    }

    #[test]
    fn escapes_do_not_end_strings() {
        let fm = lex("src/x.rs", "let s = \"a\\\"b\"; let t = 1;\n");
        assert_eq!(fm.strings[0].text, "a\\\"b");
        assert!(fm.lines[0].code.ends_with("let t = 1;"));
    }

    #[test]
    fn raw_strings_close_on_matching_hashes() {
        let fm = lex("src/x.rs", "let s = r#\"quote \" inside\"#; let u = 9;\n");
        assert_eq!(fm.strings[0].text, "quote \" inside");
        assert!(fm.lines[0].code.ends_with("let u = 9;"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let lines = code_lines("fn f<'a>(x: &'a str) -> char { 'y' }\n");
        assert_eq!(lines[0], "fn f<'a>(x: &'a str) -> char { ' ' }");
    }

    #[test]
    fn escaped_char_literals_blank_cleanly() {
        let lines = code_lines("let q = '\\''; let n = '\\n'; let z = 3;\n");
        assert_eq!(lines[0], "let q = '  '; let n = '  '; let z = 3;");
    }

    #[test]
    fn cfg_test_regions_marked() {
        let src = "fn a() {}\n\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\n\nfn c() {}\n";
        let fm = lex("src/x.rs", src);
        let flags: Vec<bool> = fm.lines.iter().map(|l| l.in_test).collect();
        assert!(!flags[0]);
        assert!(flags[2] && flags[3] && flags[4] && flags[5]);
        assert!(!flags[7]);
    }

    #[test]
    fn flat_code_maps_bytes_to_lines() {
        let fm = lex("src/x.rs", "a.lock()\n    .unwrap();\n");
        let (flat, line_of) = flat_code(&fm);
        let p = flat.find(".unwrap()").unwrap();
        assert_eq!(line_of[p], 2);
        assert!(flat.contains(".lock().unwrap()"));
    }

    #[test]
    fn token_boundaries_respected() {
        assert!(has_token("x.partial_cmp(y)", "partial_cmp"));
        assert!(!has_token("x.partial_cmp_else(y)", "partial_cmp"));
        assert!(!has_token("my_partial_cmp(y)", "partial_cmp"));
        assert!(has_token("eprintln!(\"x\")", "eprintln!"));
        assert!(!has_token("eprintln!(\"x\")", "println!"));
    }
}
