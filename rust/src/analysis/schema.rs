//! Rule 8 — cross-file metrics-schema completeness.
//!
//! Every counter/series name registered against `EngineMetrics` in
//! `coordinator/metrics.rs` or `coordinator/service.rs` must appear in
//! the exporter schema (`obs/export.rs`): the `KNOWN_COUNTERS` /
//! `KNOWN_SERIES` zero-fill arrays and the `documented_metrics()`
//! exposition list. And vice versa: a known name with no registration
//! site is a stale schema entry. This is the rule that stops an
//! exporter from ever silently dropping a series again (the PR 8
//! exporters had to be reconciled by hand).

use std::collections::BTreeMap;

use super::lexer::{self, FileModel};
use super::report::Finding;

/// Rule name (used by the `lint: allow(..)` pragma).
pub const NAME: &str = "metrics-schema";

/// One-line summary for docs and `lint --rules`.
pub const SUMMARY: &str = "counter/series names registered in coordinator/{metrics,service}.rs \
                           must match obs/export.rs KNOWN_COUNTERS/KNOWN_SERIES/documented_metrics";

/// Registration call markers: `.inc(`/`.add(` register counters,
/// `.observe(`/`.observe_value(` register value series.
const COUNTER_CALLS: [&str; 2] = [".inc(", ".add("];
const SERIES_CALLS: [&str; 2] = [".observe(", ".observe_value("];

/// Name -> first registration site (path, 1-based line).
type Sites = BTreeMap<String, (String, usize)>;

/// Run the cross-file check. Inert when the exporter or both
/// registration files are absent from the file set (partial fixtures).
pub fn check(files: &[FileModel]) -> Vec<Finding> {
    let Some(export) = files.iter().find(|f| f.path.ends_with("obs/export.rs")) else {
        return Vec::new();
    };
    let reg_files: Vec<&FileModel> = files
        .iter()
        .filter(|f| {
            f.path.ends_with("coordinator/metrics.rs")
                || f.path.ends_with("coordinator/service.rs")
        })
        .collect();
    if reg_files.is_empty() {
        return Vec::new();
    }

    let counters = registrations(&reg_files, &COUNTER_CALLS);
    let series = registrations(&reg_files, &SERIES_CALLS);
    let (known_counters, kc_line) = array_literal(export, "KNOWN_COUNTERS");
    let (known_series, ks_line) = array_literal(export, "KNOWN_SERIES");
    let documented = fn_literals(export, "documented_metrics");

    let mut out = Vec::new();
    for (name, (path, line)) in &counters {
        if !known_counters.contains(name) {
            out.push(site_finding(
                path,
                *line,
                format!(
                    "counter `{name}` is registered here but missing from KNOWN_COUNTERS \
                     in obs/export.rs — the exporter would not zero-fill it"
                ),
            ));
        }
        if !documented.contains(&format!("bof4_{name}_total")) {
            out.push(site_finding(
                path,
                *line,
                format!(
                    "counter `{name}` has no `bof4_{name}_total` entry in obs/export.rs \
                     documented_metrics()"
                ),
            ));
        }
    }
    for (name, (path, line)) in &series {
        if !known_series.contains(name) {
            out.push(site_finding(
                path,
                *line,
                format!(
                    "series `{name}` is registered here but missing from KNOWN_SERIES \
                     in obs/export.rs — the exporter would not zero-fill it"
                ),
            ));
        }
        let ms = format!("bof4_{name}_ms");
        let ratio = format!("bof4_{name}_ratio");
        if !documented.contains(&ms) && !documented.contains(&ratio) {
            out.push(site_finding(
                path,
                *line,
                format!(
                    "series `{name}` has neither `{ms}` nor `{ratio}` in obs/export.rs \
                     documented_metrics()"
                ),
            ));
        }
    }
    for name in &known_counters {
        if !counters.contains_key(name) {
            out.push(site_finding(
                &export.path,
                kc_line,
                format!(
                    "KNOWN_COUNTERS entry `{name}` has no registration site in \
                     coordinator/metrics.rs or coordinator/service.rs (stale schema entry)"
                ),
            ));
        }
    }
    for name in &known_series {
        if !series.contains_key(name) {
            out.push(site_finding(
                &export.path,
                ks_line,
                format!(
                    "KNOWN_SERIES entry `{name}` has no registration site in \
                     coordinator/metrics.rs or coordinator/service.rs (stale schema entry)"
                ),
            ));
        }
    }
    out
}

fn site_finding(path: &str, line: usize, message: String) -> Finding {
    Finding {
        rule: NAME,
        path: path.to_string(),
        line,
        message,
    }
}

/// Scan non-test code for registration calls and take the string
/// literal naming the metric — on the call line, or on the next line
/// when rustfmt wrapped the argument list.
fn registrations(files: &[&FileModel], calls: &[&str]) -> Sites {
    let mut out = Sites::new();
    for fm in files {
        for (idx, li) in fm.lines.iter().enumerate() {
            if li.in_test || !calls.iter().any(|c| li.code.contains(c)) {
                continue;
            }
            let mut name = first_string_on(fm, idx + 1);
            if name.is_none() && li.code.trim_end().ends_with('(') {
                name = first_string_on(fm, idx + 2);
            }
            let Some(name) = name else {
                continue;
            };
            out.entry(name).or_insert_with(|| (fm.path.clone(), idx + 1));
        }
    }
    out
}

fn first_string_on(fm: &FileModel, line: usize) -> Option<String> {
    fm.strings
        .iter()
        .find(|s| s.line == line)
        .map(|s| s.text.clone())
}

/// String entries of a `const NAME: [..] = [ ... ];` array literal,
/// plus the declaration line (for anchoring stale-entry findings).
fn array_literal(fm: &FileModel, name: &str) -> (Vec<String>, usize) {
    for (idx, li) in fm.lines.iter().enumerate() {
        if !lexer::has_token(&li.code, "const") || !lexer::has_token(&li.code, name) {
            continue;
        }
        let mut end = idx;
        while end < fm.lines.len() && !fm.lines[end].code.contains("];") {
            end += 1;
        }
        let entries = fm
            .strings
            .iter()
            .filter(|s| s.line >= idx + 1 && s.line <= end + 1)
            .map(|s| s.text.clone())
            .collect();
        return (entries, idx + 1);
    }
    (Vec::new(), 1)
}

/// Every string literal inside the body of `fn <name>`, located by
/// brace counting from the declaration line.
fn fn_literals(fm: &FileModel, name: &str) -> Vec<String> {
    for (idx, li) in fm.lines.iter().enumerate() {
        if !lexer::has_token(&li.code, "fn") || !lexer::has_token(&li.code, name) {
            continue;
        }
        let mut depth = 0i64;
        let mut opened = false;
        let mut end = fm.lines.len();
        for (j, lj) in fm.lines.iter().enumerate().skip(idx) {
            for ch in lj.code.chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                end = j + 1;
                break;
            }
        }
        return fm
            .strings
            .iter()
            .filter(|s| s.line >= idx + 1 && s.line <= end)
            .map(|s| s.text.clone())
            .collect();
    }
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn export_src(counters: &[&str], documented: &[&str]) -> String {
        let mut s = String::from("const KNOWN_COUNTERS: [&str; N] = [\n");
        for c in counters {
            s.push_str(&format!("    \"{c}\",\n"));
        }
        s.push_str("];\n\nconst KNOWN_SERIES: [&str; 0] = [];\n\n");
        s.push_str("pub fn documented_metrics() -> &'static [&'static str] {\n    &[\n");
        for d in documented {
            s.push_str(&format!("        \"{d}\",\n"));
        }
        s.push_str("    ]\n}\n");
        s
    }

    fn models(metrics_src: &str, export_src: &str) -> Vec<FileModel> {
        vec![
            lex("src/coordinator/metrics.rs", metrics_src),
            lex("src/obs/export.rs", export_src),
        ]
    }

    #[test]
    fn consistent_schema_is_clean() {
        let metrics = "fn f(m: &M) {\n    m.inc(\"batches\");\n}\n";
        let export = export_src(&["batches"], &["bof4_batches_total"]);
        assert!(check(&models(metrics, &export)).is_empty());
    }

    #[test]
    fn unknown_counter_flagged_at_registration_site() {
        let metrics = "fn f(m: &M) {\n    m.inc(\"brand_new\");\n}\n";
        let export = export_src(&[], &[]);
        let hits = check(&models(metrics, &export));
        assert_eq!(hits.len(), 2); // missing from KNOWN_COUNTERS + undocumented
        assert_eq!(hits[0].path, "src/coordinator/metrics.rs");
        assert_eq!(hits[0].line, 2);
    }

    #[test]
    fn stale_known_entry_flagged_at_export_decl() {
        let metrics = "fn f(_m: &M) {}\n";
        let export = export_src(&["ghost"], &["bof4_ghost_total"]);
        let hits = check(&models(metrics, &export));
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].path, "src/obs/export.rs");
        assert!(hits[0].message.contains("ghost"));
    }

    #[test]
    fn test_code_does_not_register_names() {
        let metrics = "fn f(m: &M) {\n    m.inc(\"batches\");\n}\n\
                       #[cfg(test)]\nmod tests {\n    fn t(m: &M) {\n        \
                       m.inc(\"test_only\");\n    }\n}\n";
        let export = export_src(&["batches"], &["bof4_batches_total"]);
        assert!(check(&models(metrics, &export)).is_empty());
    }

    #[test]
    fn inert_without_the_exporter() {
        let metrics = "fn f(m: &M) {\n    m.inc(\"whatever\");\n}\n";
        let files = vec![lex("src/coordinator/metrics.rs", metrics)];
        assert!(check(&files).is_empty());
    }
}
