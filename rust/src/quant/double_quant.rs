//! Double quantization: 8-bit affine quantization of the per-block
//! quantization constants (Dettmers et al. §QLoRA; discussed in the BOF4
//! paper's Limitations — signed constants double the input range, which is
//! why the affine min/max form is used here rather than absmax-of-absmax).
//!
//! Constants are grouped into chunks of [`CHUNK`]; each chunk stores an
//! f32 (min, scale) pair plus one u8 per constant.

/// Constants per double-quantization chunk.
pub const CHUNK: usize = 256;

/// Reconstruct one constant from its chunk parameters. Every consumer of
/// double-quantized constants — [`DoubleQuant::dequantize`], the CPU
/// backend's fused q4 serving kernels, and the serving-path dense oracle
/// — must go through this helper so the floating-point expression (and
/// therefore bit-exact equivalence between those paths) stays structural
/// rather than comment-enforced.
#[inline]
pub fn reconstruct(mn: f32, scale: f32, code: u8) -> f32 {
    mn + code as f32 * scale
}

/// 8-bit affine-quantized block constants.
#[derive(Clone, Debug, PartialEq)]
pub struct DoubleQuant {
    pub codes: Vec<u8>,
    /// Per-chunk (min, scale): value = min + code * scale.
    pub chunk_params: Vec<(f32, f32)>,
    pub len: usize,
}

impl DoubleQuant {
    /// Quantize the block constants.
    pub fn quantize(absmax: &[f32]) -> Self {
        let mut codes = Vec::with_capacity(absmax.len());
        let mut chunk_params = Vec::with_capacity(absmax.len().div_ceil(CHUNK));
        for chunk in absmax.chunks(CHUNK) {
            let mn = chunk.iter().cloned().fold(f32::INFINITY, f32::min);
            let mx = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let scale = if mx > mn { (mx - mn) / 255.0 } else { 0.0 };
            chunk_params.push((mn, scale));
            for &a in chunk {
                let code = if scale > 0.0 {
                    ((a - mn) / scale).round().clamp(0.0, 255.0) as u8
                } else {
                    0
                };
                codes.push(code);
            }
        }
        DoubleQuant {
            codes,
            chunk_params,
            len: absmax.len(),
        }
    }

    /// Reconstruct the block constants.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len);
        for (ci, chunk) in self.codes.chunks(CHUNK).enumerate() {
            let (mn, scale) = self.chunk_params[ci];
            for &c in chunk {
                out.push(reconstruct(mn, scale, c));
            }
        }
        out
    }

    /// Storage bytes: 1 per constant + 8 per chunk.
    pub fn bytes(&self) -> usize {
        self.codes.len() + 8 * self.chunk_params.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let mut rng = Pcg64::seed_from_u64(8);
        let absmax: Vec<f32> = (0..1000)
            .map(|_| 1.0 + rng.next_f32() * 3.0)
            .collect();
        let dq = DoubleQuant::quantize(&absmax);
        let rec = dq.dequantize();
        assert_eq!(rec.len(), absmax.len());
        for (ci, chunk) in absmax.chunks(CHUNK).enumerate() {
            let (_, scale) = dq.chunk_params[ci];
            for (i, (&a, &r)) in chunk.iter().zip(&rec[ci * CHUNK..]).enumerate() {
                assert!(
                    (a - r).abs() <= scale / 2.0 + 1e-6,
                    "chunk {ci} idx {i}: {a} vs {r}"
                );
            }
        }
    }

    #[test]
    fn signed_constants_supported() {
        // BOF4-S constants carry signs; affine handles the doubled range
        // (this is the Limitations-section trade-off made explicit).
        let absmax = vec![-3.0f32, -1.0, 1.0, 3.0];
        let dq = DoubleQuant::quantize(&absmax);
        let rec = dq.dequantize();
        for (a, r) in absmax.iter().zip(&rec) {
            assert!((a - r).abs() <= (6.0 / 255.0) / 2.0 + 1e-6);
        }
    }

    #[test]
    fn constant_chunk_is_exact() {
        let absmax = vec![2.5f32; 300];
        let dq = DoubleQuant::quantize(&absmax);
        assert_eq!(dq.dequantize(), absmax);
    }

    #[test]
    fn memory_accounting() {
        let absmax = vec![1.0f32; 600];
        let dq = DoubleQuant::quantize(&absmax);
        // 600 bytes + 3 chunks * 8
        assert_eq!(dq.bytes(), 600 + 24);
    }

    #[test]
    fn endpoints_representable() {
        let absmax = vec![1.0f32, 2.0, 4.0];
        let dq = DoubleQuant::quantize(&absmax);
        let rec = dq.dequantize();
        assert_eq!(rec[0], 1.0);
        assert_eq!(rec[2], 4.0);
    }
}
