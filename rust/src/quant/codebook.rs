//! 4-bit quantization codebooks: published constants (NF4, BOF4, BOF4-S)
//! and the dynamic registry that EM-designs missing (method, norm, block)
//! combinations on demand (caching them process-wide).
//!
//! AF4 note: Yoshida's AF4 is defined as the codebook minimizing the MAE of
//! *normalized* weights for Gaussian inputs at a given block size, with
//! levels −1/0/+1 constrained. The original paper ships constants only for
//! I = 64; we regenerate AF4 for every block size from its defining
//! optimization (the App.-D "normalized" EM variant), which reproduces the
//! published behaviour (strong MAE at small I, weak MSE at large I).

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use crate::stats::blockmax::Norm;

/// Number of reconstruction levels (4-bit).
pub const LEVELS: usize = 16;

/// A scalar quantization codebook: 16 sorted reconstruction levels plus the
/// 15 midpoint decision boundaries (nearest-neighbor regions).
#[derive(Clone, Debug, PartialEq)]
pub struct Codebook {
    pub name: String,
    pub levels: [f32; LEVELS],
    /// Midpoints; `bounds[15]` is +inf padding for the branchless encoder.
    pub bounds: [f32; LEVELS],
}

impl Codebook {
    pub fn new(name: impl Into<String>, levels: [f32; LEVELS]) -> Self {
        // total_cmp (not partial_cmp().unwrap()) so non-finite levels —
        // e.g. an EM design fed NaN/inf training data — fail on the
        // explicit asserts below instead of panicking inside the sort.
        assert!(
            levels.iter().all(|l| l.is_finite()),
            "codebook levels must be finite, got {levels:?}"
        );
        let mut sorted = levels;
        sorted.sort_by(f32::total_cmp);
        assert_eq!(sorted, levels, "codebook levels must be sorted");
        let mut bounds = [f32::INFINITY; LEVELS];
        for i in 0..LEVELS - 1 {
            bounds[i] = 0.5 * (levels[i] + levels[i + 1]);
        }
        Codebook {
            name: name.into(),
            levels,
            bounds,
        }
    }

    pub fn from_f64(name: impl Into<String>, levels: &[f64]) -> Self {
        assert_eq!(levels.len(), LEVELS);
        let mut arr = [0.0f32; LEVELS];
        for (a, &l) in arr.iter_mut().zip(levels) {
            *a = l as f32;
        }
        Codebook::new(name, arr)
    }

    /// Branchless 4-step binary search: returns the nearest-level code for
    /// a normalized weight (ties at a boundary resolve upward, matching
    /// the python oracle's `searchsorted(side="right")`).
    #[inline(always)]
    pub fn encode1(&self, x: f32) -> u8 {
        let b = &self.bounds;
        let mut i = 0usize;
        i += 8 * usize::from(x >= b[i + 7]);
        i += 4 * usize::from(x >= b[i + 3]);
        i += 2 * usize::from(x >= b[i + 1]);
        i += usize::from(x >= b[i]);
        i as u8
    }

    #[inline(always)]
    pub fn decode1(&self, code: u8) -> f32 {
        self.levels[(code & 0x0f) as usize]
    }

    /// Max half-gap between adjacent levels.
    pub fn max_half_gap(&self) -> f32 {
        self.levels
            .windows(2)
            .map(|w| (w[1] - w[0]) / 2.0)
            .fold(0.0, f32::max)
    }

    /// Worst-case error for a normalized weight in [-1, 1]: the larger of
    /// the interior half-gaps and the clamp distances at the endpoints
    /// (BOF4-S has levels[0] > -1, so deep-negative weights clamp).
    pub fn max_norm_error(&self) -> f32 {
        self.max_half_gap()
            .max((self.levels[0] - (-1.0)).abs())
            .max((1.0 - self.levels[15]).abs())
    }
}

// ---------------------------------------------------------------------
// Published constants
// ---------------------------------------------------------------------

/// NF4 (Dettmers et al., QLoRA) — the bitsandbytes constants.
pub const NF4_LEVELS: [f32; 16] = [
    -1.0,
    -0.696_192_8,
    -0.525_073_05,
    -0.394_917_5,
    -0.284_441_38,
    -0.184_773_43,
    -0.091_050_036,
    0.0,
    0.079_580_3,
    0.160_930_2,
    0.246_112_3,
    0.337_915_24,
    0.440_709_83,
    0.562_617,
    0.722_956_84,
    1.0,
];

/// BOF4 (MSE), I = 64 — paper Table 6.
pub const BOF4_MSE_64: [f32; 16] = [
    -1.0,
    -0.753_524_54,
    -0.579_203_7,
    -0.438_599_88,
    -0.316_768,
    -0.205_992_45,
    -0.101_538_76,
    0.0,
    0.088_724_53,
    0.179_376_96,
    0.274_149_98,
    0.375_821_14,
    0.488_493_77,
    0.618_705_87,
    0.779_045_22,
    1.0,
];

/// BOF4 (MAE), I = 64 — paper Table 6.
pub const BOF4_MAE_64: [f32; 16] = [
    -1.0,
    -0.702_630_6,
    -0.527_270_4,
    -0.394_673_82,
    -0.283_214_48,
    -0.183_531_36,
    -0.090_308_666,
    0.0,
    0.078_960,
    0.159_879_25,
    0.244_986_36,
    0.337_221_89,
    0.441_359_28,
    0.565_777_06,
    0.729_917_82,
    1.0,
];

/// BOF4-S (MSE), I = 64 — paper Table 6.
pub const BOF4_S_MSE_64: [f32; 16] = [
    -0.856_846_4,
    -0.669_287_44,
    -0.523_526_6,
    -0.400_488_26,
    -0.291_063_82,
    -0.190_009_3,
    -0.093_852_96,
    0.0,
    0.088_767_17,
    0.179_480_27,
    0.274_309_6,
    0.376_019_75,
    0.488_653,
    0.618_860_36,
    0.779_139_6,
    1.0,
];

/// BOF4-S (MAE), I = 64 — paper Table 6.
pub const BOF4_S_MAE_64: [f32; 16] = [
    -0.801_879_8,
    -0.607_605_16,
    -0.468_828_02,
    -0.355_960_28,
    -0.257_616_94,
    -0.167_748_14,
    -0.082_736_626,
    0.0,
    0.078_943_48,
    0.159_796_68,
    0.244_849_55,
    0.337_148,
    0.441_257_39,
    0.565_681_93,
    0.729_806_84,
    1.0,
];

/// BOF4-S (MSE) for other block sizes — paper Table 7 (I = 32, 128, 256).
pub fn bof4_s_mse_published(block: usize) -> Option<[f32; 16]> {
    let v: [f64; 16] = match block {
        32 => [
            -0.8732797503471375,
            -0.6907446384429932,
            -0.5437039136886597,
            -0.4173701703548431,
            -0.3038933575153351,
            -0.1986017823219299,
            -0.0981557220220566,
            0.0,
            0.0925938412547112,
            0.187048003077507,
            0.2855197489261627,
            0.3907126188278198,
            0.506283164024353,
            0.6379748582839966,
            0.7956376671791077,
            1.0,
        ],
        64 => return Some(BOF4_S_MSE_64),
        128 => [
            -0.83739173412323,
            -0.6462452411651611,
            -0.5028634667396545,
            -0.3836247622966766,
            -0.2783779501914978,
            -0.1815713942050934,
            -0.0896477326750755,
            0.0,
            0.0850915610790253,
            0.1720834821462631,
            0.2632072865962982,
            0.3613293170928955,
            0.4707452654838562,
            0.5988966822624207,
            0.761027991771698,
            1.0,
        ],
        256 => [
            -0.8146829009056091,
            -0.6221838593482971,
            -0.4820549190044403,
            -0.3669650852680206,
            -0.2659871876239777,
            -0.1733742356300354,
            -0.0855776593089104,
            0.0,
            0.0815095230937004,
            0.1649149656295776,
            0.2524392008781433,
            0.3470274209976196,
            0.4531534314155579,
            0.578848659992218,
            0.7418596744537354,
            1.0,
        ],
        _ => return None,
    };
    let mut out = [0.0f32; 16];
    for (o, &x) in out.iter_mut().zip(&v) {
        *o = x as f32;
    }
    Some(out)
}

// ---------------------------------------------------------------------
// Method selection + dynamic registry
// ---------------------------------------------------------------------

/// Quantizer family.
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// NF4 (fixed levels, block-size independent by construction).
    Nf4,
    /// AF4 (normalized-MAE-optimal; regenerated per block size).
    Af4,
    /// BOF4 family, end-to-end optimal via the paper's EM (this work).
    /// `mse = false` selects MAE optimization.
    Bof4 { mse: bool },
    /// A caller-provided codebook.
    Custom(Codebook),
}

impl Method {
    pub fn label(&self, norm: Norm) -> String {
        match self {
            Method::Nf4 => "NF4".into(),
            Method::Af4 => "AF4".into(),
            Method::Bof4 { mse } => format!(
                "BOF4{} ({})",
                if norm == Norm::SignedAbsmax { "-S" } else { "" },
                if *mse { "MSE" } else { "MAE" }
            ),
            Method::Custom(cb) => cb.name.clone(),
        }
    }
}

type Key = (String, bool, usize); // (family tag, signed, block)

static REGISTRY: OnceLock<Mutex<HashMap<Key, Codebook>>> = OnceLock::new();

fn registry() -> &'static Mutex<HashMap<Key, Codebook>> {
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Resolve the codebook for (method, norm, block). Published constants are
/// used where the paper provides them; everything else is EM-designed on
/// first use (empirical backend, fixed seed) and cached.
pub fn codebook_for(method: &Method, norm: Norm, block: usize) -> Codebook {
    match method {
        Method::Custom(cb) => return cb.clone(),
        Method::Nf4 => return Codebook::new("NF4", NF4_LEVELS),
        _ => {}
    }
    let signed = norm == Norm::SignedAbsmax;
    // Published BOF4 constants
    if let Method::Bof4 { mse } = method {
        if block == 64 {
            let (name, lv) = match (signed, mse) {
                (false, true) => ("BOF4 (MSE) I=64", BOF4_MSE_64),
                (false, false) => ("BOF4 (MAE) I=64", BOF4_MAE_64),
                (true, true) => ("BOF4-S (MSE) I=64", BOF4_S_MSE_64),
                (true, false) => ("BOF4-S (MAE) I=64", BOF4_S_MAE_64),
            };
            return Codebook::new(name, lv);
        }
        if signed && *mse {
            if let Some(lv) = bof4_s_mse_published(block) {
                return Codebook::new(format!("BOF4-S (MSE) I={block}"), lv);
            }
        }
    }
    let tag = match method {
        Method::Af4 => "af4".to_string(),
        Method::Bof4 { mse } => format!("bof4-{}", if *mse { "mse" } else { "mae" }),
        _ => unreachable!(),
    };
    let key = (tag.clone(), signed, block);
    if let Some(cb) = crate::util::sync::lock_recover(registry()).get(&key) {
        return cb.clone();
    }
    // Design it. (lloyd depends on quant::Codebook; intra-crate cycles are
    // fine in rust.)
    let cb = match method {
        Method::Af4 => crate::lloyd::design_af4(block),
        Method::Bof4 { mse } => crate::lloyd::design_bof4_empirical_default(*mse, norm, block),
        _ => unreachable!(),
    };
    crate::util::sync::lock_recover(registry()).insert(key, cb.clone());
    cb
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_published_books_valid() {
        for (name, lv) in [
            ("nf4", NF4_LEVELS),
            ("bof4-mse", BOF4_MSE_64),
            ("bof4-mae", BOF4_MAE_64),
            ("bof4s-mse", BOF4_S_MSE_64),
            ("bof4s-mae", BOF4_S_MAE_64),
        ] {
            let cb = Codebook::new(name, lv);
            assert_eq!(cb.levels[15], 1.0);
            assert!(cb.levels.contains(&0.0), "{name} has 0");
            // BOF4-S (MAE) clamps hardest: levels[0] ≈ -0.80 -> 0.198
            assert!(cb.max_norm_error() < 0.2, "{name}");
        }
        for b in [32, 128, 256] {
            let lv = bof4_s_mse_published(b).unwrap();
            Codebook::new("t", lv);
        }
        assert!(bof4_s_mse_published(512).is_none());
    }

    #[test]
    fn encode1_matches_linear_scan() {
        let cb = Codebook::new("nf4", NF4_LEVELS);
        let mut x = -1.2f32;
        while x <= 1.2 {
            let brute = cb
                .levels
                .iter()
                .enumerate()
                .min_by(|a, b| {
                    let da = (a.1 - x).abs();
                    let db = (b.1 - x).abs();
                    da.total_cmp(&db)
                })
                .unwrap()
                .0 as u8;
            let fast = cb.encode1(x);
            // Ties at exact midpoints may differ; exclude them.
            let on_boundary = cb.bounds.iter().any(|&b| b == x);
            if !on_boundary {
                assert_eq!(fast, brute, "x={x}");
            }
            x += 0.001;
        }
    }

    #[test]
    fn encode1_boundary_ties_go_up() {
        let cb = Codebook::new("nf4", NF4_LEVELS);
        for i in 0..15 {
            assert_eq!(cb.encode1(cb.bounds[i]), (i + 1) as u8);
        }
    }

    #[test]
    fn encode_decode_endpoints() {
        let cb = Codebook::new("bof4s", BOF4_S_MSE_64);
        assert_eq!(cb.encode1(1.0), 15);
        assert_eq!(cb.encode1(5.0), 15); // saturates
        assert_eq!(cb.encode1(-5.0), 0);
        assert_eq!(cb.decode1(15), 1.0);
        assert_eq!(cb.decode1(0x7), 0.0);
        // decode masks the high nibble
        assert_eq!(cb.decode1(0xf7), 0.0);
    }

    #[test]
    fn registry_resolves_published() {
        let cb = codebook_for(&Method::Bof4 { mse: true }, Norm::SignedAbsmax, 128);
        assert_eq!(cb.levels, bof4_s_mse_published(128).unwrap());
        let cb = codebook_for(&Method::Nf4, Norm::Absmax, 999);
        assert_eq!(cb.levels, NF4_LEVELS);
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn rejects_unsorted() {
        let mut lv = NF4_LEVELS;
        lv.swap(3, 4);
        Codebook::new("bad", lv);
    }

    /// Non-finite levels (an EM design fed poisoned training data) must
    /// fail on the explicit finiteness assert, not a sort-comparator
    /// unwrap.
    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_non_finite() {
        let mut lv = NF4_LEVELS;
        lv[5] = f32::NAN;
        Codebook::new("bad", lv);
    }
}
