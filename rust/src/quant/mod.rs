//! Block-wise absmax quantization core (the paper's algorithmic system).
//!
//! - [`codebook`]: NF4/AF4/BOF4/BOF4-S codebooks + dynamic EM registry
//! - [`absmax`]: absolute & signed block normalization (eqs. 1–4)
//! - [`pack`]: 4-bit nibble packing
//! - [`opq`]: outlier-preserving quantization (§3.3)
//! - [`double_quant`]: 8-bit quantization of the block constants
//! - [`kv`]: block-wise quantization of KV-cache activation rows (the
//!   serving engine's `BOF4_KV=f32|q8|q4` formats)
//! - [`error`]: MAE/MSE/SQNR metrics
//!
//! The high-level entry point is [`Quantizer`]:
//!
//! ```no_run
//! use bof4::quant::{Quantizer, QuantConfig, Method, Norm};
//! let q = Quantizer::new(QuantConfig {
//!     method: Method::Bof4 { mse: true },
//!     norm: Norm::SignedAbsmax,
//!     block: 64,
//!     ..Default::default()
//! });
//! let w = vec![0.1f32, -0.5, 0.25, 1.5, -0.02, 0.33, 0.7, -1.1];
//! let qt = q.quantize(&w);
//! let w_hat = q.dequantize(&qt);
//! assert_eq!(w_hat.len(), w.len());
//! ```

pub mod absmax;
pub mod codebook;
pub mod double_quant;
pub mod error;
pub mod kv;
pub mod opq;
pub mod pack;

pub use absmax::Norm;
pub use codebook::{codebook_for, Codebook, Method};
pub use double_quant::DoubleQuant;
pub use kv::KvFormat;
pub use opq::{OpqConfig, Outlier};

/// Full quantizer configuration.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    pub method: Method,
    pub norm: Norm,
    /// Block size I.
    pub block: usize,
    /// Outlier-preserving quantization (None = off).
    pub opq: Option<OpqConfig>,
    /// 8-bit double quantization of the block constants.
    pub double_quant: bool,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            method: Method::Bof4 { mse: true },
            norm: Norm::SignedAbsmax,
            block: 64,
            opq: None,
            double_quant: false,
        }
    }
}

impl QuantConfig {
    pub fn label(&self) -> String {
        let mut s = self.method.label(self.norm);
        if self.opq.is_some() {
            s.push_str(" +OPQ");
        }
        if self.double_quant {
            s.push_str(" +DQ");
        }
        s
    }
}

/// A quantized flat tensor (storage form).
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    /// Packed 4-bit codes (2 per byte), padded to a block multiple.
    pub codes: Vec<u8>,
    /// Per-block constants (f32 storage form), present unless
    /// double-quantized.
    pub absmax: Vec<f32>,
    /// Double-quantized constants (replaces `absmax` storage accounting).
    pub dq: Option<DoubleQuant>,
    /// OPQ outliers (empty when OPQ is off).
    pub outliers: Vec<Outlier>,
    /// Original element count (before block padding).
    pub len: usize,
    pub block: usize,
}

impl QuantizedTensor {
    /// Number of blocks.
    pub fn n_blocks(&self) -> usize {
        self.len.div_ceil(self.block)
    }

    /// Total storage bytes (the paper's memory-footprint accounting:
    /// packed codes + constants (+DQ) + OPQ side table).
    pub fn bytes(&self) -> usize {
        let code_bytes = self.codes.len();
        let const_bytes = match &self.dq {
            Some(dq) => dq.bytes(),
            None => 4 * self.absmax.len(),
        };
        code_bytes + const_bytes + opq::opq_bytes(self.outliers.len())
    }

    /// Effective bits per weight.
    pub fn bits_per_weight(&self) -> f64 {
        8.0 * self.bytes() as f64 / self.len as f64
    }
}

/// The block-wise absmax quantizer (paper eq. 3 with the chosen codebook).
#[derive(Clone, Debug)]
pub struct Quantizer {
    pub config: QuantConfig,
    pub codebook: Codebook,
}

impl Quantizer {
    pub fn new(config: QuantConfig) -> Self {
        let codebook = codebook_for(&config.method, config.norm, config.block);
        Quantizer { config, codebook }
    }

    /// Build with an explicit codebook (skips the registry).
    pub fn with_codebook(config: QuantConfig, codebook: Codebook) -> Self {
        Quantizer { config, codebook }
    }

    /// Quantize a flat tensor.
    pub fn quantize(&self, w: &[f32]) -> QuantizedTensor {
        let block = self.config.block;
        let mut work = w.to_vec();

        // OPQ: pull outliers out before the block-max search (paper §3.3).
        let outliers = match self.config.opq {
            Some(cfg) => opq::extract_outliers(&mut work, block, cfg),
            None => Vec::new(),
        };

        // pad to a block multiple with zeros
        let padded = work.len().div_ceil(block) * block;
        work.resize(padded, 0.0);

        let n_blocks = padded / block;
        let mut absmax = Vec::with_capacity(n_blocks);
        let mut codes = Vec::with_capacity(padded);
        for chunk in work.chunks_exact(block) {
            let c = absmax::block_constant(chunk, self.config.norm);
            absmax.push(c);
            let inv = 1.0 / absmax::safe_constant(c);
            for &v in chunk {
                codes.push(self.codebook.encode1(v * inv));
            }
        }
        let packed = pack::pack_u4(&codes);
        let dq = if self.config.double_quant {
            Some(DoubleQuant::quantize(&absmax))
        } else {
            None
        };
        QuantizedTensor {
            codes: packed,
            absmax,
            dq,
            outliers,
            len: w.len(),
            block,
        }
    }

    /// Dequantize back to f32 (the L3 decode hot path).
    pub fn dequantize(&self, qt: &QuantizedTensor) -> Vec<f32> {
        let block = qt.block;
        let absmax: Vec<f32> = match &qt.dq {
            Some(dq) => dq.dequantize(),
            None => qt.absmax.clone(),
        };
        let mut out = vec![0.0f32; qt.len];
        // Per-block LUT: levels * absmax computed once per block, then a
        // single table lookup per weight.
        let mut lut = [0.0f32; 16];
        for (b, m) in absmax.iter().enumerate() {
            let msafe = absmax::safe_constant(*m);
            for (l, v) in lut.iter_mut().enumerate() {
                *v = self.codebook.levels[l] * msafe;
            }
            let start = b * block;
            if start >= qt.len {
                break;
            }
            let end = (start + block).min(qt.len);
            let out_blk = &mut out[start..end];
            for (i, v) in out_blk.iter_mut().enumerate() {
                *v = lut[pack::get_u4(&qt.codes, start + i) as usize];
            }
        }
        opq::restore_outliers(&mut out, &qt.outliers);
        out
    }

    /// Quantize + dequantize (error-evaluation convenience).
    pub fn roundtrip(&self, w: &[f32]) -> Vec<f32> {
        self.dequantize(&self.quantize(w))
    }
}

/// Quantize, dequantize, and report (MAE, MSE) in one call.
pub fn quant_error(q: &Quantizer, w: &[f32]) -> (f64, f64) {
    let w_hat = q.roundtrip(w);
    (error::mae(w, &w_hat), error::mse(w, &w_hat))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, GaussianVec, Prop};
    use crate::util::rng::Pcg64;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed_from_u64(seed);
        (0..n).map(|_| rng.next_gaussian() as f32).collect()
    }

    fn q(method: Method, norm: Norm, block: usize) -> Quantizer {
        Quantizer::new(QuantConfig {
            method,
            norm,
            block,
            ..Default::default()
        })
    }

    #[test]
    fn roundtrip_len_and_bound() {
        let w = gaussian(64 * 100 + 13, 1); // non-multiple length
        let qz = q(Method::Nf4, Norm::Absmax, 64);
        let qt = qz.quantize(&w);
        assert_eq!(qt.len, w.len());
        assert_eq!(qt.n_blocks(), 101);
        let w_hat = qz.dequantize(&qt);
        assert_eq!(w_hat.len(), w.len());
        // error bound: |w - ŵ| <= |m_b| * max_norm_error
        let gap = qz.codebook.max_norm_error();
        for (b, chunk) in w.chunks(64).enumerate() {
            let m = qt.absmax[b].abs();
            for (i, &x) in chunk.iter().enumerate() {
                let err = (x - w_hat[b * 64 + i]).abs();
                assert!(err <= m * gap + 1e-5, "b={b} i={i} err={err}");
            }
        }
    }

    #[test]
    fn absmax_weight_exact_under_both_norms() {
        // The largest-magnitude weight must be exactly representable
        // (level ±1 · constant).
        let mut w = gaussian(64, 2);
        w[10] = -3.5; // max magnitude, negative
        for norm in [Norm::Absmax, Norm::SignedAbsmax] {
            let qz = q(Method::Bof4 { mse: true }, norm, 64);
            let w_hat = qz.roundtrip(&w);
            assert_eq!(w_hat[10], -3.5, "{norm:?}");
        }
    }

    #[test]
    fn zeros_exact() {
        let mut w = gaussian(128, 3);
        w[5] = 0.0;
        w[77] = 0.0;
        let qz = q(Method::Bof4 { mse: true }, Norm::SignedAbsmax, 64);
        let w_hat = qz.roundtrip(&w);
        assert_eq!(w_hat[5], 0.0);
        assert_eq!(w_hat[77], 0.0);
    }

    #[test]
    fn all_zero_tensor() {
        let w = vec![0.0f32; 200];
        let qz = q(Method::Nf4, Norm::Absmax, 64);
        let w_hat = qz.roundtrip(&w);
        assert_eq!(w_hat, w);
    }

    #[test]
    fn signed_beats_absolute_on_gaussian() {
        // The paper's headline: BOF4-S < BOF4 in MSE for Gaussian weights.
        let w = gaussian(64 * 4096, 4);
        let (_, mse_abs) = quant_error(&q(Method::Bof4 { mse: true }, Norm::Absmax, 64), &w);
        let (_, mse_sgn) = quant_error(
            &q(Method::Bof4 { mse: true }, Norm::SignedAbsmax, 64),
            &w,
        );
        assert!(
            mse_sgn < mse_abs,
            "signed {mse_sgn} should beat absolute {mse_abs}"
        );
    }

    #[test]
    fn bof4_beats_nf4_on_gaussian_mse() {
        let w = gaussian(64 * 4096, 5);
        let (_, mse_nf4) = quant_error(&q(Method::Nf4, Norm::Absmax, 64), &w);
        let (_, mse_bof4) = quant_error(&q(Method::Bof4 { mse: true }, Norm::Absmax, 64), &w);
        assert!(
            mse_bof4 < mse_nf4,
            "BOF4 {mse_bof4} should beat NF4 {mse_nf4}"
        );
    }

    #[test]
    fn opq_reduces_error_with_outliers() {
        let mut w = gaussian(64 * 512, 6);
        // plant super-Gaussian outliers
        let mut rng = Pcg64::seed_from_u64(60);
        for _ in 0..80 {
            let i = rng.next_below(w.len() as u64) as usize;
            w[i] = (rng.next_gaussian() as f32) * 20.0;
        }
        let base = QuantConfig {
            method: Method::Bof4 { mse: true },
            norm: Norm::SignedAbsmax,
            block: 64,
            ..Default::default()
        };
        let no_opq = Quantizer::new(base.clone());
        let with_opq = Quantizer::new(QuantConfig {
            opq: Some(OpqConfig::default()),
            ..base
        });
        let (_, mse0) = quant_error(&no_opq, &w);
        let (_, mse1) = quant_error(&with_opq, &w);
        assert!(mse1 < mse0, "OPQ {mse1} should beat {mse0}");
    }

    #[test]
    fn opq_restores_outliers_to_bf16() {
        let mut w = gaussian(256, 7);
        w[100] = 42.0;
        let qz = Quantizer::new(QuantConfig {
            opq: Some(OpqConfig::default()),
            ..Default::default()
        });
        let w_hat = qz.roundtrip(&w);
        assert_eq!(w_hat[100], 42.0); // 42 is bf16-exact
    }

    #[test]
    fn double_quant_shrinks_memory() {
        let w = gaussian(64 * 2048, 8);
        let base = QuantConfig::default();
        let qt0 = Quantizer::new(base.clone()).quantize(&w);
        let qt1 = Quantizer::new(QuantConfig {
            double_quant: true,
            ..base
        })
        .quantize(&w);
        assert!(qt1.bytes() < qt0.bytes());
        // and the error penalty is small
        let q0 = Quantizer::new(QuantConfig::default());
        let q1 = Quantizer::new(QuantConfig {
            double_quant: true,
            ..QuantConfig::default()
        });
        let (_, e0) = quant_error(&q0, &w);
        let (_, e1) = quant_error(&q1, &w);
        assert!(e1 < e0 * 1.35, "DQ error {e1} vs {e0}");
    }

    #[test]
    fn bits_per_weight_near_4() {
        let w = gaussian(64 * 1024, 9);
        let qt = Quantizer::new(QuantConfig::default()).quantize(&w);
        let bpw = qt.bits_per_weight();
        // 4 bits + 32/64 for the constant = 4.5
        assert!((bpw - 4.5).abs() < 0.01, "{bpw}");
        let qt = Quantizer::new(QuantConfig {
            double_quant: true,
            ..Default::default()
        })
        .quantize(&w);
        // 4 + 8/64 + chunk overhead ≈ 4.13
        assert!(qt.bits_per_weight() < 4.2);
    }

    #[test]
    fn property_roundtrip_error_bounded() {
        let gen = GaussianVec {
            max_len: 300,
            max_scale: 8.0,
        };
        let qz = q(Method::Bof4 { mse: true }, Norm::SignedAbsmax, 64);
        forall("quant-bounded", 21, 60, &gen, |w| {
            let qt = qz.quantize(w);
            let w_hat = qz.dequantize(&qt);
            let gap = qz.codebook.max_norm_error();
            for (i, (&a, &b)) in w.iter().zip(&w_hat).enumerate() {
                let m = qt.absmax[i / 64].abs();
                if (a - b).abs() > m * gap + 1e-5 {
                    return Prop::Fail(format!("i={i} a={a} b={b} m={m}"));
                }
            }
            Prop::Pass
        });
    }

    #[test]
    fn property_idempotent() {
        // Quantizing an already-dequantized tensor is exact (fixed point).
        let gen = GaussianVec {
            max_len: 256,
            max_scale: 2.0,
        };
        let qz = q(Method::Nf4, Norm::Absmax, 64);
        forall("quant-idempotent", 22, 40, &gen, |w| {
            let once = qz.roundtrip(w);
            let twice = qz.roundtrip(&once);
            Prop::check(
                once.iter().zip(&twice).all(|(a, b)| (a - b).abs() < 1e-6),
                || "not idempotent".into(),
            )
        });
    }

    #[test]
    fn config_labels() {
        let c = QuantConfig {
            method: Method::Bof4 { mse: true },
            norm: Norm::SignedAbsmax,
            opq: Some(OpqConfig::default()),
            double_quant: true,
            block: 64,
        };
        assert_eq!(c.label(), "BOF4-S (MSE) +OPQ +DQ");
        let c = QuantConfig {
            method: Method::Nf4,
            norm: Norm::Absmax,
            ..Default::default()
        };
        assert_eq!(c.label(), "NF4");
    }
}
