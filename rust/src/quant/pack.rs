//! 4-bit nibble packing: two codes per byte, low nibble first.
//!
//! Storage layout note (DESIGN.md): codes are packed for *storage*; the
//! serving path unpacks per weight-matrix on load because the XLA graph
//! (and a real TPU kernel's VPU gather) consumes one code per int8 lane.

/// Pack codes (each < 16) into bytes, low nibble = even index.
pub fn pack_u4(codes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    let mut it = codes.chunks_exact(2);
    for pair in &mut it {
        debug_assert!(pair[0] < 16 && pair[1] < 16);
        out.push(pair[0] | (pair[1] << 4));
    }
    if let [last] = it.remainder() {
        out.push(*last & 0x0f);
    }
    out
}

/// Unpack `n` codes from packed bytes.
pub fn unpack_u4(packed: &[u8], n: usize) -> Vec<u8> {
    assert!(packed.len() * 2 >= n, "packed buffer too short");
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let b = packed[i / 2];
        out.push(if i % 2 == 0 { b & 0x0f } else { b >> 4 });
    }
    out
}

/// Iterate codes without materializing (hot decode path).
#[inline(always)]
pub fn get_u4(packed: &[u8], i: usize) -> u8 {
    let b = packed[i / 2];
    if i % 2 == 0 {
        b & 0x0f
    } else {
        b >> 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, GaussianVec, Prop};

    #[test]
    fn roundtrip_even_odd() {
        for n in [0usize, 1, 2, 7, 8, 63, 64, 65] {
            let codes: Vec<u8> = (0..n).map(|i| (i % 16) as u8).collect();
            let packed = pack_u4(&codes);
            assert_eq!(packed.len(), n.div_ceil(2));
            assert_eq!(unpack_u4(&packed, n), codes, "n={n}");
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(get_u4(&packed, i), c);
            }
        }
    }

    #[test]
    fn nibble_order_low_first() {
        let packed = pack_u4(&[0x3, 0xa]);
        assert_eq!(packed, vec![0xa3]);
    }

    #[test]
    fn property_roundtrip_random() {
        let gen = GaussianVec {
            max_len: 257,
            max_scale: 1.0,
        };
        forall("pack-roundtrip", 17, 100, &gen, |v| {
            let codes: Vec<u8> = v
                .iter()
                .map(|x| ((x.abs() * 37.0) as usize % 16) as u8)
                .collect();
            let rt = unpack_u4(&pack_u4(&codes), codes.len());
            Prop::check(rt == codes, || format!("mismatch len {}", codes.len()))
        });
    }
}
