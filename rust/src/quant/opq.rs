//! Outlier-preserving quantization — OPQ (paper §3.3, App. E).
//!
//! A weight `w_{b,i}` is an outlier iff `|w_{b,i}| > σ_b · F_M^{-1}(q)`
//! (eq. 9), where `σ_b` is the corrected sample std of its block (eq. 73)
//! and `F_M^{-1}` the quantile of the absolute-block-max distribution for
//! unit-std Gaussian blocks. Outliers are stored losslessly-ish in bf16
//! with a 64-bit flat index, replaced by 0 before the block-max search, and
//! patched back after dequantization.

use crate::stats::blockmax::BlockMax;
use crate::tensor::Bf16;

/// OPQ hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpqConfig {
    /// Quantile of the absolute block-max distribution (paper: q = 0.95).
    pub q: f64,
}

impl Default for OpqConfig {
    fn default() -> Self {
        OpqConfig { q: 0.95 }
    }
}

/// A preserved outlier: flat index into the tensor + bf16 value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Outlier {
    pub index: u64,
    pub value: Bf16,
}

/// Corrected sample standard deviation (paper eq. 73).
pub fn block_std(block: &[f32]) -> f64 {
    let n = block.len();
    if n < 2 {
        return 0.0;
    }
    let mean = block.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
    let var = block
        .iter()
        .map(|&x| {
            let d = x as f64 - mean;
            d * d
        })
        .sum::<f64>()
        / (n - 1) as f64;
    var.sqrt()
}

/// Detect outliers in a flat tensor of blocked weights and *zero them in
/// place* (so the subsequent block-max search ignores them). Returns the
/// preserved outliers. `block` is the quantization block size I.
pub fn extract_outliers(w: &mut [f32], block: usize, cfg: OpqConfig) -> Vec<Outlier> {
    let bm = BlockMax::new(block);
    let full_threshold_sigma = bm.quantile(cfg.q);
    let mut out = Vec::new();
    for (b, chunk) in w.chunks_mut(block).enumerate() {
        // The padding tail (shorter than I) computes σ from its own
        // elements, so the absolute-block-max quantile must be taken at
        // the tail's length too (F_M^{-1} for I = chunk.len()); chunks
        // too short for a sample std (len < 2) carry no outlier signal
        // and are skipped. Tails exist only for non-multiple tensors.
        if chunk.len() < 2 {
            continue;
        }
        let threshold_sigma = if chunk.len() == block {
            full_threshold_sigma
        } else {
            BlockMax::new(chunk.len()).quantile(cfg.q)
        };
        let sigma = block_std(chunk);
        if sigma <= 0.0 || !sigma.is_finite() {
            continue;
        }
        let thr = (sigma * threshold_sigma) as f32;
        for (i, v) in chunk.iter_mut().enumerate() {
            if v.abs() > thr {
                out.push(Outlier {
                    index: (b * block + i) as u64,
                    value: Bf16::from_f32(*v),
                });
                *v = 0.0;
            }
        }
    }
    out
}

/// Patch preserved outliers back into a dequantized tensor.
pub fn restore_outliers(w: &mut [f32], outliers: &[Outlier]) {
    for o in outliers {
        w[o.index as usize] = o.value.to_f32();
    }
}

/// Memory cost of OPQ in bytes: bf16 value + u64 index per outlier
/// (paper App. E: "stores outlier weights separately in bfloat16 and
/// additionally uses a 64-bit integer ... to address the outlier").
pub fn opq_bytes(n_outliers: usize) -> usize {
    n_outliers * (2 + 8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed_from_u64(seed);
        (0..n).map(|_| rng.next_gaussian() as f32).collect()
    }

    #[test]
    fn block_std_matches_definition() {
        let b = [1.0f32, 2.0, 3.0, 4.0];
        // mean 2.5, var = (2.25+0.25+0.25+2.25)/3 = 5/3
        assert!((block_std(&b) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(block_std(&[1.0]), 0.0);
    }

    #[test]
    fn planted_outliers_found_and_zeroed() {
        let mut w = gaussian(64 * 16, 1);
        w[17] = 25.0;
        w[64 * 5 + 3] = -30.0;
        let outliers = extract_outliers(&mut w, 64, OpqConfig::default());
        let idx: Vec<u64> = outliers.iter().map(|o| o.index).collect();
        assert!(idx.contains(&17));
        assert!(idx.contains(&(64 * 5 + 3)));
        assert_eq!(w[17], 0.0);
        assert_eq!(w[64 * 5 + 3], 0.0);
        // bf16 round-trips the magnitudes closely
        let v17 = outliers.iter().find(|o| o.index == 17).unwrap().value;
        assert!((v17.to_f32() - 25.0).abs() < 0.125);
    }

    #[test]
    fn gaussian_data_rarely_flagged() {
        // With q = 0.95, pure Gaussian blocks should flag roughly
        // P[|w| > σ F_M^{-1}(.95)] ≈ tiny per weight; over 32k weights
        // expect well under 1%.
        let mut w = gaussian(64 * 512, 2);
        let outliers = extract_outliers(&mut w, 64, OpqConfig::default());
        let frac = outliers.len() as f64 / w.len() as f64;
        assert!(frac < 0.01, "flagged {frac}");
    }

    #[test]
    fn lower_q_flags_more() {
        let w0 = gaussian(64 * 256, 3);
        let mut w1 = w0.clone();
        let mut w2 = w0.clone();
        let o_90 = extract_outliers(&mut w1, 64, OpqConfig { q: 0.90 });
        let o_99 = extract_outliers(&mut w2, 64, OpqConfig { q: 0.99 });
        assert!(o_90.len() >= o_99.len());
    }

    /// Regression: the padding tail must be thresholded with the
    /// quantile of its *own* length, not the full block's. The planted
    /// value sits between σ·F_M^{-1}(q) at I = 16 (the tail length) and
    /// at I = 64 (the block size), so only the corrected code flags it.
    #[test]
    fn tail_block_uses_own_length_quantile() {
        let mut tail = vec![0.0f32; 16];
        tail[0] = 3.05;
        for i in 1..16 {
            tail[i] = if i % 2 == 0 { 0.6 } else { -0.6 };
        }
        let sigma = block_std(&tail);
        let thr_tail = sigma * BlockMax::new(16).quantile(0.95);
        let thr_full = sigma * BlockMax::new(64).quantile(0.95);
        assert!(
            thr_tail < 3.05 && 3.05 < thr_full,
            "construction broken: want {thr_tail} < 3.05 < {thr_full}"
        );
        let mut w: Vec<f32> = gaussian(64, 9).iter().map(|x| x * 0.5).collect();
        w.extend_from_slice(&tail);
        let outliers = extract_outliers(&mut w, 64, OpqConfig::default());
        assert!(
            outliers.iter().any(|o| o.index == 64),
            "tail outlier must be flagged under the tail-length quantile"
        );
        assert_eq!(w[64], 0.0);
    }

    /// A 1-element tail has no sample std: it must be skipped, not
    /// flagged (and BlockMax::new(1) must never be constructed).
    #[test]
    fn one_element_tail_skipped() {
        let mut w = vec![0.1f32; 65];
        w[64] = 100.0;
        let outliers = extract_outliers(&mut w, 64, OpqConfig::default());
        assert!(outliers.iter().all(|o| o.index != 64));
        assert_eq!(w[64], 100.0, "skipped tail must stay untouched");
    }

    /// Non-finite blocks (NaN/inf poison σ) are skipped without panicking.
    #[test]
    fn non_finite_blocks_skipped() {
        let mut w = gaussian(128, 10);
        w[3] = f32::NAN;
        w[70] = f32::INFINITY;
        let before = w.clone();
        let outliers = extract_outliers(&mut w, 64, OpqConfig::default());
        assert!(outliers.is_empty());
        // nothing zeroed in the poisoned blocks
        assert_eq!(w[1].to_bits(), before[1].to_bits());
        assert!(w[3].is_nan());
    }

    #[test]
    fn restore_roundtrip() {
        let mut w = gaussian(128, 4);
        w[5] = 40.0;
        let orig = w.clone();
        let outliers = extract_outliers(&mut w, 64, OpqConfig::default());
        assert!(!outliers.is_empty());
        restore_outliers(&mut w, &outliers);
        // restored value equals bf16(original)
        assert_eq!(w[5], Bf16::from_f32(orig[5]).to_f32());
    }

    #[test]
    fn memory_accounting() {
        assert_eq!(opq_bytes(0), 0);
        assert_eq!(opq_bytes(10), 100);
    }

    #[test]
    fn matches_python_fixture_semantics() {
        // Mirrors aot.py's OPQ fixture: threshold σ multiplier for I=64,
        // q=0.95 is F_M^{-1}(0.95) ≈ 3.3524.
        let bm = BlockMax::new(64);
        assert!((bm.quantile(0.95) - 3.3524).abs() < 1e-4);
    }
}
