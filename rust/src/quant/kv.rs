//! Block-wise quantization of KV-cache *activations* (the `BOF4_KV`
//! subsystem) — the paper's weight machinery (absmax block constants,
//! BOF4 codebooks) turned onto the per-position K/V rows the serving
//! engine keeps resident, the W4A8/BlockDialect direction of PAPERS.md.
//!
//! Three formats, selected by [`KvFormat`] (`EngineConfig::kv_format`,
//! env-overridable via `BOF4_KV=f32|q8|q4` like `BOF4_THREADS` /
//! `BOF4_SIMD`):
//!
//! - **f32** (default): the existing resident slabs, byte-for-byte
//!   unchanged — streams stay bit-identical to the pre-`BOF4_KV` engine.
//! - **q8**: block-wise absmax int8. Each `d_model`-element K/V row is
//!   split into blocks of `block` elements; per block one f32 scale
//!   `absmax/127` plus one signed byte per element
//!   (`code = round(x/absmax * 127)`, reconstruction `code * scale`).
//!   1 B/element + 4 B/block ⇒ ≥3.5× smaller than f32 at the canonical
//!   geometry.
//! - **q4** (experimental): BOF4 4-bit codes against a 16-level
//!   codebook, nibble-packed two per byte, one f32 block constant per
//!   block. 0.5 B/element + 4 B/block.
//!
//! Quantization happens **at append** (prefill scatter + each decode
//! step's new K/V column); dequantization is fused into the decode
//! attention kernels ([`crate::runtime::kernels::kv`]) through the same
//! canonical 8-lane reduction order as every other kernel, so quantized
//! streams are deterministic across `BOF4_THREADS × BOF4_SIMD`.
//!
//! The row quantizers here are deliberately scalar and path-independent:
//! append cost is O(d_model) per token against the O(d_model · seq)
//! attention that reads it back, and a single implementation keeps the
//! encode bits trivially identical at every knob setting.

use std::sync::OnceLock;

use super::absmax::{block_constant, safe_constant, Norm};
use super::codebook::Codebook;
use super::pack::get_u4;
use crate::error::Result;

/// Storage format of the engine's resident K/V cache slabs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KvFormat {
    /// Unquantized f32 rows (bit-identical to the pre-`BOF4_KV` engine).
    F32,
    /// Block-wise absmax int8 codes + one f32 scale per block.
    Q8,
    /// Block-wise BOF4 4-bit codes (nibble-packed) + one f32 constant
    /// per block (experimental).
    Q4,
}

impl KvFormat {
    /// Knob spelling, as accepted by `BOF4_KV` and `bof4 serve --kv`.
    pub fn name(&self) -> &'static str {
        match self {
            KvFormat::F32 => "f32",
            KvFormat::Q8 => "q8",
            KvFormat::Q4 => "q4",
        }
    }

    /// Parse a knob value (`f32|q8|q4`, case-insensitive).
    pub fn parse(s: &str) -> Result<KvFormat> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "" => Ok(KvFormat::F32),
            "q8" | "int8" => Ok(KvFormat::Q8),
            "q4" | "bof4" => Ok(KvFormat::Q4),
            other => Err(crate::err!(
                "unknown KV format '{other}' (expected 'f32', 'q8' or 'q4')"
            )),
        }
    }

    /// Format from `BOF4_KV`, else `F32`. Cached after first read (the
    /// same once-per-process contract as `BOF4_THREADS`/`BOF4_SIMD`);
    /// unparseable values fall back to `F32` rather than failing engine
    /// start.
    pub fn from_env() -> KvFormat {
        static FMT: OnceLock<KvFormat> = OnceLock::new();
        *FMT.get_or_init(|| match std::env::var("BOF4_KV") {
            Ok(v) => KvFormat::parse(&v).unwrap_or(KvFormat::F32),
            Err(_) => KvFormat::F32,
        })
    }

    /// Bytes of resident storage per `d`-element K/V row under this
    /// format with `block`-element quantization blocks (codes + per-block
    /// constants; f32 rows have no constants).
    pub fn row_bytes(&self, d: usize, block: usize) -> usize {
        let nb = d.div_ceil(block.max(1));
        match self {
            KvFormat::F32 => 4 * d,
            KvFormat::Q8 => d + 4 * nb,
            KvFormat::Q4 => d.div_ceil(2) + 4 * nb,
        }
    }
}

impl std::fmt::Display for KvFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Quantize one activation row block-wise to absmax int8.
///
/// `codes` receives one signed byte per element (two's-complement bit
/// pattern stored as `u8`); `scales` one f32 per block
/// (`safe_constant(c)/127`, so all-zero blocks reconstruct exactly and a
/// NaN anywhere in a block poisons that block's scale, mirroring
/// [`block_constant`]). Non-finite elements encode to code 0 — no panic,
/// but no reconstruction guarantee (the error bound below is for finite
/// rows).
///
/// Reconstruction error: `|x - code*scale| <= |c|/254 + eps` per element
/// (half a q8 step of the block's absmax).
pub fn quantize_row_q8(row: &[f32], block: usize, norm: Norm, codes: &mut [u8], scales: &mut [f32]) {
    assert!(block > 0, "kv quant block must be positive");
    assert_eq!(codes.len(), row.len(), "q8 codes buffer mismatch");
    assert_eq!(scales.len(), row.len().div_ceil(block), "q8 scales buffer mismatch");
    for (bi, chunk) in row.chunks(block).enumerate() {
        let c = safe_constant(block_constant(chunk, norm));
        scales[bi] = c / 127.0;
        let inv = 1.0 / c;
        for (j, &x) in chunk.iter().enumerate() {
            // NaN and ±inf saturate/zero through the `as` cast — never a
            // panic, and the block stays readable
            let q = (x * inv * 127.0).round().clamp(-127.0, 127.0) as i8;
            codes[bi * block + j] = q as u8;
        }
    }
}

/// Dequantize a full q8 row (slow path: tests, eval, debugging — the
/// serving path reads blocks fused inside the attention kernels).
pub fn dequantize_row_q8(codes: &[u8], scales: &[f32], block: usize, out: &mut [f32]) {
    assert_eq!(codes.len(), out.len());
    for (i, o) in out.iter_mut().enumerate() {
        *o = (codes[i] as i8) as f32 * scales[i / block];
    }
}

/// Quantize one activation row block-wise to 4-bit codes against `cb`
/// (the BOF4 / BOF4-S codebook over normalized values), nibble-packed
/// two per byte. `scales` receives one `safe_constant` per block (the
/// raw block constant, not divided — reconstruction is
/// `cb.decode1(code) * scale`). `row.len()` must be even (nibble
/// packing; the engine enforces even `d_model` for q4 KV).
pub fn quantize_row_q4(
    row: &[f32],
    block: usize,
    norm: Norm,
    cb: &Codebook,
    codes: &mut [u8],
    scales: &mut [f32],
) {
    assert!(block > 0, "kv quant block must be positive");
    assert_eq!(row.len() % 2, 0, "q4 KV rows must have even length");
    assert_eq!(codes.len(), row.len() / 2, "q4 codes buffer mismatch");
    assert_eq!(scales.len(), row.len().div_ceil(block), "q4 scales buffer mismatch");
    for (bi, chunk) in row.chunks(block).enumerate() {
        let c = safe_constant(block_constant(chunk, norm));
        scales[bi] = c;
        let inv = 1.0 / c;
        for (j, &x) in chunk.iter().enumerate() {
            let code = cb.encode1(x * inv);
            let e = bi * block + j;
            let b = &mut codes[e / 2];
            if e % 2 == 0 {
                *b = (*b & 0xf0) | code;
            } else {
                *b = (*b & 0x0f) | (code << 4);
            }
        }
    }
}

/// Dequantize a full q4 row (slow path, as [`dequantize_row_q8`]).
pub fn dequantize_row_q4(
    codes: &[u8],
    scales: &[f32],
    block: usize,
    levels: &[f32; 16],
    out: &mut [f32],
) {
    assert_eq!(codes.len() * 2, out.len());
    for (i, o) in out.iter_mut().enumerate() {
        *o = levels[get_u4(codes, i) as usize] * scales[i / block];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{codebook_for, Method};
    use crate::testkit::{forall, GaussianVec, Prop};

    fn levels(norm: Norm, block: usize) -> [f32; 16] {
        let cb = codebook_for(&Method::Bof4 { mse: true }, norm, block);
        let mut l = [0.0f32; 16];
        for (i, v) in l.iter_mut().enumerate() {
            *v = cb.decode1(i as u8);
        }
        l
    }

    #[test]
    fn format_parse_and_names() {
        assert_eq!(KvFormat::parse("f32").unwrap(), KvFormat::F32);
        assert_eq!(KvFormat::parse("Q8").unwrap(), KvFormat::Q8);
        assert_eq!(KvFormat::parse(" q4 ").unwrap(), KvFormat::Q4);
        assert_eq!(KvFormat::parse("int8").unwrap(), KvFormat::Q8);
        assert!(KvFormat::parse("q2").is_err());
        for f in [KvFormat::F32, KvFormat::Q8, KvFormat::Q4] {
            assert_eq!(KvFormat::parse(f.name()).unwrap(), f);
            assert_eq!(format!("{f}"), f.name());
        }
        // from_env is cached and always returns a valid format
        let f = KvFormat::from_env();
        assert_eq!(f, KvFormat::from_env());
    }

    /// The acceptance geometry: at the canonical `d_model=128, block=64`
    /// the q8 row is ≥3.5× smaller than f32 and q4 ≥6×.
    #[test]
    fn row_bytes_reduction_at_canonical_geometry() {
        let f32b = KvFormat::F32.row_bytes(128, 64);
        let q8b = KvFormat::Q8.row_bytes(128, 64);
        let q4b = KvFormat::Q4.row_bytes(128, 64);
        assert_eq!(f32b, 512);
        assert_eq!(q8b, 128 + 8);
        assert_eq!(q4b, 64 + 8);
        assert!(f32b as f64 / q8b as f64 >= 3.5, "q8 ratio {}", f32b as f64 / q8b as f64);
        assert!(f32b as f64 / q4b as f64 >= 6.0);
        // ragged tail: 5 blocks for d=130 @ block 32
        assert_eq!(KvFormat::Q8.row_bytes(130, 32), 130 + 4 * 5);
    }

    #[test]
    fn q8_roundtrip_exact_cases() {
        // all-zero block reconstructs exactly (safe_constant)
        let row = [0.0f32; 8];
        let mut codes = [0u8; 8];
        let mut scales = [0.0f32; 2];
        quantize_row_q8(&row, 4, Norm::Absmax, &mut codes, &mut scales);
        let mut out = [9.0f32; 8];
        dequantize_row_q8(&codes, &scales, 4, &mut out);
        assert_eq!(out, [0.0; 8]);
        // the absmax element itself reconstructs to ±c exactly
        let row = [1.0f32, -2.0, 0.5, 2.0];
        quantize_row_q8(&row[..4], 4, Norm::Absmax, &mut codes[..4], &mut scales[..1]);
        let mut out = [0.0f32; 4];
        dequantize_row_q8(&codes[..4], &scales[..1], 4, &mut out);
        assert_eq!(out[1], -2.0);
        assert_eq!(out[3], 2.0);
    }

    /// Property: q8 round-trip over ragged tail blocks, both norms —
    /// never panics, and every finite element reconstructs within half a
    /// quantization step of the block's constant.
    #[test]
    fn property_q8_roundtrip_bounded() {
        let gen = GaussianVec {
            max_len: 200,
            max_scale: 8.0,
        };
        for norm in [Norm::Absmax, Norm::SignedAbsmax] {
            for block in [1usize, 3, 8, 32, 64] {
                forall("kv-q8-roundtrip", 41, 40, &gen, |row| {
                    if row.is_empty() {
                        return Prop::Pass;
                    }
                    let nb = row.len().div_ceil(block);
                    let mut codes = vec![0u8; row.len()];
                    let mut scales = vec![0.0f32; nb];
                    quantize_row_q8(row, block, norm, &mut codes, &mut scales);
                    let mut out = vec![0.0f32; row.len()];
                    dequantize_row_q8(&codes, &scales, block, &mut out);
                    for (bi, chunk) in row.chunks(block).enumerate() {
                        let c = block_constant(chunk, norm).abs();
                        let bound = c / 254.0 + c * 1e-5 + 1e-7;
                        for (j, (&x, &y)) in
                            chunk.iter().zip(&out[bi * block..bi * block + chunk.len()]).enumerate()
                        {
                            if (x - y).abs() > bound {
                                return Prop::Fail(format!(
                                    "block {bi} elem {j}: {x} -> {y} (bound {bound}, norm {norm:?})"
                                ));
                            }
                        }
                    }
                    Prop::Pass
                });
            }
        }
    }

    /// Property: q4 round-trip error obeys the codebook's normalized
    /// error bound times the block constant, both norms, ragged tails.
    #[test]
    fn property_q4_roundtrip_bounded() {
        let gen = GaussianVec {
            max_len: 101,
            max_scale: 4.0,
        };
        for norm in [Norm::Absmax, Norm::SignedAbsmax] {
            for block in [2usize, 8, 30, 64] {
                let cb = codebook_for(&Method::Bof4 { mse: true }, norm, block);
                let lv = levels(norm, block);
                let max_err = cb.max_norm_error();
                forall("kv-q4-roundtrip", 43, 40, &gen, |row| {
                    // nibble packing needs even length
                    let row = &row[..row.len() & !1];
                    if row.is_empty() {
                        return Prop::Pass;
                    }
                    let nb = row.len().div_ceil(block);
                    let mut codes = vec![0u8; row.len() / 2];
                    let mut scales = vec![0.0f32; nb];
                    quantize_row_q4(row, block, norm, &cb, &mut codes, &mut scales);
                    let mut out = vec![0.0f32; row.len()];
                    dequantize_row_q4(&codes, &scales, block, &lv, &mut out);
                    for (bi, chunk) in row.chunks(block).enumerate() {
                        let c = block_constant(chunk, norm).abs();
                        let bound = c * max_err + c * 1e-5 + 1e-7;
                        for (j, (&x, &y)) in
                            chunk.iter().zip(&out[bi * block..bi * block + chunk.len()]).enumerate()
                        {
                            if (x - y).abs() > bound {
                                return Prop::Fail(format!(
                                    "block {bi} elem {j}: {x} -> {y} (bound {bound}, norm {norm:?})"
                                ));
                            }
                        }
                    }
                    Prop::Pass
                });
            }
        }
    }

    /// NaN / ±inf inputs must not panic under either norm or format; the
    /// poisoned block stays readable (finite or NaN output, never UB) and
    /// clean neighbouring blocks are unaffected.
    #[test]
    fn non_finite_inputs_never_panic() {
        let specials = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY];
        for &bad in &specials {
            for pos in 0..4 {
                let mut row = [1.0f32, -0.5, 0.25, 2.0, 0.1, 0.2, -0.3, 0.4];
                row[pos] = bad;
                for norm in [Norm::Absmax, Norm::SignedAbsmax] {
                    let mut codes = [0u8; 8];
                    let mut scales = [0.0f32; 2];
                    quantize_row_q8(&row, 4, norm, &mut codes, &mut scales);
                    let mut out = [0.0f32; 8];
                    dequantize_row_q8(&codes, &scales, 4, &mut out);
                    // the clean second block is unaffected by the poisoned first
                    let c = block_constant(&row[4..], norm).abs();
                    for (x, y) in row[4..].iter().zip(&out[4..]) {
                        assert!((x - y).abs() <= c / 254.0 + 1e-6, "{norm:?} {bad}");
                    }
                    let cb = codebook_for(&Method::Bof4 { mse: true }, norm, 4);
                    let lv = levels(norm, 4);
                    let mut codes4 = [0u8; 4];
                    quantize_row_q4(&row, 4, norm, &cb, &mut codes4, &mut scales);
                    let mut out4 = [0.0f32; 8];
                    dequantize_row_q4(&codes4, &scales, 4, &lv, &mut out4);
                    let bound = c * cb.max_norm_error() + c * 1e-5 + 1e-6;
                    for (x, y) in row[4..].iter().zip(&out4[4..]) {
                        assert!((x - y).abs() <= bound, "{norm:?} {bad}");
                    }
                }
            }
        }
    }
}
