//! Block-wise absmax normalization (paper §2.1 eqs. 1–3 and §3.1 eq. 4).

pub use crate::stats::blockmax::Norm;

/// Quantization constant of one block: the absolute maximum (eq. 1) or the
/// signed value of the absolutely-largest weight (eq. 4, BOF4-S).
/// Ties in magnitude resolve to the lowest index (matches the python
/// oracle's `argmax`).
///
/// A NaN anywhere in the block poisons the constant to NaN under *both*
/// norms (the `f32::max` fold would silently drop it for `Absmax` while
/// the comparison chain froze on the first element for `SignedAbsmax`,
/// making the two norms disagree on the same poisoned block); the NaN
/// then propagates through normalization instead of being half-ignored.
#[inline]
pub fn block_constant(block: &[f32], norm: Norm) -> f32 {
    debug_assert!(!block.is_empty());
    match norm {
        Norm::Absmax => {
            let mut best = 0.0f32;
            for &w in block {
                let a = w.abs();
                if a.is_nan() {
                    return f32::NAN;
                }
                if a > best {
                    best = a;
                }
            }
            best
        }
        Norm::SignedAbsmax => {
            let mut best = block[0];
            let mut best_abs = best.abs();
            if best_abs.is_nan() {
                return f32::NAN;
            }
            for &w in &block[1..] {
                let a = w.abs();
                if a.is_nan() {
                    return f32::NAN;
                }
                if a > best_abs {
                    best = w;
                    best_abs = a;
                }
            }
            best
        }
    }
}

/// Safe divisor: all-zero blocks normalize by 1.0 (weights stay 0, which
/// every paper codebook represents exactly).
#[inline]
pub fn safe_constant(c: f32) -> f32 {
    if c == 0.0 {
        1.0
    } else {
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absmax_basic() {
        assert_eq!(block_constant(&[1.0, -3.0, 2.0], Norm::Absmax), 3.0);
        assert_eq!(block_constant(&[1.0, -3.0, 2.0], Norm::SignedAbsmax), -3.0);
        assert_eq!(block_constant(&[0.5], Norm::SignedAbsmax), 0.5);
    }

    #[test]
    fn signed_tie_takes_first() {
        // |−2| == |2|: the first one (index 0) wins.
        assert_eq!(block_constant(&[-2.0, 2.0], Norm::SignedAbsmax), -2.0);
        assert_eq!(block_constant(&[2.0, -2.0], Norm::SignedAbsmax), 2.0);
    }

    #[test]
    fn zero_block() {
        assert_eq!(block_constant(&[0.0, 0.0], Norm::Absmax), 0.0);
        assert_eq!(safe_constant(0.0), 1.0);
        assert_eq!(safe_constant(-2.5), -2.5);
    }

    /// A poisoned block must yield NaN under *both* norms, wherever the
    /// NaN sits (the old fold dropped it for Absmax; the comparison
    /// chain froze on element 0 for SignedAbsmax).
    #[test]
    fn nan_propagates_identically_for_both_norms() {
        for pos in 0..3 {
            let mut b = [1.0f32, -3.0, 2.0];
            b[pos] = f32::NAN;
            assert!(block_constant(&b, Norm::Absmax).is_nan(), "abs pos {pos}");
            assert!(
                block_constant(&b, Norm::SignedAbsmax).is_nan(),
                "signed pos {pos}"
            );
        }
        // infinities are ordinary magnitudes, not poison
        assert_eq!(
            block_constant(&[1.0, f32::INFINITY], Norm::Absmax),
            f32::INFINITY
        );
        assert_eq!(
            block_constant(&[1.0, f32::NEG_INFINITY], Norm::SignedAbsmax),
            f32::NEG_INFINITY
        );
    }

    #[test]
    fn signed_normalization_maps_max_to_one() {
        let b = [0.3f32, -0.9, 0.1];
        let c = block_constant(&b, Norm::SignedAbsmax);
        assert_eq!(b[1] / c, 1.0); // the largest-magnitude weight -> +1
        assert!(b[0] / c < 0.0); // others flip sign
    }
}
