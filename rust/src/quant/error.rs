//! Quantization error metrics (MAE / MSE) with f64 accumulation.

/// Mean absolute error between original and reconstructed weights.
pub fn mae(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let s: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| ((x as f64) - (y as f64)).abs())
        .sum();
    s / a.len() as f64
}

/// Mean squared error.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let s: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x as f64) - (y as f64);
            d * d
        })
        .sum();
    s / a.len() as f64
}

/// Signal-to-quantization-noise ratio in dB (reports).
pub fn sqnr_db(orig: &[f32], deq: &[f32]) -> f64 {
    let sig: f64 = orig.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let noise: f64 = orig
        .iter()
        .zip(deq)
        .map(|(&x, &y)| {
            let d = (x as f64) - (y as f64);
            d * d
        })
        .sum();
    10.0 * (sig / noise.max(1e-300)).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_error_when_equal() {
        let a = [1.0f32, -2.0, 3.0];
        assert_eq!(mae(&a, &a), 0.0);
        assert_eq!(mse(&a, &a), 0.0);
    }

    #[test]
    fn known_values() {
        let a = [0.0f32, 0.0];
        let b = [1.0f32, -3.0];
        assert_eq!(mae(&a, &b), 2.0);
        assert_eq!(mse(&a, &b), 5.0);
    }

    #[test]
    fn mse_dominated_by_outliers_vs_mae() {
        let a = vec![0.0f32; 100];
        let mut b = vec![0.01f32; 100];
        b[0] = 1.0;
        // MSE is relatively more sensitive to the single outlier
        let ratio_mse = mse(&a, &b) / mse(&a, &vec![0.01; 100]);
        let ratio_mae = mae(&a, &b) / mae(&a, &vec![0.01; 100]);
        assert!(ratio_mse > ratio_mae);
    }

    #[test]
    fn sqnr_positive_for_small_noise() {
        let a: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = a.iter().map(|x| x + 0.001).collect();
        assert!(sqnr_db(&a, &b) > 40.0);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(mae(&[], &[]), 0.0);
        assert_eq!(mse(&[], &[]), 0.0);
    }
}
