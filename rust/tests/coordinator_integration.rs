//! Coordinator invariants: scheduler property tests + batched-service
//! behaviour over the real PJRT runtime.

use std::sync::Arc;

use bof4::coordinator::{BatchedLm, QuantJob, QuantScheduler, ServiceConfig};
use bof4::quant::{Method, Norm, QuantConfig};
use bof4::runtime::{HostTensor, Meta, Runtime};
use bof4::testkit::{forall, Gen, Prop, USizeRange};
use bof4::util::rng::Pcg64;

// ---------------------------------------------------------------------
// scheduler properties (no runtime needed)
// ---------------------------------------------------------------------

struct JobBatchGen;

impl Gen<Vec<QuantJob>> for JobBatchGen {
    fn generate(&self, rng: &mut Pcg64) -> Vec<QuantJob> {
        let n = 1 + rng.next_below(12) as usize;
        (0..n)
            .map(|i| {
                let len = 1 + rng.next_below(500) as usize;
                let mut data = vec![0.0f32; len];
                for v in data.iter_mut() {
                    *v = rng.next_gaussian() as f32;
                }
                QuantJob {
                    name: format!("j{i}"),
                    data,
                }
            })
            .collect()
    }
    fn shrink(&self, v: &Vec<QuantJob>) -> Vec<Vec<QuantJob>> {
        if v.len() > 1 {
            vec![v[..v.len() / 2].to_vec()]
        } else {
            Vec::new()
        }
    }
}

#[test]
fn property_no_lost_or_duplicated_jobs() {
    let sched = QuantScheduler::new(QuantConfig {
        method: Method::Nf4,
        norm: Norm::Absmax,
        ..Default::default()
    })
    .with_workers(4);
    forall("scheduler-exactly-once", 31, 25, &JobBatchGen, |jobs| {
        let res = match sched.run(jobs.clone()) {
            Ok(r) => r,
            Err(e) => return Prop::Fail(format!("scheduler error: {e}")),
        };
        if res.len() != jobs.len() {
            return Prop::Fail(format!("{} results for {} jobs", res.len(), jobs.len()));
        }
        for (j, r) in jobs.iter().zip(&res) {
            if j.name != r.name {
                return Prop::Fail(format!("order broken: {} vs {}", j.name, r.name));
            }
            if r.tensor.len != j.data.len() {
                return Prop::Fail("length mismatch".into());
            }
        }
        Prop::Pass
    });
}

#[test]
fn property_worker_count_invariant() {
    // Result bits must not depend on parallelism.
    let mk = |workers| QuantScheduler::new(QuantConfig::default()).with_workers(workers);
    forall(
        "scheduler-worker-invariance",
        32,
        10,
        &USizeRange(1, 6),
        |&workers| {
            let mut rng = Pcg64::seed_from_u64(777);
            let jobs: Vec<QuantJob> = (0..5)
                .map(|i| {
                    let mut data = vec![0.0f32; 320];
                    rng.fill_gaussian_f32(&mut data, 1.0);
                    QuantJob {
                        name: format!("t{i}"),
                        data,
                    }
                })
                .collect();
            let base = mk(1).run(jobs.clone()).unwrap();
            let other = mk(workers).run(jobs).unwrap();
            for (a, b) in base.iter().zip(&other) {
                if a.tensor.codes != b.tensor.codes || a.mse != b.mse {
                    return Prop::Fail(format!("workers={workers} diverged"));
                }
            }
            Prop::Pass
        },
    );
}

// ---------------------------------------------------------------------
// batched service over the real runtime
// ---------------------------------------------------------------------

fn service() -> Option<(Arc<Runtime>, BatchedLm)> {
    if !Meta::default_dir().join("meta.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let rt = Arc::new(Runtime::new().unwrap());
    let params = rt
        .run("init_params", &[HostTensor::scalar_u32(3)])
        .unwrap();
    let svc = BatchedLm::start(rt.clone(), params, ServiceConfig::default()).unwrap();
    Some((rt, svc))
}

#[test]
fn every_request_answered_exactly_once() {
    let Some((rt, svc)) = service() else { return };
    let n = 40;
    let mut rng = Pcg64::seed_from_u64(5);
    let prompts: Vec<Vec<u8>> = (0..n)
        .map(|_| {
            (0..20)
                .map(|_| rng.next_below(64) as u8)
                .collect::<Vec<u8>>()
        })
        .collect();
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| svc.infer_async(p).unwrap())
        .collect();
    let mut answers = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert!((resp.next_token as usize) < rt.meta.model.vocab);
        answers += 1;
    }
    assert_eq!(answers, n);
    // batching actually happened: fewer batches than requests
    let batches = svc.metrics.get("batches");
    assert!(batches < n as u64, "batches={batches}");
    assert_eq!(svc.metrics.get("batched_requests"), n as u64);
}

#[test]
fn batch_size_never_exceeds_model_batch() {
    let Some((rt, svc)) = service() else { return };
    let b = rt.meta.model.batch as u64;
    let n = 3 * b + 1;
    let rxs: Vec<_> = (0..n)
        .map(|i| svc.infer_async(&[(i % 60) as u8; 8]).unwrap())
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let batches = svc.metrics.get("batches");
    let reqs = svc.metrics.get("batched_requests");
    assert_eq!(reqs, n);
    assert!(batches >= n / b, "impossible packing: {batches} batches");
}

#[test]
fn deterministic_responses_for_same_prompt() {
    let Some((_rt, svc)) = service() else { return };
    let p = vec![1u8, 2, 3, 4, 5];
    let a = svc.infer(&p).unwrap();
    let b = svc.infer(&p).unwrap();
    assert_eq!(a, b);
}

#[test]
fn generate_extends_context() {
    let Some((_rt, svc)) = service() else { return };
    let out = svc.generate(&[1, 2, 3], 5).unwrap();
    assert_eq!(out.len(), 5);
}
