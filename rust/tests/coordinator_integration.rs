//! Coordinator invariants: scheduler property tests + batched-service
//! behaviour over the default (pure-Rust CPU) runtime. Everything here
//! runs hermetically — no artifacts, no Python, no network.

use std::sync::Arc;
use std::time::{Duration, Instant};

use bof4::coordinator::{BatchedLm, QuantJob, QuantScheduler, ServiceConfig};
use bof4::quant::{Method, Norm, QuantConfig};
use bof4::runtime::{HostTensor, Runtime};
use bof4::testkit::{forall, Gen, Prop, USizeRange};
use bof4::util::rng::Pcg64;

// ---------------------------------------------------------------------
// scheduler properties (no runtime needed)
// ---------------------------------------------------------------------

struct JobBatchGen;

impl Gen<Vec<QuantJob>> for JobBatchGen {
    fn generate(&self, rng: &mut Pcg64) -> Vec<QuantJob> {
        let n = 1 + rng.next_below(12) as usize;
        (0..n)
            .map(|i| {
                let len = 1 + rng.next_below(500) as usize;
                let mut data = vec![0.0f32; len];
                for v in data.iter_mut() {
                    *v = rng.next_gaussian() as f32;
                }
                QuantJob {
                    name: format!("j{i}"),
                    data,
                }
            })
            .collect()
    }
    fn shrink(&self, v: &Vec<QuantJob>) -> Vec<Vec<QuantJob>> {
        if v.len() > 1 {
            vec![v[..v.len() / 2].to_vec()]
        } else {
            Vec::new()
        }
    }
}

#[test]
fn property_no_lost_or_duplicated_jobs() {
    let sched = QuantScheduler::new(QuantConfig {
        method: Method::Nf4,
        norm: Norm::Absmax,
        ..Default::default()
    })
    .with_workers(4);
    forall("scheduler-exactly-once", 31, 25, &JobBatchGen, |jobs| {
        let res = match sched.run(jobs.clone()) {
            Ok(r) => r,
            Err(e) => return Prop::Fail(format!("scheduler error: {e}")),
        };
        if res.len() != jobs.len() {
            return Prop::Fail(format!("{} results for {} jobs", res.len(), jobs.len()));
        }
        for (j, r) in jobs.iter().zip(&res) {
            if j.name != r.name {
                return Prop::Fail(format!("order broken: {} vs {}", j.name, r.name));
            }
            if r.tensor.len != j.data.len() {
                return Prop::Fail("length mismatch".into());
            }
        }
        Prop::Pass
    });
}

#[test]
fn property_worker_count_invariant() {
    // Result bits must not depend on parallelism.
    let mk = |workers| QuantScheduler::new(QuantConfig::default()).with_workers(workers);
    forall(
        "scheduler-worker-invariance",
        32,
        10,
        &USizeRange(1, 6),
        |&workers| {
            let mut rng = Pcg64::seed_from_u64(777);
            let jobs: Vec<QuantJob> = (0..5)
                .map(|i| {
                    let mut data = vec![0.0f32; 320];
                    rng.fill_gaussian_f32(&mut data, 1.0);
                    QuantJob {
                        name: format!("t{i}"),
                        data,
                    }
                })
                .collect();
            let base = mk(1).run(jobs.clone()).unwrap();
            let other = mk(workers).run(jobs).unwrap();
            for (a, b) in base.iter().zip(&other) {
                if a.tensor.codes != b.tensor.codes || a.mse != b.mse {
                    return Prop::Fail(format!("workers={workers} diverged"));
                }
            }
            Prop::Pass
        },
    );
}

/// Exactly-once + submission order with 1 worker, 4 workers, and more
/// workers than jobs (idle workers must exit cleanly, not hang or dup).
#[test]
fn scheduler_exactly_once_across_worker_counts() {
    let n_jobs = 7usize;
    let mut rng = Pcg64::seed_from_u64(99);
    let jobs: Vec<QuantJob> = (0..n_jobs)
        .map(|i| {
            let mut data = vec![0.0f32; 257];
            rng.fill_gaussian_f32(&mut data, 1.0);
            QuantJob {
                name: format!("tensor-{i}"),
                data,
            }
        })
        .collect();
    for workers in [1usize, 4, n_jobs + 9] {
        let sched = QuantScheduler::new(QuantConfig {
            method: Method::Nf4,
            ..Default::default()
        })
        .with_workers(workers);
        let res = sched.run(jobs.clone()).unwrap();
        assert_eq!(res.len(), n_jobs, "workers={workers}");
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.name, format!("tensor-{i}"), "workers={workers}");
        }
        assert_eq!(sched.metrics.get("tensors_done"), n_jobs as u64);
    }
}

/// A worker panic must surface as an error, not a hang or a lost job.
/// (block = 0 makes the quantizer divide by zero inside the worker.)
#[test]
fn scheduler_surfaces_worker_panics() {
    let sched = QuantScheduler::new(QuantConfig {
        method: Method::Nf4,
        norm: Norm::Absmax,
        block: 0, // invalid on purpose: panics inside quantize()
        ..Default::default()
    })
    .with_workers(3);
    let jobs = vec![
        QuantJob {
            name: "boom".into(),
            data: vec![1.0, 2.0, 3.0],
        },
        QuantJob {
            name: "boom2".into(),
            data: vec![4.0, 5.0],
        },
    ];
    let err = sched.run(jobs).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("panic"), "unexpected error: {msg}");
}

// ---------------------------------------------------------------------
// batched service over the default CPU runtime
// ---------------------------------------------------------------------

fn service() -> (Arc<Runtime>, BatchedLm) {
    service_with(ServiceConfig::default())
}

fn service_with(cfg: ServiceConfig) -> (Arc<Runtime>, BatchedLm) {
    let rt = Arc::new(Runtime::new().unwrap());
    let params = rt
        .run("init_params", &[HostTensor::scalar_u32(3)])
        .unwrap();
    let svc = BatchedLm::start(rt.clone(), params, cfg).unwrap();
    (rt, svc)
}

#[test]
fn every_request_answered_exactly_once() {
    let (rt, svc) = service();
    let n = 40;
    let mut rng = Pcg64::seed_from_u64(5);
    let prompts: Vec<Vec<u8>> = (0..n)
        .map(|_| {
            (0..20)
                .map(|_| rng.next_below(64) as u8)
                .collect::<Vec<u8>>()
        })
        .collect();
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| svc.infer_async(p).unwrap())
        .collect();
    let mut answers = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert!((resp.next_token as usize) < rt.meta.model.vocab);
        answers += 1;
    }
    assert_eq!(answers, n);
    // batching actually happened: fewer batches than requests
    let batches = svc.metrics.get("batches");
    assert!(batches < n as u64, "batches={batches}");
    assert_eq!(svc.metrics.get("batched_requests"), n as u64);
}

#[test]
fn batch_size_never_exceeds_model_batch() {
    let (rt, svc) = service();
    let b = rt.meta.model.batch as u64;
    let n = 3 * b + 1;
    let rxs: Vec<_> = (0..n)
        .map(|i| svc.infer_async(&[(i % 60) as u8; 8]).unwrap())
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let batches = svc.metrics.get("batches");
    let reqs = svc.metrics.get("batched_requests");
    assert_eq!(reqs, n);
    assert!(batches >= n / b, "impossible packing: {batches} batches");
}

#[test]
fn deterministic_responses_for_same_prompt() {
    let (_rt, svc) = service();
    let p = vec![1u8, 2, 3, 4, 5];
    let a = svc.infer(&p).unwrap();
    let b = svc.infer(&p).unwrap();
    assert_eq!(a, b);
}

#[test]
fn generate_extends_context() {
    let (_rt, svc) = service();
    let out = svc.generate(&[1, 2, 3], 5).unwrap();
    assert_eq!(out.len(), 5);
}

/// A lone request must be answered after ~one batching window plus one
/// forward pass — the batcher may not wait for a full batch that never
/// arrives. We measure the wall clock of a warm single request and check
/// it against the window plus a generous compute budget (the CPU forward
/// itself is the dominant term on debug builds).
#[test]
fn lone_request_answered_within_batching_window() {
    let window = Duration::from_millis(5);
    let (_rt, svc) = service_with(ServiceConfig { window });
    // warm-up: first request pays one-time costs
    svc.infer(&[1, 2, 3]).unwrap();
    let compute_budget = Duration::from_secs(30);
    let t0 = Instant::now();
    let resp = svc.infer(&[4, 5, 6]).unwrap();
    let elapsed = t0.elapsed();
    assert!((resp.next_token as usize) < 64);
    assert!(
        elapsed < window + compute_budget,
        "lone request took {elapsed:?} (window {window:?})"
    );
    // it ran as a batch of one, not by waiting for batch-mates
    assert_eq!(svc.metrics.get("batches"), 2);
    assert_eq!(svc.metrics.get("batched_requests"), 2);
}
