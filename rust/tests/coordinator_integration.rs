//! Coordinator invariants: scheduler property tests + batched-service
//! behaviour over the default (pure-Rust CPU) runtime. Everything here
//! runs hermetically — no artifacts, no Python, no network.

use std::sync::Arc;
use std::time::{Duration, Instant};

use bof4::coordinator::{
    BatchedLm, Engine, EngineConfig, QuantJob, QuantScheduler, ServiceConfig,
};
use bof4::quant::{Method, Norm, QuantConfig};
use bof4::runtime::{HostTensor, KvFormat, Runtime};
use bof4::testkit::{forall, Gen, Prop, USizeRange};
use bof4::util::rng::Pcg64;

// ---------------------------------------------------------------------
// scheduler properties (no runtime needed)
// ---------------------------------------------------------------------

struct JobBatchGen;

impl Gen<Vec<QuantJob>> for JobBatchGen {
    fn generate(&self, rng: &mut Pcg64) -> Vec<QuantJob> {
        let n = 1 + rng.next_below(12) as usize;
        (0..n)
            .map(|i| {
                let len = 1 + rng.next_below(500) as usize;
                let mut data = vec![0.0f32; len];
                for v in data.iter_mut() {
                    *v = rng.next_gaussian() as f32;
                }
                QuantJob {
                    name: format!("j{i}"),
                    data,
                }
            })
            .collect()
    }
    fn shrink(&self, v: &Vec<QuantJob>) -> Vec<Vec<QuantJob>> {
        if v.len() > 1 {
            vec![v[..v.len() / 2].to_vec()]
        } else {
            Vec::new()
        }
    }
}

#[test]
fn property_no_lost_or_duplicated_jobs() {
    let sched = QuantScheduler::new(QuantConfig {
        method: Method::Nf4,
        norm: Norm::Absmax,
        ..Default::default()
    })
    .with_workers(4);
    forall("scheduler-exactly-once", 31, 25, &JobBatchGen, |jobs| {
        let res = match sched.run(jobs.clone()) {
            Ok(r) => r,
            Err(e) => return Prop::Fail(format!("scheduler error: {e}")),
        };
        if res.len() != jobs.len() {
            return Prop::Fail(format!("{} results for {} jobs", res.len(), jobs.len()));
        }
        for (j, r) in jobs.iter().zip(&res) {
            if j.name != r.name {
                return Prop::Fail(format!("order broken: {} vs {}", j.name, r.name));
            }
            if r.tensor.len != j.data.len() {
                return Prop::Fail("length mismatch".into());
            }
        }
        Prop::Pass
    });
}

#[test]
fn property_worker_count_invariant() {
    // Result bits must not depend on parallelism.
    let mk = |workers| QuantScheduler::new(QuantConfig::default()).with_workers(workers);
    forall(
        "scheduler-worker-invariance",
        32,
        10,
        &USizeRange(1, 6),
        |&workers| {
            let mut rng = Pcg64::seed_from_u64(777);
            let jobs: Vec<QuantJob> = (0..5)
                .map(|i| {
                    let mut data = vec![0.0f32; 320];
                    rng.fill_gaussian_f32(&mut data, 1.0);
                    QuantJob {
                        name: format!("t{i}"),
                        data,
                    }
                })
                .collect();
            let base = mk(1).run(jobs.clone()).unwrap();
            let other = mk(workers).run(jobs).unwrap();
            for (a, b) in base.iter().zip(&other) {
                if a.tensor.codes != b.tensor.codes || a.mse != b.mse {
                    return Prop::Fail(format!("workers={workers} diverged"));
                }
            }
            Prop::Pass
        },
    );
}

/// Exactly-once + submission order with 1 worker, 4 workers, and more
/// workers than jobs (idle workers must exit cleanly, not hang or dup).
#[test]
fn scheduler_exactly_once_across_worker_counts() {
    let n_jobs = 7usize;
    let mut rng = Pcg64::seed_from_u64(99);
    let jobs: Vec<QuantJob> = (0..n_jobs)
        .map(|i| {
            let mut data = vec![0.0f32; 257];
            rng.fill_gaussian_f32(&mut data, 1.0);
            QuantJob {
                name: format!("tensor-{i}"),
                data,
            }
        })
        .collect();
    for workers in [1usize, 4, n_jobs + 9] {
        let sched = QuantScheduler::new(QuantConfig {
            method: Method::Nf4,
            ..Default::default()
        })
        .with_workers(workers);
        let res = sched.run(jobs.clone()).unwrap();
        assert_eq!(res.len(), n_jobs, "workers={workers}");
        for (i, r) in res.iter().enumerate() {
            assert_eq!(r.name, format!("tensor-{i}"), "workers={workers}");
        }
        assert_eq!(sched.metrics.get("tensors_done"), n_jobs as u64);
    }
}

/// A worker panic must surface as an error, not a hang or a lost job.
/// (block = 0 makes the quantizer divide by zero inside the worker.)
#[test]
fn scheduler_surfaces_worker_panics() {
    let sched = QuantScheduler::new(QuantConfig {
        method: Method::Nf4,
        norm: Norm::Absmax,
        block: 0, // invalid on purpose: panics inside quantize()
        ..Default::default()
    })
    .with_workers(3);
    let jobs = vec![
        QuantJob {
            name: "boom".into(),
            data: vec![1.0, 2.0, 3.0],
        },
        QuantJob {
            name: "boom2".into(),
            data: vec![4.0, 5.0],
        },
    ];
    let err = sched.run(jobs).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("panic"), "unexpected error: {msg}");
}

// ---------------------------------------------------------------------
// batched service over the default CPU runtime
// ---------------------------------------------------------------------

fn service() -> (Arc<Runtime>, BatchedLm) {
    service_with(ServiceConfig::default())
}

fn service_with(cfg: ServiceConfig) -> (Arc<Runtime>, BatchedLm) {
    let rt = Arc::new(Runtime::new().unwrap());
    let params = rt
        .run("init_params", &[HostTensor::scalar_u32(3)])
        .unwrap();
    let svc = BatchedLm::start(rt.clone(), params, cfg).unwrap();
    (rt, svc)
}

#[test]
fn every_request_answered_exactly_once() {
    let (rt, svc) = service();
    let n = 40;
    let mut rng = Pcg64::seed_from_u64(5);
    let prompts: Vec<Vec<u8>> = (0..n)
        .map(|_| {
            (0..20)
                .map(|_| rng.next_below(64) as u8)
                .collect::<Vec<u8>>()
        })
        .collect();
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| svc.infer_async(p).unwrap())
        .collect();
    let mut answers = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert!((resp.next_token as usize) < rt.meta.model.vocab);
        answers += 1;
    }
    assert_eq!(answers, n);
    // batching actually happened: fewer batches than requests
    let batches = svc.metrics.get("batches");
    assert!(batches < n as u64, "batches={batches}");
    assert_eq!(svc.metrics.get("batched_requests"), n as u64);
}

#[test]
fn batch_size_never_exceeds_model_batch() {
    let (rt, svc) = service();
    let b = rt.meta.model.batch as u64;
    let n = 3 * b + 1;
    let rxs: Vec<_> = (0..n)
        .map(|i| svc.infer_async(&[(i % 60) as u8; 8]).unwrap())
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let batches = svc.metrics.get("batches");
    let reqs = svc.metrics.get("batched_requests");
    assert_eq!(reqs, n);
    assert!(batches >= n / b, "impossible packing: {batches} batches");
}

#[test]
fn deterministic_responses_for_same_prompt() {
    let (_rt, svc) = service();
    let p = vec![1u8, 2, 3, 4, 5];
    let a = svc.infer(&p).unwrap();
    let b = svc.infer(&p).unwrap();
    assert_eq!(a, b);
}

#[test]
fn generate_extends_context() {
    let (_rt, svc) = service();
    let out = svc.generate(&[1, 2, 3], 5).unwrap();
    assert_eq!(out.len(), 5);
}

// ---------------------------------------------------------------------
// session engine: streaming, continuous batching, replicas
// ---------------------------------------------------------------------

fn engine_with(cfg: EngineConfig) -> (Arc<Runtime>, Engine) {
    let rt = Arc::new(Runtime::new().unwrap());
    let params = rt
        .run("init_params", &[HostTensor::scalar_u32(3)])
        .unwrap();
    let engine = Engine::start(rt.clone(), params, cfg).unwrap();
    (rt, engine)
}

#[test]
fn session_streams_exact_token_count() {
    let (_rt, engine) = engine_with(EngineConfig::default());
    let toks = engine
        .session_with(&[1, 2, 3], 7)
        .unwrap()
        .collect_tokens()
        .unwrap();
    assert_eq!(toks.len(), 7);
    // 1 prefill token stream start + 6 incremental decode tokens
    assert_eq!(engine.metrics.core.get("sessions"), 1);
    assert_eq!(engine.metrics.core.get("decode_tokens"), 6);
    assert_eq!(engine.metrics.core.get("prefill_tokens"), 3);
    // the CPU backend runs on the kernel pool, so the replica records the
    // pool_busy saturation gauge next to slot_occupancy
    let busy = engine
        .metrics
        .core
        .latency_stats("pool_busy")
        .expect("pool_busy gauge recorded");
    assert!(busy.count >= 1 && busy.max_ms <= 1.0, "{busy:?}");
}

/// Session streams are capped by the KV-cache capacity: a prompt of
/// `seq_len - 2` can produce at most 3 tokens however large the budget.
#[test]
fn session_ends_when_kv_cache_fills() {
    let (rt, engine) = engine_with(EngineConfig::default());
    let s = rt.meta.model.seq_len;
    let prompt = vec![7u8; s - 2];
    let toks = engine
        .session(&prompt)
        .unwrap()
        .collect_tokens()
        .unwrap();
    assert_eq!(toks.len(), 3); // prefill token + 2 decode columns
}

/// Continuous batching: a session that arrives while another is
/// mid-decode is admitted into a free slot (no waiting for the batch to
/// drain) and both still stream exactly-once token counts.
#[test]
fn late_session_admitted_mid_decode_exactly_once() {
    let (_rt, engine) = engine_with(EngineConfig::default());
    let mut a = engine.session_with(&[5; 8], 40).unwrap();
    let mut a_tokens = Vec::new();
    // A is demonstrably mid-decode once its first tokens arrive
    for _ in 0..2 {
        a_tokens.push(a.next_token().unwrap().unwrap().next_token);
    }
    let b = engine.session_with(&[9; 4], 5).unwrap();
    let b_tokens = b.collect_tokens().unwrap();
    assert_eq!(b_tokens.len(), 5, "late session must stream its budget");
    for ev in a {
        a_tokens.push(ev.unwrap().next_token);
    }
    assert_eq!(a_tokens.len(), 40, "first session must stream its budget");
    // exactly-once accounting: two sessions, two separate prefills
    assert_eq!(engine.metrics.core.get("sessions"), 2);
    assert_eq!(engine.metrics.core.get("batched_requests"), 2);
    assert_eq!(engine.metrics.core.get("batches"), 2);
    // overlap actually happened: some decode step ran with both slots live
    let occ = engine
        .metrics
        .core
        .latency_stats("slot_occupancy")
        .expect("occupancy recorded");
    assert!(
        occ.max_ms >= 2.0 / 16.0 - 1e-9,
        "no decode step saw both sessions live: {occ:?}"
    );
}

#[test]
fn multi_replica_engine_serves_all_sessions() {
    let (_rt, engine) = engine_with(EngineConfig {
        replicas: 2,
        ..EngineConfig::default()
    });
    let sessions: Vec<_> = (0..6)
        .map(|i| engine.session_with(&[i as u8 + 1; 5], 4).unwrap())
        .collect();
    for sess in sessions {
        assert_eq!(sess.collect_tokens().unwrap().len(), 4);
    }
    assert_eq!(engine.metrics.core.get("sessions"), 6);
    // round-robin over 2 replicas: at least 2 prefill batches ran
    assert!(engine.metrics.core.get("batches") >= 2);
    assert!(engine.metrics.summary().contains("sessions: 6"));
}

/// OPQ serving through the engine: a q4 prefix with non-empty outlier
/// side-tables admits, streams deterministically, and matches a dense
/// engine over the outlier-patched oracle weights token-for-token and
/// logit-for-logit (the serving-ABI gap this closes: OPQ used to be
/// rejected by `quantize_for_serving`).
#[test]
fn opq_q4_engine_serves_sessions_bit_identical_to_patched_dense() {
    use bof4::coordinator::EngineParams;
    use bof4::models::ParamSet;
    use bof4::quant::OpqConfig;

    let rt = Arc::new(Runtime::new().unwrap());
    let params = rt
        .run("init_params", &[HostTensor::scalar_u32(7)])
        .unwrap();
    let gm = rt.meta.graph("lm_nll").unwrap().clone();
    let mut pset = ParamSet::from_tensors(&gm, &params).unwrap();
    for (name, shape, data) in pset.entries.iter_mut() {
        if shape.len() == 2 && name.contains(".w") {
            for i in (5..data.len()).step_by(409) {
                data[i] *= 30.0;
            }
        }
    }
    let qsp = bof4::eval::quantize_for_serving(
        &rt.meta,
        &pset,
        &QuantConfig {
            method: Method::Bof4 { mse: true },
            norm: Norm::SignedAbsmax,
            block: rt.meta.model.block,
            opq: Some(OpqConfig::default()),
            double_quant: true,
        },
    )
    .unwrap();
    assert!(qsp.outliers > 0, "spiked weights must yield outliers");

    let opq_engine = Engine::start(
        rt.clone(),
        EngineParams::QuantizedQ4(qsp.prefix.clone()),
        EngineConfig::default(),
    )
    .unwrap();
    let dense_engine = Engine::start(
        rt.clone(),
        EngineParams::Dense(qsp.dense.clone()),
        EngineConfig::default(),
    )
    .unwrap();
    for prompt in [&[1u8, 2, 3][..], &[40; 12][..], &[7][..]] {
        let a: Vec<_> = opq_engine
            .session_with(prompt, 6)
            .unwrap()
            .map(|ev| {
                let ev = ev.unwrap();
                (ev.next_token, ev.logit)
            })
            .collect();
        let b: Vec<_> = dense_engine
            .session_with(prompt, 6)
            .unwrap()
            .map(|ev| {
                let ev = ev.unwrap();
                (ev.next_token, ev.logit)
            })
            .collect();
        assert_eq!(a, b, "OPQ q4 vs patched dense diverged for {prompt:?}");
        assert_eq!(a.len(), 6);
        // determinism: a second identical session streams the same bits
        let again: Vec<_> = opq_engine
            .session_with(prompt, 6)
            .unwrap()
            .map(|ev| {
                let ev = ev.unwrap();
                (ev.next_token, ev.logit)
            })
            .collect();
        assert_eq!(a, again);
    }
    assert_eq!(opq_engine.metrics.core.get("sessions"), 6);
}

/// Shared-weight serving: every replica reads the one Arc-shared weight
/// set, so parameter bytes are resident once no matter the replica
/// count — only the private KV slabs scale. Pins the strong-count
/// invariant (`replicas + 1` handles while running) and the
/// [`Engine::memory_profile`] accounting.
#[test]
fn replicas_share_one_weight_set() {
    let rt = Arc::new(Runtime::new().unwrap());
    let params = rt
        .run("init_params", &[HostTensor::scalar_u32(3)])
        .unwrap();
    let e1 = Engine::start(rt.clone(), params.clone(), EngineConfig::default()).unwrap();
    let e3 = Engine::start(
        rt.clone(),
        params,
        EngineConfig {
            replicas: 3,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    // sharing invariant: one handle per running replica + the engine's
    assert_eq!(Arc::strong_count(e1.shared_weights()), 2);
    assert_eq!(Arc::strong_count(e3.shared_weights()), 4);
    let p1 = e1.memory_profile().clone();
    let p3 = e3.memory_profile().clone();
    assert!(p1.shared_param_bytes > 0, "{p1:?}");
    assert_eq!(
        p1.shared_param_bytes, p3.shared_param_bytes,
        "parameter bytes scaled with replica count"
    );
    assert_eq!(p1.per_replica_bytes.len(), 1);
    assert_eq!(p3.per_replica_bytes.len(), 3);
    // totals are internally consistent and grow sub-linearly: tripling
    // replicas only triples the private slabs, never the weights
    assert_eq!(
        p1.total_resident_bytes,
        p1.shared_param_bytes + p1.per_replica_bytes.iter().sum::<usize>()
    );
    assert_eq!(
        p3.total_resident_bytes,
        p3.shared_param_bytes + p3.per_replica_bytes.iter().sum::<usize>()
    );
    assert!(
        p3.total_resident_bytes < 3 * p1.total_resident_bytes,
        "resident bytes scaled linearly: {} @1r vs {} @3r",
        p1.total_resident_bytes,
        p3.total_resident_bytes
    );
    // both engines still serve, and identically
    let a = e1.generate(&[1, 2, 3], 4).unwrap();
    let b = e3.generate(&[1, 2, 3], 4).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.len(), 4);
}

/// Artifact round-trip through the engine: save → load → serve must be
/// bit-identical (tokens and logits) to the in-memory engine, for a
/// dense artifact, a q4+OPQ artifact with a non-empty outlier
/// side-table, and the RLE compressed-at-rest variant.
#[test]
fn artifact_reload_serves_bit_identical_streams() {
    use bof4::coordinator::EngineParams;
    use bof4::eval::{load_artifact, save_artifact, SaveOptions};
    use bof4::models::ParamSet;
    use bof4::quant::OpqConfig;

    let rt = Arc::new(Runtime::new().unwrap());
    let params = rt
        .run("init_params", &[HostTensor::scalar_u32(7)])
        .unwrap();
    let gm = rt.meta.graph("lm_nll").unwrap().clone();
    let mut pset = ParamSet::from_tensors(&gm, &params).unwrap();
    for (name, shape, data) in pset.entries.iter_mut() {
        if shape.len() == 2 && name.contains(".w") {
            for i in (5..data.len()).step_by(409) {
                data[i] *= 30.0;
            }
        }
    }
    let qsp = bof4::eval::quantize_for_serving(
        &rt.meta,
        &pset,
        &QuantConfig {
            method: Method::Bof4 { mse: true },
            norm: Norm::SignedAbsmax,
            block: rt.meta.model.block,
            opq: Some(OpqConfig::default()),
            double_quant: true,
        },
    )
    .unwrap();
    assert!(qsp.outliers > 0, "spiked weights must yield outliers");

    let cases = [
        ("dense", EngineParams::Dense(qsp.dense.clone()), false),
        ("q4opq", EngineParams::QuantizedQ4(qsp.prefix.clone()), false),
        ("q4opq_rle", EngineParams::QuantizedQ4(qsp.prefix.clone()), true),
    ];
    for (tag, p, compress) in cases {
        let path = std::env::temp_dir().join(format!("bof4_test_artifact_serve_{tag}.bof4"));
        let info = save_artifact(
            &path,
            &rt.meta.model,
            &p,
            &SaveOptions {
                label: tag.into(),
                compress,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(info.compressed, compress, "{tag}");
        let (loaded, linfo) = load_artifact(&path, &rt.meta.model).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(linfo.kind, info.kind, "{tag}");
        assert_eq!(linfo.n_tensors, info.n_tensors, "{tag}");
        let mem_engine = Engine::start(rt.clone(), p, EngineConfig::default()).unwrap();
        let art_engine = Engine::start(rt.clone(), loaded, EngineConfig::default()).unwrap();
        for prompt in [&[1u8, 2, 3][..], &[40; 12][..]] {
            let a: Vec<_> = mem_engine
                .session_with(prompt, 6)
                .unwrap()
                .map(|ev| {
                    let ev = ev.unwrap();
                    (ev.next_token, ev.logit)
                })
                .collect();
            let b: Vec<_> = art_engine
                .session_with(prompt, 6)
                .unwrap()
                .map(|ev| {
                    let ev = ev.unwrap();
                    (ev.next_token, ev.logit)
                })
                .collect();
            assert_eq!(a, b, "{tag}: artifact stream diverged for {prompt:?}");
            assert_eq!(a.len(), 6);
        }
    }
}

/// The full-context fallback mode (what `Engine::start` auto-selects on
/// backends without the KV serving graphs, e.g. the XLA artifact ABI)
/// must stream exactly the same tokens and logits as KV-cached serving.
#[test]
fn full_context_fallback_matches_kv_engine() {
    let rt = Arc::new(Runtime::new().unwrap());
    let params = rt
        .run("init_params", &[HostTensor::scalar_u32(3)])
        .unwrap();
    // Pin f32 KV: this test asserts bit-identity against the full-context
    // mode, which only holds for an unquantized cache (the CI matrix
    // re-runs the suite under `BOF4_KV=q8`).
    let cfg = EngineConfig {
        kv_format: KvFormat::F32,
        ..EngineConfig::default()
    };
    let kv = Engine::start(rt.clone(), params.clone(), cfg.clone()).unwrap();
    let full = Engine::start_full_context(rt.clone(), params, cfg).unwrap();
    for prompt in [&[1u8, 2, 3][..], &[7; 10][..]] {
        let a: Vec<_> = kv
            .session_with(prompt, 5)
            .unwrap()
            .map(|ev| {
                let ev = ev.unwrap();
                (ev.next_token, ev.logit)
            })
            .collect();
        let b: Vec<_> = full
            .session_with(prompt, 5)
            .unwrap()
            .map(|ev| {
                let ev = ev.unwrap();
                (ev.next_token, ev.logit)
            })
            .collect();
        assert_eq!(a, b, "modes diverged for prompt {prompt:?}");
        assert_eq!(a.len(), 5);
    }
}

/// The engine's generate must agree with the deprecated shim's generate
/// (same implementation, one KV-cached session under the hood).
#[test]
fn engine_generate_matches_shim_generate() {
    let (rt, engine) = engine_with(EngineConfig::default());
    let params = rt
        .run("init_params", &[HostTensor::scalar_u32(3)])
        .unwrap();
    let svc = BatchedLm::start(rt.clone(), params, ServiceConfig::default()).unwrap();
    let a = engine.generate(&[1, 2, 3, 4], 6).unwrap();
    let b = svc.generate(&[1, 2, 3, 4], 6).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.len(), 6);
}

/// A lone request must be answered after ~one batching window plus one
/// forward pass — the batcher may not wait for a full batch that never
/// arrives. We measure the wall clock of a warm single request and check
/// it against the window plus a generous compute budget (the CPU forward
/// itself is the dominant term on debug builds).
#[test]
fn lone_request_answered_within_batching_window() {
    let window = Duration::from_millis(5);
    let (_rt, svc) = service_with(ServiceConfig { window });
    // warm-up: first request pays one-time costs
    svc.infer(&[1, 2, 3]).unwrap();
    let compute_budget = Duration::from_secs(30);
    let t0 = Instant::now();
    let resp = svc.infer(&[4, 5, 6]).unwrap();
    let elapsed = t0.elapsed();
    assert!((resp.next_token as usize) < 64);
    assert!(
        elapsed < window + compute_budget,
        "lone request took {elapsed:?} (window {window:?})"
    );
    // it ran as a batch of one, not by waiting for batch-mates
    assert_eq!(svc.metrics.get("batches"), 2);
    assert_eq!(svc.metrics.get("batched_requests"), 2);
}
